"""End-to-end system behaviour: train a tiny LM to signal, compress with the
full SLiM pipeline, verify the paper's qualitative claims hold on a model
that actually learned something, then recover with PEFT (paper Fig. 1 flow)."""
import jax
import pytest

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch, synthetic_batches
from repro.models import transformer as T
from repro.models.compress import compress_model, peft_mask
from repro.optim import adafactor, adamw, apply_updates, cosine_schedule
from repro.serving import ServeEngine


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("slim-tiny")
    import dataclasses
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    dcfg = SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=64, global_batch=16, seed=0
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    init, update = adamw(cosine_schedule(5e-3, 60, 5))
    state = init(params)

    @jax.jit
    def step(p, s, b):
        l, g = jax.value_and_grad(lambda pp: T.train_loss(pp, cfg, b))(p)
        u, s = update(g, s, p)
        return apply_updates(p, u), s, l

    it = synthetic_batches(dcfg)
    l0 = None
    for _ in range(60):
        params, state, loss = step(params, state, next(it))
        if l0 is None:
            l0 = float(loss)
    lT = float(loss)
    assert lT < l0 - 0.5, f"tiny model failed to learn ({l0} -> {lT})"
    eval_batch = next(synthetic_batches(dcfg, start_step=10 ** 6))
    return cfg, dcfg, params, eval_batch


def test_compression_method_ordering(trained):
    """The paper's Tbl-1 ordering on a *trained* model:
    no-adapter < naive-LoRA <= SLiM-LoRA (in eval quality)."""
    cfg, dcfg, params, eval_batch = trained
    calib = calibration_batch(dcfg, n_samples=8)
    losses = {}
    for adapter in ["none", "naive", "slim"]:
        cp, _ = compress_model(
            params, cfg, calib, CompressionConfig(adapter=adapter, rank=16)
        )
        losses[adapter] = float(T.train_loss(cp, cfg, eval_batch))
    dense = float(T.train_loss(params, cfg, eval_batch))
    assert losses["slim"] < losses["none"], losses
    assert losses["naive"] < losses["none"], losses
    assert losses["slim"] <= losses["naive"] * 1.02, losses
    assert losses["slim"] - dense < 1.5, (dense, losses)


def test_peft_recovers(trained):
    cfg, dcfg, params, eval_batch = trained
    calib = calibration_batch(dcfg, n_samples=8)
    cp, _ = compress_model(
        params, cfg, calib, CompressionConfig(adapter="slim", rank=16)
    )
    l_before = float(T.train_loss(cp, cfg, eval_batch))
    mask = peft_mask(cp)
    init, update = adafactor(3e-3, mask=jax.tree.map(lambda m: bool(m), mask))
    state = init(cp)

    @jax.jit
    def step(p, s, b):
        l, g = jax.value_and_grad(
            lambda pp: T.train_loss(pp, cfg, b), allow_int=True
        )(p)
        u, s = update(g, s, p)
        return apply_updates(p, u), s, l

    it = synthetic_batches(dcfg, start_step=100)
    for _ in range(30):
        cp, state, _ = step(cp, state, next(it))
    l_after = float(T.train_loss(cp, cfg, eval_batch))
    assert l_after < l_before + 0.05, (l_before, l_after)


def test_serving_compressed(trained):
    cfg, dcfg, params, eval_batch = trained
    calib = calibration_batch(dcfg, n_samples=4)
    cp, _ = compress_model(
        params, cfg, calib,
        CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
    )
    engine = ServeEngine(cp, cfg, max_len=96)
    batch = {"tokens": eval_batch["tokens"][:4, :32]}
    res = engine.generate(batch, max_new_tokens=8)
    assert res.steps == 8
    assert all(len(t) == 8 for t in res.tokens)
    assert all(0 <= tok < cfg.vocab_size for t in res.tokens for tok in t)
