"""Observability subsystem tests: span tracer, metrics registry, and
engine integration.

Covers the ISSUE acceptance surface: the ring-buffered ``SpanTracer``
(event recording, drop accounting, Chrome trace-event export and the CI
schema gate ``validate_trace``), the typed instruments behind
``ServingMetrics`` (Counter monotonicity, Gauge time series, Histogram
exact vs streaming quantiles — the streaming estimate is property-tested
against exact order statistics), the ``end_time`` regression (every
timestamped event advances the run's duration, not just ``on_finish``),
empty-run / zero-completion edge cases, and an end-to-end engine run with
``trace=True`` whose exported spans reconstruct every request's lifecycle
and agree exactly with the TTFT/latency summary.
"""

import dataclasses
import json
import math

import jax
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (
    ContinuousEngine,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Request,
    ServingMetrics,
    SpanTracer,
    synthetic_trace,
    validate_trace,
)
from repro.serving.metrics import _quantile
from repro.serving.tracing import ENGINE_TID, QUEUE_TID, slot_tid

MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n, plen, max_new, seed=7):
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed), (n, plen), 0, cfg.vocab_size
    )
    return [
        Request(
            rid=i,
            prompt=[int(t) for t in prompts[i]],
            arrival=0.0,
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _spans(events, name):
    return [e for e in events if e.get("ph") == "X" and e["name"] == name]


# ---------------------------------------------------------------------------
# SpanTracer (host-only)
# ---------------------------------------------------------------------------


class TestSpanTracer:
    def test_complete_span_units_and_lanes(self):
        tr = SpanTracer()
        tr.complete("prefill", slot_tid(2), 1.0, 1.5, {"rid": 7})
        (ev,) = _spans(tr.events(), "prefill")
        assert ev["ts"] == pytest.approx(1.0e6)  # seconds -> microseconds
        assert ev["dur"] == pytest.approx(0.5e6)
        assert ev["tid"] == 3 and ev["pid"] == 0
        assert ev["args"] == {"rid": 7}

    def test_negative_duration_clamps_to_zero(self):
        tr = SpanTracer()
        tr.complete("queued", QUEUE_TID, 2.0, 1.0)
        (ev,) = _spans(tr.events(), "queued")
        assert ev["dur"] == 0.0

    def test_instant_and_counter_events(self):
        tr = SpanTracer()
        tr.instant("preempt", slot_tid(0), 3.0, {"rid": 1})
        tr.counter("queue_depth", 3.0, depth=4)
        evs = tr.events()
        (inst,) = [e for e in evs if e["ph"] == "i"]
        assert inst["name"] == "preempt" and inst["s"] == "t"
        (ctr,) = [e for e in evs if e["ph"] == "C"]
        assert ctr["args"] == {"depth": 4}
        assert ctr["tid"] == ENGINE_TID

    def test_ring_buffer_drops_oldest(self):
        tr = SpanTracer(capacity=3)
        for i in range(5):
            tr.instant(f"e{i}", ENGINE_TID, float(i))
        assert len(tr) == 3 and tr.dropped == 2
        kept = [e["name"] for e in tr.events() if e["ph"] == "i"]
        assert kept == ["e2", "e3", "e4"]  # oldest evicted first
        assert tr.to_dict()["otherData"]["dropped_events"] == 2

    def test_metadata_names_slots(self):
        tr = SpanTracer(process_name="engine-0")
        tr.name_slots(2)
        meta = {
            (e["name"], e["tid"]): e["args"]["name"]
            for e in tr.events()
            if e["ph"] == "M"
        }
        assert meta[("process_name", ENGINE_TID)] == "engine-0"
        assert meta[("thread_name", slot_tid(0))] == "slot 0"
        assert meta[("thread_name", slot_tid(1))] == "slot 1"
        assert meta[("thread_name", QUEUE_TID)] == "queue"

    def test_export_roundtrip_is_json(self, tmp_path):
        tr = SpanTracer()
        tr.complete("queued", QUEUE_TID, 0.0, 1.0, {"rid": 0})
        tr.complete("prefill", slot_tid(0), 1.0, 2.0, {"rid": 0})
        tr.complete("decode_burst", ENGINE_TID, 2.0, 3.0)
        tr.complete("request", slot_tid(0), 1.0, 3.0, {"rid": 0})
        path = tmp_path / "trace.json"
        tr.export(str(path))
        loaded = json.loads(path.read_text())
        assert validate_trace(loaded) == []
        assert loaded["displayTimeUnit"] == "ms"

    def test_validate_trace_catches_gaps(self):
        assert validate_trace({}) == ["traceEvents missing or empty"]
        # a complete event without dur, and no lifecycle spans at all
        bad = {"traceEvents": [{"ph": "X", "ts": 0, "pid": 0}]}
        problems = validate_trace(bad)
        assert any("missing 'dur'" in p for p in problems)
        assert any("'queued'" in p for p in problems)
        assert any("decode_burst" in p for p in problems)

    def test_capacity_must_be_positive(self):
        with pytest.raises(ValueError):
            SpanTracer(capacity=0)


# ---------------------------------------------------------------------------
# Instruments / registry (host-only)
# ---------------------------------------------------------------------------


class TestInstruments:
    def test_counter_monotone(self):
        c = Counter("x")
        c.inc()
        c.inc(2.5)
        assert c.value == 3.5
        c.set(10.0)  # mirrored cumulative counts may jump forward
        with pytest.raises(ValueError):
            c.set(9.0)  # ...but never backwards

    def test_gauge_time_series(self):
        g = Gauge("depth")
        assert g.mean() == 0.0  # empty gauge: defined, not NaN
        for t, v in [(0.0, 1.0), (1.0, 4.0), (2.0, 1.0)]:
            g.set(v, t)
        assert g.last == 1.0 and g.peak == 4.0
        assert g.mean() == pytest.approx(2.0)
        assert g.values() == [1.0, 4.0, 1.0]
        assert g.samples[1] == (1.0, 4.0)

    def test_histogram_exact_quantiles_match_order_statistics(self):
        h = Histogram("lat")
        xs = [0.3, 0.1, 0.9, 0.2, 0.5]
        for x in xs:
            h.observe(x)
        for q in (0.5, 0.95, 0.99):
            assert h.quantile(q) == _quantile(xs, q)
        assert h.mean() == pytest.approx(sum(xs) / len(xs))

    def test_histogram_streaming_bounded_by_bucket(self):
        """The streaming estimate lands inside the bucket that holds the
        target rank — error bounded by that bucket's width."""
        bounds = (1.0, 2.0, 4.0, 8.0)
        h = Histogram("lat", boundaries=bounds, track_exact=False)
        xs = [0.5, 1.5, 1.7, 3.0, 3.5, 5.0, 9.0]
        for x in xs:
            h.observe(x)
        for q in (0.1, 0.5, 0.9):
            exact = _quantile(xs, q)
            est = h.quantile(q)
            # the bucket containing the exact order statistic
            edges = (0.5,) + bounds + (9.0,)
            width = max(
                hi - lo
                for lo, hi in zip(edges, edges[1:], strict=False)
                if lo <= exact <= hi
            )
            assert abs(est - exact) <= width
        assert h._samples is None  # bounded memory: no raw samples

    def test_histogram_ignores_nan_and_rejects_bad_bounds(self):
        h = Histogram("x")
        h.observe(float("nan"))
        assert h.n == 0 and math.isnan(h.quantile(0.5))
        with pytest.raises(ValueError):
            Histogram("y", boundaries=())
        with pytest.raises(ValueError):
            Histogram("z", boundaries=(2.0, 1.0))

    @settings(max_examples=25, deadline=None)
    @given(
        st.integers(min_value=1, max_value=60),
        st.integers(min_value=0, max_value=10_000),
        st.sampled_from([0.5, 0.9, 0.95, 0.99]),
    )
    def test_streaming_quantile_property(self, n, seed, q):
        """Streaming estimates stay within one bucket width of the exact
        order statistic and inside the observed [min, max] for arbitrary
        sample sets."""
        import random

        rng = random.Random(seed)
        bounds = (0.01, 0.1, 1.0, 10.0)
        h = Histogram("p", boundaries=bounds, track_exact=False)
        xs = [rng.uniform(0.001, 20.0) for _ in range(n)]
        for x in xs:
            h.observe(x)
        exact = _quantile(xs, q)
        est = h.quantile_est(q)
        assert min(xs) <= est <= max(xs)
        edges = (min(xs),) + bounds + (max(xs),)
        tol = max(
            hi - lo
            for lo, hi in zip(edges, edges[1:], strict=False)
            if lo <= exact <= hi
        )
        assert abs(est - exact) <= tol + 1e-12

    def test_registry_get_or_create_and_kind_pinning(self):
        r = MetricsRegistry()
        c = r.counter("steps")
        assert r.counter("steps") is c
        with pytest.raises(TypeError):
            r.gauge("steps")
        r.gauge("depth")
        r.histogram("ttft")
        assert r.names() == ["depth", "steps", "ttft"]
        snap = r.snapshot()
        assert set(snap) == {
            "counter/steps",
            "gauge/depth",
            "histogram/ttft",
        }
        assert snap["counter/steps"] == {"value": 0.0}


# ---------------------------------------------------------------------------
# ServingMetrics edge cases (host-only)
# ---------------------------------------------------------------------------


class TestMetricsEdgeCases:
    def test_empty_run_summary_is_sane(self):
        s = ServingMetrics(n_slots=2).summary()
        assert s["n_requests"] == 0 and s["completed"] == 0
        assert s["total_tokens"] == 0 and s["tokens_per_s"] == 0
        assert s["duration_s"] > 0  # epsilon floor, no div-by-zero
        for k in (
            "mean_ttft_s",
            "p95_ttft_s",
            "mean_latency_s",
            "tpot_p50_s",
            "tpot_p95_s",
        ):
            assert math.isnan(s[k]), k
        assert s["mean_occupancy"] == 0.0
        assert s["mean_queue_depth"] == 0.0
        for p in ("schedule", "prefill", "decode", "verify"):
            assert s[f"phase_{p}_s"] == 0.0

    def test_zero_completions_keeps_duration(self):
        """Regression: end_time used to advance only in on_finish, so a
        run where nothing finished reported duration ~0 and a garbage
        tokens/s. Every timestamped event advances it now."""
        m = ServingMetrics(n_slots=1)
        m.on_submit(0, 0.0)
        m.on_admit(0, 1.0)
        m.on_first_token(0, 2.5)  # still decoding, never finishes
        s = m.summary()
        assert s["completed"] == 0
        assert s["duration_s"] == pytest.approx(2.5)
        assert math.isnan(s["mean_latency_s"])  # NaN stays NaN
        assert math.isnan(s["p99_latency_s"])

    def test_every_event_kind_advances_end_time(self):
        m = ServingMetrics(n_slots=1)
        m.on_submit(0, 1.0)
        assert m.end_time == 1.0
        m.on_preempt(0, 2.0)
        assert m.end_time == 2.0
        m.on_blocks_in_use(3, 4.0)
        assert m.end_time == 4.0
        m.on_queue_depth(2, 5.5)
        assert m.end_time == 5.5
        m.on_finish(0, 5.0, 1)  # late event cannot move time backwards
        assert m.end_time == 5.5

    def test_tpot_definition(self):
        m = ServingMetrics(n_slots=1)
        m.on_submit(0, 0.0)
        m.on_first_token(0, 1.0)
        m.on_finish(0, 4.0, 4)  # 3 inter-token gaps over 3s
        m.on_submit(1, 0.0)
        m.on_first_token(1, 1.0)
        m.on_finish(1, 9.0, 1)  # single token: no interval, excluded
        s = m.summary()
        assert m.requests[0].tpot == pytest.approx(1.0)
        assert m.requests[1].tpot is None
        assert s["mean_tpot_s"] == pytest.approx(1.0)
        assert s["tpot_p50_s"] == pytest.approx(1.0)

    def test_phase_attribution_accumulates(self):
        m = ServingMetrics(n_slots=1)
        m.on_phase("prefill", 0.5)
        m.on_phase("prefill", 0.25)
        m.on_phase("decode", 1.0)
        s = m.summary()
        assert s["phase_prefill_s"] == pytest.approx(0.75)
        assert s["phase_decode_s"] == pytest.approx(1.0)
        assert s["phase_verify_s"] == 0.0
        with pytest.raises(KeyError):
            m.on_phase("warp", 1.0)  # not a known phase

    def test_queue_depth_summary(self):
        m = ServingMetrics(n_slots=1)
        for t, d in [(0.0, 0), (1.0, 3), (2.0, 1)]:
            m.on_queue_depth(d, t)
        s = m.summary()
        assert s["mean_queue_depth"] == pytest.approx(4 / 3)
        assert s["peak_queue_depth"] == 3.0


# ---------------------------------------------------------------------------
# Engine integration
# ---------------------------------------------------------------------------


class TestEngineTracing:
    def test_disabled_by_default(self, model):
        cfg, params = model
        eng = ContinuousEngine(params, cfg, n_slots=1, max_len=MAX_LEN)
        assert eng.tracer is None
        # trace=False (e.g. a benchmark toggling the tracer) is off too
        off = ContinuousEngine(params, cfg, n_slots=1, max_len=MAX_LEN, trace=False)
        assert off.tracer is None
        # a caller-supplied tracer is kept even while empty (len 0 makes
        # it falsy, so truthiness must not decide this)
        mine = SpanTracer()
        on = ContinuousEngine(params, cfg, n_slots=1, max_len=MAX_LEN, trace=mine)
        assert on.tracer is mine

    def test_lifecycle_spans_reconstruct_summary(self, model):
        """Every request's lifecycle reconstructs from the trace: queued +
        request spans tile arrival->finish, queued + prefill spans tile
        arrival->first-token, and both agree exactly with the metrics
        summary — the spans and the summary read the same clock."""
        cfg, params = model
        trace = synthetic_trace(
            5,
            rate=100.0,
            vocab_size=cfg.vocab_size,
            prompt_len=(5, 10),
            max_new_tokens=(3, 6),
            seed=3,
        )
        eng = ContinuousEngine(params, cfg, n_slots=2, max_len=MAX_LEN, trace=True)
        res = eng.run(trace, sync_every=2)
        d = eng.tracer.to_dict()
        assert validate_trace(d) == []
        evs = d["traceEvents"]
        queued = {e["args"]["rid"]: e for e in _spans(evs, "queued")}
        prefill = {e["args"]["rid"]: e for e in _spans(evs, "prefill")}
        request = {e["args"]["rid"]: e for e in _spans(evs, "request")}
        assert set(queued) == set(prefill) == set(request) == set(range(5))
        lats = [
            (queued[r]["ts"] + queued[r]["dur"] + request[r]["dur"]) / 1e6
            for r in request
        ]
        ttfts = [
            (queued[r]["ts"] + queued[r]["dur"] + prefill[r]["dur"]) / 1e6
            for r in prefill
        ]
        m = res.metrics
        # spans start at arrival=ts(queued); latency = finish - arrival
        arr = {r: queued[r]["ts"] / 1e6 for r in queued}
        mean = lambda xs: sum(xs) / len(xs)  # noqa: E731
        got_lat = mean([lat - arr[r] for lat, r in zip(lats, request, strict=True)])
        assert got_lat == pytest.approx(m["mean_latency_s"], abs=1e-9)
        got_ttft = mean([t - arr[r] for t, r in zip(ttfts, prefill, strict=True)])
        assert got_ttft == pytest.approx(m["mean_ttft_s"], abs=1e-9)
        # the engine lane saw at least one decode burst, and counter
        # tracks sampled the backlog
        assert _spans(evs, "decode_burst")
        assert any(e["ph"] == "C" and e["name"] == "queue_depth" for e in evs)
        # every slot span sits on a slot lane, never the engine lane
        for e in _spans(evs, "request"):
            assert e["tid"] >= slot_tid(0)

    def test_preemption_emits_instants_and_split_spans(self, model):
        """A forced eviction shows up as a preempt instant plus a request
        span marked preempted=True; the re-admission opens a fresh request
        span, so the victim's lifecycle is fully reconstructable."""
        cfg, params = model
        eng = ContinuousEngine(
            params,
            cfg,
            n_slots=2,
            max_len=MAX_LEN,
            block_size=4,
            n_blocks=10,
            preemption=True,
            decode_reserve=0,
            trace=True,
        )
        res = eng.run(_requests(cfg, 5, plen=10, max_new=10), sync_every=2)
        assert res.metrics["preemptions"] >= 1
        evs = eng.tracer.to_dict()["traceEvents"]
        instants = [e for e in evs if e["ph"] == "i" and e["name"] == "preempt"]
        assert len(instants) == int(res.metrics["preemptions"])
        cut = [e for e in _spans(evs, "request") if e["args"].get("preempted")]
        assert len(cut) == len(instants)
        # a preempted rid later finishes with a second request span
        rid = cut[0]["args"]["rid"]
        finished = [
            e
            for e in _spans(evs, "request")
            if e["args"]["rid"] == rid and not e["args"].get("preempted")
        ]
        assert finished, "victim never got a closing request span"
        # the queued lane shows the re-admission wait (resume=True)
        resumes = [e for e in _spans(evs, "queued") if e["args"].get("resume")]
        assert resumes and resumes[0]["tid"] == QUEUE_TID

    def test_tracer_off_produces_identical_outputs(self, model):
        """Tracing is observability only: the tokens the engine emits are
        bit-identical with the tracer on and off."""
        cfg, params = model
        reqs = _requests(cfg, 3, plen=8, max_new=5)
        traced = ContinuousEngine(params, cfg, n_slots=2, max_len=MAX_LEN, trace=True)
        plain = ContinuousEngine(params, cfg, n_slots=2, max_len=MAX_LEN)
        on = traced.run(reqs, sync_every=2)
        off = plain.run(reqs, sync_every=2)
        assert on.outputs == off.outputs

    def test_phase_breakdown_present_after_run(self, model):
        cfg, params = model
        eng = ContinuousEngine(params, cfg, n_slots=2, max_len=MAX_LEN)
        res = eng.run(_requests(cfg, 2, plen=6, max_new=3), sync_every=2)
        m = res.metrics
        # host attribution uses the real host clock (perf_counter), so
        # the phases that ran are strictly positive
        assert m["phase_prefill_s"] > 0
        assert m["phase_decode_s"] > 0
        assert m["phase_verify_s"] == 0.0  # not a speculative run
        assert m["tpot_p50_s"] > 0 or math.isnan(m["tpot_p50_s"])

    def test_speculative_burst_spans_and_verify_phase(self, model):
        cfg, params = model
        eng = ContinuousEngine(
            params,
            cfg,
            n_slots=2,
            max_len=MAX_LEN,
            block_size=4,
            n_blocks=24,
            speculative=3,
            trace=True,
        )
        res = eng.run(_requests(cfg, 3, plen=8, max_new=6), sync_every=2)
        m = res.metrics
        assert m["completed"] == 3
        assert m["phase_verify_s"] > 0  # the fused round lands here
        assert m["phase_decode_s"] == 0.0
        evs = eng.tracer.to_dict()["traceEvents"]
        assert _spans(evs, "speculative_burst")
        assert validate_trace(eng.tracer.to_dict()) == []
