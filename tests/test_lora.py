"""SLiM-LoRA (Alg. 2) tests: optimality in the saliency norm, invertibility,
adapter quantization, rank monotonicity."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import naive_lora, quantize_adapters, slim_lora
from repro.core.lora import (
    default_rank,
    lowrank_factor,
    saliency_error,
    shift_activation_mean,
)


def _setup(seed=0, d_in=64, d_out=48):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.1, (d_in, d_out)), jnp.float32)
    w_c = w * jnp.asarray(rng.random((d_in, d_out)) > 0.5, jnp.float32)
    x = jnp.abs(jnp.asarray(rng.normal(0, 1.0, (d_in,)), jnp.float32))
    return w, w_c, x


class TestSlimLora:
    def test_saliency_optimality(self):
        """SLiM-LoRA must beat Naive-LoRA in the diag(x)-weighted norm, and
        Naive-LoRA must beat SLiM-LoRA in the plain Frobenius norm — the
        Eckart-Young optimality of each in its own metric (paper Eq. 8-11)."""
        w, w_c, x = _setup()
        r = 8
        ln, rn = naive_lora(w, w_c, r)
        ls, rs = slim_lora(w, w_c, x, r)
        sal_naive = float(saliency_error(w, w_c, ln, rn, x))
        sal_slim = float(saliency_error(w, w_c, ls, rs, x))
        assert sal_slim <= sal_naive * 1.0001
        fro_naive = float(jnp.sum((w - (w_c + ln @ rn)) ** 2))
        fro_slim = float(jnp.sum((w - (w_c + ls @ rs)) ** 2))
        assert fro_naive <= fro_slim * 1.0001

    def test_full_rank_exact(self):
        """Invertibility: at full rank the adapters reconstruct W exactly."""
        w, w_c, x = _setup(1, 32, 24)
        l, r = slim_lora(w, w_c, x, rank=24)
        np.testing.assert_allclose(
            np.asarray(w_c + l @ r), np.asarray(w), rtol=0, atol=1e-4
        )

    @given(st.integers(1, 5))
    @settings(max_examples=5, deadline=None)
    def test_rank_monotone(self, k):
        w, w_c, x = _setup(2)
        e_lo = float(saliency_error(w, w_c, *slim_lora(w, w_c, x, 4 * k), x))
        e_hi = float(saliency_error(w, w_c, *slim_lora(w, w_c, x, 4 * k + 4), x))
        assert e_hi <= e_lo * 1.0001

    def test_shift_makes_positive(self):
        x = jnp.asarray([0.0, 1e-9, 0.5, 2.0])
        s = shift_activation_mean(x)
        assert float(jnp.min(s)) > 0

    def test_randomized_svd_close_to_exact(self):
        w, w_c, x = _setup(3, 128, 96)
        le, re_ = slim_lora(w, w_c, x, 16, method="exact")
        lr, rr = slim_lora(w, w_c, x, 16, method="randomized")
        e_exact = float(saliency_error(w, w_c, le, re_, x))
        e_rand = float(saliency_error(w, w_c, lr, rr, x))
        assert e_rand <= e_exact * 1.10  # HMT bound is loose; 10% observed

    def test_default_rank(self):
        assert default_rank(4096, 0.1) == 416  # 409.6 -> mult of 8
        assert default_rank(10, 0.1) == 8


class TestAdapterQuant:
    def test_group_quant_roundtrip_error(self):
        w, w_c, x = _setup(4, 256, 128)
        l, r = slim_lora(w, w_c, x, 16)
        lq, rq = quantize_adapters(l, r, bits=4, group_size=128)
        l2, r2 = lq.dequantize(), rq.dequantize()
        rel = float(jnp.linalg.norm(l2 - l) / jnp.linalg.norm(l))
        assert rel < 0.2  # 4-bit group quant keeps adapters close

    def test_lowrank_factor_eckart_young(self):
        a = jnp.asarray(np.random.default_rng(0).normal(0, 1, (40, 30)), jnp.float32)
        l, r = lowrank_factor(a, 10)
        u, s, vt = jnp.linalg.svd(a, full_matrices=False)
        best = float(jnp.sum(s[10:] ** 2))
        got = float(jnp.sum((a - l @ r) ** 2))
        assert abs(got - best) < 1e-3 * max(best, 1.0)
