"""Data-parallel router over engine replicas.

Pins the routing layer's contracts: deterministic upfront placement,
greedy token-exactness regardless of placement (a routed fleet generates
exactly what one engine generates), sticky prefix-affinity, bounded-queue
shedding one layer above the engine, and the fleet aggregation helpers
(metrics merge + multi-pid trace merge).
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (
    ContinuousEngine,
    EngineConfig,
    PagingConfig,
    PrefixCacheConfig,
    Request,
    RequestState,
    Router,
    merge_replica_summaries,
    synthetic_trace,
    validate_trace,
)
from repro.serving.router import plan_least_loaded, plan_prefix_affinity

MAX_LEN = 48
BLOCK = 8


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def engine_config(**kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("prefill_bucket", 8)
    kw.setdefault("paging", PagingConfig(block_size=BLOCK))
    return EngineConfig(**kw)


def trace(cfg, n=6, seed=3, **kw):
    kw.setdefault("prompt_len", (8, 12))
    kw.setdefault("max_new_tokens", (4, 8))
    return synthetic_trace(n, 1e6, cfg.vocab_size, seed=seed, **kw)


# ---------------------------------------------------------------------------
# Placement planning (host-only, no engines)
# ---------------------------------------------------------------------------

class TestPlanning:
    def _reqs(self, costs, arrivals=None):
        return [
            Request(
                rid=i, prompt=[1] * 4, max_new_tokens=c - 4,
                arrival=0.0 if arrivals is None else arrivals[i],
            )
            for i, c in enumerate(costs)
        ]

    def test_least_loaded_balances_cost(self):
        # costs 10, 10, 6, 6: r0 gets 10, r1 gets 10, then r0/r1 get a 6
        a, shed = plan_least_loaded(self._reqs([10, 10, 6, 6]), 2, 0, 0, 0.0)
        assert not shed
        loads = [0, 0]
        for rid, rep in a.items():
            loads[rep] += [10, 10, 6, 6][rid]
        assert loads[0] == loads[1] == 16

    def test_ties_go_to_lowest_index(self):
        a, _ = plan_least_loaded(self._reqs([8]), 4, 0, 0, 0.0)
        assert a == {0: 0}

    def test_plan_is_deterministic_in_arrival_order(self):
        reqs = self._reqs([8, 12, 8, 12, 8], arrivals=[0.4, 0.1, 0.3, 0.0, 0.2])
        a1, _ = plan_least_loaded(reqs, 2, 0, 0, 0.0)
        a2, _ = plan_least_loaded(list(reversed(reqs)), 2, 0, 0, 0.0)
        assert a1 == a2  # planning sorts by (arrival, rid), not input order

    def test_affinity_is_sticky_per_prefix(self):
        prefix_a, prefix_b = [1] * BLOCK, [2] * BLOCK
        reqs = [
            Request(rid=i, prompt=p + [i], max_new_tokens=4, arrival=float(i))
            for i, p in enumerate([prefix_a, prefix_b, prefix_a, prefix_b])
        ]
        a, shed = plan_prefix_affinity(reqs, 2, BLOCK, 0, 0.0)
        assert not shed
        assert a[0] == a[2] and a[1] == a[3]  # same prefix -> same replica
        assert a[0] != a[1]  # second tenant spilled to the idle replica

    def test_affinity_without_full_block_falls_back(self):
        # prompts shorter than one block carry no route key
        reqs = [
            Request(rid=i, prompt=[5] * (BLOCK - 1), max_new_tokens=4)
            for i in range(2)
        ]
        a, _ = plan_prefix_affinity(reqs, 2, BLOCK, 0, 0.0)
        assert set(a.values()) == {0, 1}  # spread like least-loaded

    def test_bounded_queue_sheds_when_all_full(self):
        # est_tpot huge -> every placed request occupies its replica forever;
        # capacity 1 on 2 replicas admits exactly 2 of 5 burst arrivals
        reqs = self._reqs([8] * 5)
        a, shed = plan_least_loaded(reqs, 2, 0, 1, 1e9)
        assert len(a) == 2 and len(shed) == 3

    def test_queue_drains_over_time(self):
        # service estimate 0.1 s/token * 8 tokens = 0.8s; arrivals 1s apart
        # never see a full queue
        reqs = self._reqs([8] * 4, arrivals=[0.0, 1.0, 2.0, 3.0])
        a, shed = plan_least_loaded(reqs, 1, 0, 1, 0.1)
        assert len(a) == 4 and not shed


# ---------------------------------------------------------------------------
# Routed serving (engines)
# ---------------------------------------------------------------------------

class TestRouterRun:
    def test_token_exact_vs_single_engine(self, model):
        cfg, params = model
        config = engine_config()
        single = ContinuousEngine(params, cfg, config)
        want = single.run(trace(cfg), sync_every=4, max_new_cap=8).outputs
        for placement in ("least_loaded", "prefix_affinity"):
            router = Router(params, cfg, config, n_replicas=2, placement=placement)
            got = router.run(trace(cfg), sync_every=4, max_new_cap=8)
            assert got.outputs == want, placement

    def test_run_is_deterministic(self, model):
        cfg, params = model
        router = Router(params, cfg, engine_config(), n_replicas=2)
        a = router.run(trace(cfg), sync_every=4, max_new_cap=8)
        b = router.run(trace(cfg), sync_every=4, max_new_cap=8)
        assert a.outputs == b.outputs
        assert a.assignment == b.assignment

    def test_every_request_lands_on_its_assigned_replica(self, model):
        cfg, params = model
        router = Router(params, cfg, engine_config(), n_replicas=2)
        res = router.run(trace(cfg), sync_every=4, max_new_cap=8)
        assert set(res.assignment) == {r.rid for r in res.requests}
        for i, rep_res in enumerate(res.replica_results):
            assert rep_res is not None
            rids = {r.rid for r in rep_res.requests}
            assert rids == {r for r, rep in res.assignment.items() if rep == i}

    def test_aggregate_metrics(self, model):
        cfg, params = model
        router = Router(params, cfg, engine_config(), n_replicas=2)
        res = router.run(trace(cfg), sync_every=4, max_new_cap=8)
        m = res.metrics
        assert m["router_n_replicas"] == 2.0
        assert m["router_shed"] == 0.0
        assert m["completed"] == 6
        assert m["total_tokens"] == (
            m["replica0_total_tokens"] + m["replica1_total_tokens"]
        )
        assert m["tokens_per_s"] == pytest.approx(
            m["replica0_tokens_per_s"] + m["replica1_tokens_per_s"]
        )

    def test_shed_requests_end_aborted(self, model):
        cfg, params = model
        router = Router(
            params, cfg, engine_config(), n_replicas=2,
            queue_capacity=1, est_tpot=1e9,
        )
        res = router.run(trace(cfg, n=5), sync_every=4, max_new_cap=8)
        shed = [r for r in res.requests if r.state == RequestState.ABORTED]
        assert len(shed) == 3 and res.metrics["router_shed"] == 3.0
        for r in shed:
            assert r.output is None and "capacity" in r.error
            assert r.rid not in res.assignment
        done = [r for r in res.requests if r.state == RequestState.FINISHED]
        assert len(done) == 2

    def test_idle_replica_allowed(self, model):
        cfg, params = model
        router = Router(params, cfg, engine_config(), n_replicas=3)
        res = router.run(trace(cfg, n=2), sync_every=4, max_new_cap=8)
        assert res.replica_results[2] is None
        assert all(r.state == RequestState.FINISHED for r in res.requests)

    def test_affinity_lifts_hit_rate_on_multi_tenant_trace(self, model):
        cfg, params = model
        config = engine_config(
            n_slots=2,
            prefix_cache=PrefixCacheConfig(enabled=True),
            paging=PagingConfig(block_size=BLOCK, n_blocks=48),
        )
        def tenant_trace():
            return trace(
                cfg, n=9, seed=5,
                prompt_len=(3 * BLOCK, 4 * BLOCK),
                max_new_tokens=(2, 6),
                shared_prefix_len=3 * BLOCK,
                shared_prefix_groups=3,
            )
        rates = {}
        for placement in ("prefix_affinity", "least_loaded"):
            router = Router(
                params, cfg, config, n_replicas=2, placement=placement
            )
            res = router.run(tenant_trace(), sync_every=4, max_new_cap=6)
            rates[placement] = res.metrics["prefix_cache_hit_rate"]
        assert rates["prefix_affinity"] > rates["least_loaded"]

    def test_custom_placement_callable(self, model):
        cfg, params = model

        def all_on_one(requests, n_replicas, block_size, cap, est):
            return {r.rid: 0 for r in requests}, []

        router = Router(params, cfg, engine_config(), n_replicas=2,
                        placement=all_on_one)
        res = router.run(trace(cfg), sync_every=4, max_new_cap=8)
        assert set(res.assignment.values()) == {0}
        assert res.replica_results[1] is None

    def test_invalid_args_rejected(self, model):
        cfg, params = model
        with pytest.raises(ValueError, match="n_replicas"):
            Router(params, cfg, engine_config(), n_replicas=0)
        with pytest.raises(ValueError, match="placement"):
            Router(params, cfg, engine_config(), placement="round_robin")
        with pytest.raises(ValueError, match="multiple"):
            Router(params, cfg, EngineConfig(
                max_len=50, paging=PagingConfig(block_size=8)))

    def test_per_replica_trace_lanes_merge(self, model):
        cfg, params = model
        router = Router(params, cfg, engine_config(), n_replicas=2, trace=True)
        router.run(trace(cfg), sync_every=4, max_new_cap=8)
        d = router.trace_dict()
        validate_trace(d)
        pids = {e["pid"] for e in d["traceEvents"]}
        assert pids == {0, 1}
        names = {
            e["args"]["name"]
            for e in d["traceEvents"]
            if e.get("ph") == "M" and e.get("name") == "process_name"
        }
        assert names == {"replica0", "replica1"}

    def test_trace_dict_requires_trace(self, model):
        cfg, params = model
        router = Router(params, cfg, engine_config(), n_replicas=2)
        with pytest.raises(ValueError, match="trace=False"):
            router.trace_dict()


# ---------------------------------------------------------------------------
# Fleet metrics aggregation (pure)
# ---------------------------------------------------------------------------

class TestMergeSummaries:
    def test_sums_counts_and_rates(self):
        m = merge_replica_summaries([
            {"total_tokens": 10, "tokens_per_s": 100.0, "completed": 2},
            {"total_tokens": 6, "tokens_per_s": 50.0, "completed": 1},
        ])
        assert m["total_tokens"] == 16
        assert m["tokens_per_s"] == 150.0
        assert m["completed"] == 3

    def test_weighted_means_and_maxima(self):
        m = merge_replica_summaries([
            {"completed": 1, "mean_ttft_s": 0.1, "duration_s": 2.0,
             "peak_queue_depth": 3},
            {"completed": 3, "mean_ttft_s": 0.5, "duration_s": 5.0,
             "peak_queue_depth": 1},
        ])
        assert m["mean_ttft_s"] == pytest.approx(0.4)  # (0.1 + 3*0.5) / 4
        assert m["duration_s"] == 5.0  # replicas run side by side
        assert m["peak_queue_depth"] == 3

    def test_hit_rate_recomputed_from_counters(self):
        # not a mean of the per-replica rates (that would be 0.375 only by
        # luck of equal weights) — recomputed token-weighted from the sums
        m = merge_replica_summaries([
            {"cached_prompt_tokens": 30.0, "total_prompt_tokens": 40.0,
             "prefix_cache_hit_rate": 0.75},
            {"cached_prompt_tokens": 0.0, "total_prompt_tokens": 120.0,
             "prefix_cache_hit_rate": 0.0},
        ])
        assert m["prefix_cache_hit_rate"] == pytest.approx(30 / 160)
