"""Property-test shim: use hypothesis when installed, otherwise a minimal
deterministic fallback so the tier-1 suite collects and runs on a clean
environment (the real dependency is recorded in requirements-dev.txt).

The fallback implements just the surface these tests use — ``@given`` with
positional strategies, ``@settings(max_examples=..., deadline=...)``, and
the ``integers`` / ``floats`` / ``sampled_from`` strategies — and runs each
test body on a handful of examples drawn from a per-test seeded RNG. No
shrinking, no search: thinner coverage than hypothesis, same invariants.
"""
__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on clean environments
    HAVE_HYPOTHESIS = False

    import functools
    import random
    import zlib

    _FALLBACK_EXAMPLES = 5  # cap: fallback trades coverage for speed

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 - mirrors `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: elements[r.randrange(len(elements))])

    def settings(max_examples=None, **_kw):
        def deco(fn):
            if max_examples is not None:
                fn._hyp_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            n = min(getattr(fn, "_hyp_max_examples", _FALLBACK_EXAMPLES),
                    _FALLBACK_EXAMPLES)

            @functools.wraps(fn)
            def wrapper(*args, **kw):
                rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
                for _ in range(n):
                    fn(*args, *[s.draw(rng) for s in strategies], **kw)

            # keep pytest from introspecting the wrapped signature and
            # mistaking strategy-filled params for fixtures
            del wrapper.__wrapped__
            return wrapper

        return deco
