"""Pruning tests: mask invariants (hypothesis), Wanda vs magnitude, SparseGPT."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core import check_nm, jsq_compress, magnitude_prune, nm_mask, sparsegpt_prune, wanda_prune
from repro.core.pruning import unstructured_mask


def _w(seed=0, shape=(128, 64)):
    return jnp.asarray(np.random.default_rng(seed).normal(0, 0.1, shape), jnp.float32)


class TestMasks:
    @given(st.integers(0, 100), st.sampled_from([(1, 4), (2, 4), (4, 8)]))
    @settings(max_examples=20, deadline=None)
    def test_nm_invariant(self, seed, nm):
        n, m = nm
        sal = jnp.abs(_w(seed, (64, 32)))
        mask = nm_mask(sal, n, m)
        assert check_nm(mask, n, m)

    def test_nm_keeps_top(self):
        sal = jnp.asarray(
            np.tile(np.array([4.0, 3.0, 2.0, 1.0]), 8)[:, None], jnp.float32
        )
        sal = jnp.broadcast_to(sal, (32, 4))
        mask = nm_mask(sal, 2, 4)
        m = np.asarray(mask).reshape(8, 4, 4)
        assert (m[:, 0] == 1).all() and (m[:, 1] == 1).all()
        assert (m[:, 2] == 0).all() and (m[:, 3] == 0).all()

    @given(st.floats(0.1, 0.9))
    @settings(max_examples=10, deadline=None)
    def test_unstructured_rate(self, sparsity):
        sal = jnp.abs(_w(3, (100, 40)))
        mask = unstructured_mask(sal, sparsity)
        per_col = np.asarray(mask).sum(0)
        expect = round(100 * (1 - sparsity))
        assert (per_col == expect).all()

    def test_wanda_uses_activations(self):
        w = jnp.ones((8, 4))
        x_l2 = jnp.asarray([10.0, 1, 1, 1, 1, 1, 1, 10.0])
        mask = wanda_prune(w, x_l2, pattern="2:4")
        m = np.asarray(mask)
        assert m[0].all() and m[7].all()  # high-activation channels survive


class TestSparseGPT:
    def test_updates_reduce_output_error(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(0, 1, (256, 32)), jnp.float32)
        mix = jnp.asarray(np.eye(32) + rng.normal(0, 0.25, (32, 32)), jnp.float32)
        x = x @ mix
        w = jnp.asarray(rng.normal(0, 0.1, (32, 16)), jnp.float32)
        h = x.T @ x
        w_sg, mask_sg = sparsegpt_prune(w, h, pattern="2:4")
        assert check_nm(mask_sg, 2, 4)
        # baseline: magnitude mask, no updates
        mask_mag = magnitude_prune(w, pattern="2:4")
        e_sg = float(jnp.sum((x @ (w_sg - w)) ** 2))
        e_mag = float(jnp.sum((x @ (w * mask_mag - w)) ** 2))
        assert e_sg < e_mag

    def test_unstructured_path(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(0, 1, (128, 16)), jnp.float32)
        w = jnp.asarray(rng.normal(0, 0.1, (16, 8)), jnp.float32)
        w_sg, mask = sparsegpt_prune(w, x.T @ x, sparsity=0.5, pattern="unstructured")
        assert abs(float(mask.mean()) - 0.5) < 0.05
        # pruned positions are zero
        assert float(jnp.max(jnp.abs(w_sg * (1 - mask)))) == 0.0


class TestJSQ:
    def test_joint_compress(self):
        w = _w(2, (64, 32))
        x_l2 = jnp.abs(_w(3, (64,))) + 0.1
        qt, mask = jsq_compress(w, x_l2[:, 0] if x_l2.ndim > 1 else x_l2)
        assert check_nm(mask, 2, 4)
        assert qt.codes.shape == w.shape
