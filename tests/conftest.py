"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the real single
CPU device; multi-device tests (dry-run, collectives) spawn subprocesses that
set --xla_force_host_platform_device_count before importing jax."""
import os
import sys

import jax
import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
