"""Live observability plane: rolling-window instruments, Prometheus
export, SLO monitors driving the degradation ladder, and the per-request
flight recorder.

Four layers, mirroring docs/observability.md §Live plane:

* host-only units — ``WindowedHistogram``/``WindowedRate`` ring
  semantics (quantiles within one bucket width of the exact order
  statistic, sub-window expiry), the Prometheus text exposition checked
  by a small strict parser, ``SloMonitor`` burn math, the ladder's
  pressure-source hook with hysteresis, the flight recorder's bounded
  rings, and ``SnapshotWriter`` crash-safe flushes;
* engine integration — a TTFT-SLO breach with zero queue backlog walks
  the ladder and recovers under a deterministic ``StepClock``; a chaos
  run dumps postmortem bundles for its terminal requests;
* HTTP endpoints — ``/metrics`` / ``/metrics.json`` / ``/healthz``
  served from a live registry over a real (ephemeral-port) socket;
* fleet — a two-replica router's quantiles equal the single merged-
  histogram computation, never the per-replica max.
"""

import dataclasses
import json
import math
import os
import re
import time
import urllib.request

import jax
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (
    ContinuousEngine,
    DegradationLadder,
    EngineConfig,
    EngineLiveSource,
    FaultPlan,
    FaultSpec,
    FlightRecorder,
    GuardConfig,
    MetricsServer,
    ObservabilityConfig,
    PagingConfig,
    Request,
    RequestState,
    Router,
    RouterLiveSource,
    ServingMetrics,
    SloMonitor,
    SnapshotWriter,
    WindowedHistogram,
    WindowedRate,
    atomic_write_json,
    merge_histogram_states,
    merge_replica_summaries,
    quantile_of_state,
    render_prometheus,
)
from repro.serving.export import parse_listen, registry_rows
from repro.serving.metrics import Histogram
from repro.serving.slo import P95_BUDGET

MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n, plen=8, max_new=8, seed=7):
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed), (n, plen), 0, cfg.vocab_size
    )
    return [
        Request(
            rid=i,
            prompt=[int(t) for t in prompts[i]],
            arrival=0.0,
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


class StepClock:
    """Deterministic virtual clock (see tests/test_robustness.py)."""

    def __init__(self, tick=1e-4):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t

    def sleep(self, s):
        self.t += s


# ---------------------------------------------------------------------------
# WindowedHistogram / WindowedRate: ring semantics
# ---------------------------------------------------------------------------

BOUNDS = (0.1, 0.5, 1.0, 2.0, 5.0)


class TestWindowedHistogram:
    @settings(max_examples=30)
    @given(
        st.integers(1, 60),
        st.integers(0, 10_000),
        st.sampled_from([0.5, 0.9, 0.95, 0.99]),
    )
    def test_quantile_within_one_bucket_of_exact(self, n, seed, q):
        """The bucket-interpolated quantile lands inside the bucket that
        contains the exact order statistic — never further than one
        bucket width away."""
        import random

        rng = random.Random(seed)
        xs = [rng.uniform(0.0, 8.0) for _ in range(n)]
        wh = WindowedHistogram("w", window=10.0, n_sub=5, boundaries=BOUNDS)
        for x in xs:
            wh.observe(x, 0.5)
        xs.sort()
        rank = min(n - 1, max(0, math.ceil(q * n) - 1))
        exact = xs[rank]
        est = wh.quantile(q, now=0.5)
        # the bucket interval containing the exact order statistic,
        # clamped to the observed min/max like the estimator itself
        import bisect

        i = bisect.bisect_left(BOUNDS, exact)
        lo = BOUNDS[i - 1] if i > 0 else xs[0]
        hi = BOUNDS[i] if i < len(BOUNDS) else xs[-1]
        lo, hi = max(lo, xs[0]), min(max(hi, lo), xs[-1])
        assert lo - 1e-9 <= est <= hi + 1e-9, (est, exact, lo, hi)

    def test_expiry_drops_old_subwindows(self):
        wh = WindowedHistogram("w", window=10.0, n_sub=5, boundaries=BOUNDS)
        wh.observe(3.0, 1.0)  # epoch 0
        wh.observe(0.3, 9.0)  # epoch 4
        assert wh.count(now=9.0) == 2
        # at now=12 the live epochs are [2, 6]: the t=1 sample is gone
        assert wh.count(now=12.0) == 1
        assert wh.quantile(0.5, now=12.0) == pytest.approx(0.3)
        # the whole window expires eventually
        assert wh.count(now=40.0) == 0
        assert math.isnan(wh.quantile(0.5, now=40.0))

    def test_stale_sample_cannot_corrupt_newer_subwindow(self):
        wh = WindowedHistogram("w", window=10.0, n_sub=5, boundaries=BOUNDS)
        wh.observe(1.0, 25.0)  # epoch 12 -> slot 2
        # an ancient timestamp mapping to the same ring slot must be
        # dropped, not folded into the newer sub-window
        wh.observe(1.0, 5.0)  # epoch 2 -> slot 2, older: ignored
        assert wh.count(now=25.0) == 1

    def test_fraction_above(self):
        wh = WindowedHistogram("w", window=10.0, n_sub=5, boundaries=BOUNDS)
        for _ in range(3):
            wh.observe(1.5, 1.0)  # bucket (1.0, 2.0]
        for _ in range(7):
            wh.observe(0.05, 1.0)  # bucket (-inf, 0.1]
        # threshold on a bucket boundary: no interpolation ambiguity
        assert wh.fraction_above(1.0, now=1.0) == pytest.approx(0.3)
        assert wh.fraction_above(10.0, now=1.0) == pytest.approx(0.0)

    def test_reads_do_not_mutate(self):
        wh = WindowedHistogram("w", window=10.0, n_sub=5, boundaries=BOUNDS)
        wh.observe(1.0, 1.0)
        # evaluating far in the future must not clear the ring: a later
        # read at the true engine time still sees the sample
        assert wh.count(now=1000.0) == 0
        assert wh.count(now=1.0) == 1


class TestWindowedRate:
    def test_rate_over_window(self):
        wr = WindowedRate("r", window=10.0, n_sub=5)
        for t in (0.5, 1.5, 2.5, 3.5):
            wr.add(5, t)
        assert wr.total(now=4.0) == pytest.approx(20.0)
        # early in the run the denominator is elapsed time, not the full
        # window — a 4s-old run is not diluted to a 10s average
        assert wr.rate(now=4.0) == pytest.approx(20.0 / 4.0)

    def test_expiry(self):
        wr = WindowedRate("r", window=10.0, n_sub=5)
        wr.add(100, 1.0)
        wr.add(10, 11.0)
        assert wr.total(now=11.0) == pytest.approx(10.0)
        assert wr.total(now=30.0) == pytest.approx(0.0)


# ---------------------------------------------------------------------------
# Prometheus text exposition: strict conformance parser
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?"
    r" (?P<value>\S+)$"
)
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text):
    """Strict parse of the 0.0.4 text exposition: returns
    ``(families, samples)`` and raises AssertionError on any violation —
    unknown line shape, sample without a TYPE, duplicate TYPE, histogram
    whose cumulative buckets decrease or whose +Inf != _count."""
    families = {}
    samples = []
    for line in text.splitlines():
        if not line:
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            assert len(parts) == 4, f"bad TYPE line: {line!r}"
            name, kind = parts[2], parts[3]
            assert kind in ("counter", "gauge", "histogram", "summary")
            assert name not in families, f"duplicate TYPE for {name}"
            families[name] = kind
            continue
        assert not line.startswith("#"), f"unexpected comment: {line!r}"
        m = _SAMPLE_RE.match(line)
        assert m, f"unparseable sample line: {line!r}"
        labels = dict(_LABEL_RE.findall(m.group("labels") or ""))
        value = float(m.group("value").replace("+Inf", "inf"))
        samples.append((m.group("name"), labels, value))
    # every sample must belong to a declared family
    for name, labels, _ in samples:
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix) and name[: -len(suffix)] in families:
                base = name[: -len(suffix)]
                break
        assert base in families, f"sample {name} has no TYPE"
    # histogram invariants, per label-set series
    for fam, kind in families.items():
        if kind != "histogram":
            continue
        series = {}
        for name, labels, value in samples:
            if name == f"{fam}_bucket":
                key = tuple(
                    sorted((k, v) for k, v in labels.items() if k != "le")
                )
                series.setdefault(key, []).append(
                    (float(labels["le"].replace("+Inf", "inf")), value)
                )
        counts = {
            tuple(sorted(labels.items())): value
            for name, labels, value in samples
            if name == f"{fam}_count"
        }
        for key, buckets in series.items():
            buckets.sort()
            les = [le for le, _ in buckets]
            assert les == sorted(set(les)), f"{fam}: dup/unsorted le"
            assert les[-1] == math.inf, f"{fam}: no +Inf bucket"
            cums = [c for _, c in buckets]
            assert cums == sorted(cums), f"{fam}: non-cumulative buckets"
            assert cums[-1] == counts[key], f"{fam}: +Inf != _count"
    return families, samples


class TestPrometheusExposition:
    def test_registry_renders_conformant(self):
        m = ServingMetrics(4, window=10.0, window_subs=5)
        m.on_submit(1, 0.0)
        m.on_admit(1, 0.05)
        m.on_first_token(1, 0.3)
        m.on_finish(1, 1.2, 8)
        m.on_tokens(8, 1.2)
        m.on_fault("nan_logits", 0.5)
        text = render_prometheus(registry_rows(m.registry, now=1.2))
        families, samples = parse_prometheus(text)
        assert families["repro_ttft_s"] == "histogram"
        assert families["repro_window_ttft_s"] == "histogram"
        assert families["repro_fault_fired"] == "counter"
        assert families["repro_window_tokens_per_s"] == "gauge"
        by_name = {(n, tuple(sorted(l.items()))): v for n, l, v in samples}
        assert by_name[("repro_fault_fired", (("site", "nan_logits"),))] == 1
        assert by_name[("repro_tokens_emitted", ())] == 8

    def test_label_escaping(self):
        m = ServingMetrics(2)
        m.on_fault('we"ird\\site\n', 0.0)
        text = render_prometheus(registry_rows(m.registry))
        _, samples = parse_prometheus(text)
        assert any(n == "repro_fault_fired" for n, _, _ in samples)
        assert '\\"' in text and "\\n" in text

    def test_type_conflict_raises(self):
        rows = [
            ("x", "counter", {}, {"value": 1.0}),
            ("x", "gauge", {}, {"value": 2.0}),
        ]
        with pytest.raises(ValueError, match="both"):
            render_prometheus(rows)


# ---------------------------------------------------------------------------
# SloMonitor: burn math + ladder pressure with hysteresis
# ---------------------------------------------------------------------------


def _obs(**kw):
    kw.setdefault("window_s", 10.0)
    kw.setdefault("window_subs", 5)
    return ObservabilityConfig(**kw)


class TestSloMonitor:
    def test_needs_a_target(self):
        with pytest.raises(ValueError, match="target"):
            SloMonitor(_obs(), ServingMetrics(2))

    def test_ttft_burn_is_miss_fraction_over_budget(self):
        m = ServingMetrics(2, window=10.0, window_subs=5)
        slo = SloMonitor(_obs(slo_ttft_p95_s=0.5), m)
        # 1 of 10 requests misses the 0.5s target (sample at 1.0s falls
        # entirely above the 0.5 bucket boundary: exact fraction)
        for i in range(9):
            m.on_submit(i, 0.0)
            m.on_first_token(i, 0.05)
        m.on_submit(9, 0.0)
        m.on_first_token(9, 1.0)
        burns = slo.burns(now=1.0)
        assert burns["ttft"] == pytest.approx(0.1 / P95_BUDGET)

    def test_shed_burn_and_cap(self):
        m = ServingMetrics(2, window=10.0, window_subs=5)
        slo = SloMonitor(
            _obs(slo_shed_rate=0.01, slo_pressure_cap=4.0), m
        )
        for i in range(10):
            m.on_submit(i, 1.0)
        for i in range(5):
            m.on_shed(i, 1.0)
        # shed rate 0.5 against target 0.01 -> burn 50, capped at 4
        assert slo.burns(now=1.0)["shed"] == pytest.approx(50.0)
        assert slo.update(1.0) == pytest.approx(4.0)
        assert slo.pressure() == pytest.approx(4.0)

    def test_breach_walks_ladder_and_recovers_with_hysteresis(self):
        """The acceptance trajectory, scripted: full breach -> L1;
        partial breach inside the hysteresis band -> holds L1 (no flap
        up or down); window expiry -> burn 0 -> back to L0."""
        m = ServingMetrics(2, window=10.0, window_subs=5)
        slo = SloMonitor(_obs(slo_ttft_p95_s=0.5), m)
        ladder = DegradationLadder()
        ladder.add_pressure_source(slo.pressure)
        # phase A: 3 hard misses at t~1 -> miss fraction 1.0, burn
        # capped at 4 -> the ladder walks up on backlog pressure 0
        for i in range(3):
            m.on_submit(i, 0.0)
            m.on_first_token(i, 1.0)
        slo.update(1.0)
        assert ladder.update(0.0) == 1
        # phase B: 96 fast requests at t~2 dilute the miss fraction to
        # 3/99 -> burn ~0.61, inside the (exit=0.5, enter=1.0) band:
        # the level holds, round after round
        for i in range(3, 99):
            m.on_submit(i, 1.95)
            m.on_first_token(i, 2.0)
        for _ in range(4):
            slo.update(2.0)
            assert ladder.update(0.0) == 1
        assert slo.pressure() == pytest.approx((3 / 99) / P95_BUDGET)
        # phase C: the window rolls past every sample -> burn 0 ->
        # hysteresis exit -> full service restored
        slo.update(30.0)
        assert slo.pressure() == 0.0
        assert ladder.update(0.0) == 0


# ---------------------------------------------------------------------------
# FlightRecorder: bounded rings + bundles
# ---------------------------------------------------------------------------


class TestFlightRecorder:
    def test_per_request_ring_bounds_and_drop_count(self):
        rec = FlightRecorder(events_per_request=4, max_requests=8)
        for i in range(10):
            rec.record(1, float(i), "tick", i=i)
        evs = rec.events(1)
        assert len(evs) == 4
        assert [e["i"] for e in evs] == [6, 7, 8, 9]  # oldest evicted
        assert rec.dropped(1) == 6

    def test_lru_eviction_of_tracked_requests(self):
        rec = FlightRecorder(events_per_request=4, max_requests=3)
        for rid in (1, 2, 3):
            rec.record(rid, 0.0, "submit")
        rec.record(1, 1.0, "touch")  # 1 becomes most recent
        rec.record(4, 2.0, "submit")  # evicts 2 (least recently touched)
        assert rec.events(2) == []
        assert rec.events(1) and rec.events(3) and rec.events(4)
        assert rec.evicted_requests == 1

    def test_bundle_shape(self):
        rec = FlightRecorder(events_per_request=8)
        req = Request(rid=7, prompt=[1, 2, 3], arrival=0.5, max_new_tokens=4)
        req.state = RequestState.EXPIRED
        req.error = "deadline"
        rec.record(7, 0.5, "submit")
        rec.record(7, 1.0, "expire", where="queued")
        b = rec.bundle(req, {"degradation_level": 2})
        assert b["rid"] == 7
        assert b["state"] == "EXPIRED"
        assert b["prompt_len"] == 3
        assert [e["event"] for e in b["events"]] == ["submit", "expire"]
        assert b["context"]["degradation_level"] == 2
        json.dumps(b)  # must be JSON-serializable as-is
        rec.discard(7)
        assert rec.tracked() == 0


# ---------------------------------------------------------------------------
# Fleet merge: bucket-merged quantiles, not per-replica max
# ---------------------------------------------------------------------------


class TestFleetMerge:
    def test_skewed_two_replica_p95_regression(self):
        """Replica A: 19 fast requests. Replica B: 1 slow one. The fleet
        p95 is fast (the slow request is the top 5%), but the old
        max-of-p95 semantics said 1.0s. The merged key must say 0.01s
        and the ``_peak`` key must keep the old answer."""
        ha, hb = Histogram("ttft_s"), Histogram("ttft_s")
        for _ in range(19):
            ha.observe(0.01)
        hb.observe(1.0)
        sa = {"p95_ttft_s": 0.01, "n_requests": 19.0}
        sb = {"p95_ttft_s": 1.0, "n_requests": 1.0}
        merged = merge_replica_summaries(
            [sa, sb],
            histograms=[{"ttft_s": ha.state()}, {"ttft_s": hb.state()}],
        )
        assert merged["p95_ttft_s"] == pytest.approx(0.01)
        assert merged["p95_ttft_s_peak"] == pytest.approx(1.0)
        assert merged["n_requests"] == pytest.approx(20.0)

    def test_without_histograms_falls_back_to_peak(self):
        merged = merge_replica_summaries(
            [{"p95_ttft_s": 0.01}, {"p95_ttft_s": 1.0}]
        )
        assert merged["p95_ttft_s"] == pytest.approx(1.0)
        assert merged["p95_ttft_s_peak"] == pytest.approx(1.0)

    def test_merge_histogram_states_sums_buckets(self):
        ha, hb = Histogram("h"), Histogram("h")
        for _ in range(3):
            ha.observe(0.01)
        hb.observe(1.0)
        st_m = merge_histogram_states([ha.state(), hb.state()])
        assert st_m["n"] == 4
        assert st_m["min"] == pytest.approx(0.01)
        assert st_m["max"] == pytest.approx(1.0)
        assert sum(st_m["counts"]) == 4
        assert quantile_of_state(st_m, 0.5) == pytest.approx(0.01)

    def test_boundary_mismatch_raises(self):
        ha = Histogram("h", boundaries=(0.1, 1.0))
        hb = Histogram("h", boundaries=(0.2, 2.0))
        ha.observe(0.05)
        hb.observe(0.05)
        with pytest.raises(ValueError, match="boundaries"):
            merge_histogram_states([ha.state(), hb.state()])


# ---------------------------------------------------------------------------
# SnapshotWriter + atomic_write_json: crash-safe snapshots
# ---------------------------------------------------------------------------


class TestSnapshots:
    def test_atomic_write_leaves_no_temp_files(self, tmp_path):
        path = tmp_path / "snap.json"
        atomic_write_json(str(path), {"a": 1})
        assert json.loads(path.read_text()) == {"a": 1}
        assert os.listdir(tmp_path) == ["snap.json"]

    def test_periodic_flush_and_final_payload(self, tmp_path):
        path = tmp_path / "live.json"
        ticks = []

        def payload():
            ticks.append(1)
            return {"ticks": len(ticks)}

        w = SnapshotWriter(str(path), payload, interval=0.02).start()
        deadline = time.time() + 2.0
        while not path.exists() and time.time() < deadline:
            time.sleep(0.01)
        assert path.exists(), "no flush within 2s"
        assert json.loads(path.read_text())["ticks"] >= 1
        w.stop(final_payload={"final": True})
        assert json.loads(path.read_text()) == {"final": True}
        assert w.flushes >= 1

    def test_payload_exception_does_not_kill_writer(self, tmp_path):
        path = tmp_path / "live.json"
        calls = []

        def payload():
            calls.append(1)
            if len(calls) == 1:
                raise RuntimeError("transient")
            return {"ok": True}

        w = SnapshotWriter(str(path), payload, interval=0.02).start()
        deadline = time.time() + 2.0
        while not path.exists() and time.time() < deadline:
            time.sleep(0.01)
        w.stop()
        assert json.loads(path.read_text())["ok"] is True


# ---------------------------------------------------------------------------
# HTTP endpoints over a live registry
# ---------------------------------------------------------------------------


class TestMetricsServer:
    def test_parse_listen(self):
        assert parse_listen(":9100") == ("127.0.0.1", 9100)
        assert parse_listen("0.0.0.0:9100") == ("0.0.0.0", 9100)
        assert parse_listen("9100") == ("127.0.0.1", 9100)
        with pytest.raises(ValueError):
            parse_listen("nope")

    def test_endpoints(self):
        m = ServingMetrics(2, window=10.0, window_subs=5)
        m.on_submit(1, 0.0)
        m.on_first_token(1, 0.2)

        class Src:
            def prometheus(self):
                return render_prometheus(registry_rows(m.registry, now=0.2))

            def snapshot_json(self):
                return {"live": m.live_snapshot(0.2)}

            def health(self):
                return {"status": "serving", "degradation_level": 0}

        srv = MetricsServer(Src(), port=0).start()
        try:
            body = urllib.request.urlopen(srv.url + "/metrics").read()
            parse_prometheus(body.decode())
            js = json.loads(
                urllib.request.urlopen(srv.url + "/metrics.json").read()
            )
            assert js["live"]["window_ttft_n"] == 1
            hz = json.loads(
                urllib.request.urlopen(srv.url + "/healthz").read()
            )
            assert hz["status"] == "serving"
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(srv.url + "/nope")
        finally:
            srv.stop()


# ---------------------------------------------------------------------------
# Engine integration: SLO-driven ladder walk + chaos postmortems
# ---------------------------------------------------------------------------


def _config(**kw):
    obs = kw.pop("observability", None)
    guard = kw.pop("guard", None)
    return EngineConfig(
        n_slots=kw.pop("n_slots", 3),
        max_len=MAX_LEN,
        prefill_bucket=kw.pop("prefill_bucket", 8),
        check_retrace=True,
        paging=PagingConfig(block_size=8),
        guard=guard if guard is not None else GuardConfig(degradation=True),
        observability=obs if obs is not None else ObservabilityConfig(),
        **kw,
    )


class TestEngineIntegration:
    def test_slo_breach_walks_ladder_and_recovers(self, model):
        """An induced TTFT-SLO breach with no queue backlog (2 requests
        against 3 slots: backlog pressure stays under the enter
        threshold, see the control test below) walks the ladder off
        level 0 on SLO pressure alone, and the short rolling window
        lets it recover to level 0 before the run ends — deterministic
        under StepClock."""
        cfg, params = model
        clk = StepClock(tick=1e-3)
        config = _config(
            observability=ObservabilityConfig(
                window_s=0.05,
                window_subs=5,
                slo_ttft_p95_s=1e-6,  # every TTFT breaches
            ),
        )
        eng = ContinuousEngine(params, cfg, config, clock=clk)
        res = eng.run(_requests(cfg, 2, max_new=24), sync_every=1)
        m = res.metrics
        assert m["jit_retraces"] == 0
        # no backlog ever existed, yet the ladder walked
        assert m["peak_queue_depth"] == 0
        assert m["peak_degradation_level"] >= 1
        # burns expired with the window -> hysteresis walk back down
        assert eng.live_level == 0
        assert m["degraded_rounds"] >= 1

    def test_no_slo_no_walk(self, model):
        """Same workload without SLO targets: the ladder never moves
        (the walk above really was SLO pressure)."""
        cfg, params = model
        clk = StepClock(tick=1e-3)
        eng = ContinuousEngine(params, cfg, _config(), clock=clk)
        res = eng.run(_requests(cfg, 2, max_new=24), sync_every=1)
        assert res.metrics["peak_degradation_level"] == 0

    def test_chaos_postmortem_bundles(self, model, tmp_path):
        """A quarantined (nan_logits) and an expired request each leave
        a self-contained postmortem bundle on disk."""
        cfg, params = model
        clk = StepClock()
        pm = tmp_path / "postmortems"
        config = _config(
            guard=GuardConfig(degradation=True, default_ttl=0.25),
            observability=ObservabilityConfig(
                postmortem_dir=str(pm), flight_recorder_events=16
            ),
        )
        faults = FaultPlan([FaultSpec("nan_logits", nth=1)])
        eng = ContinuousEngine(params, cfg, config, clock=clk, faults=faults)
        reqs = _requests(cfg, 5, max_new=16)
        res = eng.run(reqs, sync_every=2)
        terminal = [
            r
            for r in res.requests
            if r.state in (RequestState.FAILED, RequestState.EXPIRED)
        ]
        assert terminal, "chaos produced no terminal requests"
        for r in terminal:
            path = pm / f"postmortem_rid{r.rid}.json"
            assert path.exists(), f"no bundle for rid {r.rid} ({r.state})"
            b = json.loads(path.read_text())
            assert b["rid"] == r.rid
            assert b["state"] == r.state.name
            events = [e["event"] for e in b["events"]]
            assert events[0] == "submit"
            if r.state is RequestState.FAILED:
                assert "quarantine" in events
            assert b["context"]["faults"]["fault_nan_logits"] == 1.0
        # clean finishes leave no bundle and no tracked ring
        finished = [
            r for r in res.requests if r.state is RequestState.FINISHED
        ]
        for r in finished:
            assert not (pm / f"postmortem_rid{r.rid}.json").exists()
        assert eng.recorder.tracked() == 0

    def test_live_endpoint_during_engine_lifetime(self, model):
        """The exporter serves a conformant exposition against a real
        engine registry, including windowed families, fleet health, and
        the engine's live snapshot."""
        cfg, params = model
        eng = ContinuousEngine(params, cfg, _config())
        srv = MetricsServer(EngineLiveSource(eng), port=0).start()
        try:
            # before the first run: empty exposition, idle health
            hz = json.loads(
                urllib.request.urlopen(srv.url + "/healthz").read()
            )
            assert hz["status"] == "idle"
            eng.run(_requests(cfg, 4), sync_every=2)
            body = urllib.request.urlopen(srv.url + "/metrics").read()
            families, _ = parse_prometheus(body.decode())
            assert "repro_window_ttft_s" in families
            js = json.loads(
                urllib.request.urlopen(srv.url + "/metrics.json").read()
            )
            assert js["live"]["completed"] == 4
        finally:
            srv.stop()


# ---------------------------------------------------------------------------
# Fleet: router /metrics quantiles == single merged-histogram computation
# ---------------------------------------------------------------------------


class TestFleetEndpoint:
    def test_two_replica_quantiles_match_merged_histogram(self, model):
        cfg, params = model
        router = Router(
            params, cfg,
            _config(guard=GuardConfig(degradation=True)),
            n_replicas=2,
        )
        res = router.run(_requests(cfg, 6), sync_every=2)
        states = [
            eng.metrics.histogram_states()["ttft_s"]
            for eng in router.engines
        ]
        merged = merge_histogram_states(states)
        expect = quantile_of_state(merged, 0.95)
        assert res.metrics["p95_ttft_s"] == pytest.approx(expect)
        assert router.live_snapshot()["p95_ttft_s"] == pytest.approx(expect)
        # and over HTTP: per-replica + fleet series, all conformant
        srv = MetricsServer(RouterLiveSource(router), port=0).start()
        try:
            body = urllib.request.urlopen(srv.url + "/metrics").read()
            families, samples = parse_prometheus(body.decode())
            fleet_buckets = {
                l["le"]: v
                for n, l, v in samples
                if n == "repro_ttft_s_bucket" and l.get("replica") == "fleet"
            }
            per_replica = [
                {
                    l["le"]: v
                    for n, l, v in samples
                    if n == "repro_ttft_s_bucket"
                    and l.get("replica") == str(i)
                }
                for i in range(2)
            ]
            for le, v in fleet_buckets.items():
                assert v == per_replica[0][le] + per_replica[1][le]
            js = json.loads(
                urllib.request.urlopen(srv.url + "/metrics.json").read()
            )
            assert js["fleet"]["p95_ttft_s"] == pytest.approx(expect)
            assert set(js["replicas"]) == {"0", "1"}
        finally:
            srv.stop()
