"""Paged (block-granular) KV cache: allocator invariants, scheduler
admission deferral, and token-exactness of the paged continuous engine
against the contiguous (`block_size=0`) path and solo static runs — dense
and SLiM-compressed, with and without kv_quant.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch
from repro.models import transformer as T
from repro.models.compress import compress_model
from repro.serving import (
    BlockAllocator,
    ContinuousEngine,
    Request,
    Scheduler,
    ServeEngine,
    blocks_needed,
    synthetic_trace,
)
from repro.serving.block_pool import NULL_BLOCK, RESERVED_BLOCKS, TRASH_BLOCK

MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n, s, seed=7):
    return jax.random.randint(jax.random.PRNGKey(seed), (n, s), 0, cfg.vocab_size)


def _as_requests(prompts, max_new=6):
    return [
        Request(rid=i, prompt=[int(t) for t in prompts[i]], arrival=0.0,
                max_new_tokens=max_new)
        for i in range(prompts.shape[0])
    ]


# ---------------------------------------------------------------------------
# BlockAllocator (host-only)
# ---------------------------------------------------------------------------

class TestBlockAllocator:
    def test_reserved_blocks_never_allocated(self):
        a = BlockAllocator(n_blocks=6, block_size=8)
        got = a.allocate(slot=0, n=4)  # the entire usable pool
        assert NULL_BLOCK not in got and TRASH_BLOCK not in got
        assert a.available() == 0
        a.check()

    def test_exhaustion_and_reuse_after_release(self):
        a = BlockAllocator(n_blocks=8, block_size=8)  # 6 usable
        first = a.allocate(0, 4)
        assert not a.can_allocate(3)  # only 2 left
        with pytest.raises(RuntimeError):
            a.allocate(1, 3)
        a.release(0)
        assert a.available() == 6
        again = a.allocate(1, 6)
        assert set(first) <= set(again)  # freed blocks really recirculate
        a.check()

    def test_double_allocate_is_a_bug(self):
        a = BlockAllocator(n_blocks=8, block_size=8)
        a.allocate(0, 1)
        with pytest.raises(RuntimeError):
            a.allocate(0, 1)

    def test_blocks_needed(self):
        assert blocks_needed(1, 16) == 1
        assert blocks_needed(16, 16) == 1
        assert blocks_needed(17, 16) == 2

    def test_pool_too_small(self):
        with pytest.raises(ValueError):
            BlockAllocator(n_blocks=RESERVED_BLOCKS, block_size=8)


# ---------------------------------------------------------------------------
# Scheduler with block admission control
# ---------------------------------------------------------------------------

class TestPagedScheduler:
    def test_admission_defers_until_blocks_free(self):
        # 2 slots but only 4 usable blocks of 8 = 32 positions; each request
        # needs 3 blocks (prompt 10 + budget 10 = 20 positions) so only one
        # fits at a time despite both slots being free.
        alloc = BlockAllocator(n_blocks=6, block_size=8)
        s = Scheduler(n_slots=2, max_len=32, allocator=alloc)
        for i in range(2):
            s.submit(Request(i, [1] * 10, arrival=0.0, max_new_tokens=10))
        first = s.admit(now=0.0)
        assert [slot for slot, _ in first] == [0]
        assert s.admit(now=0.0) == []  # deferred: 1 block free, needs 3
        alloc.check()
        s.release(0)
        nxt = s.admit(now=0.0)
        assert len(nxt) == 1 and nxt[0][1].rid == 1
        alloc.check()

    def test_submit_rejects_request_larger_than_pool(self):
        alloc = BlockAllocator(n_blocks=4, block_size=8)  # 16 positions usable
        s = Scheduler(n_slots=1, max_len=32, allocator=alloc)
        with pytest.raises(ValueError):
            s.submit(Request(0, [1] * 20, max_new_tokens=10))

    def test_block_need_covers_bucketed_prefill(self):
        # prompt 3 pads to bucket 16 -> the prefill write spans 2 blocks of
        # 8 even though prompt+budget is only 4 positions
        alloc = BlockAllocator(n_blocks=6, block_size=8)
        s = Scheduler(n_slots=1, max_len=32, prefill_bucket=16, allocator=alloc)
        assert s.block_need(Request(0, [1] * 3, max_new_tokens=1)) == 2


# ---------------------------------------------------------------------------
# Paged engine end-to-end: token-exact vs contiguous and static
# ---------------------------------------------------------------------------

class TestPagedEngine:
    def test_matches_static_greedy_dense(self, model):
        cfg, params = model
        prompts = _prompts(cfg, 3, 10)
        ref = ServeEngine(params, cfg, max_len=MAX_LEN).generate(
            {"tokens": prompts}, max_new_tokens=6
        )
        eng = ContinuousEngine(
            params, cfg, n_slots=3, max_len=MAX_LEN, block_size=16
        )
        res = eng.run(_as_requests(prompts), sync_every=2)
        assert [res.outputs[i] for i in range(3)] == ref.tokens

    def test_matches_contiguous_compressed(self, model):
        cfg, params = model
        dcfg = SyntheticLMConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0
        )
        calib = calibration_batch(dcfg, n_samples=4)
        cp, _ = compress_model(
            params, cfg, calib,
            CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
        )
        prompts = _prompts(cfg, 2, 8)
        cont = ContinuousEngine(cp, cfg, n_slots=2, max_len=MAX_LEN)
        ref = cont.run(_as_requests(prompts, max_new=5), sync_every=3)
        paged = ContinuousEngine(
            cp, cfg, n_slots=2, max_len=MAX_LEN, block_size=8
        )
        res = paged.run(_as_requests(prompts, max_new=5), sync_every=3)
        assert res.outputs == ref.outputs

    @pytest.mark.parametrize("kv_quant", [False, True])
    def test_recycling_under_tight_pool(self, model, kv_quant):
        """More requests than slots, a pool smaller than slots x max_len
        (blocks must be reused across admissions), bucketing on: every
        output equals its solo static run — for f32 and int8 KV caches."""
        cfg, params = model
        if kv_quant:
            cfg = dataclasses.replace(cfg, kv_quant=True)
        trace = synthetic_trace(
            5, rate=100.0, vocab_size=cfg.vocab_size,
            prompt_len=(5, 12), max_new_tokens=(3, 6), seed=11,
        )
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, prefill_bucket=4,
            block_size=8, n_blocks=8,  # 6 usable blocks = 48 pos << 2*48
        )
        res = eng.run(trace, sync_every=2)
        assert res.metrics["completed"] == 5
        static = ServeEngine(params, cfg, max_len=MAX_LEN)
        for r in res.requests:
            solo = static.generate(
                {"tokens": jnp.asarray([r.prompt], jnp.int32)},
                max_new_tokens=r.max_new_tokens,
            )
            assert solo.tokens[0] == r.output, r.rid

    def test_eos_recycling_matches_contiguous(self, model):
        """EOS mid-stream frees a slot and its blocks; the recycled request
        decodes exactly as in the contiguous engine, and the stop token
        never appears in any output."""
        cfg, params = model
        prompts = _prompts(cfg, 2, 10)
        probe = ServeEngine(params, cfg, max_len=MAX_LEN).generate(
            {"tokens": prompts[:1]}, max_new_tokens=8
        )
        eos = probe.tokens[0][2]
        ref = ContinuousEngine(
            params, cfg, n_slots=1, max_len=MAX_LEN, eos_id=eos
        ).run(_as_requests(prompts, max_new=8), sync_every=2)
        res = ContinuousEngine(
            params, cfg, n_slots=1, max_len=MAX_LEN, eos_id=eos,
            block_size=16,
        ).run(_as_requests(prompts, max_new=8), sync_every=2)
        assert res.outputs == ref.outputs
        assert all(eos not in out for out in res.outputs.values())

    def test_more_slots_than_lanes_at_equal_memory(self, model):
        """The decoupling the paging buys: a pool equal in memory to 2
        contiguous max_len lanes runs 4 slots concurrently when requests
        only need a quarter lane each."""
        cfg, params = model
        bs = 8
        lanes2 = 2 * (MAX_LEN // bs)  # block equivalent of 2 lanes
        prompts = _prompts(cfg, 4, 6)
        eng = ContinuousEngine(
            params, cfg, n_slots=4, max_len=MAX_LEN,
            block_size=bs, n_blocks=lanes2 + RESERVED_BLOCKS,
        )
        res = eng.run(_as_requests(prompts, max_new=4), sync_every=2)
        assert res.metrics["peak_concurrency"] == 4  # > the 2 lane-slots
        ref = ServeEngine(params, cfg, max_len=MAX_LEN).generate(
            {"tokens": prompts}, max_new_tokens=4
        )
        assert [res.outputs[i] for i in range(4)] == ref.tokens

    def test_hybrid_ssm_attn_arch(self):
        """Mixed periods: attention leaves page into the pool while the
        O(1) SSM conv/state stays in per-slot lanes — same tokens as the
        contiguous cache."""
        from repro.configs import get_config
        from repro.models.config import LayerSpec

        base = get_config("jamba-v0.1-52b", reduced=True)
        cfg = dataclasses.replace(
            base, name="hybrid-paged-test", n_layers=4,
            period=(LayerSpec("ssm"), LayerSpec("attn")),
        )
        params = T.init_params(cfg, jax.random.PRNGKey(0))

        def trace():
            return synthetic_trace(
                4, rate=100.0, vocab_size=cfg.vocab_size,
                prompt_len=(5, 10), max_new_tokens=(3, 5), seed=2,
            )

        ref = ContinuousEngine(params, cfg, n_slots=2, max_len=32).run(
            trace(), sync_every=2
        )
        res = ContinuousEngine(
            params, cfg, n_slots=2, max_len=32, block_size=8
        ).run(trace(), sync_every=2)
        assert res.outputs == ref.outputs

    def test_rejects_sliding_window(self, model):
        cfg, _ = model
        swcfg = dataclasses.replace(cfg, sliding_window=8)
        assert not T.supports_paged_cache(swcfg)
        with pytest.raises(ValueError):
            ContinuousEngine(
                jax.tree.map(lambda x: x, {}), swcfg, n_slots=1,
                max_len=MAX_LEN, block_size=8,
            )

    def test_rejects_misaligned_max_len(self, model):
        cfg, params = model
        with pytest.raises(ValueError):
            ContinuousEngine(
                params, cfg, n_slots=1, max_len=MAX_LEN, block_size=7
            )


# ---------------------------------------------------------------------------
# retrace guard: steady-state compile-count invariants (check_retrace=True)
# ---------------------------------------------------------------------------


class TestRetraceGuard:
    def test_steady_state_paged_decode_compiles_once(self, model):
        """Bucketed paged decode: one compile per hot path on the cold
        run, ZERO on a warm re-run — enforced, not just observed (the
        guard is frozen before the second run, so any compile raises)."""
        cfg, params = model
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=16,
            prefill_bucket=16, check_retrace=True,
        )

        def trace():
            return synthetic_trace(
                4, rate=100.0, vocab_size=cfg.vocab_size,
                prompt_len=(5, 12), max_new_tokens=(3, 6), seed=3,
            )

        res = eng.run(trace(), sync_every=2, max_new_cap=6)
        assert res.metrics["completed"] == 4
        assert res.metrics["jit_compiles_decode"] == 1.0
        assert res.metrics["jit_compiles_prefill"] == 1.0  # one bucket
        assert res.metrics["jit_retraces"] == 0.0
        eng.retrace_guard.freeze()
        warm = eng.run(trace(), sync_every=2, max_new_cap=6)
        assert warm.metrics["completed"] == 4
        assert warm.metrics["jit_compiles_decode"] == 0.0
        assert warm.metrics["jit_compiles_prefill"] == 0.0
        assert warm.metrics["jit_retraces"] == 0.0

    def test_slim_compressed_zero_post_warmup_compiles(self, model):
        cfg, params = model
        dcfg = SyntheticLMConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0
        )
        calib = calibration_batch(dcfg, n_samples=4)
        cp, _ = compress_model(
            params, cfg, calib,
            CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
        )
        prompts = _prompts(cfg, 2, 8)
        eng = ContinuousEngine(
            cp, cfg, n_slots=2, max_len=MAX_LEN, block_size=8,
            check_retrace=True,
        )
        eng.run(_as_requests(prompts, max_new=5), sync_every=2, max_new_cap=5)
        eng.retrace_guard.freeze()
        warm = eng.run(
            _as_requests(prompts, max_new=5), sync_every=2, max_new_cap=5
        )
        assert warm.metrics["jit_compiles_decode"] == 0.0
        assert warm.metrics["jit_retraces"] == 0.0

    def test_unbucketed_prefill_compiles_per_shape_not_per_request(
        self, model
    ):
        """Without bucketing, prefill compiles once per distinct prompt
        length — shape-keyed, never per-request. Two requests per length
        must share one trace."""
        cfg, params = model
        reqs = []
        for i, plen in enumerate((6, 6, 9, 9)):
            reqs.append(
                Request(
                    rid=i, prompt=list(range(1, plen + 1)), arrival=0.0,
                    max_new_tokens=3,
                )
            )
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=16,
            check_retrace=True,
        )
        res = eng.run(reqs, sync_every=2, max_new_cap=3)
        assert res.metrics["jit_compiles_prefill"] == 2.0  # lengths, not reqs
        assert res.metrics["jit_retraces"] == 0.0
