"""Substrate tests: optimizers, data determinism, checkpoint fault-tolerance,
distributed utilities."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore_pytree, save_pytree
from repro.data import SyntheticLMConfig, calibration_batch, synthetic_batches
from repro.distributed import (
    choose_mesh_shape,
    ef_compress_grads,
    microbatch_grads,
    quantize_int8,
    dequantize_int8,
)
from repro.distributed.straggler import StepMonitor
from repro.optim import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
    global_norm,
    sgd_momentum,
)


class TestOptimizers:
    def _rosenbrock_ish(self):
        target = jnp.asarray([1.0, -2.0, 3.0])

        def loss(p):
            return jnp.sum((p["w"] - target) ** 2) + 0.1 * jnp.sum(p["m"] ** 2)

        params = {"w": jnp.zeros(3), "m": jnp.ones((2, 4)), "frozen": jnp.zeros((2,), jnp.int32)}
        return loss, params

    @pytest.mark.parametrize("opt", ["adamw", "adafactor", "sgd"])
    def test_converges(self, opt):
        loss, params = self._rosenbrock_ish()
        maker = {
            "adamw": lambda: adamw(0.1, weight_decay=0.0),
            "adafactor": lambda: adafactor(0.5),
            "sgd": lambda: sgd_momentum(0.05),
        }[opt]
        init, update = maker()
        state = init(params)
        l0 = float(loss(params))
        for _ in range(100):
            g = jax.grad(loss, allow_int=True)(params)
            u, state = update(g, state, params)
            params = apply_updates(params, u)
        assert float(loss(params)) < l0 * 0.1

    def test_mask_freezes(self):
        loss, params = self._rosenbrock_ish()
        mask = {"w": True, "m": False, "frozen": False}
        init, update = adamw(0.1, mask=mask)
        state = init(params)
        g = jax.grad(loss, allow_int=True)(params)
        u, state = update(g, state, params)
        p2 = apply_updates(params, u)
        assert not np.allclose(np.asarray(p2["w"]), np.asarray(params["w"]))
        np.testing.assert_array_equal(np.asarray(p2["m"]), np.asarray(params["m"]))

    def test_int_leaves_skipped(self):
        loss, params = self._rosenbrock_ish()
        init, update = adamw(0.1)
        state = init(params)
        g = jax.grad(loss, allow_int=True)(params)  # frozen int leaf -> float0 grad
        u, state = update(g, state, params)
        p2 = apply_updates(params, u)
        np.testing.assert_array_equal(np.asarray(p2["frozen"]), np.asarray(params["frozen"]))

    def test_clip(self):
        g = {"a": jnp.ones(4) * 100.0}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
        assert float(norm) == pytest.approx(200.0)

    def test_schedule(self):
        s = cosine_schedule(1.0, 100, warmup=10)
        assert float(s(0)) == 0.0
        assert float(s(10)) == pytest.approx(1.0)
        assert float(s(100)) == pytest.approx(0.1)


class TestData:
    def test_deterministic_and_resumable(self):
        cfg = SyntheticLMConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
        a = [next(synthetic_batches(cfg, start_step=i))["tokens"] for i in range(3)]
        it = synthetic_batches(cfg)
        b = [next(it)["tokens"] for _ in range(3)]
        for x, y in zip(a, b, strict=True):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_host_sharding_disjoint(self):
        cfg = SyntheticLMConfig(vocab_size=100, seq_len=16, global_batch=8, seed=7)
        h0 = next(synthetic_batches(cfg, host_id=0, host_count=2))["tokens"]
        h1 = next(synthetic_batches(cfg, host_id=1, host_count=2))["tokens"]
        assert h0.shape == (4, 16)
        assert not np.array_equal(np.asarray(h0), np.asarray(h1))

    def test_labels_shifted(self):
        cfg = SyntheticLMConfig(vocab_size=100, seq_len=16, global_batch=2, seed=1)
        b = next(synthetic_batches(cfg))
        # labels are next-token: both drawn from same underlying seq
        assert b["tokens"].shape == b["labels"].shape

    def test_markov_learnable(self):
        """Markov stream must be lower-entropy than zipf (it's learnable)."""
        cfg = SyntheticLMConfig(vocab_size=64, seq_len=128, global_batch=8, seed=0)
        b = next(synthetic_batches(cfg))
        toks = np.asarray(b["tokens"])
        # count distinct successors per token: banded chain -> small
        succ = {}
        for row in toks:
            for a, bb in zip(row[:-1], row[1:], strict=True):
                succ.setdefault(int(a), set()).add(int(bb))
        avg = np.mean([len(v) for v in succ.values()])
        assert avg <= 8 + 1

    def test_calibration_differs_from_train(self):
        cfg = SyntheticLMConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
        train0 = next(synthetic_batches(cfg))["tokens"]
        calib = calibration_batch(cfg, n_samples=4)["tokens"]
        assert not np.array_equal(np.asarray(train0), np.asarray(calib))


class TestCheckpoint:
    def _tree(self, seed=0):
        k = jax.random.PRNGKey(seed)
        return {"a": jax.random.normal(k, (8, 4)), "b": {"c": jnp.arange(5)}}

    def test_roundtrip(self, tmp_path):
        t = self._tree()
        save_pytree(str(tmp_path), 3, t)
        out = restore_pytree(str(tmp_path), 3, t)
        for x, y in zip(jax.tree.leaves(t), jax.tree.leaves(out), strict=True):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))

    def test_latest_skips_corrupt(self, tmp_path):
        t = self._tree()
        save_pytree(str(tmp_path), 1, t)
        save_pytree(str(tmp_path), 2, t)
        # corrupt step 2's manifest
        with open(tmp_path / "step_00000002" / "manifest.json", "w") as f:
            f.write("{not json")
        assert latest_step(str(tmp_path)) == 1

    def test_tmp_dirs_ignored_and_gced(self, tmp_path):
        t = self._tree()
        os.makedirs(tmp_path / "step_00000009.tmp-dead")
        save_pytree(str(tmp_path), 1, t)
        assert latest_step(str(tmp_path)) == 1
        assert not any(".tmp-" in d for d in os.listdir(tmp_path))

    def test_manager_retention_and_async(self, tmp_path):
        t = self._tree()
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for s in (1, 2, 3):
            mgr.save(s, t, blocking=(s == 3))
        mgr.wait()
        steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert steps == ["step_00000002", "step_00000003"]

    def test_restore_latest_none_when_empty(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        assert mgr.restore_latest(self._tree()) is None


class TestDistributed:
    def test_int8_roundtrip_bounded(self):
        x = jnp.asarray(np.random.default_rng(0).normal(0, 1, (128,)), jnp.float32)
        q, s = quantize_int8(x)
        err = float(jnp.max(jnp.abs(dequantize_int8(q, s) - x)))
        assert err <= float(s) / 2 + 1e-7

    def test_error_feedback_reduces_bias(self):
        """With EF, the *accumulated* compressed signal tracks the true sum."""
        rng = np.random.default_rng(1)
        total_true = np.zeros(64)
        total_comp = np.zeros(64)
        residual = None
        for _ in range(50):
            g = jnp.asarray(rng.normal(0, 1, (64,)) * 0.01, jnp.float32)
            total_true += np.asarray(g)
            cg, residual = ef_compress_grads({"g": g}, residual)
            total_comp += np.asarray(cg["g"])
        resid_leaf = np.asarray(jax.tree.leaves(residual)[0])
        np.testing.assert_allclose(total_comp + resid_leaf, total_true, atol=1e-4)

    def test_microbatch_equals_fullbatch(self):
        def loss(p, b):
            return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

        rng = np.random.default_rng(2)
        p = {"w": jnp.asarray(rng.normal(0, 1, (8, 2)), jnp.float32)}
        batch = {
            "x": jnp.asarray(rng.normal(0, 1, (16, 8)), jnp.float32),
            "y": jnp.asarray(rng.normal(0, 1, (16, 2)), jnp.float32),
        }
        l1, g1 = microbatch_grads(loss, p, batch, 1)
        l4, g4 = microbatch_grads(loss, p, batch, 4)
        assert abs(float(l1) - float(l4)) < 1e-5
        np.testing.assert_allclose(
            np.asarray(g1["w"]), np.asarray(g4["w"]), rtol=1e-4, atol=1e-6
        )

    def test_choose_mesh_shape(self):
        assert choose_mesh_shape(512, 16) == (32, 16)
        assert choose_mesh_shape(96, 16, model_divides=8) == (12, 8)
        assert choose_mesh_shape(7, 16) == (1, 7)  # prime: model gets it all

    def test_step_monitor(self):
        mon = StepMonitor(slow_factor=2.0, hang_timeout_s=60)
        import time
        for _ in range(3):
            mon.step_begin()
            time.sleep(0.01)
            mon.step_end()
        mon.step_begin()
        time.sleep(0.08)
        assert mon.step_end() is True  # flagged straggler
        mon.stop()
