"""slimcheck static analysis: seeded-bug self-tests per rule, traced-scope
resolution (decorators, call-form jit on local closures, pallas partials),
taint precision, suppression syntax, baseline machinery — and the gate:
``src/`` lints clean against the checked-in baseline.

Pure stdlib on the lint side (no jax import), mirroring the CI lint job.
"""
import os
import textwrap

import pytest

from repro.analysis.lint import (
    Baseline,
    FileModel,
    lint_paths,
    lint_source,
)
from repro.analysis.rules import RULES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def lint(src, path="<test>", rules=None):
    return lint_source(textwrap.dedent(src), path=path, rules=rules)


def codes(findings):
    return [f.rule for f in findings]


# ---------------------------------------------------------------------------
# SC001: Python control flow on traced values
# ---------------------------------------------------------------------------


class TestSC001:
    def test_if_on_traced_param(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                if x > 0:
                    return x
                return -x
            """
        )
        assert codes(out) == ["SC001"]
        assert "['x']" in out[0].message

    def test_while_and_assert_and_ifexp(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x, n):
                assert x.sum() > 0
                y = x if n > 2 else -x
                while n > 0:
                    n = n - 1
                return y
            """
        )
        assert sorted(codes(out)) == ["SC001", "SC001", "SC001"]

    def test_static_projections_are_branchable(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                m, k = x.shape
                if m > k and len(x) > 1 and x.ndim == 2:
                    return x * 2
                return x
            """
        )
        assert out == []

    def test_static_argnames_param_is_branchable(self):
        out = lint(
            """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnames=("greedy",))
            def f(x, greedy):
                if greedy:
                    return x
                return -x
            """
        )
        assert out == []

    def test_is_none_test_is_structural(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x, table):
                if table is None:
                    return x
                return x + table
            """
        )
        assert out == []


# ---------------------------------------------------------------------------
# SC002: host syncs in traced scope / the serving loop
# ---------------------------------------------------------------------------


class TestSC002:
    def test_device_get_in_traced_scope(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                y = jax.device_get(x)
                return y
            """
        )
        assert codes(out) == ["SC002"]

    def test_item_and_float_on_tracer(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                a = x.sum().item()
                b = float(x[0])
                return a + b
            """
        )
        assert sorted(codes(out)) == ["SC002", "SC002"]

    def test_np_asarray_on_traced_value(self):
        out = lint(
            """
            import jax
            import numpy as np

            @jax.jit
            def f(x):
                return np.asarray(x)
            """
        )
        assert codes(out) == ["SC002"]

    def test_np_asarray_on_host_list_ok(self):
        out = lint(
            """
            import jax
            import numpy as np

            @jax.jit
            def f(x):
                mask = np.asarray([1, 0, 1])
                return x * mask
            """
        )
        assert out == []

    def test_serving_loop_sync_flagged(self):
        out = lint(
            """
            import jax

            def run(reqs):
                while reqs:
                    state = step(state)
                    flags = jax.device_get(state)
                return state
            """,
            path="src/repro/serving/fake.py",
        )
        assert codes(out) == ["SC002"]
        assert "per-round loop" in out[0].message

    def test_serving_loop_sync_through_local_helper(self):
        # the engine's `preempt_slot` pattern: the sync hides in a local
        # (non-traced) helper called from the loop
        out = lint(
            """
            import jax

            def run(reqs):
                def fetch(state):
                    return jax.device_get(state)

                while reqs:
                    flags = fetch(reqs)
                return flags
            """,
            path="src/repro/serving/fake.py",
        )
        assert codes(out) == ["SC002"]

    def test_loop_outside_serving_not_scored(self):
        out = lint(
            """
            import jax

            def run(reqs):
                while reqs:
                    flags = jax.device_get(reqs)
                return flags
            """,
            path="src/repro/bench/fake.py",
        )
        assert out == []

    def test_host_numpy_tolist_in_loop_not_scored(self):
        # .tolist() on host numpy is idiom, not a device sync — loop mode
        # only flags explicit jax.device_get / block_until_ready
        out = lint(
            """
            import numpy as np

            def make(n):
                out = []
                for i in range(n):
                    out.append(np.arange(i).tolist())
                return out
            """,
            path="src/repro/serving/fake.py",
        )
        assert out == []

    def test_sync_site_annotation_suppresses(self):
        out = lint(
            """
            import jax

            def run(reqs):
                while reqs:
                    flags = jax.device_get(reqs)  # slimcheck: sync-site
                return flags
            """,
            path="src/repro/serving/fake.py",
        )
        assert out == []


# ---------------------------------------------------------------------------
# SC003: config-like jit params not static
# ---------------------------------------------------------------------------


class TestSC003:
    def test_loose_config_param(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x, block_size):
                return x.reshape(-1, block_size)
            """
        )
        assert codes(out) == ["SC003"]
        assert "block_size" in out[0].message

    def test_static_argnums_clears(self):
        out = lint(
            """
            import functools
            import jax

            @functools.partial(jax.jit, static_argnums=(1,))
            def f(x, block_size):
                return x.reshape(-1, block_size)
            """
        )
        assert out == []

    def test_static_argnames_clears(self):
        out = lint(
            """
            import jax

            def g(x, bits):
                return x * bits

            h = jax.jit(g, static_argnames=("bits",))
            """
        )
        assert out == []

    def test_array_annotated_k_not_config(self):
        # in attention code `k` is the key tensor; annotation marks it
        out = lint(
            """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def attn(q: jnp.ndarray, K: jnp.ndarray):
                return q @ K.T
            """
        )
        assert out == []

    def test_non_literal_static_argnums_skipped(self):
        out = lint(
            """
            import jax

            nums = (1,)

            def f(x, block_size):
                return x.reshape(-1, block_size)

            g = jax.jit(f, static_argnums=nums)
            """
        )
        assert out == []


# ---------------------------------------------------------------------------
# SC004: pallas entry points bypassing default_interpret
# ---------------------------------------------------------------------------


class TestSC004:
    def test_bare_pallas_call_flagged(self):
        out = lint(
            """
            from jax.experimental import pallas as pl

            def op(x):
                return pl.pallas_call(kernel, out_shape=x)(x)
            """
        )
        assert codes(out) == ["SC004"]

    def test_resolver_plus_kwarg_clears(self):
        out = lint(
            """
            from jax.experimental import pallas as pl

            from repro.kernels.common import resolve_interpret

            def op(x, interpret=None):
                return pl.pallas_call(
                    kernel,
                    out_shape=x,
                    interpret=resolve_interpret(interpret),
                )(x)
            """
        )
        assert out == []

    def test_interpret_kwarg_without_resolver_flagged(self):
        out = lint(
            """
            from jax.experimental import pallas as pl

            def op(x):
                return pl.pallas_call(kernel, out_shape=x, interpret=True)(x)
            """
        )
        assert codes(out) == ["SC004"]


# ---------------------------------------------------------------------------
# SC005: un-donated cache mutation in jitted functions
# ---------------------------------------------------------------------------


class TestSC005:
    def test_undonated_cache_set(self):
        out = lint(
            """
            import jax

            @jax.jit
            def step(params, cache, x):
                cache = cache.at[0].set(x)
                return cache
            """
        )
        assert codes(out) == ["SC005"]

    def test_donate_argnums_clears(self):
        out = lint(
            """
            import jax

            def step(params, cache, x):
                cache = cache.at[0].set(x)
                return cache

            step_j = jax.jit(step, donate_argnums=(1,))
            """
        )
        assert out == []

    def test_non_literal_donation_skipped(self):
        # `donate_argnums=(1,) if flag else ()` is not statically readable
        out = lint(
            """
            import jax

            flag = True

            def step(params, cache, x):
                cache = cache.at[0].set(x)
                return cache

            step_j = jax.jit(step, donate_argnums=(1,) if flag else ())
            """
        )
        assert out == []

    def test_non_cache_param_not_scored(self):
        out = lint(
            """
            import jax

            @jax.jit
            def step(params, logits, x):
                logits = logits.at[0].set(x)
                return logits
            """
        )
        assert out == []


# ---------------------------------------------------------------------------
# traced-scope resolution and taint seeding
# ---------------------------------------------------------------------------


class TestScopeResolution:
    def test_call_form_jit_on_local_closure(self):
        # the ContinuousEngine idiom: `self._step = jax.jit(_step, ...)`
        # where _step is a closure defined inside __init__
        out = lint(
            """
            import jax

            class Engine:
                def __init__(self):
                    def _step(params, cache, x):
                        if x > 0:
                            return cache
                        return cache * 2

                    self._step = jax.jit(_step, donate_argnums=(1,))
            """
        )
        assert codes(out) == ["SC001"]

    def test_call_propagation_taints_helpers(self):
        out = lint(
            """
            import jax

            def helper(y):
                if y > 0:
                    return y
                return -y

            @jax.jit
            def f(x):
                return helper(x)
            """
        )
        assert codes(out) == ["SC001"]

    def test_call_propagation_static_args_stay_static(self):
        # bits is static at the real jit site; the helper receiving it
        # must not be over-tainted (the slim_quant _quant_error_at case)
        out = lint(
            """
            import functools
            import jax

            def helper(y, bits):
                half = float(2 ** (bits - 1))
                if bits > 4:
                    return y * half
                return y

            @functools.partial(jax.jit, static_argnames=("bits",))
            def f(x, bits):
                return helper(x, bits)
            """
        )
        assert out == []

    def test_pallas_partial_kwargs_are_static(self):
        # the group_quant idiom: partial-bound kernel config is a python
        # int at trace time, not a Ref
        out = lint(
            """
            import functools

            from jax.experimental import pallas as pl

            from repro.kernels.common import resolve_interpret

            def _kernel(x_ref, o_ref, *, g, bits):
                half = float(2 ** (bits - 1))
                if g > 1:
                    o_ref[...] = x_ref[...] * half

            def op(x, g, bits, interpret=None):
                return pl.pallas_call(
                    functools.partial(_kernel, g=g, bits=bits),
                    out_shape=x,
                    interpret=resolve_interpret(interpret),
                )(x)
            """
        )
        assert out == []

    def test_pallas_kernel_ref_taint_still_scored(self):
        out = lint(
            """
            from jax.experimental import pallas as pl

            from repro.kernels.common import resolve_interpret

            def _kernel(x_ref, o_ref):
                v = x_ref[0, 0]
                if v > 0:
                    o_ref[...] = v

            def op(x, interpret=None):
                return pl.pallas_call(
                    _kernel,
                    out_shape=x,
                    interpret=resolve_interpret(interpret),
                )(x)
            """
        )
        assert codes(out) == ["SC001"]


# ---------------------------------------------------------------------------
# suppressions / baseline / runner
# ---------------------------------------------------------------------------


SC001_SRC = """
import jax

@jax.jit
def f(x):
    if x > 0:
        return x
    return -x
"""


class TestSuppression:
    def test_same_line_disable(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                if x > 0:  # slimcheck: disable=SC001
                    return x
                return -x
            """
        )
        assert out == []

    def test_preceding_comment_line_disable(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                # slimcheck: disable=SC001
                if x > 0:
                    return x
                return -x
            """
        )
        assert out == []

    def test_wrong_code_does_not_suppress(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                if x > 0:  # slimcheck: disable=SC002
                    return x
                return -x
            """
        )
        assert codes(out) == ["SC001"]

    def test_bare_disable_suppresses_all(self):
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                if x > 0:  # slimcheck: disable
                    return x
                return -x
            """
        )
        assert out == []

    def test_preceding_code_line_comment_does_not_leak_down(self):
        # a disable on a *code* line only covers that line, not the next
        out = lint(
            """
            import jax

            @jax.jit
            def f(x):
                y = x * 2  # slimcheck: disable=SC001
                if y > 0:
                    return y
                return -y
            """
        )
        assert codes(out) == ["SC001"]


class TestBaseline:
    def test_roundtrip_and_budget(self, tmp_path):
        findings = lint(SC001_SRC, path="pkg/mod.py")
        assert len(findings) == 1
        base = Baseline.from_findings(findings)
        p = tmp_path / "base.json"
        base.dump(str(p))
        loaded = Baseline.load(str(p))
        assert loaded.new_findings(findings) == []

    def test_new_finding_beyond_budget(self):
        findings = lint(SC001_SRC, path="pkg/mod.py")
        base = Baseline.from_findings(findings)
        # the same finding twice: one covered, one new
        assert len(base.new_findings(findings * 2)) == 1

    def test_line_number_changes_do_not_churn(self):
        base = Baseline.from_findings(lint(SC001_SRC, path="pkg/mod.py"))
        shifted = "\n\n\n" + SC001_SRC  # same code, different line numbers
        moved = lint(shifted, path="pkg/mod.py")
        assert base.new_findings(moved) == []

    def test_stale_entries_reported(self):
        base = Baseline.from_findings(lint(SC001_SRC, path="pkg/mod.py"))
        assert base.stale_entries([]) == [
            ("SC001", "pkg/mod.py", "if x > 0:")
        ]


class TestRunner:
    def test_rule_registry_complete(self):
        assert sorted(RULES) == ["SC001", "SC002", "SC003", "SC004", "SC005"]

    def test_rule_subset_selection(self):
        out = lint(SC001_SRC, rules=["SC002"])
        assert out == []

    def test_file_model_windows_paths_normalized(self):
        m = FileModel("src\\repro\\serving\\x.py", "x = 1\n")
        assert m.path == "src/repro/serving/x.py"

    def test_syntax_error_collected_not_raised(self, tmp_path):
        bad = tmp_path / "bad.py"
        bad.write_text("def f(:\n")
        res = lint_paths([str(tmp_path)])
        assert res.findings == [] and len(res.errors) == 1


# ---------------------------------------------------------------------------
# the gate: src/ lints clean against the checked-in baseline
# ---------------------------------------------------------------------------


class TestRepoClean:
    def test_src_lints_clean(self):
        res = lint_paths([os.path.join(REPO, "src")])
        base_path = os.path.join(REPO, "slimcheck-baseline.json")
        base = Baseline.load(base_path)
        new = base.new_findings(res.findings)
        assert new == [], "\n".join(f.render() for f in new)
        assert res.errors == []
        # the engine's declared sync sites stay annotated, not silently
        # dropped: the suppression count is the contract
        assert res.suppressed >= 5

    def test_cli_module_entrypoint(self, tmp_path):
        import subprocess
        import sys

        clean = tmp_path / "ok.py"
        clean.write_text("x = 1\n")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        proc = subprocess.run(
            [
                sys.executable, "-m", "repro.analysis",
                str(clean), "--no-baseline",
            ],
            capture_output=True, text=True, env=env, cwd=str(tmp_path),
        )
        assert proc.returncode == 0, proc.stdout + proc.stderr

        seeded = tmp_path / "bug.py"
        seeded.write_text(SC001_SRC)
        proc = subprocess.run(
            [
                sys.executable, "-m", "repro.analysis",
                str(seeded), "--no-baseline", "--stats",
            ],
            capture_output=True, text=True, env=env, cwd=str(tmp_path),
        )
        assert proc.returncode == 1
        assert "SC001" in proc.stdout
