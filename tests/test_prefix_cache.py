"""Prefix-cache serving: refcounted copy-on-write block sharing.

Covers the three layers of the feature:

* ``BlockAllocator`` with ``prefix_cache=True`` — chained content hashes,
  refcount bookkeeping, evictable (refcount-0 cached) blocks, clock-hand
  eviction, CoW accounting for fully cached prompts, and the extended
  ``check`` invariants after every mutation.
* ``ContinuousEngine(prefix_cache=True)`` — shared-prefix outputs are
  token-exact against the cold-prefill paged engine (dense, SLiM-compressed
  and kv_quant, greedy), including the fully-cached CoW admission.
* Capacity: at equal pool memory, sharing admits strictly more concurrent
  requests than the cold paged engine.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch
from repro.models import transformer as T
from repro.models.compress import compress_model
from repro.serving import (
    BlockAllocator,
    ContinuousEngine,
    Request,
    Scheduler,
    chain_hashes,
    synthetic_trace,
)
from repro.serving.block_pool import NULL_BLOCK, RESERVED_BLOCKS, TRASH_BLOCK

MAX_LEN = 48
BS = 8


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _shared_trace(cfg, n=5, prefix=16, seed=3):
    return synthetic_trace(
        n, rate=100.0, vocab_size=cfg.vocab_size,
        prompt_len=(prefix + 2, prefix + 8), max_new_tokens=(3, 6), seed=seed,
        shared_prefix_len=prefix,
    )


# ---------------------------------------------------------------------------
# Allocator: refcounts, hash index, CoW, eviction
# ---------------------------------------------------------------------------

class TestPrefixAllocator:
    def test_chain_hashes_identify_prefixes_not_blocks(self):
        # same tokens in block 1 but different block 0 -> different chains
        a = chain_hashes([1] * 8 + [2] * 8, 8)
        b = chain_hashes([3] * 8 + [2] * 8, 8)
        assert len(a) == len(b) == 2
        assert a[1] != b[1]
        # partial tail block contributes no hash
        assert len(chain_hashes([1] * 11, 8)) == 1

    def test_share_increments_refcount_and_release_decrements(self):
        a = BlockAllocator(n_blocks=12, block_size=BS, prefix_cache=True)
        prompt = list(range(20))  # 2 full blocks + partial
        i0 = a.admit_request(0, prompt, n_pos=24)
        assert i0.cached_len == 0
        a.check()
        i1 = a.admit_request(1, prompt, n_pos=24)
        assert i1.cached_len == 16 and i1.cached_blocks == 2
        shared = a.blocks_of(0)[:2]
        assert a.blocks_of(1)[:2] == shared  # same physical blocks
        assert a._ref[shared[0]] == 2
        a.check()
        a.release(0)
        assert a._ref[shared[0]] == 1  # decrement, not free
        a.check()
        a.release(1)
        # hashed blocks become evictable (content kept), not free
        assert a.n_evictable() == 2
        a.check()

    def test_evictable_blocks_revive_on_match(self):
        a = BlockAllocator(n_blocks=12, block_size=BS, prefix_cache=True)
        prompt = list(range(12))  # 1 full block + a partial (never shared)
        a.admit_request(0, prompt, n_pos=20)
        first = a.blocks_of(0)
        a.release(0)
        info = a.admit_request(1, prompt, n_pos=20)
        # the full block revives from the evictable pool, same physical id
        assert a.blocks_of(1)[0] == first[0]
        assert info.cached_len == 8
        a.check()

    def test_cow_fully_cached_prompt(self):
        a = BlockAllocator(n_blocks=12, block_size=BS, prefix_cache=True)
        prompt = list(range(16))  # exactly 2 blocks
        a.admit_request(0, prompt, n_pos=20)
        blocks0 = a.blocks_of(0)
        info = a.admit_request(1, prompt, n_pos=20)
        assert info.cached_len == 15  # last token recomputed
        assert info.cow_src == blocks0[1]
        assert info.cow_dst == a.blocks_of(1)[1]
        assert info.cow_dst != info.cow_src  # fresh copy, refcount 1
        assert a.blocks_of(1)[0] == blocks0[0]  # head still shared
        assert a._ref[info.cow_dst] == 1
        a.check()

    def test_clock_hand_eviction_when_admission_would_defer(self):
        # 6 usable blocks; request A caches 2 full blocks then releases;
        # an unrelated request needing 6 must evict them rather than defer
        a = BlockAllocator(n_blocks=8, block_size=BS, prefix_cache=True)
        a.admit_request(0, list(range(16)), n_pos=16)
        a.release(0)
        assert a.n_evictable() == 2 and len(a._free) == 4
        info = a.admit_request(1, [99] * 8, n_pos=48)  # needs all 6
        assert info is not None and info.cached_len == 0
        assert a.n_evictable() == 0  # cached blocks were dropped
        a.check()
        a.release(1)
        # and the dropped prefix no longer matches
        assert a.match_prefix(list(range(16))) == []

    def test_defers_when_eviction_cannot_cover(self):
        a = BlockAllocator(n_blocks=8, block_size=BS, prefix_cache=True)
        a.admit_request(0, list(range(16)), n_pos=40)  # pins 5 of 6
        assert a.admit_request(1, [7] * 8, n_pos=16) is None  # 2 > 1 free
        a.check()  # failed admission mutates nothing
        assert a.blocks_of(1) == []

    def test_matched_evictable_blocks_not_double_counted(self):
        # slot 1 revives the 2 evictable blocks as its prefix; they must
        # not also be counted as reclaimable capacity for its fresh need
        a = BlockAllocator(n_blocks=8, block_size=BS, prefix_cache=True)
        a.admit_request(0, list(range(16)), n_pos=16)
        a.release(0)  # 4 free + 2 evictable
        info = a.admit_request(1, list(range(16)) + [9] * 8, n_pos=48)
        # needs 6 total, 2 cached -> 4 fresh = exactly the free list
        assert info is not None and info.cached_blocks == 2
        a.check()
        assert a.admit_request(2, [5] * 8, n_pos=8) is None  # pool truly full

    def test_scheduler_charges_only_uncached_remainder(self):
        alloc = BlockAllocator(n_blocks=10, block_size=BS, prefix_cache=True)
        s = Scheduler(n_slots=4, max_len=48, allocator=alloc)
        prompt = list(range(16))
        # each request needs 3 blocks cold (16 + 8); after the first, the
        # 2-block prefix rides shared so each extra costs 1+1 (CoW) blocks
        for i in range(3):
            s.submit(Request(i, list(prompt), arrival=0.0, max_new_tokens=8))
        admitted = s.admit(now=0.0)
        assert len(admitted) == 3  # cold would need 9 > 8 usable blocks
        alloc.check()

    def test_non_prefix_mode_unchanged(self):
        a = BlockAllocator(n_blocks=8, block_size=BS)
        assert not a.prefix_cache
        got = a.allocate(0, 6)
        assert NULL_BLOCK not in got and TRASH_BLOCK not in got
        a.check()
        a.release(0)
        assert a.available() == 6
        a.check()

    def test_index_cap_keeps_a_matchable_prefix(self):
        # cap of 2: registering a 4-block chain keeps the 2-entry *head*
        # — the cap drops chain tails (and skips entries whose prefix is
        # gone), so everything that survives in the index stays matchable
        a = BlockAllocator(
            n_blocks=16, block_size=BS, prefix_cache=True,
            prefix_cache_max_entries=2,
        )
        toks = list(range(4 * BS))
        a.admit_request(0, toks, n_pos=len(toks))
        assert len(a.match_prefix(toks)) == 2
        assert a.index_evictions == 1  # block 2's entry; block 3's skipped
        a.check()
        a.release(0)
        # only the 2 still-indexed blocks demote to cached; the unindexed
        # ones went straight back to the free list
        assert a.n_evictable() == 2
        a.check()

    def test_index_cap_frees_evictable_blocks_on_overflow(self):
        # an entry evicted from the index while its block is refcount-0
        # cached must move that block to the free list immediately
        a = BlockAllocator(
            n_blocks=16, block_size=BS, prefix_cache=True,
            prefix_cache_max_entries=3,
        )
        a.admit_request(0, list(range(2 * BS)), n_pos=2 * BS)
        a.release(0)  # 2 cached entries, refcount 0
        free_before = a.available() - a.n_evictable()
        a.admit_request(1, [7] * (2 * BS), n_pos=2 * BS)  # 2 new entries
        assert a.index_evictions == 1  # cap 3: the oldest chain lost its tail
        assert a.n_evictable() == 1
        assert a.available() - a.n_evictable() == free_before - 2 + 1
        # the surviving entry is the old chain's head — still matchable
        assert len(a.match_prefix(list(range(2 * BS)))) == 1
        a.check()

    def test_index_ttl_expires_old_entries(self):
        a = BlockAllocator(n_blocks=16, block_size=BS, prefix_cache=True)
        a.tick(0.0)
        a.admit_request(0, list(range(2 * BS)), n_pos=2 * BS)
        a.release(0)  # 2 cached entries stamped at t=0
        a.tick(5.0)
        a.admit_request(1, [7] * (2 * BS), n_pos=2 * BS)  # stamped at t=5
        assert a.expire_index(4.0) == 2  # the t=0 entries age out
        assert a.index_evictions == 2
        assert a.n_evictable() == 0  # expired refcount-0 blocks went free
        assert a.match_prefix(list(range(2 * BS))) == []
        assert len(a.match_prefix([7] * (2 * BS))) == 2  # fresh survive
        assert a.expire_index(4.0) == 0  # idempotent below the cutoff
        a.check()

    def test_deep_chain_ttl_drop_is_iterative(self):
        # a 2000-entry chain is one parent->child line; the TTL cascade
        # must not recurse chain-length deep (RecursionError at ~1000)
        a = BlockAllocator(n_blocks=2100, block_size=1, prefix_cache=True)
        a.admit_request(0, list(range(2000)), n_pos=2000)
        a.tick(1.0)
        a.release(0)
        assert a.expire_index(2.0) == 2000
        assert a.n_evictable() == 0
        a.check()

    def test_finished_release_registers_chain(self):
        # release_cached (the finished-request path) demotes the full
        # blocks of prompt + output to cached entries a follow-up turn can
        # match — same machinery as preemption demotion
        a = BlockAllocator(n_blocks=16, block_size=BS, prefix_cache=True)
        prompt = list(range(BS + 4))
        a.admit_request(0, prompt, n_pos=len(prompt) + BS)
        output = [3] * (BS - 4 + 2)  # chain = 2 full blocks + 2 spare
        chain = prompt + output
        a.release_cached(0, chain)
        assert len(a.match_prefix(chain)) == 2
        assert a.n_evictable() == 2
        a.check()
        info = a.admit_request(1, chain + [9] * 4, n_pos=len(chain) + 8)
        assert info is not None and info.cached_len == 2 * BS
        a.check()


# ---------------------------------------------------------------------------
# Engine: token-exactness vs cold prefill
# ---------------------------------------------------------------------------

def _run_pair(params, cfg, trace_fn, **kw):
    cold = ContinuousEngine(
        params, cfg, block_size=BS, max_len=MAX_LEN, **kw
    ).run(trace_fn(), sync_every=2)
    warm = ContinuousEngine(
        params, cfg, block_size=BS, max_len=MAX_LEN, prefix_cache=True, **kw
    ).run(trace_fn(), sync_every=2)
    return cold, warm


class TestPrefixEngine:
    def test_shared_prefix_token_exact_dense(self, model):
        cfg, params = model
        cold, warm = _run_pair(params, cfg, lambda: _shared_trace(cfg), n_slots=2)
        assert warm.outputs == cold.outputs
        assert warm.metrics["prefix_cache_hit_rate"] > 0.0
        assert warm.metrics["cached_prompt_tokens"] > 0
        assert cold.metrics["prefix_cache_hit_rate"] == 0.0

    def test_shared_prefix_token_exact_slim(self, model):
        cfg, params = model
        dcfg = SyntheticLMConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0
        )
        calib = calibration_batch(dcfg, n_samples=4)
        cp, _ = compress_model(
            params, cfg, calib,
            CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
        )
        cold, warm = _run_pair(cp, cfg, lambda: _shared_trace(cfg, n=4), n_slots=2)
        assert warm.outputs == cold.outputs
        assert warm.metrics["prefix_cache_hit_rate"] > 0.0

    def test_shared_prefix_token_exact_kv_quant(self, model):
        cfg, params = model
        qcfg = dataclasses.replace(cfg, kv_quant=True)
        cold, warm = _run_pair(
            params, qcfg, lambda: _shared_trace(qcfg, n=4), n_slots=2
        )
        assert warm.outputs == cold.outputs
        assert warm.metrics["prefix_cache_hit_rate"] > 0.0

    def test_fully_cached_prompt_cow_exact(self, model):
        """Identical block-aligned prompts: the second admission shares
        every block, CoW-copies the last, and recomputes only the final
        token — outputs must match running each prompt cold."""
        cfg, params = model
        p = [int(t) for t in
             jax.random.randint(jax.random.PRNGKey(9), (16,), 0, cfg.vocab_size)]
        def mk():
            return [
                Request(rid=i, prompt=list(p), arrival=0.0, max_new_tokens=4)
                for i in range(2)
            ]
        cold, warm = _run_pair(params, cfg, mk, n_slots=1)
        assert warm.outputs == cold.outputs
        # plen - 1 tokens rode the cache (the last is recomputed for logits)
        assert warm.metrics["cached_prompt_tokens"] == len(p) - 1
        # bucketing pads the 1-token recompute to 4: the offset prefill then
        # starts mid-block (position plen-1 inside the CoW'd block)
        warm_b = ContinuousEngine(
            params, cfg, n_slots=1, max_len=MAX_LEN, block_size=BS,
            prefill_bucket=4, prefix_cache=True,
        ).run(mk(), sync_every=2)
        assert warm_b.outputs == cold.outputs

    def test_bucketed_suffix_prefill_exact(self, model):
        """Prefill bucketing pads the *suffix* on a hit; pad writes are
        masked to the null block, so outputs stay exact."""
        cfg, params = model
        cold = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=BS,
            prefill_bucket=4,
        ).run(_shared_trace(cfg), sync_every=2)
        warm = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=BS,
            prefill_bucket=4, prefix_cache=True,
        ).run(_shared_trace(cfg), sync_every=2)
        assert warm.outputs == cold.outputs
        assert warm.metrics["prefix_cache_hit_rate"] > 0.0

    def test_sharing_lifts_admission_at_equal_memory(self, model):
        """The capacity win: 4 requests sharing a 16-token prefix fit a
        pool that can only run 2 cold — peak concurrency is strictly
        higher with sharing at identical pool size."""
        cfg, params = model
        prefix = [int(t) for t in
                  jax.random.randint(jax.random.PRNGKey(3), (16,), 0, cfg.vocab_size)]
        def mk():
            rng = jax.random.split(jax.random.PRNGKey(7), 4)
            return [
                Request(
                    rid=i,
                    prompt=list(prefix) + [
                        int(t) for t in jax.random.randint(rng[i], (4,), 0, cfg.vocab_size)
                    ],
                    arrival=0.0,
                    max_new_tokens=4,
                )
                for i in range(4)
            ]
        # each request cold: ceil(24/8) = 3 blocks; pool of 8 usable runs 2
        # concurrently. Shared: 2 prefix blocks + 4 x 1 unique = 6 blocks.
        kw = dict(n_slots=4, max_len=MAX_LEN, block_size=BS,
                  n_blocks=8 + RESERVED_BLOCKS)
        cold = ContinuousEngine(params, cfg, **kw).run(mk(), sync_every=1)
        warm = ContinuousEngine(params, cfg, prefix_cache=True, **kw).run(
            mk(), sync_every=1
        )
        assert warm.outputs == cold.outputs
        assert (
            warm.metrics["peak_concurrency"] > cold.metrics["peak_concurrency"]
        )
        assert warm.metrics["peak_concurrency"] == 4
        assert warm.metrics["peak_blocks_in_use"] <= 8

    def test_multi_turn_follow_up_rides_finished_blocks(self, model):
        """A *finished* request's full blocks — generated tokens included —
        demote to cached entries at release, so a follow-up turn whose
        prompt extends prompt + output re-prefills only its new suffix,
        token-exactly against a cold run."""
        cfg, params = model
        prompt = [
            int(t) for t in
            jax.random.randint(jax.random.PRNGKey(11), (12,), 0, cfg.vocab_size)
        ]
        kw = dict(n_slots=2, max_len=MAX_LEN, block_size=BS,
                  prefix_cache=True, check_invariants=True)
        eng = ContinuousEngine(params, cfg, **kw)
        # solo turn 1 learns the output *and* warms the prefill/decode jit
        # caches on this engine, so in the replay below turn 1 finishes
        # (and releases its blocks) well before the follow-up arrives
        first = eng.run(
            [Request(0, list(prompt), arrival=0.0, max_new_tokens=8)],
            sync_every=2,
        )
        out1 = first.requests[0].output
        follow = prompt + out1 + [5, 9]
        # replay turn 1 plus the follow-up through one engine run; the
        # follow-up arrives only after turn 1 has finished and released
        reqs = [
            Request(0, list(prompt), arrival=0.0, max_new_tokens=8),
            Request(1, list(follow), arrival=0.6, max_new_tokens=6),
        ]
        res = eng.run(reqs, sync_every=2)
        m = res.metrics
        chain_blocks = (len(prompt) + len(out1)) // BS
        assert m["prefix_hits"] >= 1
        assert m["cached_prompt_tokens"] >= chain_blocks * BS
        cold = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=BS
        ).run(
            [Request(7, list(follow), arrival=0.0, max_new_tokens=6)],
            sync_every=2,
        )
        assert res.requests[1].output == cold.requests[0].output
        assert res.requests[0].output == out1

    def test_rejects_non_attention_arch(self):
        base = get_config("jamba-v0.1-52b", reduced=True)
        from repro.models.config import LayerSpec
        cfg = dataclasses.replace(
            base, name="hybrid-prefix-test", n_layers=2,
            period=(LayerSpec("ssm"), LayerSpec("attn")),
        )
        assert not T.supports_prefix_cache(cfg)
        with pytest.raises(ValueError):
            ContinuousEngine(
                {}, cfg, n_slots=1, max_len=32, block_size=8, prefix_cache=True
            )

    def test_rejects_contiguous_cache(self, model):
        cfg, _ = model
        with pytest.raises(ValueError):
            ContinuousEngine(
                {}, cfg, n_slots=1, max_len=MAX_LEN, prefix_cache=True
            )
