"""Prefix-cache serving: refcounted copy-on-write block sharing.

Covers the three layers of the feature:

* ``BlockAllocator`` with ``prefix_cache=True`` — chained content hashes,
  refcount bookkeeping, evictable (refcount-0 cached) blocks, clock-hand
  eviction, CoW accounting for fully cached prompts, and the extended
  ``check`` invariants after every mutation.
* ``ContinuousEngine(prefix_cache=True)`` — shared-prefix outputs are
  token-exact against the cold-prefill paged engine (dense, SLiM-compressed
  and kv_quant, greedy), including the fully-cached CoW admission.
* Capacity: at equal pool memory, sharing admits strictly more concurrent
  requests than the cold paged engine.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch
from repro.models import transformer as T
from repro.models.compress import compress_model
from repro.serving import (
    BlockAllocator,
    ContinuousEngine,
    Request,
    Scheduler,
    chain_hashes,
    synthetic_trace,
)
from repro.serving.block_pool import NULL_BLOCK, RESERVED_BLOCKS, TRASH_BLOCK

MAX_LEN = 48
BS = 8


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _shared_trace(cfg, n=5, prefix=16, seed=3):
    return synthetic_trace(
        n, rate=100.0, vocab_size=cfg.vocab_size,
        prompt_len=(prefix + 2, prefix + 8), max_new_tokens=(3, 6), seed=seed,
        shared_prefix_len=prefix,
    )


# ---------------------------------------------------------------------------
# Allocator: refcounts, hash index, CoW, eviction
# ---------------------------------------------------------------------------

class TestPrefixAllocator:
    def test_chain_hashes_identify_prefixes_not_blocks(self):
        # same tokens in block 1 but different block 0 -> different chains
        a = chain_hashes([1] * 8 + [2] * 8, 8)
        b = chain_hashes([3] * 8 + [2] * 8, 8)
        assert len(a) == len(b) == 2
        assert a[1] != b[1]
        # partial tail block contributes no hash
        assert len(chain_hashes([1] * 11, 8)) == 1

    def test_share_increments_refcount_and_release_decrements(self):
        a = BlockAllocator(n_blocks=12, block_size=BS, prefix_cache=True)
        prompt = list(range(20))  # 2 full blocks + partial
        i0 = a.admit_request(0, prompt, n_pos=24)
        assert i0.cached_len == 0
        a.check()
        i1 = a.admit_request(1, prompt, n_pos=24)
        assert i1.cached_len == 16 and i1.cached_blocks == 2
        shared = a.blocks_of(0)[:2]
        assert a.blocks_of(1)[:2] == shared  # same physical blocks
        assert a._ref[shared[0]] == 2
        a.check()
        a.release(0)
        assert a._ref[shared[0]] == 1  # decrement, not free
        a.check()
        a.release(1)
        # hashed blocks become evictable (content kept), not free
        assert a.n_evictable() == 2
        a.check()

    def test_evictable_blocks_revive_on_match(self):
        a = BlockAllocator(n_blocks=12, block_size=BS, prefix_cache=True)
        prompt = list(range(12))  # 1 full block + a partial (never shared)
        a.admit_request(0, prompt, n_pos=20)
        first = a.blocks_of(0)
        a.release(0)
        info = a.admit_request(1, prompt, n_pos=20)
        # the full block revives from the evictable pool, same physical id
        assert a.blocks_of(1)[0] == first[0]
        assert info.cached_len == 8
        a.check()

    def test_cow_fully_cached_prompt(self):
        a = BlockAllocator(n_blocks=12, block_size=BS, prefix_cache=True)
        prompt = list(range(16))  # exactly 2 blocks
        a.admit_request(0, prompt, n_pos=20)
        blocks0 = a.blocks_of(0)
        info = a.admit_request(1, prompt, n_pos=20)
        assert info.cached_len == 15  # last token recomputed
        assert info.cow_src == blocks0[1]
        assert info.cow_dst == a.blocks_of(1)[1]
        assert info.cow_dst != info.cow_src  # fresh copy, refcount 1
        assert a.blocks_of(1)[0] == blocks0[0]  # head still shared
        assert a._ref[info.cow_dst] == 1
        a.check()

    def test_clock_hand_eviction_when_admission_would_defer(self):
        # 6 usable blocks; request A caches 2 full blocks then releases;
        # an unrelated request needing 6 must evict them rather than defer
        a = BlockAllocator(n_blocks=8, block_size=BS, prefix_cache=True)
        a.admit_request(0, list(range(16)), n_pos=16)
        a.release(0)
        assert a.n_evictable() == 2 and len(a._free) == 4
        info = a.admit_request(1, [99] * 8, n_pos=48)  # needs all 6
        assert info is not None and info.cached_len == 0
        assert a.n_evictable() == 0  # cached blocks were dropped
        a.check()
        a.release(1)
        # and the dropped prefix no longer matches
        assert a.match_prefix(list(range(16))) == []

    def test_defers_when_eviction_cannot_cover(self):
        a = BlockAllocator(n_blocks=8, block_size=BS, prefix_cache=True)
        a.admit_request(0, list(range(16)), n_pos=40)  # pins 5 of 6
        assert a.admit_request(1, [7] * 8, n_pos=16) is None  # 2 > 1 free
        a.check()  # failed admission mutates nothing
        assert a.blocks_of(1) == []

    def test_matched_evictable_blocks_not_double_counted(self):
        # slot 1 revives the 2 evictable blocks as its prefix; they must
        # not also be counted as reclaimable capacity for its fresh need
        a = BlockAllocator(n_blocks=8, block_size=BS, prefix_cache=True)
        a.admit_request(0, list(range(16)), n_pos=16)
        a.release(0)  # 4 free + 2 evictable
        info = a.admit_request(1, list(range(16)) + [9] * 8, n_pos=48)
        # needs 6 total, 2 cached -> 4 fresh = exactly the free list
        assert info is not None and info.cached_blocks == 2
        a.check()
        assert a.admit_request(2, [5] * 8, n_pos=8) is None  # pool truly full

    def test_scheduler_charges_only_uncached_remainder(self):
        alloc = BlockAllocator(n_blocks=10, block_size=BS, prefix_cache=True)
        s = Scheduler(n_slots=4, max_len=48, allocator=alloc)
        prompt = list(range(16))
        # each request needs 3 blocks cold (16 + 8); after the first, the
        # 2-block prefix rides shared so each extra costs 1+1 (CoW) blocks
        for i in range(3):
            s.submit(Request(i, list(prompt), arrival=0.0, max_new_tokens=8))
        admitted = s.admit(now=0.0)
        assert len(admitted) == 3  # cold would need 9 > 8 usable blocks
        alloc.check()

    def test_non_prefix_mode_unchanged(self):
        a = BlockAllocator(n_blocks=8, block_size=BS)
        assert not a.prefix_cache
        got = a.allocate(0, 6)
        assert NULL_BLOCK not in got and TRASH_BLOCK not in got
        a.check()
        a.release(0)
        assert a.available() == 6
        a.check()


# ---------------------------------------------------------------------------
# Engine: token-exactness vs cold prefill
# ---------------------------------------------------------------------------

def _run_pair(params, cfg, trace_fn, **kw):
    cold = ContinuousEngine(
        params, cfg, block_size=BS, max_len=MAX_LEN, **kw
    ).run(trace_fn(), sync_every=2)
    warm = ContinuousEngine(
        params, cfg, block_size=BS, max_len=MAX_LEN, prefix_cache=True, **kw
    ).run(trace_fn(), sync_every=2)
    return cold, warm


class TestPrefixEngine:
    def test_shared_prefix_token_exact_dense(self, model):
        cfg, params = model
        cold, warm = _run_pair(params, cfg, lambda: _shared_trace(cfg), n_slots=2)
        assert warm.outputs == cold.outputs
        assert warm.metrics["prefix_cache_hit_rate"] > 0.0
        assert warm.metrics["cached_prompt_tokens"] > 0
        assert cold.metrics["prefix_cache_hit_rate"] == 0.0

    def test_shared_prefix_token_exact_slim(self, model):
        cfg, params = model
        dcfg = SyntheticLMConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0
        )
        calib = calibration_batch(dcfg, n_samples=4)
        cp, _ = compress_model(
            params, cfg, calib,
            CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
        )
        cold, warm = _run_pair(cp, cfg, lambda: _shared_trace(cfg, n=4), n_slots=2)
        assert warm.outputs == cold.outputs
        assert warm.metrics["prefix_cache_hit_rate"] > 0.0

    def test_shared_prefix_token_exact_kv_quant(self, model):
        cfg, params = model
        qcfg = dataclasses.replace(cfg, kv_quant=True)
        cold, warm = _run_pair(
            params, qcfg, lambda: _shared_trace(qcfg, n=4), n_slots=2
        )
        assert warm.outputs == cold.outputs
        assert warm.metrics["prefix_cache_hit_rate"] > 0.0

    def test_fully_cached_prompt_cow_exact(self, model):
        """Identical block-aligned prompts: the second admission shares
        every block, CoW-copies the last, and recomputes only the final
        token — outputs must match running each prompt cold."""
        cfg, params = model
        p = [int(t) for t in
             jax.random.randint(jax.random.PRNGKey(9), (16,), 0, cfg.vocab_size)]
        def mk():
            return [
                Request(rid=i, prompt=list(p), arrival=0.0, max_new_tokens=4)
                for i in range(2)
            ]
        cold, warm = _run_pair(params, cfg, mk, n_slots=1)
        assert warm.outputs == cold.outputs
        # plen - 1 tokens rode the cache (the last is recomputed for logits)
        assert warm.metrics["cached_prompt_tokens"] == len(p) - 1
        # bucketing pads the 1-token recompute to 4: the offset prefill then
        # starts mid-block (position plen-1 inside the CoW'd block)
        warm_b = ContinuousEngine(
            params, cfg, n_slots=1, max_len=MAX_LEN, block_size=BS,
            prefill_bucket=4, prefix_cache=True,
        ).run(mk(), sync_every=2)
        assert warm_b.outputs == cold.outputs

    def test_bucketed_suffix_prefill_exact(self, model):
        """Prefill bucketing pads the *suffix* on a hit; pad writes are
        masked to the null block, so outputs stay exact."""
        cfg, params = model
        cold = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=BS,
            prefill_bucket=4,
        ).run(_shared_trace(cfg), sync_every=2)
        warm = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=BS,
            prefill_bucket=4, prefix_cache=True,
        ).run(_shared_trace(cfg), sync_every=2)
        assert warm.outputs == cold.outputs
        assert warm.metrics["prefix_cache_hit_rate"] > 0.0

    def test_sharing_lifts_admission_at_equal_memory(self, model):
        """The capacity win: 4 requests sharing a 16-token prefix fit a
        pool that can only run 2 cold — peak concurrency is strictly
        higher with sharing at identical pool size."""
        cfg, params = model
        prefix = [int(t) for t in
                  jax.random.randint(jax.random.PRNGKey(3), (16,), 0, cfg.vocab_size)]
        def mk():
            rng = jax.random.split(jax.random.PRNGKey(7), 4)
            return [
                Request(
                    rid=i,
                    prompt=list(prefix) + [
                        int(t) for t in jax.random.randint(rng[i], (4,), 0, cfg.vocab_size)
                    ],
                    arrival=0.0,
                    max_new_tokens=4,
                )
                for i in range(4)
            ]
        # each request cold: ceil(24/8) = 3 blocks; pool of 8 usable runs 2
        # concurrently. Shared: 2 prefix blocks + 4 x 1 unique = 6 blocks.
        kw = dict(n_slots=4, max_len=MAX_LEN, block_size=BS,
                  n_blocks=8 + RESERVED_BLOCKS)
        cold = ContinuousEngine(params, cfg, **kw).run(mk(), sync_every=1)
        warm = ContinuousEngine(params, cfg, prefix_cache=True, **kw).run(
            mk(), sync_every=1
        )
        assert warm.outputs == cold.outputs
        assert (
            warm.metrics["peak_concurrency"] > cold.metrics["peak_concurrency"]
        )
        assert warm.metrics["peak_concurrency"] == 4
        assert warm.metrics["peak_blocks_in_use"] <= 8

    def test_rejects_non_attention_arch(self):
        base = get_config("jamba-v0.1-52b", reduced=True)
        from repro.models.config import LayerSpec
        cfg = dataclasses.replace(
            base, name="hybrid-prefix-test", n_layers=2,
            period=(LayerSpec("ssm"), LayerSpec("attn")),
        )
        assert not T.supports_prefix_cache(cfg)
        with pytest.raises(ValueError):
            ContinuousEngine(
                {}, cfg, n_slots=1, max_len=32, block_size=8, prefix_cache=True
            )

    def test_rejects_contiguous_cache(self, model):
        cfg, _ = model
        with pytest.raises(ValueError):
            ContinuousEngine(
                {}, cfg, n_slots=1, max_len=MAX_LEN, prefix_cache=True
            )
