"""Tensor-parallel decode inside one engine replica.

The in-process tests cover the mesh builders' skip-path contract (this
test process sees the real single CPU device, so ``tp=2`` must raise
``MeshUnavailable``, not crash deep in the engine). The subprocess tests
set ``--xla_force_host_platform_device_count`` before jax initializes and
pin the tentpole acceptance: tp=2 sharded decode is token-exact against
tp=1 and compiles each jitted phase exactly once (zero steady-state
retraces), including under the router (2 replicas x 2-way TP).
"""
import os
import subprocess
import sys

import jax
import pytest

from repro.launch.mesh import (
    MeshUnavailable,
    make_production_mesh,
    make_serving_mesh,
    make_test_mesh,
)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


class TestMeshBuilders:
    def test_serving_mesh_shape(self):
        if len(jax.devices()) < 1:
            pytest.skip("no devices")
        mesh = make_serving_mesh(1)
        assert mesh.axis_names == ("data", "model")
        assert mesh.devices.shape == (1, 1)

    def test_serving_mesh_unavailable_is_skippable(self):
        if len(jax.devices()) >= 2:
            pytest.skip("host has multiple devices")
        with pytest.raises(MeshUnavailable, match="found 1") as e:
            make_serving_mesh(2)
        # the error both skips cleanly (RuntimeError subclass for old
        # callers) and tells the operator how to get the devices
        assert isinstance(e.value, RuntimeError)
        assert "host_platform_device_count" in str(e.value)

    def test_serving_mesh_rejects_bad_tp(self):
        with pytest.raises(ValueError, match="tp"):
            make_serving_mesh(0)

    def test_production_mesh_accepts_shape(self):
        # the shape parameter (not just multi_pod) picks the topology;
        # on this single-device host any >1 shape raises MeshUnavailable
        with pytest.raises(MeshUnavailable):
            make_production_mesh((16, 16))
        with pytest.raises(ValueError, match="not both"):
            make_production_mesh((2, 2), multi_pod=True)
        with pytest.raises(ValueError, match="axes"):
            make_production_mesh((2, 2, 2, 2))

    def test_test_mesh_unavailable(self):
        if len(jax.devices()) >= 4:
            pytest.skip("host has multiple devices")
        with pytest.raises(MeshUnavailable):
            make_test_mesh((2, 2))

    def test_engine_tp_without_devices_raises_mesh_unavailable(self):
        """EngineConfig(parallel.tp=2) on a 1-device host must fail with
        the skippable error before any replica state exists."""
        if len(jax.devices()) >= 2:
            pytest.skip("host has multiple devices")
        import dataclasses

        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.serving import ContinuousEngine, EngineConfig, ParallelConfig

        cfg = get_config("slim-tiny")
        cfg = dataclasses.replace(cfg, n_layers=1, d_model=64, d_ff=128,
                                  vocab_size=128)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(MeshUnavailable):
            ContinuousEngine(
                params, cfg,
                EngineConfig(max_len=32, parallel=ParallelConfig(tp=2)),
            )


@pytest.mark.slow
def test_tp2_decode_token_exact_and_retrace_free():
    code = """
import dataclasses, jax
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (
    ContinuousEngine, EngineConfig, PagingConfig, ParallelConfig,
    synthetic_trace,
)

cfg = get_config('slim-tiny')
cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
params = T.init_params(cfg, jax.random.PRNGKey(0))
base = EngineConfig(
    n_slots=2, max_len=48, prefill_bucket=8, check_retrace=True,
    paging=PagingConfig(block_size=8),
)
def trace():
    return synthetic_trace(5, 1e6, cfg.vocab_size, prompt_len=(8, 12),
                           max_new_tokens=(4, 8), seed=3)
want = ContinuousEngine(params, cfg, base).run(
    trace(), sync_every=4, max_new_cap=8).outputs
tp = ContinuousEngine(
    params, cfg, dataclasses.replace(base, parallel=ParallelConfig(tp=2)))
first = tp.run(trace(), sync_every=4, max_new_cap=8)
assert first.outputs == want, 'tp=2 diverged from tp=1'
again = tp.run(trace(), sync_every=4, max_new_cap=8)
assert again.outputs == want
m = again.metrics
assert m['jit_retraces'] == 0, m
assert m['jit_compiles_decode'] == 0, m  # warm run: everything cached
print('TP-EXACT-OK')
"""
    r = _run(code, devices=2)
    assert "TP-EXACT-OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_router_over_tp_replicas():
    """2 data-parallel replicas, each 2-way tensor-parallel: the full
    engine-as-replica topology stays token-exact and retrace-free."""
    code = """
import dataclasses, jax
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (
    ContinuousEngine, EngineConfig, PagingConfig, ParallelConfig, Router,
    synthetic_trace,
)

cfg = get_config('slim-tiny')
cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
params = T.init_params(cfg, jax.random.PRNGKey(0))
config = EngineConfig(
    n_slots=2, max_len=48, prefill_bucket=8, check_retrace=True,
    paging=PagingConfig(block_size=8), parallel=ParallelConfig(tp=2),
)
def trace():
    return synthetic_trace(6, 1e6, cfg.vocab_size, prompt_len=(8, 12),
                           max_new_tokens=(4, 8), seed=3)
flat = dataclasses.replace(config, parallel=ParallelConfig(tp=1))
want = ContinuousEngine(params, cfg, flat).run(
    trace(), sync_every=4, max_new_cap=8).outputs
router = Router(params, cfg, config, n_replicas=2)
res = router.run(trace(), sync_every=4, max_new_cap=8)
assert res.outputs == want, 'routed tp=2 fleet diverged'
assert res.metrics['jit_retraces'] == 0
assert res.metrics['router_shed'] == 0
print('ROUTER-TP-OK')
"""
    r = _run(code, devices=4)
    assert "ROUTER-TP-OK" in r.stdout, r.stdout + r.stderr
