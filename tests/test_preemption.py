"""Preemption + on-demand block allocation for the paged serving engine.

Covers the three layers of the feature:

* ``BlockAllocator.extend`` / ``preempt`` — on-demand growth and victim
  release keep the refcount/free-list/hash-index invariants (``check()``)
  and, with the prefix cache, demote a victim's full blocks to cached
  entries its resume can match.
* ``Scheduler`` on-demand admission — prompt-only charging with a
  decode-reserve watermark, youngest-first victim selection, and
  re-queueing that keeps the preempted request ahead of later arrivals.
* ``ContinuousEngine(preemption=True)`` — forced evictions under a tight
  pool are token-exact against solo static runs (dense, SLiM-compressed,
  kv_quant, and with the prefix cache on), the re-queued request always
  completes (no starvation), and the state machine lands on FINISHED.
"""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch
from repro.models import transformer as T
from repro.models.compress import compress_model
from repro.serving import (
    BlockAllocator,
    ContinuousEngine,
    Request,
    RequestState,
    Scheduler,
    ServeEngine,
)
from repro.serving.block_pool import RESERVED_BLOCKS

MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, n, plen, max_new, seed=7):
    prompts = jax.random.randint(jax.random.PRNGKey(seed), (n, plen), 0, cfg.vocab_size)
    return [
        Request(
            rid=i,
            prompt=[int(t) for t in prompts[i]],
            arrival=0.0,
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _assert_solo_exact(params, cfg, result):
    static = ServeEngine(params, cfg, max_len=MAX_LEN)
    for r in result.requests:
        solo = static.generate(
            {"tokens": jnp.asarray([r.prompt], jnp.int32)},
            max_new_tokens=r.max_new_tokens,
        )
        assert solo.tokens[0] == r.output, f"rid {r.rid} diverged"


# ---------------------------------------------------------------------------
# BlockAllocator: extend / preempt (host-only)
# ---------------------------------------------------------------------------


class TestAllocatorOnDemand:
    def test_extend_appends_in_order(self):
        a = BlockAllocator(n_blocks=10, block_size=8)
        first = a.allocate(0, 2)
        more = a.extend(0, 3)
        assert a.blocks_of(0) == first + more
        assert a.available() == 3
        a.check()

    def test_extend_shortfall_returns_none_without_mutation(self):
        a = BlockAllocator(n_blocks=8, block_size=8)  # 6 usable
        a.allocate(0, 4)
        before = (a.available(), a.blocks_of(0))
        assert a.extend(0, 3) is None
        assert (a.available(), a.blocks_of(0)) == before
        a.check()

    def test_extend_unknown_slot_raises(self):
        a = BlockAllocator(n_blocks=8, block_size=8)
        with pytest.raises(RuntimeError):
            a.extend(0, 1)

    def test_extend_zero_is_noop(self):
        a = BlockAllocator(n_blocks=8, block_size=8)
        a.allocate(0, 1)
        assert a.extend(0, 0) == []
        a.check()

    def test_extend_evicts_cached_blocks(self):
        a = BlockAllocator(n_blocks=8, block_size=4, prefix_cache=True)  # 6 usable
        toks = list(range(16))  # 4 full blocks
        a.admit_request(0, toks, 16)
        a.release(0)  # 4 hashed blocks demote to evictable
        assert a.n_evictable() == 4
        a.allocate(1, 2)
        got = a.extend(1, 3)  # only 0 free: must evict cached blocks
        assert got is not None and len(got) == 3
        assert a.n_evictable() == 1
        a.check()

    def test_preempt_without_prefix_cache_frees(self):
        a = BlockAllocator(n_blocks=8, block_size=8)
        a.allocate(0, 3)
        a.preempt(0, tokens=[1] * 20)
        assert a.available() == 6
        assert a.blocks_of(0) == []
        a.check()

    def test_preempt_registers_generated_blocks(self):
        """A victim's full blocks — generated tokens included — demote to
        refcount-0 cached entries that its own resume can match."""
        a = BlockAllocator(n_blocks=12, block_size=4, prefix_cache=True)
        prompt = list(range(100, 108))  # 2 full blocks
        a.admit_request(0, prompt, 8)
        a.extend(0, 2)  # decode grew into 2 more blocks
        generated = [7, 8, 9, 10, 11]  # 13 tokens total -> 3 full blocks
        served = prompt + generated
        a.preempt(0, tokens=served)
        assert a.n_evictable() == 3  # prompt's 2 + one generated block
        assert len(a.match_prefix(served)) == 3
        a.check()
        # the resume admission rides the cached chain
        info = a.admit_request(1, served, len(served) + 4)
        assert info is not None and info.cached_len == 12
        a.check()

    def test_admit_request_reserve_defers(self):
        a = BlockAllocator(n_blocks=8, block_size=4, prefix_cache=True)  # 6 usable
        toks = list(range(16))
        assert a.admit_request(0, toks, 16, reserve=3) is None  # 4 + 3 > 6
        a.check()
        assert a.admit_request(0, toks, 16, reserve=2) is not None
        a.check()


# ---------------------------------------------------------------------------
# Scheduler: watermark admission, victim selection, requeue fairness
# ---------------------------------------------------------------------------


class TestSchedulerOnDemand:
    def _sched(self, n_blocks=10, block_size=8, n_slots=2, reserve=0):
        alloc = BlockAllocator(n_blocks=n_blocks, block_size=block_size)
        return (
            Scheduler(
                n_slots=n_slots,
                max_len=64,
                allocator=alloc,
                on_demand=True,
                decode_reserve=reserve,
            ),
            alloc,
        )

    def test_on_demand_admits_where_worst_case_defers(self):
        # two requests of worst-case 4 blocks each in an 8-usable-block
        # pool: worst-case charging admits both only because 8 == 2 * 4;
        # shrink to 6 usable and worst-case runs one at a time while
        # on-demand (prompt = 1 block each) runs both concurrently.
        alloc_wc = BlockAllocator(n_blocks=8, block_size=8)
        wc = Scheduler(n_slots=2, max_len=64, allocator=alloc_wc)
        od, _ = self._sched(n_blocks=8)
        for s in (wc, od):
            for i in range(2):
                s.submit(Request(i, [1] * 8, arrival=0.0, max_new_tokens=24))
        assert len(wc.admit(0.0)) == 1  # 4 + 4 > 6 usable
        assert len(od.admit(0.0)) == 2  # 1 + 1 blocks charged
        od.allocator.check()

    def test_decode_reserve_defers_second_admission(self):
        sched, alloc = self._sched(n_blocks=6, reserve=3)  # 4 usable
        for i in range(2):
            sched.submit(Request(i, [1] * 8, arrival=0.0, max_new_tokens=8))
        admitted = sched.admit(0.0)
        # first admission ignores the reserve (idle pool); the second
        # would leave less than reserve headroom and defers
        assert [slot for slot, _ in admitted] == [0]
        assert alloc.available() == 3

    def test_reserve_waived_on_idle_pool(self):
        sched, _ = self._sched(n_blocks=6, reserve=4)  # 4 usable
        # prompt+budget = 26 positions = 4 blocks: exactly the pool, so a
        # reserve larger than the leftover headroom must not block the
        # lone admission (nothing is running that could grow into it)
        sched.submit(Request(0, [1] * 25, arrival=0.0, max_new_tokens=1))
        assert len(sched.admit(0.0)) == 1

    def test_pick_victim_is_youngest(self):
        sched, _ = self._sched()
        for i in range(2):
            sched.submit(Request(i, [1] * 8, arrival=0.0, max_new_tokens=8))
        sched.admit(0.0)
        assert sched.pick_victim() == 1
        sched.release(1)
        assert sched.pick_victim() == 0

    def test_cost_victim_frees_most_blocks_per_token_discarded(self):
        # 3 slots: slot 1 owns many blocks but has generated little (best
        # ratio), slot 2 owns few with lots of work done (worst). Cost
        # policy picks slot 1; youngest would have picked slot 2.
        alloc = BlockAllocator(n_blocks=16, block_size=4)
        sched = Scheduler(
            n_slots=3, max_len=64, allocator=alloc, on_demand=True, victim_policy="cost"
        )
        for i, plen in [(0, 8), (1, 24), (2, 4)]:
            sched.submit(Request(i, [1] * plen, arrival=0.0, max_new_tokens=8))
        sched.admit(0.0)  # blocks owned: slot0=2, slot1=6, slot2=1
        gen = {0: 4, 1: 1, 2: 7}
        assert sched.pick_victim(gen) == 1
        # missing generated counts read as zero work discarded
        assert sched.pick_victim({}) == 1
        alloc.check()

    def test_cost_victim_exempts_oldest(self):
        # the oldest-admitted slot never gets evicted while anything else
        # runs — the no-starvation guarantee youngest-first gives for free
        alloc = BlockAllocator(n_blocks=16, block_size=4)
        sched = Scheduler(
            n_slots=2, max_len=64, allocator=alloc, on_demand=True, victim_policy="cost"
        )
        # the oldest admission has the best cost score (most blocks, no
        # generated tokens) but must still be exempt
        for i, plen in [(0, 24), (1, 4)]:
            sched.submit(Request(i, [1] * plen, arrival=0.0, max_new_tokens=8))
        sched.admit(0.0)
        assert sched.pick_victim({0: 0, 1: 9}) == 1
        sched.release(1)
        # a lone running slot is its own victim of last resort
        assert sched.pick_victim({0: 0}) == 0

    def test_unknown_victim_policy_rejected(self):
        with pytest.raises(ValueError):
            Scheduler(n_slots=1, max_len=32, victim_policy="oldest")

    def test_preempt_folds_tokens_and_requeues_ahead(self):
        sched, alloc = self._sched()
        r0 = Request(0, [1] * 8, arrival=0.0, max_new_tokens=8)
        sched.submit(r0)
        sched.admit(0.0)
        late = Request(1, [2] * 8, arrival=0.0, max_new_tokens=8)
        sched.submit(late)
        sched.preempt(0, [5, 6, 7])
        assert r0.state is RequestState.QUEUED
        assert r0.generated == [5, 6, 7]
        assert r0.n_preemptions == 1
        assert r0.serving_prompt == [1] * 8 + [5, 6, 7]
        assert r0.remaining_new_tokens == 5
        assert alloc.blocks_of(0) == []
        # r0 resumes before the queued late arrival despite being pushed
        # after it (original arrival time keeps FIFO fairness)
        nxt = sched.admit(0.0)
        assert nxt[0][1].rid == 0
        alloc.check()

    def test_submit_resets_prior_run_state(self):
        # pool of 4 usable blocks: the request fits fresh (4 blocks) but
        # its stale serving_prompt from a previous run would need 7 — the
        # reset must happen before the capacity check so replaying a
        # trace through a second engine never spuriously rejects
        sched, _ = self._sched(n_blocks=6)
        r = Request(0, [1] * 20, arrival=0.0, max_new_tokens=10)
        r.generated = [9] * 30
        r.n_preemptions = 3
        r.output = [1, 2]
        r.state = RequestState.FINISHED
        sched.submit(r)
        assert r.state is RequestState.QUEUED
        assert r.generated == [] and r.output is None and r.n_preemptions == 0


# ---------------------------------------------------------------------------
# Engine end-to-end: forced eviction, token-exact resume, no starvation
# ---------------------------------------------------------------------------


class TestPreemptionEngine:
    def _tight_engine(self, params, cfg, **kw):
        # worst case per request is 5 blocks of 4 (prompt 10 + budget 10);
        # 2 slots want 10 but only 8 usable blocks exist, so on-demand
        # admission must preempt to finish the trace
        kw.setdefault("n_slots", 2)
        kw.setdefault("max_len", MAX_LEN)
        kw.setdefault("block_size", 4)
        kw.setdefault("n_blocks", 10)
        kw.setdefault("preemption", True)
        kw.setdefault("decode_reserve", 0)
        kw.setdefault("check_invariants", True)
        return ContinuousEngine(params, cfg, **kw)

    @pytest.mark.parametrize("kv_quant", [False, True])
    def test_forced_eviction_token_exact_dense(self, model, kv_quant):
        cfg, params = model
        if kv_quant:
            cfg = dataclasses.replace(cfg, kv_quant=True)
        reqs = _requests(cfg, 5, plen=10, max_new=10)
        res = self._tight_engine(params, cfg).run(reqs, sync_every=2)
        assert res.metrics["completed"] == 5
        assert res.metrics["preemptions"] >= 1
        _assert_solo_exact(params, cfg, res)

    def test_forced_eviction_token_exact_compressed(self, model):
        cfg, params = model
        dcfg = SyntheticLMConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0
        )
        calib = calibration_batch(dcfg, n_samples=4)
        cp, _ = compress_model(
            params,
            cfg,
            calib,
            CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
        )
        reqs = _requests(cfg, 4, plen=10, max_new=10)
        res = self._tight_engine(cp, cfg).run(reqs, sync_every=2)
        assert res.metrics["completed"] == 4
        assert res.metrics["preemptions"] >= 1
        _assert_solo_exact(cp, cfg, res)

    @pytest.mark.parametrize("victim_policy", ["youngest", "cost"])
    def test_no_starvation_and_state_machine(self, model, victim_policy):
        """Every request — the evicted ones included — completes under
        either victim policy (cost exempts the oldest admission, so it
        can't starve anyone either), and a preempted request's resume
        picks up exactly where it stopped."""
        cfg, params = model
        reqs = _requests(cfg, 5, plen=10, max_new=10)
        res = self._tight_engine(params, cfg, victim_policy=victim_policy).run(
            reqs, sync_every=2
        )
        evicted = [r for r in res.requests if r.n_preemptions > 0]
        assert evicted, "the tight pool should have forced an eviction"
        for r in res.requests:
            assert r.state is RequestState.FINISHED
            assert len(r.output) == r.max_new_tokens
        assert res.metrics["preempted_requests"] == float(len(evicted))

    def test_prefix_cache_resume_hits(self, model):
        """With the prefix cache on, a victim's blocks demote to cached
        entries, so its resume re-prefill is (partly) a cache hit. The
        prompts are unique, so cross-request sharing contributes nothing:
        hits land in the resume_* counters, and the sharing hit rate
        stays clean (zero)."""
        cfg, params = model
        reqs = _requests(cfg, 4, plen=16, max_new=8)
        eng = self._tight_engine(params, cfg, n_blocks=12, prefix_cache=True)
        res = eng.run(reqs, sync_every=2)
        m = res.metrics
        assert m["completed"] == 4
        assert m["preemptions"] >= 1
        assert m["resume_prefix_hits"] >= 1
        assert m["resume_cached_tokens"] > 0
        # unique prompts: resume re-matching must not inflate the
        # cross-request sharing metrics
        assert m["prefix_cache_hit_rate"] == 0.0
        _assert_solo_exact(params, cfg, res)

    def test_on_demand_lifts_concurrency_at_equal_pool(self, model):
        """The point of on-demand charging: short prompts with long
        budgets admit together instead of serializing on the worst
        case."""
        cfg, params = model
        pool = 8 + RESERVED_BLOCKS
        kw = dict(n_slots=4, max_len=MAX_LEN, block_size=4, n_blocks=pool)
        wc = ContinuousEngine(params, cfg, preemption=False, **kw)
        wres = wc.run(_requests(cfg, 4, plen=4, max_new=12), sync_every=2)
        od = ContinuousEngine(
            params, cfg, preemption=True, decode_reserve=0, check_invariants=True, **kw
        )
        ores = od.run(_requests(cfg, 4, plen=4, max_new=12), sync_every=2)
        assert ores.outputs == wres.outputs  # same tokens either way
        # worst case charges 4 blocks each -> 2 concurrent; on-demand
        # charges 1 block each -> all 4 admit together
        assert wres.metrics["peak_concurrency"] == 2
        assert ores.metrics["peak_concurrency"] == 4

    def test_worst_case_mode_never_preempts(self, model):
        cfg, params = model
        eng = ContinuousEngine(
            params,
            cfg,
            n_slots=2,
            max_len=MAX_LEN,
            block_size=4,
            n_blocks=10,
            preemption=False,
        )
        res = eng.run(_requests(cfg, 4, plen=10, max_new=10), sync_every=2)
        assert res.metrics["preemptions"] == 0
        assert res.metrics["completed"] == 4

    def test_preemption_requires_paged_cache(self, model):
        cfg, params = model
        with pytest.raises(ValueError):
            ContinuousEngine(params, cfg, n_slots=2, max_len=MAX_LEN, preemption=True)


class TestPreemptionRetrace:
    def test_preemption_resume_never_retraces_decode(self, model):
        """Forced eviction and resume churn the prefill shapes (resume
        prompts grow by the emitted tokens) but the decode step must stay
        on its single trace — and prefill must only ever compile on new
        shapes, never re-trace a seen one."""
        cfg, params = model
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=4,
            n_blocks=10, preemption=True, decode_reserve=0,
            check_invariants=True, check_retrace=True,
        )
        reqs = _requests(cfg, 5, plen=10, max_new=10)
        res = eng.run(reqs, sync_every=2, max_new_cap=10)
        assert res.metrics["completed"] == 5
        assert res.metrics["preemptions"] >= 1
        assert res.metrics["jit_compiles_decode"] == 1.0
        assert res.metrics["jit_retraces"] == 0.0
        _assert_solo_exact(params, cfg, res)

    def test_bucketed_resume_zero_post_warmup_compiles(self, model):
        """With prefill bucketing the resume shapes collapse onto the
        bucket grid: a warm engine re-serving the same trace (evictions
        included) performs zero compiles across every hot path."""
        cfg, params = model
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=4,
            n_blocks=10, preemption=True, decode_reserve=0,
            prefill_bucket=4, check_retrace=True,
        )
        eng.run(_requests(cfg, 5, plen=10, max_new=10), sync_every=2,
                max_new_cap=10)
        eng.retrace_guard.freeze()
        warm = eng.run(
            _requests(cfg, 5, plen=10, max_new=10), sync_every=2,
            max_new_cap=10,
        )
        assert warm.metrics["completed"] == 5
        assert warm.metrics["jit_compiles_decode"] == 0.0
        assert warm.metrics["jit_compiles_prefill"] == 0.0
        assert warm.metrics["jit_retraces"] == 0.0
