"""Per-assigned-architecture smoke tests (reduced configs): one forward +
one train step on CPU, asserting output shapes and no NaNs. The FULL configs
are exercised only by the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import transformer as T
from repro.optim import adamw, apply_updates


def _batch(cfg, b=2, s=32, key=0):
    k = jax.random.PRNGKey(key)
    toks = jax.random.randint(k, (b, s + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.input_mode == "embeddings":
        batch["embeds"] = (
            jax.random.normal(jax.random.fold_in(k, 1), (b, s, cfg.d_model)) * 0.1
        )
    if cfg.vision_tokens:
        batch["vision_embeds"] = (
            jax.random.normal(
                jax.random.fold_in(k, 2), (b, cfg.vision_tokens, cfg.d_model)
            )
            * 0.1
        )
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)

    # forward: loss finite
    loss = T.train_loss(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"

    # one train step: params update, still finite
    init, update = adamw(1e-3)
    state = init(params)

    @jax.jit
    def step(p, s, b):
        l, g = jax.value_and_grad(lambda pp: T.train_loss(pp, cfg, b))(p)
        u, s = update(g, s, p)
        return apply_updates(p, u), s, l

    p2, state, l1 = step(params, state, batch)
    _, _, l2 = step(p2, state, batch)
    assert bool(jnp.isfinite(l2)), f"{arch}: NaN after update"
    # loss moves (the step did something)
    assert float(l1) != float(l2)


@pytest.mark.parametrize(
    "arch", [a for a in ASSIGNED if get_config(a, reduced=True).input_mode == "tokens"]
)
def test_arch_prefill_decode(arch):
    cfg = get_config(arch, reduced=True)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, s=16)
    pb = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache = T.prefill(params, cfg, pb, max_len=24)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = T.decode_step(
        params, cfg, cache, nxt, jnp.full((2,), 16, jnp.int32)
    )
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
