"""Quantizer unit + property tests (paper §3.1 baselines + SLiM-Quant)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (
    absmax_quantize,
    group_absmax_quantize,
    optq_quantize,
    slim_quantize,
)
from repro.core.quantizers import dequantize, reconstruction_error, output_error
from repro.core.slim_quant import (
    estimate_error_curve,
    slim_quant_alpha,
    weight_abs_histogram,
)


def _w(seed=0, shape=(256, 128), scale=0.05, outliers=0):
    rng = np.random.default_rng(seed)
    w = rng.normal(0, scale, shape)
    if outliers:
        idx = rng.integers(0, w.size, outliers)
        w.flat[idx] *= 20.0
    return jnp.asarray(w, jnp.float32)


class TestAbsMax:
    def test_range(self):
        w = _w()
        qt = absmax_quantize(w, bits=4)
        assert qt.codes.dtype == jnp.int8
        assert int(jnp.max(jnp.abs(qt.codes))) <= 7

    def test_exact_on_grid(self):
        # weights already on the quantization grid reconstruct exactly
        codes = jnp.arange(-7, 8, dtype=jnp.float32)
        w = (codes / 8.0).reshape(-1, 1)
        qt = absmax_quantize(w, bits=4)
        # absmax alpha = 7/8; grid differs — just check max error bound
        # symmetric level clamp (+-7 of 8) costs up to one step at the edge
        err = jnp.max(jnp.abs(dequantize(qt) - w))
        assert float(err) <= float(qt.scale) / 8 + 1e-6

    @given(st.integers(3, 7))
    @settings(max_examples=6, deadline=None)
    def test_bits_monotone(self, bits):
        # near-monotone: the symmetric edge clamp adds a small non-monotone
        # component at very low bit widths; int8 storage caps bits at 8
        w = _w(3)
        e = float(reconstruction_error(w, absmax_quantize(w, bits=bits)))
        e_hi = float(reconstruction_error(w, absmax_quantize(w, bits=bits + 1)))
        assert e_hi <= e * 1.1

    def test_bits_over_8_rejected(self):
        with pytest.raises(ValueError):
            absmax_quantize(_w(1), bits=9)


class TestGroupAbsMax:
    def test_matches_absmax_when_one_group(self):
        w = _w(1, (128, 64))
        qg = group_absmax_quantize(w, bits=4, group_size=128)
        qa = absmax_quantize(w, bits=4)
        # per-column groups are finer than per-tensor: error must be <=
        eg = float(reconstruction_error(w, qg))
        ea = float(reconstruction_error(w, qa))
        assert eg <= ea * 1.001

    def test_group_error_beats_per_tensor_with_outliers(self):
        w = _w(2, (256, 128), outliers=30)
        eg = float(reconstruction_error(w, group_absmax_quantize(w, 4, 64)))
        ea = float(reconstruction_error(w, absmax_quantize(w, 4)))
        assert eg < ea


class TestSlimQuant:
    def test_beats_absmax(self):
        # the paper's core quantization claim: the Alg.1 scale has lower
        # reconstruction error than AbsMax on bell-shaped weights
        for seed in range(5):
            w = _w(seed)
            es = float(reconstruction_error(w, slim_quantize(w, bits=4)))
            ea = float(reconstruction_error(w, absmax_quantize(w, bits=4)))
            assert es <= ea * 1.001, f"seed {seed}: slim {es} > absmax {ea}"

    def test_beats_absmax_heavy_tails(self):
        w = _w(7, outliers=50)
        es = float(reconstruction_error(w, slim_quantize(w, bits=4)))
        ea = float(reconstruction_error(w, absmax_quantize(w, bits=4)))
        assert es < ea  # clipping outliers must win

    def test_multigrid_matches_exhaustive(self):
        """Alg. 1 multigrid finds (near-)the exhaustive-grid optimum."""
        w = _w(11)
        p, centers = weight_abs_histogram(w, 512)
        alpha_mg = float(slim_quant_alpha(p, centers, bits=4))
        dense_grid = jnp.linspace(1e-4, float(jnp.max(jnp.abs(w))), 2048)
        errs = estimate_error_curve(w, dense_grid, bits=4, n_bins=512)
        e_mg = float(estimate_error_curve(w, jnp.array([alpha_mg]), 4, 512)[0])
        e_ex = float(errs[int(jnp.argmin(errs))])
        assert e_mg <= e_ex * 1.05

    @given(st.integers(0, 1000))
    @settings(max_examples=10, deadline=None)
    def test_scale_positive_bounded(self, seed):
        w = _w(seed, (64, 32))
        qt = slim_quantize(w, bits=4)
        assert 0 < float(qt.scale) <= float(jnp.max(jnp.abs(w))) + 1e-6


class TestOPTQ:
    def test_beats_rtn_on_output_error(self):
        # OPTQ's whole point: Hessian-aware updates lower ||X(W_hat-W)||^2
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(0, 1, (512, 64)), jnp.float32)
        # correlated inputs make the OBS update matter
        mix = jnp.asarray(rng.normal(0, 1, (64, 64)) * 0.3 + np.eye(64), jnp.float32)
        x = x @ mix
        w = jnp.asarray(rng.normal(0, 0.1, (64, 32)), jnp.float32)
        h = x.T @ x
        q_optq = optq_quantize(w, h, bits=3, group_size=0)
        q_rtn = absmax_quantize(w, bits=3)
        e_optq = float(output_error(x, w, q_optq))
        e_rtn = float(output_error(x, w, q_rtn))
        assert e_optq < e_rtn

    def test_group_shapes(self):
        w = _w(1, (128, 32))
        x = _w(2, (64, 128), scale=1.0)
        qt = optq_quantize(w, x.T @ x, bits=4, group_size=64)
        assert qt.scale.shape == (2, 1, 32)
        assert qt.codes.shape == (128, 32)
