"""RetraceGuard: compile-count invariants on real jitted functions and
deterministic violation paths via a fake compile-count probe."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.retrace import (
    RetraceError,
    RetraceGuard,
    arg_signature,
    compile_count,
)


class FakeJit:
    """Callable with a controllable ``_cache_size`` — lets the tests drive
    the guard through compile/no-compile transitions deterministically."""

    def __init__(self):
        self.n = 0
        self.compile_next = True

    def _cache_size(self):
        return self.n

    def __call__(self, *args, **kwargs):
        if self.compile_next:
            self.n += 1
        return args


class TestSignatures:
    def test_leaf_kinds(self):
        x = jnp.zeros((2, 3), jnp.float32)
        sig = arg_signature((x, np.zeros(4), 3, None, "s"), {"k": 1.0})
        kinds = [leaf[0] for leaf in sig[1]]
        # strings are pytree leaves of kind "obj"; python scalars "py"
        assert kinds == ["jax", "np", "py", "obj", "py"]
        assert ("none",) not in sig[1]  # None is a treedef node, not a leaf

    def test_shape_change_changes_signature(self):
        a = arg_signature((jnp.zeros((2, 3)),), None)
        b = arg_signature((jnp.zeros((2, 4)),), None)
        assert a != b

    def test_dtype_and_weak_type_in_signature(self):
        a = arg_signature((jnp.int32(1),), None)
        b = arg_signature((1,), None)  # python int: not even a jax leaf
        assert a != b

    def test_compile_count_on_jitted_fn(self):
        f = jax.jit(lambda x: x * 2)
        base = compile_count(f) or 0
        f(jnp.zeros((3,)))
        assert compile_count(f) == base + 1
        f(jnp.ones((3,)))  # same shape: cache hit
        assert compile_count(f) == base + 1
        f(jnp.zeros((4,)))  # new shape: recompile
        assert compile_count(f) == base + 2

    def test_compile_count_none_for_plain_callable(self):
        assert compile_count(lambda x: x) is None


class TestGuardHappyPath:
    def test_real_jit_steady_state(self):
        guard = RetraceGuard()
        step = guard.wrap("decode", jax.jit(lambda x: x + 1), max_sigs=1)
        for _ in range(4):
            step(jnp.zeros((2,)))
        assert guard.compiles() == {"decode": 1}
        assert guard.retraces() == 0
        guard.freeze()
        step(jnp.ones((2,)))  # warm signature: fine post-freeze
        assert guard.compiles() == {"decode": 1}

    def test_prefill_buckets_unbounded_sigs(self):
        guard = RetraceGuard()
        prefill = guard.wrap("prefill", jax.jit(lambda x: x.sum()))
        for n in (8, 16, 32):
            prefill(jnp.zeros((n,)))
        assert guard.compiles() == {"prefill": 3}
        assert len(guard.signatures("prefill")) == 3
        assert guard.retraces() == 0


class TestGuardViolations:
    def test_shape_keyed_retrace_over_budget(self):
        guard = RetraceGuard()
        step = guard.wrap("decode", jax.jit(lambda x: x * 2), max_sigs=1)
        step(jnp.zeros((2, 3)))
        with pytest.raises(RetraceError, match="signature budget"):
            step(jnp.zeros((2, 4)))
        # the error names the offending leaf delta
        assert "(2, 3)" in guard.violations[0]
        assert "(2, 4)" in guard.violations[0]

    def test_post_freeze_compile_raises(self):
        guard = RetraceGuard()
        prefill = guard.wrap("prefill", jax.jit(lambda x: x.sum()))
        prefill(jnp.zeros((8,)))
        guard.freeze()
        with pytest.raises(RetraceError, match="post-warmup"):
            prefill(jnp.zeros((16,)))

    def test_recompile_on_seen_signature_raises(self):
        fake = FakeJit()
        guard = RetraceGuard()
        f = guard.wrap("decode", fake)
        fake.compile_next = True
        f(1)
        fake.compile_next = False
        f(1)  # cache hit
        fake.compile_next = True  # simulated eviction / unstable side input
        with pytest.raises(RetraceError, match="already-traced signature"):
            f(1)

    def test_strict_false_records_instead_of_raising(self):
        fake = FakeJit()
        guard = RetraceGuard(strict=False)
        f = guard.wrap("decode", fake)
        f(1)
        f(1)  # compile_next still True: recompile on the seen signature
        assert guard.retraces() == 1
        assert guard.compiles() == {"decode": 2}

    def test_plain_callable_degrades_to_bookkeeping(self):
        # no _cache_size: compiles can't be observed, nothing ever raises
        guard = RetraceGuard()
        f = guard.wrap("step", lambda x: x, max_sigs=1)
        f(jnp.zeros((2,)))
        f(jnp.zeros((3,)))
        assert guard.compiles() == {"step": 0}
        assert guard.retraces() == 0

    def test_context_manager_passthrough(self):
        with RetraceGuard() as guard:
            f = guard.wrap("g", jax.jit(lambda x: x))
            f(jnp.zeros((1,)))
        assert guard.compiles() == {"g": 1}
