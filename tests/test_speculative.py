"""Self-speculative decoding: SLiM's backbone as a free draft model.

Covers the four layers of the subsystem:

* ``skip_lora`` / ``skip_adapters`` — the backbone-only forward drops the
  low-rank correction (XLA and kernel paths agree) and is a no-op on
  dense weights.
* ``transformer.verify_step`` / ``verify_slot`` — one offset-prefill pass
  returns per-position logits that bit-match one-by-one decode steps
  against the same paged pool.
* ``sampling.speculative_accept`` / ``emit_speculative`` — greedy rows
  accept the longest matching prefix; temperature rows implement the
  classic rejection test whose committed-token distribution matches the
  target model's (verified empirically on a toy vocab); the bulk commit
  replays the one-token EOS/budget semantics.
* ``ContinuousEngine(speculative=K)`` — greedy outputs are token-exact
  against the non-speculative engine for dense, SLiM-compressed and
  kv_quant archs, including under forced preemption and composed with
  the prefix cache; a dense model's acceptance rate is exactly 1.0
  (drafting degenerates to lookahead).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.core.compressed import (
    SlimLinear,
    dequantize_base,
    slim_linear_apply,
)
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch
from repro.kernels.ops import slim_linear_op
from repro.models import transformer as T
from repro.serving import ContinuousEngine, Request, SpeculativeEngine
from repro.serving.sampling import (
    draw_tokens,
    emit_speculative,
    sample_and_emit,
    speculative_accept,
)

MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def compressed(model):
    cfg, params = model
    from repro.models.compress import compress_model

    dcfg = SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0
    )
    calib = calibration_batch(dcfg, n_samples=4)
    cp, _ = compress_model(
        params, cfg, calib,
        CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
    )
    return cp


def _slim_leaf(compressed) -> SlimLinear:
    """One unstacked SlimLinear (first period's wq) from the model tree."""
    sl = compressed["blocks"]["layer_0"]["wq"]
    assert isinstance(sl, SlimLinear)
    return jax.tree.map(lambda a: a[0], sl)


def _requests(cfg, n, plen, max_new, seed=7):
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed), (n, plen), 0, cfg.vocab_size
    )
    return [
        Request(rid=i, prompt=[int(t) for t in prompts[i]], arrival=0.0,
                max_new_tokens=max_new)
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# skip_lora: the backbone-only forward
# ---------------------------------------------------------------------------


class TestSkipLora:
    def test_skip_lora_is_backbone_only(self, compressed):
        sl = _slim_leaf(compressed)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, sl.d_in), jnp.float32)
        backbone = slim_linear_apply(sl, x, skip_lora=True)
        full = slim_linear_apply(sl, x)
        # the backbone is exactly x @ W_hat (with AWQ activation scaling)
        xs = x if sl.inv_act_scale is None else x * sl.inv_act_scale
        want = jnp.dot(xs, dequantize_base(sl))
        np.testing.assert_allclose(backbone, want, rtol=1e-6)
        # and the adapters really contribute: skipping them changes outputs
        assert not np.allclose(backbone, full)

    def test_kernel_fast_path_matches_xla_backbone(self, compressed):
        sl = _slim_leaf(compressed)
        x = jax.random.normal(jax.random.PRNGKey(2), (8, sl.d_in), jnp.float32)
        ker = slim_linear_op(sl, x, skip_lora=True)
        xla = slim_linear_apply(sl, x, skip_lora=True)
        np.testing.assert_allclose(ker, xla, rtol=1e-5, atol=1e-5)

    def test_skip_adapters_scope(self, compressed):
        from repro.models import layers as L

        sl = _slim_leaf(compressed)
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 4, sl.d_in))
        dense = jax.random.normal(jax.random.PRNGKey(4), (sl.d_in, 16))
        with L.skip_adapters():
            in_scope = L.linear(sl, x)
            dense_in = L.linear(dense, x)
        # SlimLinear loses its correction inside the scope...
        np.testing.assert_allclose(
            in_scope, slim_linear_apply(
                sl, x.reshape(-1, sl.d_in), skip_lora=True
            ).reshape(in_scope.shape).astype(in_scope.dtype), rtol=1e-5,
        )
        assert not np.allclose(in_scope, L.linear(sl, x))
        # ...dense weights are untouched, and the scope restores cleanly
        np.testing.assert_array_equal(dense_in, L.linear(dense, x))


# ---------------------------------------------------------------------------
# verify_step / verify_slot: per-position logits == one-by-one decode
# ---------------------------------------------------------------------------


class TestVerify:
    def _paged_setup(self, cfg, params, plen=10, bs=4):
        from repro.serving.block_pool import TRASH_BLOCK

        n_blocks = 16
        cache = T.init_cache(cfg, 2, MAX_LEN, bs, n_blocks)
        table = np.full((2, MAX_LEN // bs), TRASH_BLOCK, np.int32)
        table[0, : MAX_LEN // bs] = np.arange(2, 2 + MAX_LEN // bs)
        table = jnp.asarray(table)
        toks = jax.random.randint(jax.random.PRNGKey(5), (1, plen), 0, cfg.vocab_size)
        logits, cache = T.prefill_slot(
            params, cfg, cache, {"tokens": toks}, 0, MAX_LEN,
            block_table=table,
        )
        return cache, table, logits

    @pytest.mark.parametrize("kv_quant", [False, True])
    def test_verify_matches_decode_steps(self, model, kv_quant):
        cfg, params = model
        if kv_quant:
            cfg = dataclasses.replace(cfg, kv_quant=True)
        plen, k = 10, 4
        cache, table, carry = self._paged_setup(cfg, params, plen)

        # reference: feed the greedy continuation one token at a time
        ref_logits, toks = [], []
        cur = int(jnp.argmax(carry[0]))
        c = cache
        for i in range(k):
            toks.append(cur)
            pos = jnp.asarray([plen + i, 0], jnp.int32)
            step = jnp.asarray([cur, 0], jnp.int32)[:, None]
            lg, c = T.decode_step(params, cfg, c, step, pos, block_table=table)
            ref_logits.append(lg[0])
            cur = int(jnp.argmax(lg[0]))

        # verify: score the whole window in one pass on a fresh cache
        cache2, table2, _ = self._paged_setup(cfg, params, plen)
        window = jnp.asarray([toks, [0] * k], jnp.int32)
        vlogits, cache2 = T.verify_step(
            params, cfg, cache2, window, jnp.asarray([plen, 0], jnp.int32),
            table2,
        )
        # the batched s=K einsums reassociate float reductions, so logits
        # agree to fp tolerance rather than bit-for-bit; what greedy
        # exactness needs — and what the engine end-to-end tests pin — is
        # that the *decisions* (argmax) agree at every window position
        for i in range(k):
            np.testing.assert_allclose(
                np.asarray(vlogits[0, i]), np.asarray(ref_logits[i]),
                rtol=2e-5, atol=2e-5,
                err_msg=f"window position {i} diverged from decode",
            )
            assert int(jnp.argmax(vlogits[0, i])) == int(
                jnp.argmax(ref_logits[i])
            )

    def test_verify_slot_matches_verify_step(self, model):
        cfg, params = model
        plen, k = 10, 3
        cache, table, carry = self._paged_setup(cfg, params, plen)
        toks = jax.random.randint(jax.random.PRNGKey(6), (1, k), 0, cfg.vocab_size)
        cache2, table2, _ = self._paged_setup(cfg, params, plen)
        batched, _ = T.verify_step(
            params, cfg, cache,
            jnp.concatenate([toks, jnp.zeros((1, k), jnp.int32)]),
            jnp.asarray([plen, 0], jnp.int32), table,
        )
        single, _ = T.verify_slot(
            params, cfg, cache2, {"tokens": toks}, 0, table2, plen
        )
        np.testing.assert_allclose(
            np.asarray(single[0]), np.asarray(batched[0]), rtol=2e-5, atol=2e-5
        )

    def test_rejects_non_attention_arch(self):
        base = get_config("jamba-v0.1-52b", reduced=True)
        from repro.models.config import LayerSpec

        cfg = dataclasses.replace(
            base, name="hybrid-spec-test", n_layers=2,
            period=(LayerSpec("ssm"), LayerSpec("attn")),
        )
        assert not T.supports_speculative(cfg)
        with pytest.raises(ValueError):
            ContinuousEngine(
                {}, cfg, n_slots=1, max_len=32, block_size=8, speculative=4
            )

    def test_rejects_contiguous_and_k1(self, model):
        cfg, _ = model
        with pytest.raises(ValueError):
            ContinuousEngine({}, cfg, n_slots=1, max_len=MAX_LEN, speculative=4)
        with pytest.raises(ValueError):
            ContinuousEngine(
                {}, cfg, n_slots=1, max_len=MAX_LEN, block_size=8,
                speculative=1,
            )


# ---------------------------------------------------------------------------
# Sampling: rejection acceptance + bulk emit semantics (property tests)
# ---------------------------------------------------------------------------


class TestSpeculativeSampling:
    def test_greedy_accepts_longest_matching_prefix(self):
        v, k = 8, 4
        key = jax.random.PRNGKey(0)
        tgt = jax.random.normal(key, (3, k, v))
        drf = jax.random.normal(jax.random.fold_in(key, 1), (3, k - 1, v))
        want = jnp.argmax(tgt, axis=-1)  # greedy target continuation
        fed = np.asarray(want)
        fed = np.concatenate([np.zeros((3, 1), np.int64), fed[:, :-1]], axis=1)
        # row 0: all proposals match; row 1: mismatch at window pos 2;
        # row 2: mismatch at the first proposal
        fed[1, 2] = (fed[1, 2] + 1) % v
        fed[2, 1] = (fed[2, 1] + 1) % v
        n_acc, carry, _ = speculative_accept(
            jnp.asarray(fed, jnp.int32), drf, tgt,
            jnp.zeros((3,)), jax.random.PRNGKey(7),
        )
        assert list(np.asarray(n_acc)) == [k, 2, 1]
        # the carry is the target distribution after the last accepted token
        np.testing.assert_array_equal(np.asarray(carry[0]), np.asarray(tgt[0, k - 1]))
        np.testing.assert_array_equal(np.asarray(carry[1]), np.asarray(tgt[1, 1]))
        np.testing.assert_array_equal(np.asarray(carry[2]), np.asarray(tgt[2, 0]))

    def test_rejection_sampler_matches_target_distribution(self):
        """The committed token at a drafted position — the proposal when
        accepted, else the next round's draw from the residual carry —
        must be distributed exactly like a draw from the target model."""
        v = 5
        key = jax.random.PRNGKey(42)
        tgt_logits = jnp.asarray([0.9, -0.3, 0.4, -1.2, 0.1], jnp.float32)
        drf_logits = jnp.asarray([-0.5, 0.8, -0.1, 0.3, -0.7], jnp.float32)
        temps = jnp.ones((1,), jnp.float32)
        tgt = jnp.tile(tgt_logits, (1, 2, 1))  # [B=1, K=2, V]
        drf = jnp.tile(drf_logits, (1, 1, 1))  # [B=1, K-1=1, V]
        counts = np.zeros(v)
        trials = 3000
        for _ in range(trials):
            key, k1, k2 = jax.random.split(key, 3)
            prop = draw_tokens(drf[:, 0], temps, k1)
            fed = jnp.stack([jnp.zeros((1,), jnp.int32), prop], axis=1)
            n_acc, carry, _ = speculative_accept(
                fed, drf, tgt, temps, k2
            )
            if int(n_acc[0]) == 2:
                tok = int(prop[0])
            else:  # rejected: the next round draws from the residual carry
                key, k3 = jax.random.split(key)
                tok = int(draw_tokens(carry, temps, k3)[0])
            counts[tok] += 1
        want = np.asarray(jax.nn.softmax(tgt_logits))
        got = counts / trials
        assert np.abs(got - want).sum() < 0.08, (got, want)

    def test_emit_speculative_stops_at_eos_and_budget(self):
        eos = 9
        fed = jnp.asarray(
            [
                [1, 2, 3, 4],  # all accepted, budget cuts after 2
                [5, eos, 6, 7],  # EOS at window pos 1: emit 1, finish
                [8, 1, 2, 3],  # rejection: only 2 accepted
                [4, 5, 6, 7],  # inactive row: nothing happens
            ],
            jnp.int32,
        )
        n_acc = jnp.asarray([4, 4, 2, 4], jnp.int32)
        buf = jnp.zeros((4, 6), jnp.int32)
        active = jnp.asarray([True, True, True, False])
        emitted = jnp.asarray([0, 0, 0, 0], jnp.int32)
        maxnew = jnp.asarray([2, 6, 6, 6], jnp.int32)
        buf, emitted, committed, still = emit_speculative(
            fed, n_acc, buf, active, emitted, maxnew, eos
        )
        assert list(np.asarray(emitted)) == [2, 1, 2, 0]
        assert list(np.asarray(committed)) == [2, 1, 2, 0]
        assert list(np.asarray(still)) == [False, False, True, False]
        assert list(np.asarray(buf[0, :2])) == [1, 2]
        assert list(np.asarray(buf[1, :1])) == [5]
        assert eos not in np.asarray(buf)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 7), st.integers(1, 6), st.floats(0.0, 2.0))
    def test_sample_and_emit_never_buffers_eos(self, eos, cap, temp):
        key = jax.random.PRNGKey(eos * 31 + cap)
        logits = jax.random.normal(key, (4, 8), jnp.float32) * 4
        buf = -jnp.ones((4, cap), jnp.int32)
        live = jnp.asarray([True, True, False, True])
        emitted = jnp.zeros((4,), jnp.int32)
        nxt, buf, emitted, hit, _ = sample_and_emit(
            logits, jnp.full((4,), temp), key, buf, live, emitted, eos
        )
        out = np.asarray(buf)
        assert eos not in out
        # EOS rows and dead rows emit nothing; others emit exactly once
        want = np.asarray(live & ~hit).astype(int)
        assert list(np.asarray(emitted)) == list(want)

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1000))
    def test_temp0_matches_argmax(self, seed):
        key = jax.random.PRNGKey(seed)
        logits = jax.random.normal(key, (3, 16), jnp.float32)
        toks = draw_tokens(logits, jnp.zeros((3,)), key)
        assert list(np.asarray(toks)) == list(np.asarray(jnp.argmax(logits, -1)))


# ---------------------------------------------------------------------------
# Engine end-to-end: token-exact vs the non-speculative engine
# ---------------------------------------------------------------------------


class TestSpeculativeEngine:
    def _run(self, params, cfg, speculative=0, reqs=None, **kw):
        kw.setdefault("n_slots", 2)
        kw.setdefault("max_len", MAX_LEN)
        kw.setdefault("block_size", 4)
        kw.setdefault("check_invariants", True)
        eng = ContinuousEngine(params, cfg, speculative=speculative, **kw)
        return eng.run(reqs or _requests(cfg, 4, plen=10, max_new=10),
                       sync_every=2)

    @pytest.mark.parametrize("k", [2, 4])
    def test_dense_token_exact_and_lookahead(self, model, k):
        cfg, params = model
        base = self._run(params, cfg)
        spec = self._run(params, cfg, speculative=k)
        assert spec.outputs == base.outputs
        # dense self-drafting degenerates to lookahead: the draft IS the
        # target, so every proposal must be accepted
        assert spec.metrics["draft_acceptance_rate"] == 1.0
        assert spec.metrics["draft_proposed"] > 0

    def test_slim_compressed_token_exact(self, model, compressed):
        cfg, _ = model
        base = self._run(compressed, cfg)
        spec = self._run(compressed, cfg, speculative=4)
        assert spec.outputs == base.outputs
        assert 0.0 < spec.metrics["draft_acceptance_rate"] <= 1.0

    def test_kv_quant_token_exact(self, model):
        cfg, params = model
        cfg = dataclasses.replace(cfg, kv_quant=True)
        base = self._run(params, cfg)
        spec = self._run(params, cfg, speculative=4)
        assert spec.outputs == base.outputs

    def test_token_exact_under_forced_preemption(self, model, compressed):
        cfg, _ = model
        kw = dict(preemption=True, n_blocks=12, decode_reserve=0)
        base = self._run(compressed, cfg, **kw)
        spec = self._run(compressed, cfg, speculative=4, **kw)
        assert spec.outputs == base.outputs
        assert spec.metrics["preemptions"] >= 1
        assert spec.metrics["completed"] == base.metrics["completed"] == 4

    def test_composes_with_prefix_cache(self, model, compressed):
        cfg, _ = model
        base = self._run(compressed, cfg, prefix_cache=True)
        spec = self._run(compressed, cfg, speculative=4, prefix_cache=True)
        assert spec.outputs == base.outputs

    def test_speculative_engine_alias(self, model):
        cfg, params = model
        eng = SpeculativeEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=4
        )
        assert eng.speculative == 4
        res = eng.run(_requests(cfg, 2, plen=10, max_new=6), sync_every=2)
        base = self._run(params, cfg, reqs=_requests(cfg, 2, plen=10, max_new=6))
        assert res.outputs == base.outputs
        with pytest.raises(ValueError):
            SpeculativeEngine(params, cfg, speculative=1, block_size=4)

    def test_scratch_tail_block_reuse_is_exact(self, model):
        """A request whose prompt+budget fills max_len charges into the
        scratch tail — the one table region cold prefill does not
        overwrite wholesale. On a tight pool later requests recycle
        earlier requests' blocks there, so admission must wipe the
        recycled tail blocks' stale pos entries or their prior owner's
        positions would leak into the verify gather's mask."""
        cfg, params = model
        prompts = jax.random.randint(
            jax.random.PRNGKey(17), (3, 8), 0, cfg.vocab_size
        )

        def reqs():
            # mixed budgets misalign the free-list recycling order, so a
            # mid-sequence block of one request lands in a later
            # request's scratch-tail table entry
            return [
                Request(rid=i, prompt=[int(t) for t in prompts[i]],
                        arrival=0.0, max_new_tokens=mn)
                for i, mn in enumerate([28, 40, 40])  # 8 + 40 == MAX_LEN
            ]

        kw = dict(
            n_slots=2, max_len=MAX_LEN, block_size=4, n_blocks=15,
            check_invariants=True,
        )
        base = ContinuousEngine(params, cfg, **kw).run(reqs(), sync_every=2)
        spec = ContinuousEngine(params, cfg, speculative=4, **kw).run(
            reqs(), sync_every=2
        )
        assert spec.outputs == base.outputs
        assert spec.metrics["completed"] == 3

    def test_spec_pad_charges_scratch_blocks(self, model):
        """The scheduler charges up to K positions of draft scratch, and
        the engine's tables grow a matching scratch tail."""
        cfg, params = model
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=4,
            speculative=4,
        )
        assert eng.table_blocks == MAX_LEN // 4 + 1
        from repro.serving.block_pool import BlockAllocator
        from repro.serving.scheduler import Scheduler

        alloc = BlockAllocator(n_blocks=32, block_size=4)
        sched = Scheduler(2, MAX_LEN, allocator=alloc, spec_pad=4)
        req = Request(0, [1] * 8, arrival=0.0, max_new_tokens=8)
        # 8 + 8 positions -> 4 blocks, plus 4 scratch positions -> 1 more
        assert sched.block_need(req) == 5


# ---------------------------------------------------------------------------
# retrace guard: the speculative round is fixed-shape (check_retrace=True)
# ---------------------------------------------------------------------------


class TestSpeculativeRetrace:
    @pytest.mark.parametrize("k", [2, 4])
    def test_spec_round_compiles_once_and_never_retraces(self, model, k):
        """The fused draft+verify+commit round must compile exactly once
        per serve (max_sigs=1 in the guard: a second signature raises) and
        zero times on a warm re-run."""
        cfg, params = model
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=4,
            speculative=k, check_retrace=True,
        )
        reqs = _requests(cfg, 4, plen=10, max_new=10)
        res = eng.run(reqs, sync_every=2, max_new_cap=10)
        assert res.metrics["completed"] == 4
        assert res.metrics["jit_compiles_spec_round_greedy"] == 1.0
        # the plain decode step never runs in speculative mode
        assert res.metrics["jit_compiles_decode"] == 0.0
        assert res.metrics["jit_retraces"] == 0.0
        eng.retrace_guard.freeze()
        warm = eng.run(
            _requests(cfg, 4, plen=10, max_new=10), sync_every=2,
            max_new_cap=10,
        )
        assert warm.metrics["jit_compiles_spec_round_greedy"] == 0.0
        assert warm.metrics["jit_compiles_prefill"] == 0.0
        assert warm.metrics["jit_retraces"] == 0.0

    def test_sampled_round_guarded_separately(self, model):
        cfg, params = model
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, block_size=4,
            speculative=2, check_retrace=True,
        )
        reqs = _requests(cfg, 2, plen=8, max_new=6)
        for r in reqs:
            r.temperature = 0.8
        res = eng.run(reqs, sync_every=2, max_new_cap=6)
        assert res.metrics["jit_compiles_spec_round_sampled"] == 1.0
        assert res.metrics["jit_retraces"] == 0.0
