"""Fault injection + graceful degradation for the continuous engine.

Three layers, mirroring docs/robustness.md:

* host-only units — ``FaultPlan`` trigger semantics and ``--chaos``
  parsing, ``DegradationLadder`` hysteresis, ``GuardConfig`` validation,
  the ``RequestQueue`` deadline/shedding primitives, the allocator's
  quarantine hooks, and the never-admittable fail-fast in the scheduler;
* sampling properties — degenerate logits rows (all ``-inf``, NaN)
  have a *defined* outcome (token 0) on both the greedy and the
  temperature path, and ``degenerate_rows`` flags exactly them;
* chaos suite — every fault family runs through a real
  ``ContinuousEngine``: the run never crashes or hangs, surviving
  requests stay token-exact against solo static runs, faulted requests
  land in the right terminal state with the right counter and trace
  event, and the retrace guard stays at zero steady-state recompiles
  with chaos enabled.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (
    BlockAllocator,
    ContinuousEngine,
    DegradationLadder,
    FaultPlan,
    FaultSpec,
    GuardConfig,
    NeverAdmittable,
    Request,
    RequestQueue,
    RequestState,
    Scheduler,
    ServeEngine,
    SpanTracer,
    validate_trace,
)
from repro.serving.sampling import degenerate_rows, draw_tokens

MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(
        cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def solo(model):
    """Token-exact oracle: the static engine run one request at a time."""
    cfg, params = model
    static = ServeEngine(params, cfg, max_len=MAX_LEN)

    def gen(r):
        return static.generate(
            {"tokens": jnp.asarray([r.prompt], jnp.int32)},
            max_new_tokens=r.max_new_tokens,
        ).tokens[0]

    return gen


def _requests(cfg, n, plen=8, max_new=8, seed=7):
    prompts = jax.random.randint(
        jax.random.PRNGKey(seed), (n, plen), 0, cfg.vocab_size
    )
    return [
        Request(
            rid=i,
            prompt=[int(t) for t in prompts[i]],
            arrival=0.0,
            max_new_tokens=max_new,
        )
        for i in range(n)
    ]


def _engine(model, **kw):
    cfg, params = model
    kw.setdefault("n_slots", 3)
    kw.setdefault("max_len", MAX_LEN)
    kw.setdefault("block_size", 8)
    kw.setdefault("check_retrace", True)
    return ContinuousEngine(params, cfg, **kw)


def _assert_survivors_exact(res, solo):
    for r in res.requests:
        if r.rid >= 0 and r.state is RequestState.FINISHED:
            assert r.output == solo(r), f"survivor rid {r.rid} diverged"


class StepClock:
    """Deterministic virtual clock: each read advances a tick, sleeps
    advance their full duration. Lets deadline tests script time instead
    of racing the wall clock."""

    def __init__(self, tick=1e-4):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t

    def sleep(self, s):
        self.t += s


# ---------------------------------------------------------------------------
# FaultPlan: trigger semantics + --chaos parsing (host-only)
# ---------------------------------------------------------------------------


class TestFaultPlan:
    def test_bare_clause_fires_first_check_once(self):
        plan = FaultPlan([FaultSpec("nan_logits")])
        assert plan.should_fire("nan_logits") == 1
        assert plan.should_fire("nan_logits") == 0  # budget spent
        assert plan.fired["nan_logits"] == 1
        assert plan.checks["nan_logits"] == 2

    def test_nth_waits_then_fires(self):
        plan = FaultPlan([FaultSpec("kv_corrupt", nth=2)])
        assert [plan.should_fire("kv_corrupt") for _ in range(4)] == [
            0, 0, 1, 0,
        ]

    def test_count_budget_extends_firing(self):
        plan = FaultPlan([FaultSpec("admit_shortfall", nth=1, count=2)])
        assert [plan.should_fire("admit_shortfall") for _ in range(4)] == [
            0, 1, 1, 0,
        ]

    def test_every_period(self):
        plan = FaultPlan([FaultSpec("burst_stall", every=2, count=0)])
        # every=2 fires on checks 2, 4, ... (check 0 is exempt)
        assert [plan.should_fire("burst_stall") for _ in range(5)] == [
            0, 0, 1, 0, 1,
        ]

    def test_arg_knob_and_default(self):
        plan = FaultPlan([
            FaultSpec("burst_stall", nth=0, arg=40),
            FaultSpec("queue_flood", nth=0),
        ])
        assert plan.should_fire("burst_stall", arg_default=99) == 40
        assert plan.should_fire("queue_flood", arg_default=8) == 8

    def test_prob_is_deterministic_per_seed(self):
        mk = lambda s: FaultPlan(
            [FaultSpec("nan_logits", prob=0.3, count=0)], seed=s
        )
        a, b = mk(5), mk(5)
        seq_a = [a.should_fire("nan_logits") for _ in range(200)]
        seq_b = [b.should_fire("nan_logits") for _ in range(200)]
        assert seq_a == seq_b
        assert 0 < sum(seq_a) < 200  # actually Bernoulli, not const

    def test_inactive_site_never_fires(self):
        plan = FaultPlan([FaultSpec("nan_logits")])
        assert plan.should_fire("kv_corrupt") == 0
        assert plan.active_sites() == ["nan_logits"]

    def test_summary_keys(self):
        plan = FaultPlan([FaultSpec("nan_logits")])
        plan.should_fire("nan_logits")
        s = plan.summary()
        assert s["fault_nan_logits"] == 1.0
        assert s["fault_kv_corrupt"] == 0.0

    def test_unknown_site_rejected(self):
        with pytest.raises(ValueError, match="unknown fault site"):
            FaultSpec("bad_site")

    def test_parse_round_trip(self):
        plan = FaultPlan.parse(
            "nan_logits@3; burst_stall:every=4,arg=50,count=2;"
            "queue_flood:prob=0.25,arg=8"
        )
        nl = plan.specs["nan_logits"][0]
        assert (nl.nth, nl.count) == (3, 1)
        bs = plan.specs["burst_stall"][0]
        assert (bs.every, bs.arg, bs.count) == (4, 50, 2)
        qf = plan.specs["queue_flood"][0]
        assert (qf.prob, qf.arg) == (0.25, 8)

    def test_parse_rejects_garbage(self):
        with pytest.raises(ValueError):
            FaultPlan.parse("nan_logits:wat=1")
        with pytest.raises(ValueError):
            FaultPlan.parse("nan_logits:count")
        with pytest.raises(ValueError):
            FaultPlan.parse(";;")


# ---------------------------------------------------------------------------
# DegradationLadder + GuardConfig (host-only)
# ---------------------------------------------------------------------------


class TestLadder:
    def test_one_step_per_update_even_under_spike(self):
        lad = DegradationLadder()
        assert lad.update(100.0) == 1  # not straight to 3
        assert lad.update(100.0) == 2
        assert lad.update(100.0) == 3
        assert lad.update(100.0) == 3  # saturates at max_level

    def test_hysteresis_band_holds_level(self):
        lad = DegradationLadder(enter=(1.0, 2.0), exit=(0.5, 1.0))
        lad.update(1.5)  # -> 1
        # 0.7 is below enter[1]=2.0 but above exit[0]=0.5: hold
        assert lad.update(0.7) == 1
        assert lad.update(0.7) == 1
        assert lad.update(0.4) == 0  # below exit[0]: step down

    def test_recovery_walks_down_one_per_round(self):
        lad = DegradationLadder()
        for _ in range(3):
            lad.update(10.0)
        assert lad.level == 3
        levels = [lad.update(0.0) for _ in range(4)]
        assert levels == [2, 1, 0, 0]
        assert lad.transitions == 6

    def test_guard_config_validates(self):
        with pytest.raises(ValueError, match="exit < enter"):
            GuardConfig(ladder_enter=(1.0,), ladder_exit=(1.0,))
        with pytest.raises(ValueError, match="ascending"):
            GuardConfig(ladder_enter=(2.0, 1.0), ladder_exit=(0.1, 0.2))
        with pytest.raises(ValueError, match="pair up"):
            GuardConfig(ladder_enter=(1.0, 2.0), ladder_exit=(0.5,))
        with pytest.raises(ValueError):
            GuardConfig(max_queue=-1)
        assert not GuardConfig().active
        assert GuardConfig(default_ttl=5.0).active
        assert GuardConfig(degradation=True).active


# ---------------------------------------------------------------------------
# degenerate logits: property tests (satellite 2)
# ---------------------------------------------------------------------------


class TestDegenerateSampling:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 3), st.floats(0.0, 1.5))
    def test_all_neg_inf_row_draws_token_zero(self, row, temp):
        logits = jnp.zeros((4, 32), jnp.float32)
        logits = logits.at[row].set(-jnp.inf)
        bad = degenerate_rows(logits)
        assert bool(bad[row]) and int(jnp.sum(bad)) == 1
        toks = draw_tokens(logits, jnp.full((4,), temp), jax.random.PRNGKey(0))
        assert int(toks[row]) == 0

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 3), st.integers(0, 31), st.floats(0.0, 1.5))
    def test_nan_anywhere_in_row_draws_token_zero(self, row, col, temp):
        logits = jnp.ones((4, 32), jnp.float32)
        logits = logits.at[row, col].set(jnp.nan)
        bad = degenerate_rows(logits)
        assert bool(bad[row]) and int(jnp.sum(bad)) == 1
        toks = draw_tokens(logits, jnp.full((4,), temp), jax.random.PRNGKey(1))
        assert int(toks[row]) == 0

    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 3))
    def test_pos_inf_row_flagged(self, row):
        logits = jnp.zeros((4, 32), jnp.float32)
        logits = logits.at[row, 5].set(jnp.inf)
        assert bool(degenerate_rows(logits)[row])

    def test_partial_neg_inf_mask_is_fine(self):
        # a top-k style mask (-inf on most entries) is NOT degenerate
        logits = jnp.full((2, 32), -jnp.inf)
        logits = logits.at[:, 7].set(1.0)
        assert not bool(jnp.any(degenerate_rows(logits)))
        toks = draw_tokens(logits, 0.0, jax.random.PRNGKey(2))
        assert [int(t) for t in toks] == [7, 7]


# ---------------------------------------------------------------------------
# RequestQueue deadline/shedding primitives (host-only)
# ---------------------------------------------------------------------------


class TestQueueGuards:
    def test_drain_expired_removes_only_past_deadline(self):
        a = Request(rid=0, prompt=[1], arrival=0.0, deadline=1.0)
        b = Request(rid=1, prompt=[1], arrival=0.0, deadline=9.0)
        c = Request(rid=2, prompt=[1], arrival=0.0)  # no deadline
        q = RequestQueue([a, b, c])
        gone = q.drain_expired(now=2.0)
        assert [r.rid for r in gone] == [0]
        assert len(q) == 2
        assert q.drain_expired(now=2.0) == []

    def test_shed_newest_spares_old_arrivals(self):
        old = Request(rid=0, prompt=[1], arrival=0.0)
        mid = Request(rid=1, prompt=[1], arrival=1.0)
        new = Request(rid=2, prompt=[1], arrival=2.0)
        q = RequestQueue([old, mid, new])
        shed = q.shed_newest(now=5.0, max_ready=1)
        assert sorted(r.rid for r in shed) == [1, 2]
        assert q.pop_ready(5.0).rid == 0

    def test_shed_ignores_future_arrivals(self):
        here = Request(rid=0, prompt=[1], arrival=0.0)
        later = Request(rid=1, prompt=[1], arrival=100.0)
        q = RequestQueue([here, later])
        assert q.shed_newest(now=1.0, max_ready=1) == []
        assert len(q) == 2

    def test_preemption_requeue_outlives_shedding(self):
        # the preemption victim keeps its original (old) arrival, so a
        # flood of fresh arrivals is shed before it ever is
        victim = Request(rid=0, prompt=[1], arrival=0.0)
        q = RequestQueue()
        for i in range(1, 4):
            q.push(Request(rid=i, prompt=[1], arrival=3.0))
        q.push(victim, front=True)
        shed = q.shed_newest(now=5.0, max_ready=1)
        assert victim not in shed and len(shed) == 3


# ---------------------------------------------------------------------------
# allocator quarantine hooks (host-only)
# ---------------------------------------------------------------------------


class TestAllocatorQuarantine:
    def test_register_new_chains_gate(self):
        a = BlockAllocator(n_blocks=12, block_size=4, prefix_cache=True)
        a.register_new_chains = False
        a.admit_request(0, list(range(8)), 8)
        a.release_cached(0, list(range(8)))
        assert a.n_evictable() == 0  # nothing registered, nothing demoted
        a.register_new_chains = True
        a.admit_request(1, list(range(8)), 8)
        a.release_cached(1, list(range(8)))
        assert a.n_evictable() > 0
        a.check()

    def test_purge_slot_index_makes_blocks_unmatchable(self):
        a = BlockAllocator(n_blocks=12, block_size=4, prefix_cache=True)
        toks = list(range(100, 108))  # 2 full blocks
        a.admit_request(0, toks, 8)
        assert a.purge_slot_index(0) > 0
        a.release(0)
        # matching must come up empty: a fresh admission re-prefills all
        info = a.admit_request(1, toks, 8)
        assert info is not None and not info.hit and info.cached_len == 0
        a.check()


# ---------------------------------------------------------------------------
# never-admittable fail-fast (satellite 1: regression for infinite defer)
# ---------------------------------------------------------------------------


class TestNeverAdmittable:
    def test_scheduler_rejects_oversize_prompt(self):
        sched = Scheduler(n_slots=2, max_len=16)
        with pytest.raises(NeverAdmittable, match="exceeds max_len"):
            sched.submit(Request(rid=0, prompt=[1] * 20, max_new_tokens=4))

    def test_scheduler_rejects_block_need_beyond_pool(self):
        alloc = BlockAllocator(n_blocks=6, block_size=4)  # 4 usable
        sched = Scheduler(
            n_slots=2, max_len=64, allocator=alloc, on_demand=True
        )
        with pytest.raises(NeverAdmittable, match="pool only holds"):
            sched.submit(Request(rid=0, prompt=[1] * 30, max_new_tokens=8))

    def test_engine_fails_fast_and_serves_the_rest(self, model, solo):
        """The regression: a prompt larger than the whole pool used to
        defer forever at the head of the FIFO, starving the run. Now it
        lands in FAILED at submit and co-batched requests complete."""
        cfg, _ = model
        # 7 blocks - 2 reserved = 5 usable = 40 positions: the whale's
        # 44-token prompt could never fit even with the pool to itself
        eng = _engine(model, n_slots=2, preemption=True, n_blocks=7)
        reqs = _requests(cfg, 2, plen=8, max_new=6)
        prompts = jax.random.randint(
            jax.random.PRNGKey(3), (1, 44), 0, cfg.vocab_size
        )
        whale = Request(
            rid=99,
            prompt=[int(t) for t in prompts[0]],
            arrival=0.0,
            max_new_tokens=4,
        )
        res = eng.run(reqs + [whale])
        by_rid = {r.rid: r for r in res.requests}
        assert by_rid[99].state is RequestState.FAILED
        assert by_rid[99].error and "pool only holds" in by_rid[99].error
        assert res.metrics["failed_requests"] == 1.0
        for i in range(2):
            assert by_rid[i].state is RequestState.FINISHED
        _assert_survivors_exact(res, solo)


# ---------------------------------------------------------------------------
# chaos suite: every fault family through a real engine
# ---------------------------------------------------------------------------


class TestChaosEngine:
    def test_inert_guard_changes_nothing(self, model, solo):
        eng = _engine(model, guard=GuardConfig())
        res = eng.run(_requests(model[0], 4))
        assert all(
            r.state is RequestState.FINISHED for r in res.requests
        )
        _assert_survivors_exact(res, solo)
        assert res.metrics["shed_requests"] == 0.0
        assert res.metrics["expired_requests"] == 0.0
        assert res.metrics["failed_requests"] == 0.0

    def test_nan_logits_quarantines_only_the_victim(self, model, solo):
        eng = _engine(
            model,
            faults=FaultPlan([FaultSpec("nan_logits", nth=1)]),
            trace=True,
        )
        res = eng.run(_requests(model[0], 4))
        failed = [r for r in res.requests if r.state is RequestState.FAILED]
        assert len(failed) == 1
        assert failed[0].output is None  # poisoned tokens are untrusted
        assert "quarantined" in failed[0].error
        assert res.metrics["quarantined_slots"] == 1.0
        assert res.metrics["failed_requests"] == 1.0
        assert res.metrics["fault_nan_logits"] == 1.0
        assert sum(
            r.state is RequestState.FINISHED for r in res.requests
        ) == 3
        _assert_survivors_exact(res, solo)
        assert res.metrics["jit_retraces"] == 0.0
        problems = validate_trace(
            eng.tracer.to_dict(), require=("quarantine", "fault_nan_logits")
        )
        assert problems == []

    def test_kv_corrupt_never_poisons_neighbours(self, model, solo):
        eng = _engine(
            model,
            prefix_cache=True,
            faults=FaultPlan([FaultSpec("kv_corrupt", nth=1)]),
        )
        res = eng.run(_requests(model[0], 4))
        assert res.metrics["fault_kv_corrupt"] == 1.0
        # blast radius: at most the single owning slot fails; everyone
        # else must be token-exact (CoW means shared blocks are never
        # the corruption target)
        failed = [r for r in res.requests if r.state is RequestState.FAILED]
        assert len(failed) <= 1
        assert len(failed) + sum(
            r.state is RequestState.FINISHED for r in res.requests
        ) == 4
        _assert_survivors_exact(res, solo)
        assert res.metrics["jit_retraces"] == 0.0

    def test_allocator_shortfalls_are_absorbed(self, model, solo):
        eng = _engine(
            model,
            preemption=True,
            faults=FaultPlan([
                FaultSpec("admit_shortfall", nth=0, count=2),
                FaultSpec("extend_shortfall", nth=1, count=2),
            ]),
        )
        res = eng.run(_requests(model[0], 4))
        assert all(r.state is RequestState.FINISHED for r in res.requests)
        _assert_survivors_exact(res, solo)
        assert res.metrics["fault_admit_shortfall"] == 2.0
        assert res.metrics["fault_extend_shortfall"] >= 1.0
        assert res.metrics["preemptions"] >= 1.0  # the forced evictions
        assert res.metrics["jit_retraces"] == 0.0

    def test_burst_stall_trips_watchdog_not_outputs(self, model, solo):
        eng = _engine(
            model,
            faults=FaultPlan([FaultSpec("burst_stall", nth=0, arg=30)]),
            guard=GuardConfig(watchdog_s=0.005),
            trace=True,
        )
        res = eng.run(_requests(model[0], 3))
        assert all(r.state is RequestState.FINISHED for r in res.requests)
        _assert_survivors_exact(res, solo)
        assert res.metrics["watchdog_trips"] >= 1.0
        assert res.metrics["fault_burst_stall"] == 1.0
        problems = validate_trace(
            eng.tracer.to_dict(), require=("watchdog_trip",)
        )
        assert problems == []

    def test_queue_flood_sheds_newest_first(self, model, solo):
        eng = _engine(
            model,
            faults=FaultPlan([FaultSpec("queue_flood", nth=0, arg=8)]),
            guard=GuardConfig(max_queue=2),
            trace=True,
        )
        res = eng.run(_requests(model[0], 4))
        assert len(res.requests) == 12  # 4 real + 8 synthetic flood
        shed = [r for r in res.requests if r.state is RequestState.ABORTED]
        assert res.metrics["shed_requests"] == float(len(shed)) > 0
        # the flood arrives later than the real trace, so shedding takes
        # the synthetic arrivals and every real request completes
        assert all(r.rid < 0 for r in shed)
        for r in res.requests:
            if r.rid >= 0:
                assert r.state is RequestState.FINISHED
        _assert_survivors_exact(res, solo)
        problems = validate_trace(eng.tracer.to_dict(), require=("shed",))
        assert problems == []


# ---------------------------------------------------------------------------
# deadlines / TTL
# ---------------------------------------------------------------------------


class TestDeadlines:
    def test_queued_past_deadline_expires_without_prefill(self, model, solo):
        """A request whose deadline passes while it waits never reaches
        the device: reaped to EXPIRED before admission."""
        cfg, _ = model
        reqs = _requests(cfg, 2, max_new=6)
        doomed = _requests(cfg, 1, seed=11)[0]
        doomed.rid = 10
        doomed.deadline = 1e-6  # passed before the first round
        eng = _engine(model, n_slots=1, guard=GuardConfig(), trace=True)
        res = eng.run(reqs + [doomed])
        by_rid = {r.rid: r for r in res.requests}
        assert by_rid[10].state is RequestState.EXPIRED
        assert by_rid[10].output is None
        assert "queued" in by_rid[10].error
        assert res.metrics["expired_requests"] == 1.0
        for i in range(2):
            assert by_rid[i].state is RequestState.FINISHED
        _assert_survivors_exact(res, solo)
        # no prefill span for the doomed rid: it never touched a slot
        prefills = [
            ev
            for ev in eng.tracer.events()
            if ev.get("name") == "prefill"
            and ev.get("args", {}).get("rid") == 10
        ]
        assert prefills == []

    def test_running_past_deadline_keeps_partial_output(self, model, solo):
        """Host-side cancellation mid-decode: the slot is silenced, the
        blocks are freed, and the tokens emitted so far survive — an
        exact prefix of the solo output (greedy decode)."""
        cfg, _ = model
        clk = StepClock()
        reqs = _requests(cfg, 2, max_new=12)
        reqs[0].deadline = 0.3  # ~one stalled burst away (the `every`
        # trigger skips check 0, so the first stall lands on round 1 and
        # the round-2 reap catches rid 0 mid-decode)
        eng = _engine(
            model,
            n_slots=2,
            clock=clk,
            sleep=clk.sleep,
            guard=GuardConfig(),
            faults=FaultPlan([
                FaultSpec("burst_stall", every=1, count=0, arg=400),
            ]),
        )
        res = eng.run(reqs, sync_every=4)
        by_rid = {r.rid: r for r in res.requests}
        exp = by_rid[0]
        assert exp.state is RequestState.EXPIRED
        assert "running" in exp.error
        assert exp.output is not None and 0 < len(exp.output) < 12
        assert exp.output == solo(by_rid[0])[: len(exp.output)]
        assert by_rid[1].state is RequestState.FINISHED
        _assert_survivors_exact(res, solo)
        assert res.metrics["expired_requests"] == 1.0

    def test_preempted_past_deadline_expires_not_readmits(self, model, solo):
        """Satellite: a preemption victim whose deadline passes while it
        waits for re-admission lands in EXPIRED at the reap — its blocks
        are already released and it must NOT re-prefill (reap runs
        before admission every round)."""
        cfg, _ = model
        clk = StepClock()
        reqs = _requests(cfg, 2, plen=8, max_new=6)
        reqs[1].deadline = 0.5  # alive through admission, dead after the
        # stalled burst that follows its forced preemption
        eng = _engine(
            model,
            n_slots=2,
            preemption=True,
            clock=clk,
            sleep=clk.sleep,
            guard=GuardConfig(),
            trace=True,
            faults=FaultPlan([
                # the growth shortfall forces a youngest-first preemption
                # of rid 1; the stall pushes the virtual clock past its
                # deadline before the next scheduling round
                FaultSpec("extend_shortfall", nth=0),
                FaultSpec("burst_stall", nth=0, arg=1000),
            ]),
        )
        res = eng.run(reqs, sync_every=4)
        by_rid = {r.rid: r for r in res.requests}
        victim = by_rid[1]
        assert victim.n_preemptions == 1
        assert victim.state is RequestState.EXPIRED
        assert res.metrics["expired_requests"] == 1.0
        assert res.metrics["preemptions"] == 1.0
        assert by_rid[0].state is RequestState.FINISHED
        _assert_survivors_exact(res, solo)
        # exactly ONE prefill span for the victim: admitted once, never
        # re-prefilled after its deadline passed in the queue
        prefills = [
            ev
            for ev in eng.tracer.events()
            if ev.get("name") == "prefill"
            and ev.get("args", {}).get("rid") == 1
        ]
        assert len(prefills) == 1

    def test_default_ttl_applies_to_all(self, model):
        cfg, _ = model
        clk = StepClock()
        eng = _engine(
            model,
            clock=clk,
            sleep=clk.sleep,
            guard=GuardConfig(default_ttl=0.2),
            faults=FaultPlan([
                FaultSpec("burst_stall", every=1, count=0, arg=300),
            ]),
        )
        res = eng.run(_requests(cfg, 3, max_new=12), sync_every=4)
        # every burst overshoots the TTL: every request must expire (not
        # hang, not finish) and the engine must drain cleanly
        assert all(r.state is RequestState.EXPIRED for r in res.requests)
        assert res.metrics["expired_requests"] == 3.0


# ---------------------------------------------------------------------------
# degradation ladder through the engine
# ---------------------------------------------------------------------------


class TestDegradationEngine:
    def test_ladder_degrades_and_recovers(self, model, solo):
        cfg, _ = model
        eng = _engine(
            model,
            n_slots=2,
            speculative=2,
            preemption=True,
            guard=GuardConfig(
                degradation=True,
                ladder_enter=(0.01, 0.02, 0.03),
                ladder_exit=(0.005, 0.01, 0.015),
            ),
        )
        res = eng.run(_requests(cfg, 6, max_new=12))
        # the backlog (6 requests, 2 slots) drives the ladder up; the
        # drain brings it back — and the spec->plain fallback plus the
        # mode switch back must not cost a single retrace
        assert res.metrics["degraded_rounds"] > 0
        assert res.metrics["peak_degradation_level"] >= 2.0
        assert all(r.state is RequestState.FINISHED for r in res.requests)
        _assert_survivors_exact(res, solo)
        assert res.metrics["jit_retraces"] == 0.0

    def test_degraded_run_is_token_exact_vs_undegraded(self, model):
        """The ladder changes *how* tokens are produced (plain decode vs
        speculative, paused registration), never *which* tokens."""
        cfg, _ = model
        reqs = _requests(cfg, 4, max_new=8)
        base = _engine(model, n_slots=2, speculative=2, prefix_cache=True)
        ref = {r.rid: r.output for r in base.run([
            dataclasses.replace(r) for r in reqs
        ]).requests}
        eng = _engine(
            model,
            n_slots=2,
            speculative=2,
            prefix_cache=True,
            guard=GuardConfig(
                degradation=True,
                ladder_enter=(0.01, 0.02, 0.03),
                ladder_exit=(0.005, 0.01, 0.015),
            ),
        )
        res = eng.run([dataclasses.replace(r) for r in reqs])
        assert res.metrics["degraded_rounds"] > 0
        for r in res.requests:
            assert r.output == ref[r.rid], f"rid {r.rid} diverged"
