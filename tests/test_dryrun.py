"""Multi-device integration tests via subprocess (device count must be set
before jax initializes, so these never run in the main pytest process)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 8, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    return subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )


@pytest.mark.slow
def test_ef_allreduce_int8_shardmap():
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.distributed import ef_allreduce_int8, shard_map
mesh = Mesh(np.array(jax.devices()[:4]), ('data',))
x = jnp.arange(64, dtype=jnp.float32).reshape(4, 16) / 7.0
f = jax.jit(shard_map(
    lambda a: ef_allreduce_int8(a, 'data'),
    mesh=mesh, in_specs=P('data'), out_specs=P('data')))
out = f(x)
want = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), (4, 16))
rel = float(jnp.max(jnp.abs(out - want)) / (jnp.max(jnp.abs(want)) + 1e-9))
assert rel < 0.02, f'rel err {rel}'
print('EF-ALLREDUCE-OK', rel)
"""
    r = _run(code, devices=4)
    assert "EF-ALLREDUCE-OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_sharded_train_step_runs():
    """Real (executed) sharded train step on an 8-device host mesh."""
    code = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import transformer as T
from repro.models import sharding as SH
from repro.launch.mesh import make_test_mesh
from repro.optim import adamw, apply_updates

cfg = get_config('qwen3-0.6b', reduced=True)
mesh = make_test_mesh((4, 2), ('data', 'model'))
params = T.init_params(cfg, jax.random.PRNGKey(0))
pspecs = SH.param_specs(params, cfg, mesh)
params = jax.device_put(params, SH.named(mesh, pspecs))
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab_size)
bspec = NamedSharding(mesh, P('data', None))
batch = {'tokens': jax.device_put(toks, bspec), 'labels': jax.device_put(toks, bspec)}
init, update = adamw(1e-3)
state = init(params)

@jax.jit
def step(p, s, b):
    l, g = jax.value_and_grad(lambda pp: T.train_loss(pp, cfg, b))(p)
    u, s = update(g, s, p)
    return apply_updates(p, u), s, l

with mesh:
    p2, s2, l = step(params, state, batch)
    p3, s3, l2 = step(p2, s2, batch)
assert jnp.isfinite(l) and jnp.isfinite(l2)
assert float(l2) < float(l) + 1.0
print('SHARDED-TRAIN-OK', float(l), float(l2))
"""
    r = _run(code, devices=8)
    assert "SHARDED-TRAIN-OK" in r.stdout, r.stdout + r.stderr


@pytest.mark.slow
def test_dryrun_production_mesh_one_cell():
    """The real dryrun entrypoint: 512 placeholder devices, full qwen3
    config, single + multi-pod meshes, one shape each."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    with tempfile.TemporaryDirectory() as d:
        out = os.path.join(d, "r.json")
        r = subprocess.run(
            [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", "qwen3-0.6b", "--shape", "decode_32k",
                "--mesh", "both", "--skip-analysis", "--out", out,
            ],
            capture_output=True, text=True, timeout=1200, env=env,
        )
        assert r.returncode == 0, r.stdout + r.stderr
        data = json.load(open(out))
        assert len(data["results"]) == 2  # single + multi
        assert not data["failures"]
        chips = sorted(x["chips"] for x in data["results"])
        assert chips == [256, 512]


@pytest.mark.slow
def test_elastic_restart_reshards():
    """Checkpoint written under one device count restores under another."""
    with tempfile.TemporaryDirectory() as d:
        code_save = f"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.models import transformer as T
from repro.configs import get_config
cfg = get_config('slim-tiny')
params = T.init_params(cfg, jax.random.PRNGKey(0))
CheckpointManager({d!r}).save(5, params)
print('SAVED')
"""
        r = _run(code_save, devices=4)
        assert "SAVED" in r.stdout, r.stdout + r.stderr
        code_load = f"""
import jax, jax.numpy as jnp, numpy as np
from repro.checkpoint import CheckpointManager
from repro.models import transformer as T
from repro.models import sharding as SH
from repro.configs import get_config
from repro.launch.mesh import make_test_mesh
cfg = get_config('slim-tiny')
like = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.PRNGKey(0))
mesh = make_test_mesh((2, 4), ('data', 'model'))  # different topology
shardings = SH.named(mesh, SH.param_specs(like, cfg, mesh))
step, params = CheckpointManager({d!r}).restore_latest(like, shardings)
assert step == 5
leaf = jax.tree.leaves(params)[0]
assert len(leaf.sharding.device_set) > 1
print('RESHARDED-OK')
"""
        r = _run(code_load, devices=8)
        assert "RESHARDED-OK" in r.stdout, r.stdout + r.stderr
