"""End-to-end single-matrix pipeline tests (paper Fig. 1 ordering claims)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CalibStats, CompressionConfig, compress_matrix
from repro.core.compressed import slim_linear_apply


def _setup(seed=0, d_in=128, d_out=64, n=256):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(0, 0.08, (d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.normal(0, 1.0, (n, d_in)) * (1 + rng.random(d_in)), jnp.float32)
    stats = CalibStats.init(d_in, with_hessian=True).update(x)
    return w, x, stats


def _out_err(p, x, w):
    y = slim_linear_apply(p, x)
    ref = x @ w
    return float(jnp.linalg.norm(y - ref) / jnp.linalg.norm(ref))


class TestPipelineOrdering:
    def test_adapters_reduce_output_error(self):
        w, x, stats = _setup()
        errs = {}
        for adapter in ["none", "naive", "slim"]:
            cfg = CompressionConfig(adapter=adapter, rank=16)
            p, _ = compress_matrix(w, stats, cfg)
            errs[adapter] = _out_err(p, x, w)
        assert errs["naive"] < errs["none"]
        assert errs["slim"] < errs["none"]
        # SLiM-LoRA optimizes the saliency-weighted error, which tracks the
        # true output error better than plain Frobenius (paper Tbl 1)
        assert errs["slim"] <= errs["naive"] * 1.05

    def test_l2qer_misses_sparsity_error(self):
        """Adapters fit only E_Q (L2QER-style) underperform SLiM-LoRA when
        sparsity is on — the paper's key comparison."""
        w, x, stats = _setup(1)
        p_slim, _ = compress_matrix(w, stats, CompressionConfig(adapter="slim", rank=16))
        p_l2, _ = compress_matrix(w, stats, CompressionConfig(adapter="l2qer", rank=16))
        assert _out_err(p_slim, x, w) < _out_err(p_l2, x, w)

    def test_quantized_adapters_close(self):
        w, x, stats = _setup(2)
        p_fp, _ = compress_matrix(w, stats, CompressionConfig(adapter="slim", rank=16))
        p_q, _ = compress_matrix(
            w, stats,
            CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
        )
        # SLiM-LoRA^Q costs little accuracy (paper: "negligible")
        assert _out_err(p_q, x, w) <= _out_err(p_fp, x, w) * 1.3

    def test_reports_consistent(self):
        w, x, stats = _setup(3)
        p, rep = compress_matrix(w, stats, CompressionConfig(adapter="slim", rank=16))
        assert rep.total_err_after <= rep.total_err_before * 1.0001
        assert rep.saliency_err_after <= rep.saliency_err_before * 1.0001
        assert rep.quant_err > 0 and rep.sparse_err > 0

    @pytest.mark.parametrize("quantizer", ["slim", "absmax", "group_absmax", "slim_o"])
    def test_quantizer_grid(self, quantizer):
        w, x, stats = _setup(4)
        cfg = CompressionConfig(quantizer=quantizer, adapter="slim", rank=16)
        p, _ = compress_matrix(w, stats, cfg)
        assert _out_err(p, x, w) < 0.5

    @pytest.mark.parametrize("pruner", ["wanda", "magnitude", "sparsegpt"])
    def test_pruner_grid(self, pruner):
        w, x, stats = _setup(5)
        cfg = CompressionConfig(pruner=pruner, adapter="slim", rank=16)
        p, _ = compress_matrix(w, stats, cfg)
        assert _out_err(p, x, w) < 0.5

    def test_unstructured_pattern(self):
        w, x, stats = _setup(6)
        cfg = CompressionConfig(pattern="unstructured", adapter="slim", rank=16)
        p, _ = compress_matrix(w, stats, cfg)
        assert p.fmt == "dense_int4"
        # unstructured 50% beats 2:4 (less constrained) — paper Tbl 1
        p24, _ = compress_matrix(w, stats, CompressionConfig(adapter="slim", rank=16))
        assert _out_err(p, x, w) <= _out_err(p24, x, w) * 1.05

    def test_wanda_on_quantized_weights(self):
        """SLiM prunes W^Q, not W (paper §3.2): masks must differ when
        quantization moves saliency across the 2:4 group boundary."""
        w, x, stats = _setup(7)
        p, rep = compress_matrix(w, stats, CompressionConfig(adapter="none"))
        # sanity: the pipeline produced a true 2:4 layout
        assert p.fmt == "sparse24"
        assert p.packed_vals.shape == (w.shape[0] // 4, w.shape[1])
