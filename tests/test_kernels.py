"""Pallas kernel validation: shape/dtype sweeps vs the ref.py jnp oracles
(interpret mode executes the kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressed import SlimLinear, slim_linear_apply
from repro.core.packing import pack_dense_24, pack_int4
from repro.core.pruning import nm_mask
from repro.kernels import ref as R
from repro.kernels.int4_matmul import int4_matmul
from repro.kernels.ops import slim_linear_op
from repro.kernels.slim_linear import slim_linear
from repro.kernels.sparse24_matmul import sparse24_matmul

SHAPES = [
    (16, 32, 16),
    (32, 64, 48),
    (64, 256, 128),
    (128, 128, 256),
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _mk(seed, m, k, n, dtype):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (m, k), dtype)
    codes = jax.random.randint(ks[1], (k, n), -7, 8).astype(jnp.int8)
    scale = jnp.float32(0.23 + 0.1 * seed)
    sal = jnp.abs(jax.random.normal(ks[2], (k, n)))
    mask = nm_mask(sal, 2, 4)
    masked = (codes * mask.astype(jnp.int8)).astype(jnp.int8)
    return x, codes, masked, mask, scale, ks


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_int4_matmul_pertensor(shape, dtype):
    m, k, n = shape
    x, codes, _, _, scale, _ = _mk(1, m, k, n, dtype)
    wp = pack_int4(codes)
    got = int4_matmul(x, wp, scale, bm=16, bn=16, bk=32)
    want = R.int4_matmul_ref(x, wp, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("shape", [(32, 128, 64), (64, 256, 128)])
def test_int4_matmul_group(shape):
    m, k, n = shape
    g = 64
    x, codes, _, _, _, ks = _mk(2, m, k, n, jnp.float32)
    wp = pack_int4(codes)
    gs = jax.random.uniform(ks[3], (k // g, 1, n), jnp.float32, 0.05, 0.8)
    got = int4_matmul(x, wp, gs, group_size=g, bm=16, bn=16, bk=64)
    want = R.int4_matmul_ref(x, wp, gs, group_size=g)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_sparse24_matmul(shape, dtype):
    m, k, n = shape
    x, _, masked, mask, scale, _ = _mk(3, m, k, n, dtype)
    pv, pi = pack_dense_24(masked, mask)
    got = sparse24_matmul(x, pv, pi, scale, bm=16, bn=16, bk=32)
    want = R.sparse24_matmul_ref(x, pv, pi, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("with_ias", [False, True])
def test_slim_linear_fused(shape, with_ias):
    m, k, n = shape
    r = 16
    x, _, masked, mask, scale, ks = _mk(4, m, k, n, jnp.float32)
    pv, pi = pack_dense_24(masked, mask)
    l = jax.random.normal(ks[3], (k, r)) * 0.1
    rr = jax.random.normal(ks[4], (r, n)) * 0.1
    ias = (
        jax.random.uniform(ks[5], (k,), jnp.float32, 0.5, 1.5) if with_ias else None
    )
    got = slim_linear(x, pv, pi, scale, l, rr, ias, bm=16, bn=16, bk=32)
    want = R.slim_linear_ref(x, pv, pi, scale, l, rr, ias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_kernel_matches_model_xla_path():
    """ops.slim_linear_op == core.compressed.slim_linear_apply (the model's
    XLA path) on the same SlimLinear — one semantics, two backends."""
    m, k, n, r = 32, 64, 48, 8
    x, _, masked, mask, scale, ks = _mk(5, m, k, n, jnp.float32)
    pv, pi = pack_dense_24(masked, mask)
    l = jax.random.normal(ks[3], (k, r)) * 0.1
    rr = jax.random.normal(ks[4], (r, n)) * 0.1
    ias = jax.random.uniform(ks[5], (k,), jnp.float32, 0.5, 1.5)
    p = SlimLinear(pv, pi, scale, ias, l, rr, None, None, k, n, 4, 0, "sparse24", 0, 128)
    got = slim_linear_op(p, x)
    want = slim_linear_apply(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_block_shape_independence():
    """Result must not depend on BlockSpec tiling."""
    m, k, n = 64, 128, 64
    x, _, masked, mask, scale, _ = _mk(6, m, k, n, jnp.float32)
    pv, pi = pack_dense_24(masked, mask)
    outs = [
        np.asarray(sparse24_matmul(x, pv, pi, scale, bm=bm, bn=bn, bk=bk))
        for bm, bn, bk in [(16, 16, 32), (32, 64, 64), (64, 32, 128), (64, 64, 8)]
    ]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)
