"""Sweeps for the serving-stack kernels: group (de)quant (the paper's §3.4
Triton kernels, Pallas analogue) and flash-decoding attention — contiguous
and paged (block-pool K/V gathered through a block table)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref as R
from repro.kernels.flash_decode import flash_decode
from repro.kernels.group_quant import group_dequantize, group_quantize
from repro.kernels.paged_decode import paged_decode


@pytest.mark.parametrize("shape,g", [((128, 32), 32), ((256, 64), 64), ((512, 128), 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_quant_matches_ref(shape, g, dtype):
    k, n = shape
    x = jax.random.normal(jax.random.PRNGKey(0), (k, n), dtype) * 0.3
    c, s = group_quantize(x, g=g, bk=min(k, 2 * g), bn=min(n, 64))
    cr, sr = R.group_quantize_ref(x, g=g)
    np.testing.assert_array_equal(np.asarray(c), np.asarray(cr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)


@pytest.mark.parametrize("g", [32, 64])
def test_group_roundtrip_error_bounded(g):
    k, n = 256, 64
    x = jax.random.normal(jax.random.PRNGKey(1), (k, n)) * 0.5
    c, s = group_quantize(x, g=g, bk=128, bn=32)
    xd = group_dequantize(c, s, g=g, bk=128, bn=32)
    # max error <= scale/half per group
    err = jnp.abs(xd - x).reshape(k // g, g, n)
    bound = s / 8.0 + 1e-6
    assert bool(jnp.all(jnp.max(err, axis=1, keepdims=True) <= bound))


@pytest.mark.parametrize(
    "B,S,H,dh,bs", [(2, 128, 4, 32, 32), (1, 256, 8, 64, 64), (4, 64, 2, 16, 16)]
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(B, S, H, dh, bs, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    k = jax.random.normal(ks[1], (B, S, H, dh), dtype)
    v = jax.random.normal(ks[2], (B, S, H, dh), dtype)
    lens = jax.random.randint(ks[3], (B,), 1, S + 1)
    got = flash_decode(q, k, v, lens, bs=bs)
    want = R.flash_decode_ref(q, k, v, lens)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


def test_flash_decode_block_independence():
    B, S, H, dh = 2, 256, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    lens = jnp.asarray([200, 256], jnp.int32)
    outs = [np.asarray(flash_decode(q, k, v, lens, bs=bs)) for bs in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)


def test_flash_decode_ragged_lengths():
    """Rows with different fill levels must only see their valid prefix."""
    B, S, H, dh = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    lens = jnp.asarray([10, 64], jnp.int32)
    out = flash_decode(q, k, v, lens, bs=16)
    # row 0 must equal attention over just the first 10 positions
    want0 = R.flash_decode_ref(q[:1], k[:1, :10], v[:1, :10], jnp.asarray([10]))
    np.testing.assert_allclose(np.asarray(out[:1]), np.asarray(want0), rtol=2e-5, atol=2e-5)


def test_flash_decode_empty_row():
    """Regression: kv_len == 0 once averaged uninitialized V through
    exp(_NEG - _NEG) == 1 for every masked position. Empty rows must emit
    exact zeros and leave other rows untouched."""
    B, S, H, dh = 2, 64, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    # NaN-poisoned V beyond any valid position: a leak shows up immediately
    v = jax.random.normal(ks[2], (B, S, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    lens = jnp.asarray([0, S], jnp.int32)
    out = flash_decode(q, k, v.at[0].set(jnp.nan), lens, bs=16)
    assert bool(jnp.all(out[0] == 0.0))
    assert bool(jnp.all(jnp.isfinite(out)))
    want1 = R.flash_decode_ref(q[1:], k[1:], v[1:], jnp.asarray([S]))
    np.testing.assert_allclose(np.asarray(out[1:]), np.asarray(want1), rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Paged flash-decode: K/V in a shared block pool, gathered via block tables
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_matches_gathered_ref(dtype):
    B, H, dh, bs, n_blocks, M = 2, 4, 32, 16, 12, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, dh), dtype)
    kp = jax.random.normal(ks[1], (n_blocks, bs, H, dh), dtype)
    vp = jax.random.normal(ks[2], (n_blocks, bs, H, dh), dtype)
    # scattered, non-monotone physical blocks; 0 = null for unallocated
    tbl = jnp.asarray([[3, 7, 2, 0], [9, 4, 0, 0]], jnp.int32)
    lens = jnp.asarray([41, 20], jnp.int32)
    got = paged_decode(q, kp, vp, tbl, lens)
    gk = kp[tbl].reshape(B, M * bs, H, dh)
    gv = vp[tbl].reshape(B, M * bs, H, dh)
    want = R.flash_decode_ref(q, gk, gv, lens)
    tol = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol)


def test_paged_decode_matches_contiguous():
    """A paged pool whose table is the identity permutation must reproduce
    the contiguous flash_decode bit-for-bit semantics."""
    B, S, H, dh, bs = 2, 128, 4, 32, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q = jax.random.normal(ks[0], (B, H, dh))
    k = jax.random.normal(ks[1], (B, S, H, dh))
    v = jax.random.normal(ks[2], (B, S, H, dh))
    lens = jnp.asarray([100, 128], jnp.int32)
    M = S // bs
    # row 0's lanes become blocks 0..3, row 1's blocks 4..7
    kp = k.reshape(B * M, bs, H, dh)
    vp = v.reshape(B * M, bs, H, dh)
    tbl = jnp.arange(B * M, dtype=jnp.int32).reshape(B, M)
    got = paged_decode(q, kp, vp, tbl, lens)
    want = flash_decode(q, k, v, lens, bs=bs)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-6, atol=2e-6)


def test_paged_decode_empty_row():
    B, H, dh, bs = 2, 2, 16, 8
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, H, dh))
    kp = jax.random.normal(ks[1], (6, bs, H, dh))
    vp = jax.random.normal(ks[2], (6, bs, H, dh))
    tbl = jnp.asarray([[2, 3], [4, 5]], jnp.int32)
    out = paged_decode(q, kp, vp, tbl, jnp.asarray([0, 12], jnp.int32))
    assert bool(jnp.all(out[0] == 0.0))
    want1 = R.flash_decode_ref(
        q[1:], kp[tbl[1]].reshape(1, 2 * bs, H, dh),
        vp[tbl[1]].reshape(1, 2 * bs, H, dh), jnp.asarray([12]),
    )
    np.testing.assert_allclose(np.asarray(out[1:]), np.asarray(want1), rtol=2e-5, atol=2e-5)
