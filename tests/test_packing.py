"""Packing round-trip properties (hypothesis) — the deployed HBM layout."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.packing import (
    compress_24,
    decompress_24,
    pack_dense_24,
    pack_idx2,
    pack_int4,
    unpack_dense_24,
    unpack_idx2,
    unpack_int4,
)
from repro.core.pruning import nm_mask


@given(st.integers(0, 500), st.sampled_from([(8, 4), (16, 8), (64, 32)]))
@settings(max_examples=20, deadline=None)
def test_int4_roundtrip(seed, shape):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(-8, 8, shape), jnp.int8)
    np.testing.assert_array_equal(np.asarray(unpack_int4(pack_int4(codes))), codes)


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_idx2_roundtrip(seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, 4, (32, 16)), jnp.uint8)
    np.testing.assert_array_equal(np.asarray(unpack_idx2(pack_idx2(idx))), idx)


@given(st.integers(0, 500))
@settings(max_examples=20, deadline=None)
def test_24_roundtrip(seed):
    rng = np.random.default_rng(seed)
    d_in, d_out = 32, 16
    codes = jnp.asarray(rng.integers(-7, 8, (d_in, d_out)), jnp.int8)
    sal = jnp.asarray(rng.random((d_in, d_out)), jnp.float32)
    mask = nm_mask(sal, 2, 4)
    masked = (codes * mask.astype(jnp.int8)).astype(jnp.int8)
    vals, idx = compress_24(masked, mask)
    np.testing.assert_array_equal(
        np.asarray(decompress_24(vals, idx, d_in)), np.asarray(masked)
    )
    pv, pi = pack_dense_24(masked, mask)
    np.testing.assert_array_equal(
        np.asarray(unpack_dense_24(pv, pi, d_in)), np.asarray(masked)
    )
    # deployed layout is 3 bits/position: d_in/4 + d_in/8 bytes per column
    assert pv.shape == (d_in // 4, d_out) and pi.shape == (d_in // 8, d_out)


def test_leading_dims():
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(-7, 8, (3, 5, 16, 8)), jnp.int8)
    sal = jnp.asarray(rng.random((3, 5, 16, 8)), jnp.float32)
    mask = jnp.stack([jnp.stack([nm_mask(sal[i, j]) for j in range(5)]) for i in range(3)])
    masked = (codes * mask.astype(jnp.int8)).astype(jnp.int8)
    pv, pi = pack_dense_24(masked, mask)
    np.testing.assert_array_equal(
        np.asarray(unpack_dense_24(pv, pi, 16)), np.asarray(masked)
    )
