"""Model-level compression + PEFT integration tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import CompressionConfig
from repro.models import transformer as T
from repro.models.compress import compress_model, peft_mask, summarize_reports
from repro.models.config import LayerSpec, ModelConfig
from repro.optim import adafactor, apply_updates

V = 128


def _cfg():
    return ModelConfig(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=V, n_experts=4, top_k=2, moe_group=64,
        dtype="float32", q_chunk=32, vocab_chunk=32,
        period=(LayerSpec("attn"), LayerSpec("attn", moe=True)),
    )


def _batch(cfg, b=4, s=64):
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, V)
    return {"tokens": toks, "labels": toks}


class TestCompressModel:
    def test_all_matrices_compressed(self):
        cfg = _cfg()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        cp, reports = compress_model(params, cfg, batch, CompressionConfig(rank=16))
        # 2 attn x 4 proj + 1 mlp x 3 + 1 moe x 3 x 4 experts = 23
        assert len(reports) == 23
        s = summarize_reports(reports)
        assert s["err_reduction"] > 0.3  # adapters absorb a solid chunk

    def test_compressed_model_runs_and_close(self):
        cfg = _cfg()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        cp, _ = compress_model(params, cfg, batch, CompressionConfig(rank=16))
        l_dense = float(T.train_loss(params, cfg, batch))
        l_comp = float(T.train_loss(cp, cfg, batch))
        assert np.isfinite(l_comp)
        assert abs(l_comp - l_dense) < 1.0  # same ballpark at init scale

    def test_sequential_compression_uses_compressed_prefix(self):
        """Period 1 must be calibrated on period-0 COMPRESSED activations:
        compressing with an identity period-0 vs a noisy one must change
        period-1 adapters."""
        cfg = _cfg()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        cp, reports = compress_model(params, cfg, batch, CompressionConfig(rank=16))
        assert any(k.startswith("p0/") for k in reports)

    def test_peft_step_trains_only_adapters(self):
        cfg = _cfg()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        cp, _ = compress_model(params, cfg, batch, CompressionConfig(rank=16))
        mask = peft_mask(cp)
        init, update = adafactor(1e-3, mask=jax.tree.map(lambda m: bool(m), mask))
        state = init(cp)

        @jax.jit
        def step(p, s):
            l, g = jax.value_and_grad(lambda pp: T.train_loss(pp, cfg, batch), allow_int=True)(p)
            u, s = update(g, s, p)
            return apply_updates(p, u), s, l

        before = jax.tree.map(lambda a: a, cp)
        cp2, state, l0 = step(cp, state)
        _, _, l1 = step(cp2, state)
        assert bool(jnp.isfinite(l1))

        # frozen leaves identical; only lora_l / lora_r moved
        flat0 = jax.tree_util.tree_flatten_with_path(before)[0]
        flat1 = jax.tree_util.tree_flatten_with_path(cp2)[0]
        moved, frozen_same = 0, True
        for (p0, a0), (_p1, a1) in zip(flat0, flat1, strict=True):
            names = [str(getattr(x, "name", getattr(x, "key", ""))) for x in p0]
            is_lora = any(n in ("lora_l", "lora_r") for n in names)
            same = bool(jnp.all(a0 == a1)) if a0.size else True
            if is_lora and not same:
                moved += 1
            if not is_lora and not same:
                frozen_same = False
        assert moved > 0, "no adapter moved during PEFT"
        assert frozen_same, "a frozen (non-adapter) leaf changed"
