"""Continuous-batching serving subsystem tests.

Covers the ISSUE acceptance surface: scheduler slot recycling (including a
slot freed by EOS), per-slot position decode matching fresh static batches
bit-for-bit, hand-computable metrics, and continuous == static greedy
equivalence for dense and SLiM-compressed params.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch
from repro.models import transformer as T
from repro.models.compress import compress_model
from repro.serving import (
    ContinuousEngine,
    Request,
    RequestQueue,
    Scheduler,
    ServeEngine,
    ServingMetrics,
    synthetic_trace,
)

MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg, n, s, seed=7):
    return jax.random.randint(jax.random.PRNGKey(seed), (n, s), 0, cfg.vocab_size)


# ---------------------------------------------------------------------------
# Scheduler / queue (host-only)
# ---------------------------------------------------------------------------

class TestScheduler:
    def test_queue_arrival_gating(self):
        q = RequestQueue([Request(0, [1], arrival=1.0), Request(1, [1], arrival=0.0)])
        assert q.peek_ready(0.5).rid == 1
        assert q.pop_ready(0.5).rid == 1
        assert q.pop_ready(0.5) is None  # rid 0 not arrived yet
        assert q.peek_ready(0.5) is None
        assert q.next_arrival() == 1.0
        assert q.pop_ready(2.0).rid == 0

    def test_queue_fifo_on_equal_arrivals(self):
        """Heap ties break on submission order, deterministically."""
        q = RequestQueue()
        for rid in [3, 1, 4, 1, 5]:
            q.push(Request(rid, [1], arrival=0.0))
        q.push(Request(9, [1], arrival=-1.0))  # earlier arrival jumps ahead
        popped = [q.pop_ready(0.0).rid for _ in range(6)]
        assert popped == [9, 3, 1, 4, 1, 5]
        assert q.pop_ready(0.0) is None

    def test_admission_and_recycling(self):
        s = Scheduler(n_slots=2, max_len=64)
        for i in range(4):
            s.submit(Request(i, [1] * 4, arrival=0.0, max_new_tokens=4))
        first = s.admit(now=0.0)
        assert [slot for slot, _ in first] == [0, 1]
        assert s.admit(now=0.0) == []  # pool full
        s.release(0)  # EOS frees slot 0
        nxt = s.admit(now=0.0)
        assert len(nxt) == 1 and nxt[0][0] == 0  # recycled into the freed slot
        assert nxt[0][1].rid == 2
        assert s.running() == 2 and s.pending()

    def test_admission_control_rejects_oversized(self):
        s = Scheduler(n_slots=1, max_len=16)
        with pytest.raises(ValueError):
            s.submit(Request(0, [1] * 10, max_new_tokens=10))
        with pytest.raises(ValueError):
            s.submit(Request(1, []))
        with pytest.raises(ValueError):
            s.submit(Request(2, [1], max_new_tokens=0))

    def test_prefill_bucketing(self):
        s = Scheduler(n_slots=1, max_len=64, prefill_bucket=16)
        assert s.bucket_len(1) == 16
        assert s.bucket_len(16) == 16
        assert s.bucket_len(17) == 32
        assert s.bucket_len(60) == 64  # clamped to max_len
        assert Scheduler(1, 64).bucket_len(13) == 13  # bucketing off


# ---------------------------------------------------------------------------
# Shared sample/emit core
# ---------------------------------------------------------------------------

class TestSampleAndEmit:
    def test_eos_not_written_not_counted(self):
        from repro.serving.sampling import sample_and_emit

        logits = jnp.asarray([[0.0, 0.0, 10.0], [10.0, 0.0, 0.0]], jnp.float32)
        buf = jnp.full((2, 4), -7, jnp.int32)
        live = jnp.asarray([True, True])
        emitted = jnp.zeros((2,), jnp.int32)
        nxt, buf, emitted, hit_eos, _ = sample_and_emit(
            logits, 0.0, jax.random.PRNGKey(0), buf, live, emitted, eos=2
        )
        assert list(nxt) == [2, 0] and list(hit_eos) == [True, False]
        assert list(emitted) == [0, 1]  # EOS row emitted nothing
        assert list(buf[0]) == [-7, -7, -7, -7]  # EOS never hits the buffer
        assert list(buf[1]) == [0, -7, -7, -7]

    def test_greedy_rows_skip_temperature_divide(self):
        """t == 0 rows must not feed logits / ~0 (== +-inf) into the
        discarded categorical draw; extreme logits stay finite and the
        greedy argmax is returned."""
        from repro.serving.sampling import sample_and_emit

        with jax.debug_nans(True):
            logits = jnp.asarray([[3e38, -3e38, 0.0]], jnp.float32)
            nxt, *_ = sample_and_emit(
                logits, 0.0, jax.random.PRNGKey(0),
                jnp.zeros((1, 2), jnp.int32), jnp.asarray([True]),
                jnp.zeros((1,), jnp.int32), eos=-1,
            )
            assert int(nxt[0]) == 0


# ---------------------------------------------------------------------------
# Per-slot positions / slot-targeted prefill
# ---------------------------------------------------------------------------

class TestPerSlotDecode:
    def test_matches_fresh_static_batch(self, model):
        """Slot-prefilled cache + per-slot position decode reproduces the
        logits of an equivalent fresh static batch, slot by slot."""
        cfg, params = model
        p_long = _prompts(cfg, 1, 12, seed=1)
        p_short = _prompts(cfg, 1, 7, seed=2)

        # fresh static references (each prompt alone, scalar pos)
        def solo(prompt, steps=3):
            logits, cache = T.prefill(params, cfg, {"tokens": prompt}, max_len=MAX_LEN)
            toks, ls = [], []
            for i in range(steps):
                nxt = jnp.argmax(logits, -1).astype(jnp.int32)
                toks.append(int(nxt[0]))
                ls.append(logits)
                logits, cache = T.decode_step(
                    params, cfg, cache, nxt[:, None], jnp.int32(prompt.shape[1] + i)
                )
            return toks, ls

        ref_long, logits_long = solo(p_long)
        ref_short, logits_short = solo(p_short)

        # batched: two slot-targeted prefills (one ragged) + vector-pos decode
        cache = T.init_cache(cfg, 2, MAX_LEN)
        l0, cache = T.prefill_slot(params, cfg, cache, {"tokens": p_long}, 0, MAX_LEN)
        pad = jnp.zeros((1, 5), p_short.dtype)
        l1, cache = T.prefill_slot(
            params, cfg, cache, {"tokens": jnp.concatenate([p_short, pad], 1)},
            1, MAX_LEN, true_len=7,
        )
        logits = jnp.stack([l0[0], l1[0]])
        pos = jnp.array([12, 7], jnp.int32)
        out = [[], []]
        for i in range(3):
            assert jnp.allclose(logits[0], logits_long[i][0], atol=1e-5)
            assert jnp.allclose(logits[1], logits_short[i][0], atol=1e-5)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
            out[0].append(int(nxt[0]))
            out[1].append(int(nxt[1]))
            logits, cache = T.decode_step(params, cfg, cache, nxt[:, None], pos)
            pos = pos + 1
        assert out[0] == ref_long
        assert out[1] == ref_short

    def test_ragged_prefill_exact(self, model):
        cfg, params = model
        p = _prompts(cfg, 1, 9, seed=3)
        exact, _ = T.prefill(params, cfg, {"tokens": p}, max_len=MAX_LEN)
        padded = jnp.concatenate([p, jnp.zeros((1, 7), p.dtype)], 1)
        ragged, _ = T.prefill_ragged(params, cfg, {"tokens": padded}, MAX_LEN, 9)
        assert jnp.allclose(exact, ragged, atol=1e-5)

    def test_ragged_prefill_guard(self, model):
        """Ragged prefill is refused where padding is inexact — SSM/MoE
        periods and sliding-window ring caches (pad tokens evict real
        in-window keys during the ring roll)."""
        cfg, _ = model
        assert T.supports_ragged_prefill(cfg)
        assert not T.supports_ragged_prefill(
            dataclasses.replace(cfg, sliding_window=8)
        )

    def test_scalar_pos_still_supported(self, model):
        cfg, params = model
        p = _prompts(cfg, 2, 8, seed=4)
        _, cache = T.prefill(params, cfg, {"tokens": p}, max_len=MAX_LEN)
        tok = jnp.zeros((2, 1), jnp.int32)
        d_scalar, _ = T.decode_step(params, cfg, cache, tok, jnp.int32(8))
        _, cache2 = T.prefill(params, cfg, {"tokens": p}, max_len=MAX_LEN)
        d_vec, _ = T.decode_step(params, cfg, cache2, tok, jnp.full((2,), 8, jnp.int32))
        assert jnp.allclose(d_scalar, d_vec)


# ---------------------------------------------------------------------------
# Continuous engine end-to-end
# ---------------------------------------------------------------------------

def _as_requests(prompts, max_new=6, temperature=0.0):
    return [
        Request(
            rid=i, prompt=[int(t) for t in prompts[i]], arrival=0.0,
            max_new_tokens=max_new, temperature=temperature,
        )
        for i in range(prompts.shape[0])
    ]


class TestContinuousEngine:
    def test_matches_static_greedy_dense(self, model):
        cfg, params = model
        prompts = _prompts(cfg, 3, 10)
        static = ServeEngine(params, cfg, max_len=MAX_LEN)
        ref = static.generate({"tokens": prompts}, max_new_tokens=6)
        eng = ContinuousEngine(params, cfg, n_slots=3, max_len=MAX_LEN)
        res = eng.run(_as_requests(prompts), sync_every=2)
        assert [res.outputs[i] for i in range(3)] == ref.tokens
        m = res.metrics
        assert m["completed"] == 3 and m["total_tokens"] == 18
        assert m["tokens_per_s"] > 0 and 0 < m["mean_occupancy"] <= 1

    def test_matches_static_greedy_compressed(self, model):
        cfg, params = model
        dcfg = SyntheticLMConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0
        )
        calib = calibration_batch(dcfg, n_samples=4)
        cp, _ = compress_model(
            params, cfg, calib,
            CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
        )
        prompts = _prompts(cfg, 2, 8)
        static = ServeEngine(cp, cfg, max_len=MAX_LEN)
        ref = static.generate({"tokens": prompts}, max_new_tokens=5)
        eng = ContinuousEngine(cp, cfg, n_slots=2, max_len=MAX_LEN)
        res = eng.run(_as_requests(prompts, max_new=5), sync_every=3)
        assert [res.outputs[i] for i in range(2)] == ref.tokens

    def test_eos_frees_slot_for_queued_request(self, model):
        """A queued request is admitted into the slot its predecessor freed
        via EOS, and neither output is corrupted by the recycling."""
        cfg, params = model
        prompts = _prompts(cfg, 2, 10)
        static = ServeEngine(params, cfg, max_len=MAX_LEN)
        probe = static.generate({"tokens": prompts[:1]}, max_new_tokens=8)
        eos = probe.tokens[0][2]  # a token the model emits at step 3

        static_eos = ServeEngine(params, cfg, max_len=MAX_LEN, eos_id=eos)
        ref0 = static_eos.generate({"tokens": prompts[:1]}, max_new_tokens=8, sync_every=2)
        ref1 = static_eos.generate({"tokens": prompts[1:2]}, max_new_tokens=8, sync_every=2)

        eng = ContinuousEngine(params, cfg, n_slots=1, max_len=MAX_LEN, eos_id=eos)
        res = eng.run(_as_requests(prompts, max_new=8), sync_every=2)
        # rid 0 stopped at EOS (shorter than budget) and freed the only slot
        assert res.outputs[0] == ref0.tokens[0]
        assert len(res.outputs[0]) < 8
        assert res.outputs[1] == ref1.tokens[0]
        assert res.slot_of == {0: 0, 1: 0}  # both ran in the recycled slot
        # the stop token is a signal, not output: callers never see it and
        # it doesn't count toward total_tokens / tokens_per_s
        assert all(eos not in out for out in res.outputs.values())
        n_real = sum(len(out) for out in res.outputs.values())
        assert res.metrics["total_tokens"] == n_real

    def test_more_requests_than_slots_ragged(self, model):
        """Staggered arrivals, ragged prompts and budgets, bucketing on:
        every recycled output equals its solo static run."""
        cfg, params = model
        trace = synthetic_trace(
            5, rate=100.0, vocab_size=cfg.vocab_size,
            prompt_len=(5, 12), max_new_tokens=(3, 6), seed=11,
        )
        eng = ContinuousEngine(
            params, cfg, n_slots=2, max_len=MAX_LEN, prefill_bucket=4
        )
        res = eng.run(trace, sync_every=2)
        static = ServeEngine(params, cfg, max_len=MAX_LEN)
        for r in res.requests:
            solo = static.generate(
                {"tokens": jnp.asarray([r.prompt], jnp.int32)},
                max_new_tokens=r.max_new_tokens,
            )
            assert solo.tokens[0] == r.output, r.rid
        assert res.metrics["completed"] == 5


# ---------------------------------------------------------------------------
# Metrics vs a hand-computed trace
# ---------------------------------------------------------------------------

class TestMetrics:
    def test_hand_computed_trace(self):
        m = ServingMetrics(n_slots=2)
        # rid 0: arrives 0, first token 1, finishes 3 with 4 tokens
        # rid 1: arrives 1, first token 1.5, finishes 5 with 8 tokens
        # rid 2: arrives 2, admitted 3 (queued), first 3.5, finishes 6, 4 toks
        for rid, arr in [(0, 0.0), (1, 1.0), (2, 2.0)]:
            m.on_submit(rid, arr)
        m.on_admit(0, 0.0); m.on_first_token(0, 1.0); m.on_finish(0, 3.0, 4)
        m.on_admit(1, 1.0); m.on_first_token(1, 1.5); m.on_finish(1, 5.0, 8)
        m.on_admit(2, 3.0); m.on_first_token(2, 3.5); m.on_finish(2, 6.0, 4)
        for occ in [1, 2, 2, 2, 1]:
            m.on_occupancy(occ)
        s = m.summary()
        # TTFTs: 1.0, 0.5, 1.5 -> mean 1.0, p95 = 1.5
        assert s["mean_ttft_s"] == pytest.approx(1.0)
        assert s["p95_ttft_s"] == pytest.approx(1.5)
        # latencies: 3, 4, 4 -> mean 11/3
        assert s["mean_latency_s"] == pytest.approx(11 / 3)
        # 16 tokens over the 6s span
        assert s["total_tokens"] == 16
        assert s["tokens_per_s"] == pytest.approx(16 / 6.0)
        # occupancy: (1+2+2+2+1) / (5 samples * 2 slots)
        assert s["mean_occupancy"] == pytest.approx(0.8)

    def test_token_exact_occupancy(self):
        """When decode steps are recorded, occupancy is emitted tokens over
        slot-steps — the accounting both engines share."""
        m = ServingMetrics(n_slots=2)
        m.on_submit(0, 0.0)
        m.on_finish(0, 1.0, 12)
        m.on_decode_steps(10)  # 10 steps x 2 slots = 20 slot-steps
        assert m.summary()["mean_occupancy"] == pytest.approx(12 / 20)

    def test_request_trace_properties(self):
        m = ServingMetrics(n_slots=1)
        m.on_submit(0, 1.0)
        tr = m.requests[0]
        assert tr.ttft is None and tr.latency is None
        m.on_first_token(0, 2.5)
        m.on_finish(0, 4.0, 3)
        assert tr.ttft == pytest.approx(1.5)
        assert tr.latency == pytest.approx(3.0)
