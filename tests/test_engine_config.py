"""EngineConfig: the typed front door to ContinuousEngine.

Covers the api_redesign acceptance surface: JSON round-trips are lossless
(including nested GuardConfig ladder tuples), validate() rejects every
incoherent combination at construction, and the one-release legacy-kwarg
shim builds the identical engine while warning exactly once.
"""
import dataclasses
import warnings

import jax
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.serving import (
    ContinuousEngine,
    EngineConfig,
    GuardConfig,
    PagingConfig,
    ParallelConfig,
    PrefixCacheConfig,
    SpecConfig,
    synthetic_trace,
)
from repro.serving.config import LEGACY_KWARGS

MAX_LEN = 48


@pytest.fixture(scope="module")
def model():
    cfg = get_config("slim-tiny")
    cfg = dataclasses.replace(cfg, n_layers=2, d_model=128, d_ff=384, vocab_size=256)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


def full_config():
    """One config exercising every sub-config and the nested guard."""
    return EngineConfig(
        n_slots=4,
        max_len=MAX_LEN,
        eos_id=7,
        prefill_bucket=8,
        seed=3,
        check_invariants=True,
        check_retrace=True,
        paging=PagingConfig(
            block_size=8, n_blocks=40, preemption=True,
            decode_reserve=3, victim_policy="cost",
        ),
        prefix_cache=PrefixCacheConfig(enabled=True, max_entries=16, ttl=5.0),
        speculative=SpecConfig(k=4),
        parallel=ParallelConfig(tp=2),
        guard=GuardConfig(max_queue=6, default_ttl=2.0, degradation=True),
    )


class TestRoundTrip:
    def test_dict_round_trip_is_lossless(self):
        c = full_config()
        assert EngineConfig.from_dict(c.to_dict()) == c

    def test_json_round_trip_is_lossless(self):
        c = full_config()
        s = c.to_json()
        assert isinstance(s, str)
        assert EngineConfig.from_json(s) == c

    def test_default_round_trip(self):
        assert EngineConfig.from_json(EngineConfig().to_json()) == EngineConfig()

    def test_guard_ladder_tuples_survive(self):
        """JSON lists come back as the tuples GuardConfig compares with."""
        c = EngineConfig(guard=GuardConfig(degradation=True))
        back = EngineConfig.from_json(c.to_json())
        assert back.guard.ladder_enter == c.guard.ladder_enter
        assert isinstance(back.guard.ladder_enter, tuple)

    def test_to_dict_is_plain_json_types(self):
        d = full_config().to_dict()
        assert d["paging"]["block_size"] == 8
        assert d["parallel"]["tp"] == 2
        assert isinstance(d["guard"]["ladder_enter"], list)


class TestValidate:
    def test_valid_config_chains(self):
        c = EngineConfig(paging=PagingConfig(block_size=8))
        assert c.validate() is c

    @pytest.mark.parametrize(
        "cfg_kwargs, match",
        [
            (dict(n_slots=0), "n_slots"),
            (dict(max_len=0), "max_len"),
            (dict(prefill_bucket=-1), "prefill_bucket"),
            (dict(prefix_cache=PrefixCacheConfig(enabled=True)), "block_size"),
            (dict(paging=PagingConfig(preemption=True)), "preemption"),
            (
                dict(paging=PagingConfig(block_size=8, decode_reserve=-1)),
                "decode_reserve",
            ),
            (dict(speculative=SpecConfig(k=1)), "K >= 2"),
            (dict(speculative=SpecConfig(k=4)), "block_size"),
            (
                dict(prefix_cache=PrefixCacheConfig(max_entries=4)),
                "prefix_cache",
            ),
            (
                dict(paging=PagingConfig(block_size=8, victim_policy="oldest")),
                "victim_policy",
            ),
            (
                dict(paging=PagingConfig(block_size=8, victim_policy="cost")),
                "preemption",
            ),
            (dict(max_len=50, paging=PagingConfig(block_size=8)), "multiple"),
            (dict(parallel=ParallelConfig(tp=0)), "tp"),
        ],
    )
    def test_incoherent_combinations_rejected(self, cfg_kwargs, match):
        with pytest.raises(ValueError, match=match):
            EngineConfig(**cfg_kwargs).validate()

    def test_architecture_checks_need_model_cfg(self, model):
        """Sliding-window archs reject paging only once the model is known."""
        cfg, _ = model
        swa = dataclasses.replace(
            cfg, sliding_window=8, name="swa-tiny"
        )
        c = EngineConfig(max_len=MAX_LEN, paging=PagingConfig(block_size=8))
        c.validate()  # structural-only: fine
        if not T.supports_paged_cache(swa):
            with pytest.raises(ValueError, match="paged"):
                c.validate(swa)

    def test_engine_constructor_validates(self, model):
        cfg, params = model
        bad = EngineConfig(max_len=50, paging=PagingConfig(block_size=8))
        with pytest.raises(ValueError, match="multiple"):
            ContinuousEngine(params, cfg, bad)


class TestLegacyShim:
    def test_legacy_kwargs_map_onto_config(self):
        c = EngineConfig.from_legacy_kwargs(
            dict(
                n_slots=4, max_len=MAX_LEN, block_size=8, n_blocks=40,
                preemption=True, victim_policy="cost", prefix_cache=True,
                prefix_cache_max_entries=16, speculative=4, seed=3,
            )
        )
        assert c.n_slots == 4
        assert c.paging == PagingConfig(
            block_size=8, n_blocks=40, preemption=True, victim_policy="cost"
        )
        assert c.prefix_cache.enabled and c.prefix_cache.max_entries == 16
        assert c.speculative.k == 4 and c.seed == 3

    def test_every_legacy_kwarg_is_mapped(self):
        """The shim table covers a real destination for every old kwarg."""
        c = EngineConfig()
        for name, dest in LEGACY_KWARGS.items():
            if dest is None:
                assert hasattr(c, name)
            else:
                sub, field = dest
                assert hasattr(getattr(c, sub), field)

    def test_unknown_kwarg_raises_type_error(self):
        with pytest.raises(TypeError, match="bogus"):
            EngineConfig.from_legacy_kwargs(dict(bogus=1))

    def test_shim_warns_once_and_matches_config_engine(self, model):
        cfg, params = model
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            legacy = ContinuousEngine(
                params, cfg, n_slots=2, max_len=MAX_LEN,
                prefill_bucket=8, block_size=8,
            )
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(deps) == 1
        assert "EngineConfig" in str(deps[0].message)

        typed = ContinuousEngine(
            params, cfg,
            EngineConfig(
                n_slots=2, max_len=MAX_LEN, prefill_bucket=8,
                paging=PagingConfig(block_size=8),
            ),
        )
        assert legacy.config == typed.config
        trace = synthetic_trace(
            3, 1e6, cfg.vocab_size, prompt_len=(8, 12),
            max_new_tokens=(4, 6), seed=11,
        )
        a = legacy.run(trace, sync_every=4, max_new_cap=6)
        b = typed.run(
            synthetic_trace(
                3, 1e6, cfg.vocab_size, prompt_len=(8, 12),
                max_new_tokens=(4, 6), seed=11,
            ),
            sync_every=4, max_new_cap=6,
        )
        assert a.outputs == b.outputs

    def test_config_plus_legacy_kwargs_rejected(self, model):
        cfg, params = model
        with pytest.raises(TypeError, match="not both"):
            ContinuousEngine(params, cfg, EngineConfig(), n_slots=2)

    def test_config_engines_warn_nothing(self, model):
        cfg, params = model
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            ContinuousEngine(params, cfg, EngineConfig(max_len=MAX_LEN))
        assert not [
            x for x in w if issubclass(x.category, DeprecationWarning)
        ]
