"""Model-zoo behaviour: decode == full forward (cache exactness), SSD chunked
== naive recurrence, MoE dispatch conservation, loss chunking invariance."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import LayerSpec, ModelConfig

V = 64


def _cfg(**kw):
    base = dict(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=V, dtype="float32", q_chunk=16, vocab_chunk=16,
    )
    base.update(kw)
    return ModelConfig(**base)


def _consistency(cfg, S=33, vision=False):
    B = 2
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, V)
    batch = {"tokens": toks, "labels": toks}
    if vision:
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.vision_tokens, cfg.d_model)
        )
    x = T.embed_inputs(params, cfg, batch)
    h, _, _ = T.forward_hidden(params, cfg, x, vision=batch.get("vision_embeds"))
    full = L.linear(T._head_weights(params, cfg), h[:, -1:, :])[:, 0]
    pbatch = dict(batch)
    pbatch["tokens"] = toks[:, :S]
    _, cache = T.prefill(params, cfg, pbatch, max_len=S + 8)
    dec, _ = T.decode_step(
        params, cfg, cache, toks[:, S : S + 1], jnp.full((B,), S, jnp.int32)
    )
    return float(jnp.max(jnp.abs(dec - full)))


class TestCacheExactness:
    def test_dense(self):
        assert _consistency(_cfg()) < 2e-3

    def test_swa_ring(self):
        assert _consistency(_cfg(sliding_window=16)) < 2e-3

    def test_qk_norm(self):
        assert _consistency(_cfg(qk_norm=True)) < 2e-3

    def test_mamba(self):
        cfg = _cfg(
            n_heads=0, n_kv_heads=0, d_head=0, ssm_state=16, ssm_head_dim=16,
            ssm_chunk=8, period=(LayerSpec("ssm"),),
        )
        assert _consistency(cfg) < 2e-3

    def test_hybrid_moe(self):
        cfg = _cfg(
            n_experts=4, top_k=2, ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
            moe_group=64, n_layers=4, capacity_factor=4.0,
            period=(
                LayerSpec("ssm"), LayerSpec("ssm", moe=True),
                LayerSpec("attn"), LayerSpec("ssm", moe=True),
            ),
        )
        # capacity_factor=4 -> no drops -> prefill/decode grouping agrees
        assert _consistency(cfg) < 2e-3

    def test_vlm(self):
        cfg = _cfg(
            n_layers=4, vision_tokens=16,
            period=(LayerSpec("attn"), LayerSpec("cross_attn")),
        )
        assert _consistency(cfg, vision=True) < 2e-3


class TestSSD:
    def test_chunked_matches_naive_recurrence(self):
        """ssd_chunked == step-by-step linear recurrence (the SSD duality)."""
        b, l, h, p, n = 2, 24, 4, 8, 16
        key = jax.random.PRNGKey(0)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bm = jax.random.normal(ks[3], (b, l, 1, n)) * 0.5
        cm = jax.random.normal(ks[4], (b, l, 1, n)) * 0.5
        y_c, state_c = L.ssd_chunked(x, dt, a, bm, cm, chunk=8)

        # naive recurrence
        state = jnp.zeros((b, h, p, n))
        ys = []
        for t in range(l):
            da = jnp.exp(dt[:, t] * a)  # [b, h]
            bh = jnp.broadcast_to(bm[:, t], (b, h, n))
            ch = jnp.broadcast_to(cm[:, t], (b, h, n))
            dbx = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, t], x[:, t], bh)
            state = state * da[..., None, None] + dbx
            ys.append(jnp.einsum("bhpn,bhn->bhp", state, ch))
        y_n = jnp.stack(ys, axis=1)
        np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_n), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(state_c), np.asarray(state), rtol=2e-4, atol=2e-4)

    def test_pad_is_noop(self):
        b, l, h, p, n = 1, 20, 2, 4, 8  # 20 % 8 != 0 -> pad path
        key = jax.random.PRNGKey(1)
        ks = jax.random.split(key, 5)
        x = jax.random.normal(ks[0], (b, l, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
        bm = jax.random.normal(ks[3], (b, l, 1, n)) * 0.5
        cm = jax.random.normal(ks[4], (b, l, 1, n)) * 0.5
        y8, s8 = L.ssd_chunked(x, dt, a, bm, cm, chunk=8)
        y4, s4 = L.ssd_chunked(x, dt, a, bm, cm, chunk=4)
        np.testing.assert_allclose(np.asarray(y8), np.asarray(y4), rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s8), np.asarray(s4), rtol=2e-4, atol=2e-4)


class TestMoE:
    def test_combine_weights_sum_to_gate(self):
        cfg = _cfg(n_experts=4, top_k=2, moe_group=32,
                   period=(LayerSpec("attn", moe=True),), capacity_factor=4.0)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
        p = jax.tree.map(lambda a: a[0], params["blocks"])["layer_0"]
        y, aux = L.moe_layer(p["moe"], x, cfg)
        assert y.shape == x.shape
        assert float(aux) >= 1.0 - 1e-3  # Switch aux loss lower bound is 1

    def test_capacity_drops_tokens(self):
        cfg = _cfg(n_experts=2, top_k=1, moe_group=32,
                   period=(LayerSpec("attn", moe=True),), capacity_factor=0.25)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        p = jax.tree.map(lambda a: a[0], params["blocks"])["layer_0"]
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 64))
        y, _ = L.moe_layer(p["moe"], x, cfg)
        # dropped tokens pass through the residual only: y == x for them
        diff = jnp.abs(y - x).sum(-1)
        assert float((diff < 1e-6).mean()) > 0.3


class TestLoss:
    def test_chunk_invariance(self):
        cfg = _cfg(vocab_chunk=8)
        cfg2 = _cfg(vocab_chunk=32)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, V)
        b = {"tokens": toks, "labels": toks}
        l1 = float(T.train_loss(params, cfg, b))
        l2 = float(T.train_loss(params, cfg2, b))
        assert abs(l1 - l2) < 1e-4

    def test_unroll_matches_scan(self):
        import dataclasses
        cfg = _cfg()
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, V)
        b = {"tokens": toks, "labels": toks}
        l_scan = float(T.train_loss(params, cfg, b))
        l_unroll = float(
            T.train_loss(params, dataclasses.replace(cfg, unroll_layers=True), b)
        )
        assert abs(l_scan - l_unroll) < 1e-4

    def test_sqrt_remat_matches_flat(self):
        import dataclasses
        cfg = _cfg(n_layers=4)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, V)
        b = {"tokens": toks, "labels": toks}
        l_flat = T.train_loss(params, cfg, b)
        cfg2 = dataclasses.replace(cfg, scan_groups=2)
        l_sqrt = T.train_loss(params, cfg2, b)
        assert abs(float(l_flat) - float(l_sqrt)) < 1e-4
        g1 = jax.grad(lambda p: T.train_loss(p, cfg, b))(params)
        g2 = jax.grad(lambda p: T.train_loss(p, cfg2, b))(params)
        for a, c in zip(jax.tree.leaves(g1), jax.tree.leaves(g2), strict=True):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-3, atol=1e-5)
