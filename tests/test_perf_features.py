"""Perf-iteration feature tests (EXPERIMENTS §Perf toggles): packed int4
adapters, int8 KV cache, low-precision attention probs, sqrt remat."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressed import slim_linear_apply
from repro.core.pipeline import CalibStats, CompressionConfig, compress_matrix
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig

V = 64


def _cfg(**kw):
    base = dict(
        name="t", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
        d_ff=128, vocab_size=V, dtype="float32", q_chunk=16, vocab_chunk=16,
    )
    base.update(kw)
    return ModelConfig(**base)


class TestPackedAdapters:
    def test_close_to_fp_adapters(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(0, 0.08, (128, 64)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (64, 128)), jnp.float32)
        stats = CalibStats.init(128).update(x)
        p_fp, _ = compress_matrix(w, stats, CompressionConfig(adapter="slim", rank=16))
        p_pk, _ = compress_matrix(
            w, stats, CompressionConfig(adapter="slim", rank=16, pack_adapters=True)
        )
        y_fp = slim_linear_apply(p_fp, x)
        y_pk = slim_linear_apply(p_pk, x)
        rel = float(jnp.linalg.norm(y_pk - y_fp) / jnp.linalg.norm(y_fp))
        assert rel < 0.08
        assert p_pk.lora_l.dtype == jnp.uint8
        assert p_pk.packed_bytes() < p_fp.packed_bytes()

    def test_byte_accounting(self):
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.normal(0, 0.08, (256, 128)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (32, 256)), jnp.float32)
        stats = CalibStats.init(256).update(x)
        p, _ = compress_matrix(
            w, stats, CompressionConfig(adapter="slim", rank=32, pack_adapters=True)
        )
        # adapters: (256*32 + 32*128)/2 bytes packed
        assert p.lora_l.shape == (128, 32)
        assert p.lora_r.shape == (16, 128)


class TestKVQuant:
    def test_decode_consistency(self):
        cfg = _cfg()
        cfgq = dataclasses.replace(cfg, kv_quant=True)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 33), 0, V)
        x = T.embed_inputs(params, cfg, {"tokens": toks})
        h, _, _ = T.forward_hidden(params, cfg, x)
        full = L.linear(T._head_weights(params, cfg), h[:, -1:, :])[:, 0]
        _, cache = T.prefill(params, cfgq, {"tokens": toks[:, :32]}, max_len=40)
        dec, _ = T.decode_step(
            params, cfgq, cache, toks[:, 32:33], jnp.full((2,), 32, jnp.int32)
        )
        # int8 KV costs a small, bounded error
        err = float(jnp.max(jnp.abs(dec - full)))
        assert err < 0.25, err
        assert cache["layer_0"]["k"].dtype == jnp.int8

    def test_swa_ring_with_kv_quant(self):
        cfgq = _cfg(sliding_window=16, kv_quant=True)
        params = T.init_params(cfgq, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, V)
        _, cache = T.prefill(params, cfgq, {"tokens": toks}, max_len=32)
        dec, cache = T.decode_step(
            params, cfgq, cache, toks[:, :1], jnp.full((2,), 24, jnp.int32)
        )
        assert bool(jnp.all(jnp.isfinite(dec)))
        assert cache["layer_0"]["k_scale"].shape[-1] == cfgq.n_kv_heads


class TestProbsLowPrecision:
    def test_close_to_f32(self):
        cfg = _cfg()
        cfgp = dataclasses.replace(cfg, attn_probs_low_precision=True)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, V)
        b = {"tokens": toks, "labels": toks}
        l0 = float(T.train_loss(params, cfg, b))
        l1 = float(T.train_loss(params, cfgp, b))
        assert abs(l0 - l1) < 5e-3  # f32 model: cast is exact modulo rounding
