"""Kernel-level microbenchmark: interpret-mode correctness timing is not a
TPU wall-clock (documented) — what this table contributes is the exact HBM
byte audit per kernel input layout (the quantity the roofline speedup model
consumes) plus XLA-path timings of the same math on CPU."""
import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, timed
from repro.core.compressed import slim_linear_apply, build_slim_linear
from repro.core.pruning import nm_mask


def run(table: Table):
    rng = np.random.default_rng(0)
    m, k, n, r = 64, 1024, 1024, 104
    x = jnp.asarray(rng.normal(0, 1, (m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(0, 0.05, (k, n)), jnp.float32)
    codes = jnp.clip(jnp.round(w / 0.2 * 8), -7, 7).astype(jnp.int8)
    mask = nm_mask(jnp.abs(w), 2, 4)

    dense_bytes = k * n * 2
    int4_bytes = k * n // 2
    slim_bytes = k * n // 4 + k * n // 8 + (k * r + r * n) // 2

    f_dense = jax.jit(lambda a, b: a @ b)
    _, us_dense = timed(lambda: f_dense(x, w), repeat=5)

    p = build_slim_linear(
        (codes * mask.astype(jnp.int8)).astype(jnp.int8), mask,
        jnp.float32(0.2), 4, 0, "2:4",
        lora_l=jnp.asarray(rng.normal(0, 0.02, (k, r)), jnp.float32),
        lora_r=jnp.asarray(rng.normal(0, 0.02, (r, n)), jnp.float32),
    )
    f_slim = jax.jit(lambda pp, a: slim_linear_apply(pp, a))
    _, us_slim = timed(lambda: f_slim(p, x), repeat=5)

    table.add(
        "xla_path_1024x1024",
        us_dense,
        us_dense=round(us_dense, 1),
        us_slim_xla=round(us_slim, 1),
        weight_bytes_dense=dense_bytes,
        weight_bytes_int4=int4_bytes,
        weight_bytes_slim24_with_adapters=slim_bytes,
        byte_reduction=round(dense_bytes / slim_bytes, 2),
        measured_packed_bytes=p.packed_bytes(),
    )


def main():
    t = Table("kernel_bytes")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
