"""Paper Table 21: wall-clock compression cost by method and layer size —
plus our beyond-paper randomized-SVD variant (EXPERIMENTS §Perf, compression
cost iteration)."""
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table
from repro.core import CalibStats, CompressionConfig, compress_matrix


def run(table: Table):
    rng = np.random.default_rng(0)
    for d in [256, 512, 1024]:
        w = jnp.asarray(rng.normal(0, 0.05, (d, d)), jnp.float32)
        x = jnp.asarray(rng.normal(0, 1, (256, d)), jnp.float32)
        stats = CalibStats.init(d, with_hessian=True).update(x)
        methods = [
            ("magnitude+absmax", CompressionConfig(quantizer="absmax", pruner="magnitude", adapter="none")),
            ("wanda+slim_quant", CompressionConfig(quantizer="slim", pruner="wanda", adapter="none")),
            ("sparsegpt+optq", CompressionConfig(quantizer="optq", pruner="sparsegpt", adapter="none")),
            ("slim_full_exact_svd", CompressionConfig(quantizer="slim", pruner="wanda", adapter="slim")),
            ("slim_full_randomized_svd", CompressionConfig(quantizer="slim", pruner="wanda", adapter="slim", svd_method="randomized")),
        ]
        for label, ccfg in methods:
            t0 = time.time()
            compress_matrix(w, stats, ccfg)
            dt = time.time() - t0
            table.add(f"d{d}/{label}", dt * 1e6, seconds=round(dt, 3))


def main():
    t = Table("table21_compression_cost")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
