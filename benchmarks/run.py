"""Benchmark driver: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run table1     # substring filter

Emits ``name,us_per_call,derived`` CSV lines per the repo convention.
Set BENCH_TRAIN_STEPS to trade training time for benchmark signal
(default 150; the shared tiny model is cached under /tmp/slim_bench_cache).
"""
import sys
import time
import traceback

from benchmarks import (
    bench_accuracy,
    bench_calib,
    bench_compression_cost,
    bench_finetune,
    bench_flops,
    bench_kernels,
    bench_memory,
    bench_multipod,
    bench_quant_error,
    bench_rank,
    bench_serving,
    bench_sparsity,
    bench_sparsity_vs_quant,
    bench_speedup,
)
from benchmarks.common import Table

MODULES = [
    ("table1_accuracy", bench_accuracy),
    ("table2_finetune", bench_finetune),
    ("table8_quant_only", bench_quant_error),
    ("table16_sparsity_vs_quant", bench_sparsity_vs_quant),
    ("table19_memory", bench_memory),
    ("table20_flops", bench_flops),
    ("table21_compression_cost", bench_compression_cost),
    ("fig3_speedup", bench_speedup),
    ("fig5a_rank", bench_rank),
    ("fig5b_calib", bench_calib),
    ("fig6_sparsity", bench_sparsity),
    ("kernel_bytes", bench_kernels),
    ("multipod_scaling", bench_multipod),
    ("serving_continuous", bench_serving),
]


def main() -> None:
    flt = sys.argv[1] if len(sys.argv) > 1 else ""
    failures = []
    for name, mod in MODULES:
        if flt and flt not in name:
            continue
        t0 = time.time()
        table = Table(name)
        try:
            mod.run(table)
            table.emit()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:\n{traceback.format_exc()}", flush=True)
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks ok")


if __name__ == "__main__":
    main()
