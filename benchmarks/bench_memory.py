"""Paper Table 19 (Eq. 12): memory-reduction ratios, analytic + measured.

Analytic: compressed/dense byte ratio from Eq. 12 generalized to each
assigned architecture. Measured: exact packed bytes of a compressed tiny
model (SlimLinear.packed_bytes) vs its dense fp16 bytes.
"""
import jax

from benchmarks.common import Table, compress_with, trained_model
from repro.configs import ASSIGNED, get_config
from repro.core.compressed import SlimLinear
from repro.core.pipeline import CompressionConfig


def eq12_ratio(cfg, rank_ratio=0.1, adapters_quantized=True, bits=4, sparsity=0.5):
    """Compressed/dense bytes for block matmuls + embeddings (Eq. 12 style)."""
    d = cfg.d_model
    n_block = cfg.param_count() - cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    n_embed = cfg.param_count() - n_block
    dense_bytes = (n_block + n_embed) * 2  # bf16
    # base: bits on surviving weights + 2-bit 2:4 metadata on all positions
    base_bits = bits * sparsity + 2 * 0.5
    adapter_params = 2 * rank_ratio * n_block  # L and R per matmul, r=0.1 d
    adapter_bits = (bits if adapters_quantized else 16)
    comp_bytes = (
        n_block * base_bits / 8
        + adapter_params * adapter_bits / 8
        + n_embed * 2
    )
    return comp_bytes / dense_bytes


def run(table: Table):
    for arch in ASSIGNED:
        cfg = get_config(arch)
        table.add(
            f"analytic/{arch}",
            ratio_slim_q=round(eq12_ratio(cfg, adapters_quantized=True), 3),
            ratio_slim=round(eq12_ratio(cfg, adapters_quantized=False), 3),
            ratio_wanda_absmax=round(eq12_ratio(cfg, rank_ratio=0.0), 3),
        )

    # measured on the tiny trained model
    cfg, dcfg, params = trained_model()
    dense_bytes = sum(
        x.size * 2 for x in jax.tree.leaves(params)
    )  # as-if bf16 deployment
    cp, _ = compress_with(
        params, cfg, dcfg,
        CompressionConfig(quantizer="slim", pruner="wanda", adapter="slim",
                          rank=24, quantize_adapters=True),
    )
    comp_bytes = 0
    for leaf in jax.tree.leaves(
        cp, is_leaf=lambda x: isinstance(x, SlimLinear)
    ):
        if isinstance(leaf, SlimLinear):
            comp_bytes += leaf.packed_bytes()
        else:
            comp_bytes += leaf.size * 2
    table.add(
        "measured/slim-tiny",
        dense_mb=round(dense_bytes / 2 ** 20, 2),
        compressed_mb=round(comp_bytes / 2 ** 20, 2),
        ratio=round(comp_bytes / dense_bytes, 3),
    )


def main():
    t = Table("table19_memory")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
