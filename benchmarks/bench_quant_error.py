"""Paper Table 8/14 (quantization-only) + Alg. 1 validation: per-tensor
reconstruction + layer output error for each quantizer, with/without one-shot
adapters; plus SLiM-Quant multigrid vs exhaustive-grid optimality gap."""
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Table, timed
from repro.core import (
    absmax_quantize,
    group_absmax_quantize,
    optq_quantize,
    slim_quantize,
)
from repro.core.slim_quant import estimate_error_curve, slim_quantize_activation_aware


def run(table: Table):
    rng = np.random.default_rng(0)
    d_in, d_out, n = 512, 256, 1024
    # heavy-tailed weights (LLM-like): gaussian + student-t outliers
    w = rng.normal(0, 0.05, (d_in, d_out))
    w += rng.standard_t(3, (d_in, d_out)) * 0.01
    w = jnp.asarray(w, jnp.float32)
    x = jnp.asarray(rng.normal(0, 1, (n, d_in)) * (0.5 + rng.random(d_in)), jnp.float32)
    x_absmean = jnp.mean(jnp.abs(x), axis=0)
    wnorm = float(jnp.sum(w ** 2))
    onorm = float(jnp.sum((x @ w) ** 2))

    def report(label, qt, us, cs=None):
        w_hat = qt.dequantize()
        if cs is not None:
            w_hat = w_hat / cs[:, None]
        rec = float(jnp.sum((w_hat - w) ** 2)) / wnorm
        out = float(jnp.sum((x @ (w_hat - w)) ** 2)) / onorm
        table.add(label, us, rel_recon_err=round(rec, 6), rel_out_err=round(out, 6))

    _, us = timed(lambda: absmax_quantize(w, 4), repeat=3)
    report("absmax", absmax_quantize(w, 4), us)
    _, us = timed(lambda: group_absmax_quantize(w, 4, 128), repeat=3)
    report("group_absmax_128", group_absmax_quantize(w, 4, 128), us)
    _, us = timed(lambda: slim_quantize(w, 4), repeat=3)
    report("slim_quant_w", slim_quantize(w, 4), us)
    qt, cs = slim_quantize_activation_aware(w, x_absmean, 4)
    report("slim_quant_o", qt, 0.0, cs)
    h = x.T @ x
    qt, us = timed(lambda: optq_quantize(w, h, 4, 128), repeat=1)
    report("optq_group_128", qt, us)

    # Alg. 1 optimality: multigrid error vs dense exhaustive grid
    qs = slim_quantize(w, 4)
    grid = jnp.linspace(1e-4, float(jnp.max(jnp.abs(w))), 4096)
    errs = estimate_error_curve(w, grid, 4)
    e_best = float(jnp.min(errs))
    e_mg = float(estimate_error_curve(w, jnp.asarray([qs.scale]), 4)[0])
    table.add(
        "alg1_multigrid_vs_exhaustive",
        0.0,
        multigrid_err=round(e_mg, 8),
        exhaustive_err=round(e_best, 8),
        gap_pct=round(100 * (e_mg / e_best - 1), 3),
    )


def main():
    t = Table("table8_quant_only")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
