"""Shared benchmark harness: a once-trained tiny LM (OPT-125M-shaped but
CPU-sized), calibration data, eval perplexity, and CSV emission.

The paper's tables are zero-shot accuracy on public checkpoints; offline we
substitute a model trained to signal on the deterministic Markov stream —
method *orderings* (the claims) are what the benchmarks reproduce.
"""
from __future__ import annotations

import json
import math
import os
import time
from typing import Any, Callable, Dict, List

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch, synthetic_batches
from repro.models import transformer as T
from repro.models.compress import compress_model
from repro.optim import adamw, apply_updates, cosine_schedule

_CACHE_DIR = os.environ.get("BENCH_CACHE", "/tmp/slim_bench_cache")
TRAIN_STEPS = int(os.environ.get("BENCH_TRAIN_STEPS", "150"))


def bench_config():
    import dataclasses as dc

    cfg = get_config("slim-tiny")
    return dc.replace(cfg, n_layers=4, d_model=192, d_ff=576, n_heads=6,
                      n_kv_heads=6, d_head=32, vocab_size=512)


def data_config(cfg, seq=128, batch=16):
    return SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=seq, global_batch=batch, seed=0
    )


def trained_model():
    """Train (or load cached) the shared benchmark model."""
    cfg = bench_config()
    dcfg = data_config(cfg)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    mgr = CheckpointManager(os.path.join(_CACHE_DIR, "tiny"), keep=1)
    hit = mgr.restore_latest(params)
    if hit is not None and hit[0] == TRAIN_STEPS:
        return cfg, dcfg, hit[1]

    init, update = adamw(cosine_schedule(5e-3, TRAIN_STEPS, TRAIN_STEPS // 10))
    state = init(params)

    @jax.jit
    def step(p, s, b):
        l, g = jax.value_and_grad(lambda pp: T.train_loss(pp, cfg, b))(p)
        u, s = update(g, s, p)
        return apply_updates(p, u), s, l

    it = synthetic_batches(dcfg)
    for _ in range(TRAIN_STEPS):
        params, state, loss = step(params, state, next(it))
    mgr.save(TRAIN_STEPS, params)
    return cfg, dcfg, params


def eval_ppl(params, cfg, dcfg, n_batches=2) -> float:
    it = synthetic_batches(dcfg, start_step=10 ** 6)
    tot = 0.0
    for _ in range(n_batches):
        tot += float(T.train_loss(params, cfg, next(it), aux_weight=0.0))
    return math.exp(tot / n_batches)


def compress_with(params, cfg, dcfg, ccfg: CompressionConfig, n_calib=8):
    calib = calibration_batch(dcfg, n_samples=n_calib)
    return compress_model(params, cfg, calib, ccfg)


class Table:
    """CSV emitter: name,us_per_call,derived (repo convention)."""

    def __init__(self, name: str):
        self.name = name
        self.rows: List[Dict[str, Any]] = []

    def add(self, label: str, us_per_call: float = 0.0, **derived):
        self.rows.append(
            {"label": label, "us_per_call": us_per_call, "derived": derived}
        )

    def emit(self):
        for r in self.rows:
            d = json.dumps(r["derived"], sort_keys=True)
            print(f"{self.name}/{r['label']},{r['us_per_call']:.1f},{d}")


def timed(fn: Callable, *args, repeat=1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) else None
    return out, (time.time() - t0) / repeat * 1e6  # us
