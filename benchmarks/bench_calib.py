"""Paper Figure 5b: calibration sample-count sensitivity (SLiM is robust to
small calibration sets — a few samples suffice)."""
from benchmarks.common import Table, compress_with, eval_ppl, trained_model
from repro.core.pipeline import CompressionConfig


def run(table: Table):
    cfg, dcfg, params = trained_model()
    table.add("dense", ppl=round(eval_ppl(params, cfg, dcfg), 3))
    for n in [1, 2, 4, 8, 16]:
        ccfg = CompressionConfig(quantizer="slim", pruner="wanda", adapter="slim", rank=24)
        cp, _ = compress_with(params, cfg, dcfg, ccfg, n_calib=n)
        table.add(f"calib_{n}", ppl=round(eval_ppl(cp, cfg, dcfg), 3), n_samples=n)


def main():
    t = Table("fig5b_calib")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
