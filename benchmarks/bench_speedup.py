"""Paper Figure 3/4: layer-wise speedup of the compressed matmul vs dense.

The paper measures Sparse Marlin on RTX3060/A100. Our target is TPU v5e with
no sparse MXU (DESIGN.md §4), where decode-shape matmuls are HBM-bandwidth
bound, so the roofline-modeled speedup is the ratio of bytes moved:

    t_layer = max(flops / peak_flops, bytes / hbm_bw)

Reported per LLaMA-2-7B/13B/70B layer shapes (the paper's figure) and per
our assigned-arch projection shapes, decomposed like the paper's stacked
bars: quantization-only (int4 dense) vs +2:4 sparsity (3-bit stream), at
decode batch sizes. Also cross-checks the byte counts against the actual
packed buffer sizes of the Pallas kernel inputs.
"""
from benchmarks.common import Table
from repro.launch import hw

# (name, d_in, d_out) — LLaMA-2 projection shapes (paper Fig. 3)
LLAMA_LAYERS = [
    ("7b_qkv", 4096, 4096 + 2 * 4096),
    ("7b_o", 4096, 4096),
    ("7b_ffn_up", 4096, 11008),
    ("7b_ffn_down", 11008, 4096),
    ("13b_ffn_up", 5120, 13824),
    ("70b_ffn_up", 8192, 28672),
]


def layer_time(m, k, n, bits_per_weight, act_bytes=2, rank_ratio=0.0):
    flops = 2 * m * k * n * (1 + 2 * rank_ratio)
    w_bytes = k * n * bits_per_weight / 8
    if rank_ratio:
        w_bytes += 2 * rank_ratio * k * n * 0.5  # int4 adapters
    a_bytes = (m * k + m * n) * act_bytes
    t_c = flops / hw.PEAK_FLOPS_BF16
    t_m = (w_bytes + a_bytes) / hw.HBM_BW
    return max(t_c, t_m), t_c, t_m


def run(table: Table):
    for batch in [1, 16]:
        for name, k, n in LLAMA_LAYERS:
            t_dense, _, _ = layer_time(batch, k, n, 16)
            t_int4, _, _ = layer_time(batch, k, n, 4)
            t_slim, tc, tm = layer_time(batch, k, n, 3, rank_ratio=0.1)
            table.add(
                f"b{batch}/{name}",
                speedup_int4=round(t_dense / t_int4, 2),
                speedup_slim24=round(t_dense / t_slim, 2),
                quant_contrib=round(t_dense / t_int4, 2),
                sparsity_contrib=round(t_int4 / t_slim, 2),
                bound="memory" if tm > tc else "compute",
            )

    # assigned-arch FFN shapes at decode batch 128 (decode_32k cell)
    from repro.configs import ASSIGNED, get_config

    for arch in ASSIGNED:
        cfg = get_config(arch)
        k = cfg.d_model
        n = cfg.moe_ff if cfg.n_experts else (cfg.d_ff or cfg.ssm_inner * 2)
        t_dense, _, _ = layer_time(128, k, n, 16)
        t_slim, tc, tm = layer_time(128, k, n, 3, rank_ratio=0.1)
        table.add(
            f"arch/{arch}",
            speedup_slim24=round(t_dense / t_slim, 2),
            bound="memory" if tm > tc else "compute",
        )


def main():
    t = Table("fig3_speedup")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
