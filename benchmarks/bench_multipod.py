"""Multi-pod scaling table (from the dry-run artifact): single-pod (256) vs
multi-pod (512) roofline terms per architecture — validates that the pod
axis is pure DP (per-device train terms ~halve with 2x chips at fixed global
batch; decode/serve terms shrink with the extra dp capacity)."""
import json
import os

from benchmarks.common import Table

RESULTS = os.environ.get("DRYRUN_JSON", "dryrun_results.json")


def run(table: Table):
    if not os.path.exists(RESULTS):
        table.add("skipped", note=f"{RESULTS} not found — run repro.launch.dryrun --all first")
        return
    data = json.load(open(RESULTS))
    by_key = {}
    for r in data["results"]:
        if "roofline" not in r:
            continue
        by_key[(r["arch"], r["shape"], r["mesh"])] = r
    for (arch, shape, mesh), r in sorted(by_key.items()):
        if mesh != "single":
            continue
        multi = by_key.get((arch, shape, "multi"))
        if multi is None:
            continue
        rs, rm = r["roofline"], multi["roofline"]
        tot_s = rs["t_compute_s"] + rs["t_memory_s"] + rs["t_collective_s"]
        tot_m = rm["t_compute_s"] + rm["t_memory_s"] + rm["t_collective_s"]
        table.add(
            f"{arch}/{shape}",
            t_sum_single=round(tot_s, 4),
            t_sum_multi=round(tot_m, 4),
            scaling_512_vs_256=round(tot_s / max(tot_m, 1e-12), 2),
            mem_single_gib=round(r["per_device_bytes"] / 2**30, 2),
            mem_multi_gib=round(multi["per_device_bytes"] / 2**30, 2),
        )


def main():
    t = Table("multipod_scaling")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
