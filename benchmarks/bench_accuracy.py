"""Paper Table 1 (+ Tbl 9 FT rows, Tbl 3 spirit): the full method grid at
50% sparsity + 4-bit weights, 2:4 and unstructured, eval perplexity on the
held-out stream of a trained LM. Reproduces the ordering claims:

  magnitude < wanda/sparsegpt < +Naive-LoRA < +SLiM-LoRA (~ SLiM-LoRA^Q)
"""
from repro.core.pipeline import CompressionConfig

from benchmarks.common import Table, compress_with, eval_ppl, trained_model


GRID = [
    # label, config
    ("magnitude+group_absmax", CompressionConfig(quantizer="group_absmax", pruner="magnitude", adapter="none")),
    ("wanda+group_absmax", CompressionConfig(quantizer="group_absmax", pruner="wanda", adapter="none")),
    ("sparsegpt+optq", CompressionConfig(quantizer="optq", pruner="sparsegpt", adapter="none")),
    ("jsq", CompressionConfig(quantizer="slim", pruner="jsq", adapter="none")),
    ("l2qer+slim_quant", CompressionConfig(quantizer="slim", pruner="wanda", adapter="l2qer")),
    ("naive_lora+slim_quant", CompressionConfig(quantizer="slim", pruner="wanda", adapter="naive")),
    ("slim_lora+slim_quant", CompressionConfig(quantizer="slim", pruner="wanda", adapter="slim")),
    ("slim_lora_q+slim_quant", CompressionConfig(quantizer="slim", pruner="wanda", adapter="slim", quantize_adapters=True)),
]


def run(table: Table):
    cfg, dcfg, params = trained_model()
    dense_ppl = eval_ppl(params, cfg, dcfg)
    table.add("dense", ppl=round(dense_ppl, 3))
    import dataclasses

    for pattern in ["2:4", "unstructured"]:
        for label, ccfg in GRID:
            if ccfg.pruner == "jsq":
                # JSQ-lite is matrix-level; emulate via wanda+slim w/o adapter
                ccfg = dataclasses.replace(ccfg, pruner="wanda")
            ccfg = dataclasses.replace(ccfg, pattern=pattern, rank=24)
            cp, _ = compress_with(params, cfg, dcfg, ccfg)
            ppl = eval_ppl(cp, cfg, dcfg)
            table.add(
                f"{pattern}/{label}",
                ppl=round(ppl, 3),
                delta_vs_dense=round(ppl - dense_ppl, 3),
            )


def main():
    t = Table("table1_accuracy")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
