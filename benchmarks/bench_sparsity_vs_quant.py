"""Paper Table 16/17: at ~8x compression, 4-bit + 50% sparsity beats 2-bit
dense — sparsity and quantization compose better than quantization alone."""

from benchmarks.common import Table, compress_with, eval_ppl, trained_model
from repro.core.pipeline import CompressionConfig


def run(table: Table):
    cfg, dcfg, params = trained_model()
    table.add("dense", ppl=round(eval_ppl(params, cfg, dcfg), 3))
    settings = [
        ("2bit_dense", CompressionConfig(bits=2, quantizer="slim", pruner="none", pattern="none", adapter="slim", rank=24)),
        ("4bit_2to4", CompressionConfig(bits=4, quantizer="slim", pruner="wanda", pattern="2:4", adapter="slim", rank=24)),
        ("4bit_unstructured", CompressionConfig(bits=4, quantizer="slim", pruner="wanda", pattern="unstructured", adapter="slim", rank=24)),
    ]
    for label, ccfg in settings:
        cp, _ = compress_with(params, cfg, dcfg, ccfg)
        table.add(label, ppl=round(eval_ppl(cp, cfg, dcfg), 3),
                  bits_per_weight=2.0 if ccfg.bits == 2 else (3.0 if ccfg.pattern == "2:4" else 4.0 * 0.5 + 0))


def main():
    t = Table("table16_sparsity_vs_quant")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
