"""Static-batch vs continuous-batching serving under staggered arrivals,
plus contiguous-lane vs paged KV cache at equal cache memory.

Replays the same synthetic Poisson-arrival trace (requests > slots, ragged
generation budgets) through both engines, dense and SLiM-compressed:

  * static  — waves of ``slots`` requests; each wave waits for its last
    arrival and decodes until its longest member finishes (drained slots
    burn steps).
  * continuous — the scheduler admits each arrival into the first freed
    slot; per-slot positions keep the ragged decode exact.

Reports total tokens/s, mean/p95 TTFT and mean occupancy for each
engine x params cell. Continuous batching must strictly beat static on
mean TTFT and hold tokens/s within ``TOKS_NOISE`` of it (the VERDICT
lines; a miss raises). Timing-gated cells replay best-of-3 on both sides
of every comparison, and paired comparisons (plain/speculative, prefix
cold/warm, tracer off/on) *interleave* their sides across rounds so slow
process drift (jit-cache growth, allocator state) hits both equally —
single-CPU containers show ~±5% run-to-run noise, so strict '>' between
statistically tied throughputs would be a coin flip; only the genuine
perf-claim gates (speculative vs plain) stay strict on tok/s.

The paged cell holds cache memory fixed at the contiguous engine's
``slots x max_len`` positions but allocates it in ``BLOCK_SIZE``-position
blocks: requests only occupy blocks for ``prompt + budget``, so strictly
more slots run concurrently in the same memory (the paged VERDICT asserts
``peak_concurrency > slots``).

The *shared-prefix* workload models system-prompt traffic: every request
repeats the same ``PREFIX_LEN``-token prompt prefix with a short unique
tail. It replays through the paged engine with the prefix cache off (PR 2
cold-prefill baseline) and on, at equal pool size: the prefix VERDICT
requires strictly lower mean TTFT with the cache on, tokens/s within
noise, token-exact greedy outputs, and a nonzero hit rate.

The *speculative* cells replay the paged workload with self-speculative
decoding at K in {2, 4}: the SLiM backbone (adapter path disabled) drafts,
one batched full-model pass verifies every slot's window, and accepted
prefixes commit in bulk. The slim VERDICT requires token-exact outputs vs
plain paged decode *and* a strict tok/s win at both K — the draft is a
cheaper forward of the same weights, and the round shares one weight
decompression across its K forwards. The dense cells are the control:
self-drafting an uncompressed model degenerates to exact lookahead, so
their VERDICT requires acceptance exactly 1.0 (recorded, not perf-gated).

The *oversubscribed* cell sizes the pool well below the worst-case sum of
the trace and replays it twice at equal pool size: once under worst-case
charging (admission blocks on ``blocks_needed(prompt + budget)``) and
once with on-demand allocation + preemption (charge the prompt, grow at
block boundaries, evict the youngest when the pool runs dry). The
preemption VERDICT requires the on-demand run to finish the trace
token-exactly vs the non-oversubscribed paged run, to actually preempt at
least once (otherwise the cell proves nothing), and to beat worst-case
charging on peak concurrency or tokens/s.

The *tracing-overhead* cell replays the paged workload with the span
tracer off and on (interleaved best-of-3): recording is a tuple append into a
ring buffer, and the VERDICT holds the tracer to <= 5% throughput cost —
the contract that makes always-on tracing viable in production.

The *live export* cells re-run the paged workload with the whole live
observability plane off and on: rolling-window instruments feeding an
SLO monitor registered on the degradation ladder, plus the stdlib HTTP
exporter being scraped (``/metrics`` + ``/metrics.json``) every ~100 ms
from another thread while the engine serves. Interleaved best-of-3; the
VERDICT holds the plane to <= 5% throughput cost with zero steady-state
retraces while actively scraped (docs/observability.md, Live plane).

The *overload* cells flood the slim speculative engine with a 2x
oversubscribed Poisson burst (twice the request count at several times
the arrival rate, bounded queue of ``N_SLOTS``) with the degradation
ladder off and on (docs/robustness.md). Both runs record shed rate and
the surviving requests' p95 TTFT; the VERDICT requires both runs to
account for every request (completed + shed == submitted, nothing hung
or lost), genuine load shedding on both sides, the ladder run to
actually degrade (``degraded_rounds >= 1``, the spec->plain fallback
riding a pre-registered hot path), and zero steady-state recompiles
under fire on both sides.

All cells land in ``BENCH_serving.json`` (tok/s, TTFT p50/p95, TPOT
p50/p95, per-phase host wall time, hit rate, peak blocks in use) so the
perf trajectory is tracked across PRs.

    PYTHONPATH=src python benchmarks/bench_serving.py
    PYTHONPATH=src python -m benchmarks.run serving
"""
import json
import os
import sys
import threading
import time
import urllib.request

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from benchmarks.common import Table, compress_with, trained_model
from repro.core.pipeline import CompressionConfig
from repro.serving import ContinuousEngine, GuardConfig, ServeEngine
from repro.serving import EngineConfig, PagingConfig, ParallelConfig
from repro.serving import PrefixCacheConfig, Router, SpecConfig
from repro.serving import ServingMetrics, synthetic_trace
from repro.serving import EngineLiveSource, MetricsServer, ObservabilityConfig
from repro.serving.block_pool import RESERVED_BLOCKS

# Heavy-traffic regime: arrivals fast enough that a backlog forms (the
# decode-bound case continuous batching targets) but staggered enough that
# waves assemble at different times. At very low rates both engines are
# arrival-bound and converge — see docs/serving.md.
N_REQUESTS = int(os.environ.get("BENCH_SERVE_REQUESTS", "16"))
N_SLOTS = int(os.environ.get("BENCH_SERVE_SLOTS", "4"))
RATE = float(os.environ.get("BENCH_SERVE_RATE", "25.0"))
PROMPT_LEN = 32
MAX_NEW = (4, 48)  # wide budget spread: static waves drain, continuous refills
MAX_LEN = PROMPT_LEN + MAX_NEW[1] + 8
BLOCK_SIZE = int(os.environ.get("BENCH_SERVE_BLOCK", "8"))
MAX_LEN = -(-MAX_LEN // BLOCK_SIZE) * BLOCK_SIZE  # paged cache needs a multiple
# paged cell: same cache memory as N_SLOTS contiguous max_len lanes, but
# block-granular — so slot count can exceed the lane count
PAGED_SLOTS = int(os.environ.get("BENCH_SERVE_PAGED_SLOTS", str(2 * N_SLOTS)))
PAGED_BLOCKS = N_SLOTS * (MAX_LEN // BLOCK_SIZE) + RESERVED_BLOCKS

# oversubscribed pool: far below the trace's worst-case block sum (up to
# PAGED_SLOTS x ceil((PROMPT_LEN + MAX_NEW[1]) / bs) blocks wanted), so
# worst-case charging serializes admissions while on-demand + preemption
# runs the pool at actual occupancy
OVERSUB_BLOCKS = int(
    os.environ.get("BENCH_SERVE_OVERSUB_BLOCKS", str(24 + RESERVED_BLOCKS))
)
DECODE_RESERVE = int(os.environ.get("BENCH_SERVE_DECODE_RESERVE", "2"))

# shared-prefix workload: a long common system prompt + short unique tail,
# so most prefill work repeats across requests (96 rather than 64 keeps
# the TTFT margin comfortably above CI timing noise for the slim cell)
PREFIX_LEN = int(os.environ.get("BENCH_SERVE_PREFIX", "96"))
PREFIX_TAIL = 16  # unique tokens after the shared prefix
PREFIX_MAX_NEW = (4, 16)
PREFIX_MAX_LEN = PREFIX_LEN + PREFIX_TAIL + PREFIX_MAX_NEW[1] + 8
PREFIX_MAX_LEN = -(-PREFIX_MAX_LEN // BLOCK_SIZE) * BLOCK_SIZE
PREFIX_BLOCKS = N_SLOTS * (PREFIX_MAX_LEN // BLOCK_SIZE) + RESERVED_BLOCKS

# overload cells: a 2x oversubscribed Poisson flood (twice the trace at
# several times the arrival rate) against a bounded queue, ladder off/on
N_OVERLOAD = int(os.environ.get("BENCH_SERVE_OVERLOAD_REQUESTS",
                                str(2 * N_REQUESTS)))
OVERLOAD_RATE = float(os.environ.get("BENCH_SERVE_OVERLOAD_RATE",
                                     str(8 * RATE)))
OVERLOAD_MAX_NEW = (4, 16)
OVERLOAD_MAX_QUEUE = N_SLOTS

BENCH_JSON = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), "..", "BENCH_serving.json"
)

# throughput tie tolerance for the TTFT-claim gates (continuous vs static,
# prefix cache vs cold): those features exist to cut time-to-first-token,
# and their TTFT margins (2-8x) are gated strictly. Their token throughput
# is a *no-regression* side condition, and on a 1-CPU container the two
# sides of each comparison time within run-to-run noise (~±5% observed),
# so a strict '>' between statistically tied numbers is a coin flip. The
# perf-claim gates (slim speculative vs plain decode) stay strict.
TOKS_NOISE = float(os.environ.get("BENCH_SERVE_TOKS_NOISE", "0.03"))

# cell-section filter: "all" (default) runs everything; a comma list of
# section names ("core", "router") runs just those — the host-simulated
# multi-device CI job runs BENCH_SERVE_CELLS=router so the topology cells
# don't re-pay the full single-engine matrix
CELLS = os.environ.get("BENCH_SERVE_CELLS", "all")


def _want(section):
    return CELLS == "all" or section in CELLS.split(",")


def fresh_trace(vocab, seed=0):
    return synthetic_trace(
        N_REQUESTS, rate=RATE, vocab_size=vocab,
        prompt_len=(PROMPT_LEN, PROMPT_LEN), max_new_tokens=MAX_NEW, seed=seed,
    )


def run_static(params, cfg, requests, reps=1):
    """Wave scheduling: the best a static-batch engine can do with arrivals —
    group ``N_SLOTS`` requests in arrival order, start a wave once its last
    member has arrived and the previous wave has drained."""
    engine = ServeEngine(params, cfg, max_len=MAX_LEN)
    reqs = sorted(requests, key=lambda r: r.arrival)
    waves = [reqs[i : i + N_SLOTS] for i in range(0, len(reqs), N_SLOTS)]

    # warm the jit caches outside the timed replay (per-wave shapes)
    for wave in waves:
        dummy = jnp.zeros((len(wave), PROMPT_LEN), jnp.int32)
        engine.generate(
            {"tokens": dummy},
            max_new_tokens=max(r.max_new_tokens for r in wave),
        )

    def replay():
        metrics = ServingMetrics(N_SLOTS)
        for r in reqs:
            metrics.on_submit(r.rid, r.arrival)
        t0 = time.time()

        def now():
            return time.time() - t0
        for wave in waves:
            wait = max(r.arrival for r in wave) - now()
            if wait > 0:
                time.sleep(wait)
            for r in wave:
                metrics.on_admit(r.rid, now())
            batch = jnp.asarray([r.prompt for r in wave], jnp.int32)
            steps = max(r.max_new_tokens for r in wave)
            res = engine.generate({"tokens": batch}, max_new_tokens=steps)
            t_end = now()
            t_first = t_end - res.decode_s  # prefill completion
            for j, r in enumerate(wave):
                metrics.on_first_token(r.rid, t_first)
                r.output = res.tokens[j][: r.max_new_tokens]
                metrics.on_finish(r.rid, t_end, len(r.output))
            # token-exact occupancy (same accounting as the continuous
            # engine): slots drain as their budgets are exhausted
            metrics.on_decode_steps(steps)
        return metrics.summary()

    # best-of-reps by tokens/s, same noise policy as run_continuous
    best = None
    for _ in range(reps):
        m = replay()
        if best is None or m["tokens_per_s"] > best["tokens_per_s"]:
            best = m
    return best


def run_continuous(
    params, cfg, requests, vocab, n_slots=N_SLOTS, block_size=0,
    n_blocks=None, preemption=False, speculative=0, reps=1, trace=False,
):
    if block_size > 0 and n_blocks is None:
        n_blocks = PAGED_BLOCKS
    engine = ContinuousEngine(
        params, cfg,
        EngineConfig(
            n_slots=n_slots, max_len=MAX_LEN, prefill_bucket=PROMPT_LEN,
            paging=PagingConfig(
                block_size=block_size, n_blocks=n_blocks,
                preemption=preemption, decode_reserve=DECODE_RESERVE,
            ),
            speculative=SpecConfig(k=speculative),
            # timed reps run against warm jit caches by construction; the
            # guard turns a silent mid-replay recompile into a hard failure
            # and its per-path compile counts land in the recorded row
            check_retrace=True,
        ),
        trace=trace,
    )
    # warm the prefill/decode jit caches with a minimal same-shape trace
    warm = synthetic_trace(
        2, rate=1e6, vocab_size=vocab,
        prompt_len=(PROMPT_LEN, PROMPT_LEN), max_new_tokens=(2, 2), seed=99,
    )
    engine.run(warm, sync_every=4, max_new_cap=MAX_NEW[1])
    # reps > 1 (timing-gated cells): keep the best run by tokens/s so a
    # noisy-neighbor blip doesn't flip a VERDICT; outputs are identical
    # across reps (greedy), so the choice only affects the timing row.
    # peak_concurrency is a capacity claim, not a timing one — a fast rep
    # can finish requests before the next arrival and undersample the
    # overlap — so it is taken as the max across reps.
    best = None
    peak = 0.0
    for _ in range(reps):
        res = engine.run(requests, sync_every=4, max_new_cap=MAX_NEW[1])
        peak = max(peak, res.metrics["peak_concurrency"])
        if best is None or res.metrics["tokens_per_s"] > best.metrics["tokens_per_s"]:
            best = res
    best.metrics["peak_concurrency"] = peak
    return best.metrics, best.outputs


def prefix_trace(vocab, seed=5):
    return synthetic_trace(
        N_REQUESTS, rate=RATE, vocab_size=vocab,
        prompt_len=(PREFIX_LEN + 4, PREFIX_LEN + PREFIX_TAIL),
        max_new_tokens=PREFIX_MAX_NEW, seed=seed,
        shared_prefix_len=PREFIX_LEN,
    )


def shared_prefix_runner(params, cfg, vocab, prefix_cache):
    """A zero-arg replay closure for the shared-prefix trace through the
    paged engine, cache on or off, at equal pool size — built warm so the
    caller can interleave timed replays of the two configurations."""
    engine = ContinuousEngine(
        params, cfg,
        EngineConfig(
            n_slots=N_SLOTS, max_len=PREFIX_MAX_LEN,
            prefill_bucket=PREFIX_TAIL, check_retrace=True,
            paging=PagingConfig(block_size=BLOCK_SIZE, n_blocks=PREFIX_BLOCKS),
            prefix_cache=PrefixCacheConfig(enabled=prefix_cache),
        ),
    )
    # warm every jit shape this trace will hit (cold prompt buckets and,
    # with the cache on, the suffix buckets) outside the timed replay
    engine.run(prefix_trace(vocab, seed=98), sync_every=4,
               max_new_cap=PREFIX_MAX_NEW[1])

    def one():
        res = engine.run(prefix_trace(vocab), sync_every=4,
                         max_new_cap=PREFIX_MAX_NEW[1])
        return res.metrics, res.outputs
    return one


def overload_trace(vocab, seed=13):
    return synthetic_trace(
        N_OVERLOAD, rate=OVERLOAD_RATE, vocab_size=vocab,
        prompt_len=(PROMPT_LEN, PROMPT_LEN),
        max_new_tokens=OVERLOAD_MAX_NEW, seed=seed,
    )


def run_overload(params, cfg, vocab, degrade):
    """Replay the oversubscribed flood through the slim speculative
    engine behind a bounded queue, with the degradation ladder off or
    on. Shed requests end ABORTED; survivors' TTFT lands in the
    histogram the summary reports (a shed request never gets a first
    token, so the p95 is over survivors by construction)."""
    engine = ContinuousEngine(
        params, cfg,
        EngineConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN, prefill_bucket=PROMPT_LEN,
            paging=PagingConfig(block_size=BLOCK_SIZE, n_blocks=PAGED_BLOCKS),
            speculative=SpecConfig(k=2),
            guard=GuardConfig(max_queue=OVERLOAD_MAX_QUEUE, degradation=degrade),
            check_retrace=True,
        ),
    )
    warm = synthetic_trace(
        2, rate=1e6, vocab_size=vocab,
        prompt_len=(PROMPT_LEN, PROMPT_LEN), max_new_tokens=(2, 2), seed=99,
    )
    engine.run(warm, sync_every=4, max_new_cap=OVERLOAD_MAX_NEW[1])
    res = engine.run(overload_trace(vocab), sync_every=4,
                     max_new_cap=OVERLOAD_MAX_NEW[1])
    return res.metrics


def run_live_export(params, cfg, vocab, live):
    """Replay the paged workload with the live observability plane off
    or on. "On" means the full hot-path cost stack at once: rolling-
    window instruments feeding an SLO monitor registered on the
    degradation ladder, plus an HTTP exporter scraped every ~100 ms
    from another thread while the engine serves. The SLO targets sit
    far above real latencies so the ladder holds level 0 and both sides
    replay the identical serve policy — the cell isolates observation
    cost, not degradation cost."""
    obs = (
        ObservabilityConfig(slo_ttft_p95_s=30.0, slo_tpot_p95_s=30.0)
        if live
        else ObservabilityConfig()
    )
    engine = ContinuousEngine(
        params, cfg,
        EngineConfig(
            n_slots=PAGED_SLOTS, max_len=MAX_LEN, prefill_bucket=PROMPT_LEN,
            paging=PagingConfig(block_size=BLOCK_SIZE, n_blocks=PAGED_BLOCKS),
            guard=GuardConfig(degradation=True),
            observability=obs, check_retrace=True,
        ),
    )
    warm = synthetic_trace(
        2, rate=1e6, vocab_size=vocab,
        prompt_len=(PROMPT_LEN, PROMPT_LEN), max_new_tokens=(2, 2), seed=99,
    )
    engine.run(warm, sync_every=4, max_new_cap=MAX_NEW[1])
    server = poller = None
    scrapes = [0]
    stop = threading.Event()
    if live:
        server = MetricsServer(EngineLiveSource(engine), port=0).start()

        def scrape():
            while not stop.is_set():
                try:
                    urllib.request.urlopen(
                        server.url + "/metrics", timeout=2
                    ).read()
                    urllib.request.urlopen(
                        server.url + "/metrics.json", timeout=2
                    ).read()
                    scrapes[0] += 1
                except OSError:
                    pass  # scrape racing server teardown
                stop.wait(0.1)

        poller = threading.Thread(target=scrape, daemon=True)
        poller.start()
    try:
        res = engine.run(
            fresh_trace(vocab, seed=1), sync_every=4, max_new_cap=MAX_NEW[1]
        )
    finally:
        stop.set()
        if poller is not None:
            poller.join(timeout=5)
        if server is not None:
            server.stop()
    m = res.metrics
    m["export_scrapes"] = float(scrapes[0])
    return m


def run(table: Table):
    cfg, dcfg, dense = trained_model()
    vocab = cfg.vocab_size
    slim, _ = compress_with(
        dense, cfg, dcfg,
        CompressionConfig(adapter="slim", rank=16, quantize_adapters=True),
    )

    verdicts = []
    cells = {}
    verdict_log = {}

    def record(label, m):
        row = {
            "tokens_per_s": round(m["tokens_per_s"], 2),
            "mean_ttft_s": round(m["mean_ttft_s"], 4),
            "p50_ttft_s": round(m.get("p50_ttft_s", float("nan")), 4),
            "p95_ttft_s": round(m["p95_ttft_s"], 4),
            "mean_occupancy": round(m["mean_occupancy"], 3),
            "total_tokens": int(m["total_tokens"]),
            "peak_slots": int(m.get("peak_concurrency", N_SLOTS)),
            "prefix_cache_hit_rate": round(m.get("prefix_cache_hit_rate", 0.0), 3),
            "peak_blocks_in_use": int(m.get("peak_blocks_in_use", 0)),
            "preemptions": int(m.get("preemptions", 0)),
            "draft_acceptance_rate": round(
                m.get("draft_acceptance_rate", 0.0), 3
            ),
            # inter-token latency (decode-phase steady state)
            "tpot_p50_s": round(m["tpot_p50_s"], 4),
            "tpot_p95_s": round(m["tpot_p95_s"], 4),
            # host wall-time attribution per engine phase
            "phase_schedule_s": round(m["phase_schedule_s"], 4),
            "phase_prefill_s": round(m["phase_prefill_s"], 4),
            "phase_decode_s": round(m["phase_decode_s"], 4),
            "phase_verify_s": round(m["phase_verify_s"], 4),
        }
        # robustness accounting (docs/robustness.md), recorded only when
        # the cell actually shed/expired/failed/degraded so the existing
        # cell schemas stay unchanged
        for k in (
            "shed_requests", "expired_requests", "failed_requests",
            "degraded_rounds", "watchdog_trips", "export_scrapes",
        ):
            if m.get(k):
                row[k] = int(m[k])
        # retrace-guard compile counts for the recorded (best) rep —
        # engines warm outside the timed replay, so every hot path should
        # read 0 here; a nonzero value names the path that recompiled
        jit = {
            k[len("jit_compiles_"):]: int(v)
            for k, v in m.items()
            if k.startswith("jit_compiles_")
        }
        if jit:
            row["jit_compiles"] = jit
            row["jit_retraces"] = int(m.get("jit_retraces", 0))
        cells[label] = row
        table.add(label, **row)

    for plabel, params in ([("dense", dense), ("slim", slim)] if _want("core") else []):
        s = run_static(params, cfg, fresh_trace(vocab, seed=1), reps=3)
        c, _ = run_continuous(
            params, cfg, fresh_trace(vocab, seed=1), vocab, reps=3,
        )
        # the paged trio (plain, K=2, K=4) feeds the concurrency and
        # speculative gates. Single-rep runs interleaved across rounds,
        # per-config best kept: slow process drift (jit-cache growth,
        # allocator state) then hits every config equally instead of
        # skewing a comparison between cells timed minutes apart
        trio = {}
        paged_peak = 0.0
        for _ in range(3):
            for k in (0, 2, 4):
                m, out = run_continuous(
                    params, cfg, fresh_trace(vocab, seed=1), vocab,
                    n_slots=PAGED_SLOTS, block_size=BLOCK_SIZE,
                    speculative=k,
                )
                if k == 0:
                    paged_peak = max(paged_peak, m["peak_concurrency"])
                if k not in trio or m["tokens_per_s"] > trio[k][0]["tokens_per_s"]:
                    trio[k] = (m, out)
        p, p_out = trio[0]
        p["peak_concurrency"] = paged_peak
        for elabel, m in [("static", s), ("continuous", c), ("paged", p)]:
            record(f"{plabel}/{elabel}", m)
        # TTFT strictly better, throughput no worse than timing noise
        wins = (
            c["tokens_per_s"] >= (1.0 - TOKS_NOISE) * s["tokens_per_s"]
            and c["mean_ttft_s"] < s["mean_ttft_s"]
        )
        verdicts.append(wins)
        verdict_log[f"{plabel}/continuous_beats_static"] = wins
        print(
            f"VERDICT[{plabel}]: continuous "
            f"{'BEATS' if wins else 'DOES NOT BEAT'} static "
            f"(tok/s {c['tokens_per_s']:.1f} vs {s['tokens_per_s']:.1f}, "
            f"ttft {c['mean_ttft_s']:.3f}s vs {s['mean_ttft_s']:.3f}s)"
        )
        # paged vs contiguous lanes at EQUAL cache memory (N_SLOTS lanes
        # = PAGED_BLOCKS blocks): block granularity must sustain strictly
        # more concurrent slots, and complete the whole trace
        paged_wins = (
            p["peak_concurrency"] > N_SLOTS
            and p["completed"] == c["completed"]
        )
        verdicts.append(paged_wins)
        verdict_log[f"{plabel}/paged_lifts_concurrency"] = paged_wins
        print(
            f"VERDICT[{plabel}]: paged cache "
            f"{'LIFTS' if paged_wins else 'DOES NOT LIFT'} concurrency at "
            f"equal memory ({int(p['peak_concurrency'])} slots vs "
            f"{N_SLOTS} max_len lanes in {PAGED_BLOCKS} x {BLOCK_SIZE}-pos "
            f"blocks; tok/s {p['tokens_per_s']:.1f}, "
            f"ttft {p['mean_ttft_s']:.3f}s)"
        )

        # self-speculative decoding over the same paged pool: the SLiM
        # backbone (adapter path disabled) drafts K-1 tokens per round,
        # one batched full-model pass verifies, accepted prefixes commit
        # in bulk. Token-exact vs plain paged decode by construction; the
        # slim VERDICT additionally requires a tok/s win at K in {2, 4}
        # (drafting is only worthwhile when the backbone is genuinely
        # cheaper — for dense params it degenerates to exact lookahead
        # with acceptance 1.0, recorded but not perf-gated).
        spec_cells = {k: trio[k] for k in (2, 4)}
        for k, (sm, _) in spec_cells.items():
            record(f"{plabel}/speculative_k{k}", sm)
        spec_exact = all(o == p_out for _, o in spec_cells.values())
        if plabel == "slim":
            spec_wins = spec_exact and all(
                sm["tokens_per_s"] > p["tokens_per_s"]
                and 0.0 < sm["draft_acceptance_rate"] <= 1.0
                for sm, _ in spec_cells.values()
            )
            verdicts.append(spec_wins)
            verdict_log["slim/speculative_beats_plain_decode"] = spec_wins
            print(
                f"VERDICT[slim]: self-speculative decoding "
                f"{'BEATS' if spec_wins else 'DOES NOT BEAT'} plain paged "
                "decode at equal pool size (tok/s "
                f"K=2 {spec_cells[2][0]['tokens_per_s']:.1f} / "
                f"K=4 {spec_cells[4][0]['tokens_per_s']:.1f} vs "
                f"{p['tokens_per_s']:.1f}, acceptance "
                f"K=2 {spec_cells[2][0]['draft_acceptance_rate']:.2f} / "
                f"K=4 {spec_cells[4][0]['draft_acceptance_rate']:.2f}, "
                f"outputs {'EXACT' if spec_exact else 'DIVERGED'})"
            )
        else:
            # dense self-drafting is exact lookahead: every proposal must
            # survive verification (acceptance exactly 1.0), token-exact
            lookahead = spec_exact and all(
                sm["draft_acceptance_rate"] == 1.0
                for sm, _ in spec_cells.values()
            )
            verdicts.append(lookahead)
            verdict_log["dense/speculative_is_exact_lookahead"] = lookahead
            print(
                f"VERDICT[dense]: self-speculative decoding "
                f"{'IS' if lookahead else 'IS NOT'} exact lookahead "
                "(acceptance "
                f"K=2 {spec_cells[2][0]['draft_acceptance_rate']:.2f} / "
                f"K=4 {spec_cells[4][0]['draft_acceptance_rate']:.2f}, "
                f"outputs {'EXACT' if spec_exact else 'DIVERGED'})"
            )

        # oversubscribed pool at equal size: worst-case charging vs
        # on-demand + preemption; outputs must match the roomy paged run
        wc, _ = run_continuous(
            params, cfg, fresh_trace(vocab, seed=1), vocab,
            n_slots=PAGED_SLOTS, block_size=BLOCK_SIZE,
            n_blocks=OVERSUB_BLOCKS,
        )
        od, od_out = run_continuous(
            params, cfg, fresh_trace(vocab, seed=1), vocab,
            n_slots=PAGED_SLOTS, block_size=BLOCK_SIZE,
            n_blocks=OVERSUB_BLOCKS, preemption=True,
        )
        record(f"{plabel}/oversub_worstcase", wc)
        record(f"{plabel}/oversub_preempt", od)
        od_exact = od_out == p_out
        preempt_wins = (
            od_exact
            and od["preemptions"] >= 1
            and od["completed"] == p["completed"]
            and (
                od["peak_concurrency"] > wc["peak_concurrency"]
                or od["tokens_per_s"] > wc["tokens_per_s"]
            )
        )
        verdicts.append(preempt_wins)
        verdict_log[f"{plabel}/preemption_beats_worst_case"] = preempt_wins
        print(
            f"VERDICT[{plabel}]: on-demand + preemption "
            f"{'BEATS' if preempt_wins else 'DOES NOT BEAT'} worst-case "
            "charging on the oversubscribed pool "
            f"({OVERSUB_BLOCKS - RESERVED_BLOCKS} usable blocks: "
            f"peak slots {int(od['peak_concurrency'])} vs "
            f"{int(wc['peak_concurrency'])}, tok/s {od['tokens_per_s']:.1f} "
            f"vs {wc['tokens_per_s']:.1f}, "
            f"{int(od['preemptions'])} preemptions, outputs "
            f"{'EXACT' if od_exact else 'DIVERGED'})"
        )

        # shared-prefix workload: prefix cache on vs off (PR 2 cold
        # baseline) at equal pool size, token-exact greedy outputs
        # interleaved best-of-3 by mean TTFT (TTFT is the prefix cache's
        # headline claim and the strictly-gated side of its VERDICT)
        runners = {
            False: shared_prefix_runner(params, cfg, vocab, prefix_cache=False),
            True: shared_prefix_runner(params, cfg, vocab, prefix_cache=True),
        }
        prefix_best = {}
        for _ in range(3):
            for cached, one in runners.items():
                m, out = one()
                if (
                    cached not in prefix_best
                    or m["mean_ttft_s"] < prefix_best[cached][0]["mean_ttft_s"]
                ):
                    prefix_best[cached] = (m, out)
        cold, cold_out = prefix_best[False]
        warm, warm_out = prefix_best[True]
        record(f"{plabel}/prefix_off", cold)
        record(f"{plabel}/prefix_on", warm)
        exact = warm_out == cold_out
        # TTFT strictly better, throughput no worse than timing noise
        prefix_wins = (
            warm["mean_ttft_s"] < cold["mean_ttft_s"]
            and warm["tokens_per_s"] >= (1.0 - TOKS_NOISE) * cold["tokens_per_s"]
            and warm["prefix_cache_hit_rate"] > 0.0
            and exact
        )
        verdicts.append(prefix_wins)
        verdict_log[f"{plabel}/prefix_cache_wins"] = prefix_wins
        print(
            f"VERDICT[{plabel}]: prefix cache "
            f"{'BEATS' if prefix_wins else 'DOES NOT BEAT'} cold prefill "
            "on the shared-prefix workload at equal pool size "
            f"(ttft {warm['mean_ttft_s']:.3f}s vs {cold['mean_ttft_s']:.3f}s, "
            f"tok/s {warm['tokens_per_s']:.1f} vs {cold['tokens_per_s']:.1f}, "
            f"hit rate {warm['prefix_cache_hit_rate']:.2f}, "
            f"outputs {'EXACT' if exact else 'DIVERGED'})"
        )

    if _want("core"):
        # tracing overhead: the same paged workload with the span tracer off
        # vs on (ring-buffered tuple appends; export excluded). Interleaved
        # best-of-3 on both sides squeezes container timing noise out of the
        # ratio; the VERDICT holds the tracer to <= 5% throughput cost.
        trace_best = {}
        for _ in range(3):
            for tr in (False, True):
                m, _ = run_continuous(
                    dense, cfg, fresh_trace(vocab, seed=1), vocab,
                    n_slots=PAGED_SLOTS, block_size=BLOCK_SIZE, trace=tr,
                )
                if (
                    tr not in trace_best
                    or m["tokens_per_s"] > trace_best[tr]["tokens_per_s"]
                ):
                    trace_best[tr] = m
        t_off, t_on = trace_best[False], trace_best[True]
        record("dense/trace_off", t_off)
        record("dense/trace_on", t_on)
        overhead = 1.0 - t_on["tokens_per_s"] / t_off["tokens_per_s"]
        trace_ok = t_on["tokens_per_s"] >= 0.95 * t_off["tokens_per_s"]
        verdicts.append(trace_ok)
        verdict_log["dense/tracing_overhead_within_5pct"] = trace_ok
        print(
            f"VERDICT[dense]: span tracing costs "
            f"{100 * overhead:.1f}% throughput "
            f"({'WITHIN' if trace_ok else 'EXCEEDS'} the 5% budget: "
            f"{t_on['tokens_per_s']:.1f} tok/s on vs "
            f"{t_off['tokens_per_s']:.1f} off)"
        )

        # live observability plane: the same paged workload with the live
        # plane off vs fully on — rolling-window instruments + SLO monitor
        # on the ladder + an HTTP scraper polling /metrics + /metrics.json
        # every ~100 ms while the engine serves (docs/observability.md).
        # Interleaved best-of-3; the VERDICT holds the plane to <= 5%
        # throughput cost with zero steady-state retraces while it is
        # actively being scraped (the scrape count proves the exporter
        # really ran during the timed replay).
        live_best = {}
        for _ in range(3):
            for lv in (False, True):
                m = run_live_export(dense, cfg, vocab, live=lv)
                if (
                    lv not in live_best
                    or m["tokens_per_s"] > live_best[lv]["tokens_per_s"]
                ):
                    live_best[lv] = m
        e_off, e_on = live_best[False], live_best[True]
        record("dense/export_off", e_off)
        record("dense/export_on", e_on)
        export_overhead = 1.0 - e_on["tokens_per_s"] / e_off["tokens_per_s"]
        export_ok = (
            e_on["tokens_per_s"] >= 0.95 * e_off["tokens_per_s"]
            and e_on["jit_retraces"] == 0
            and e_on["export_scrapes"] >= 1
        )
        verdicts.append(export_ok)
        verdict_log["dense/live_export_overhead_within_5pct"] = export_ok
        print(
            f"VERDICT[dense]: live metrics export costs "
            f"{100 * export_overhead:.1f}% throughput "
            f"({'WITHIN' if export_ok else 'EXCEEDS'} the 5% budget: "
            f"{e_on['tokens_per_s']:.1f} tok/s on vs "
            f"{e_off['tokens_per_s']:.1f} off, "
            f"{int(e_on['export_scrapes'])} scrapes, "
            f"retraces {int(e_on['jit_retraces'])})"
        )

        # overload: 2x oversubscribed Poisson flood against the bounded
        # queue, degradation ladder off vs on (docs/robustness.md). Not a
        # timing race — the gate is accounting and survival: every request
        # ends FINISHED or shed-ABORTED (nothing hangs or vanishes), both
        # sides genuinely shed, the ladder run actually degrades, and the
        # steady state stays retrace-free under fire. Shed rate and the
        # survivors' p95 TTFT are recorded for the trajectory.
        nl = run_overload(slim, cfg, vocab, degrade=False)
        ld = run_overload(slim, cfg, vocab, degrade=True)
        record("slim/overload_noladder", nl)
        record("slim/overload_ladder", ld)
        overload_ok = (
            nl["completed"] + nl["shed_requests"] == N_OVERLOAD
            and ld["completed"] + ld["shed_requests"] == N_OVERLOAD
            and nl["shed_requests"] > 0
            and ld["shed_requests"] > 0
            and ld["degraded_rounds"] >= 1
            and nl["jit_retraces"] == 0
            and ld["jit_retraces"] == 0
        )
        verdicts.append(overload_ok)
        verdict_log["slim/overload_survives_with_ladder"] = overload_ok
        print(
            f"VERDICT[slim]: overload flood ({N_OVERLOAD} requests, queue "
            f"bound {OVERLOAD_MAX_QUEUE}) "
            f"{'SURVIVES' if overload_ok else 'DOES NOT SURVIVE'} "
            "with full accounting (ladder off: "
            f"shed {int(nl['shed_requests'])}/{N_OVERLOAD}, surviving p95 "
            f"TTFT {nl['p95_ttft_s']:.3f}s; ladder on: "
            f"shed {int(ld['shed_requests'])}/{N_OVERLOAD}, surviving p95 "
            f"TTFT {ld['p95_ttft_s']:.3f}s, "
            f"{int(ld['degraded_rounds'])} degraded rounds, peak level "
            f"{int(ld['peak_degradation_level'])}; retraces 0/0)"
        )


    # engine-as-replica topology (docs/serving.md): the Router spreads the
    # trace over 2 independent replicas of the same EngineConfig — equal
    # per-replica pool — and its aggregate throughput (sum of per-replica
    # tok/s, each replica on its own clock) must reach >= 1.8x one
    # replica's, token-exactly. Interleaved best-of-3, same noise policy
    # as the core cells. The throughput cell uses a *saturated* trace
    # (every request arrives at t=0): under a replayed Poisson arrival
    # span each replica's wall time is floored by the arrivals it still
    # has to wait for, which caps the split speedup well below 2x — the
    # saturated trace isolates what routing actually scales, decode
    # throughput — with a *uniform* decode budget, because variable
    # budgets leave every replica a low-occupancy drain tail that weighs
    # twice as much against half the tokens. The prefix-affinity cell
    # replays a 3-tenant
    # shared-prefix trace (3 distinct system prompts over 2 replicas):
    # sticky prefix routing pays each tenant's cold prefill once fleet-
    # wide, least-loaded pays it once per replica, so affinity must show
    # the strictly higher hit rate (deterministic — placement, not
    # timing). The tensor-parallel cell (host-simulated devices permitting)
    # reruns the single-replica workload at tp=2: token-exact vs tp=1 and
    # retrace-free — throughput is recorded, not gated, because forced
    # host devices share one CPU core.
    if _want("router"):
        import jax

        rcfg = EngineConfig(
            n_slots=N_SLOTS, max_len=MAX_LEN, prefill_bucket=PROMPT_LEN,
            check_retrace=True,
            paging=PagingConfig(block_size=BLOCK_SIZE, n_blocks=PAGED_BLOCKS),
        )
        single = ContinuousEngine(slim, cfg, rcfg)
        router = Router(slim, cfg, rcfg, n_replicas=2)
        warm = synthetic_trace(
            2, rate=1e6, vocab_size=vocab,
            prompt_len=(PROMPT_LEN, PROMPT_LEN), max_new_tokens=(2, 2),
            seed=99,
        )
        single.run(warm, sync_every=4, max_new_cap=MAX_NEW[1])
        for eng in router.engines:  # warm every replica's jit caches
            eng.run(warm, sync_every=4, max_new_cap=MAX_NEW[1])

        def sat_trace(seed=1):
            return synthetic_trace(
                N_REQUESTS, rate=1e6, vocab_size=vocab,
                prompt_len=(PROMPT_LEN, PROMPT_LEN),
                max_new_tokens=(32, 32), seed=seed,
            )

        best = {}
        for _ in range(3):
            for klabel, target in (("single", single), ("router", router)):
                res = target.run(
                    sat_trace(), sync_every=4, max_new_cap=MAX_NEW[1],
                )
                if (
                    klabel not in best
                    or res.metrics["tokens_per_s"]
                    > best[klabel].metrics["tokens_per_s"]
                ):
                    best[klabel] = res
        one_m = best["single"].metrics
        agg_m = best["router"].metrics
        record("router/single_replica", one_m)
        record("router/2replicas", agg_m)
        router_exact = best["router"].outputs == best["single"].outputs
        speedup = agg_m["tokens_per_s"] / one_m["tokens_per_s"]
        router_wins = (
            router_exact
            and speedup >= 1.8
            and agg_m["router_shed"] == 0
            and agg_m.get("jit_retraces", 0) == 0
        )
        verdicts.append(router_wins)
        verdict_log["router/2replicas_aggregate_1_8x"] = router_wins
        print(
            f"VERDICT[router]: 2 replicas "
            f"{'REACH' if router_wins else 'DO NOT REACH'} >= 1.8x one "
            f"replica's throughput at equal per-replica pool (aggregate "
            f"{agg_m['tokens_per_s']:.1f} tok/s = "
            f"{agg_m['replica0_tokens_per_s']:.1f} + "
            f"{agg_m['replica1_tokens_per_s']:.1f} vs "
            f"{one_m['tokens_per_s']:.1f}, {speedup:.2f}x, outputs "
            f"{'EXACT' if router_exact else 'DIVERGED'})"
        )

        # 3-tenant shared-prefix workload, prefix cache on, 2 replicas
        gcfg = EngineConfig(
            n_slots=N_SLOTS, max_len=PREFIX_MAX_LEN,
            prefill_bucket=PREFIX_TAIL, check_retrace=True,
            paging=PagingConfig(block_size=BLOCK_SIZE, n_blocks=PREFIX_BLOCKS),
            prefix_cache=PrefixCacheConfig(enabled=True),
        )

        def group_trace(seed=5):
            return synthetic_trace(
                N_REQUESTS, rate=RATE, vocab_size=vocab,
                prompt_len=(PREFIX_LEN + 4, PREFIX_LEN + PREFIX_TAIL),
                max_new_tokens=PREFIX_MAX_NEW, seed=seed,
                shared_prefix_len=PREFIX_LEN, shared_prefix_groups=3,
            )

        placement_res = {}
        for place in ("prefix_affinity", "least_loaded"):
            r = Router(slim, cfg, gcfg, n_replicas=2, placement=place)
            placement_res[place] = r.run(
                group_trace(), sync_every=4, max_new_cap=PREFIX_MAX_NEW[1]
            )
        aff = placement_res["prefix_affinity"].metrics
        ll = placement_res["least_loaded"].metrics
        record("router/affinity_3tenants", aff)
        record("router/least_loaded_3tenants", ll)
        place_exact = (
            placement_res["prefix_affinity"].outputs
            == placement_res["least_loaded"].outputs
        )
        affinity_wins = (
            place_exact
            and aff["prefix_cache_hit_rate"] > ll["prefix_cache_hit_rate"]
        )
        verdicts.append(affinity_wins)
        verdict_log["router/affinity_beats_least_loaded_hit_rate"] = (
            affinity_wins
        )
        print(
            f"VERDICT[router]: prefix-affinity placement "
            f"{'BEATS' if affinity_wins else 'DOES NOT BEAT'} least-loaded "
            f"on the 3-tenant shared-prefix workload (hit rate "
            f"{aff['prefix_cache_hit_rate']:.2f} vs "
            f"{ll['prefix_cache_hit_rate']:.2f}, outputs "
            f"{'EXACT' if place_exact else 'DIVERGED'})"
        )

        # tensor parallelism inside one replica (needs >= 2 devices:
        # XLA_FLAGS=--xla_force_host_platform_device_count=8 on CPU)
        if len(jax.devices()) >= 2:
            import dataclasses as _dc

            tp_engine = ContinuousEngine(
                slim, cfg,
                _dc.replace(rcfg, parallel=ParallelConfig(tp=2)),
            )
            tp_engine.run(warm, sync_every=4, max_new_cap=MAX_NEW[1])
            res_tp = tp_engine.run(
                sat_trace(), sync_every=4, max_new_cap=MAX_NEW[1],
            )
            record("router/tp2_replica", res_tp.metrics)
            tp_exact = res_tp.outputs == best["single"].outputs
            tp_ok = (
                tp_exact and res_tp.metrics.get("jit_retraces", 0) == 0
            )
            verdicts.append(tp_ok)
            verdict_log["router/tp2_token_exact_retrace_free"] = tp_ok
            print(
                f"VERDICT[router]: tp=2 sharded decode "
                f"{'IS' if tp_ok else 'IS NOT'} token-exact and "
                f"retrace-free vs tp=1 "
                f"({res_tp.metrics['tokens_per_s']:.1f} tok/s recorded, "
                "not gated on forced host devices)"
            )
        else:
            print(
                "note[router]: tp=2 cell skipped — 1 visible device (set "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8)"
            )

    # a filtered run (BENCH_SERVE_CELLS) updates only its own cells in an
    # existing dump, so e.g. the multi-device router pass can refresh its
    # section without clobbering the single-device core results
    if CELLS != "all" and os.path.exists(BENCH_JSON):
        with open(BENCH_JSON) as f:
            prior = json.load(f)
        cells = {**prior.get("cells", {}), **cells}
        verdict_log = {**prior.get("verdicts", {}), **verdict_log}
    with open(BENCH_JSON, "w") as f:
        json.dump(
            {
                "generated_unix": time.time(),
                "config": {
                    "n_requests": N_REQUESTS,
                    "n_slots": N_SLOTS,
                    "rate": RATE,
                    "block_size": BLOCK_SIZE,
                    "paged_slots": PAGED_SLOTS,
                    "paged_blocks": PAGED_BLOCKS,
                    "oversub_blocks": OVERSUB_BLOCKS,
                    "decode_reserve": DECODE_RESERVE,
                    "prefix_len": PREFIX_LEN,
                    "prefix_max_len": PREFIX_MAX_LEN,
                    "prefix_blocks": PREFIX_BLOCKS,
                    "speculative_k": [2, 4],
                    "overload_requests": N_OVERLOAD,
                    "overload_rate": OVERLOAD_RATE,
                    "overload_max_queue": OVERLOAD_MAX_QUEUE,
                },
                "cells": cells,
                "verdicts": verdict_log,
            },
            f,
            indent=2,
            sort_keys=True,
        )
        f.write("\n")
    print(f"# wrote {os.path.normpath(BENCH_JSON)}")

    if not all(verdicts):
        raise RuntimeError(
            "continuous batching failed to beat static, the paged cache "
            "failed to lift concurrency at equal memory, the prefix "
            "cache failed to beat cold prefill on the shared-prefix "
            "workload, on-demand + preemption failed to beat worst-case "
            "charging on the oversubscribed pool, or self-speculative "
            "decoding failed its cells (slim: tok/s win + token-exact at "
            "K in {2, 4}; dense: exact lookahead at acceptance 1.0), or "
            "span tracing cost more than 5% throughput, or the live "
            "metrics exporter cost more than 5% throughput / retraced / "
            "was never scraped, or the overload "
            "flood broke accounting / never degraded / retraced, or the "
            "2-replica router missed 1.8x aggregate throughput / exactness, "
            "or prefix-affinity placement failed to beat least-loaded's hit "
            "rate, or tp=2 decode diverged or retraced"
        )


if __name__ == "__main__":
    t = Table("serving")
    run(t)
    t.emit()
