"""Paper Figure 6: sparsity-ratio sweep — SLiM-LoRA + SLiM-Quant degrades
gracefully up to ~60% while baselines fall off earlier."""
import dataclasses

from benchmarks.common import Table, compress_with, eval_ppl, trained_model
from repro.core.pipeline import CompressionConfig


def run(table: Table):
    cfg, dcfg, params = trained_model()
    table.add("dense", ppl=round(eval_ppl(params, cfg, dcfg), 3))
    for sparsity in [0.3, 0.4, 0.5, 0.6, 0.7]:
        for label, ccfg in [
            ("slim", CompressionConfig(quantizer="slim", pruner="wanda", adapter="slim", rank=24)),
            ("wanda_groupq", CompressionConfig(quantizer="group_absmax", pruner="wanda", adapter="none")),
        ]:
            ccfg = dataclasses.replace(
                ccfg, sparsity=sparsity, pattern="unstructured"
            )
            cp, _ = compress_with(params, cfg, dcfg, ccfg)
            table.add(
                f"s{int(sparsity*100)}/{label}",
                ppl=round(eval_ppl(cp, cfg, dcfg), 3),
            )


def main():
    t = Table("fig6_sparsity")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
