"""Paper Table 2/9: lightweight PEFT on the frozen compressed model recovers
accuracy; SLiM-LoRA gains more than Naive-LoRA (saliency-aware init)."""

import jax

from benchmarks.common import Table, compress_with, eval_ppl, trained_model
from repro.core.pipeline import CompressionConfig
from repro.data import synthetic_batches
from repro.models import transformer as T
from repro.models.compress import peft_mask
from repro.optim import adafactor, apply_updates

PEFT_STEPS = 40


def _peft(cp, cfg, dcfg):
    mask = peft_mask(cp)
    init, update = adafactor(2e-3, mask=jax.tree.map(lambda m: bool(m), mask))
    state = init(cp)

    @jax.jit
    def step(p, s, b):
        l, g = jax.value_and_grad(
            lambda pp: T.train_loss(pp, cfg, b), allow_int=True
        )(p)
        u, s = update(g, s, p)
        return apply_updates(p, u), s, l

    it = synthetic_batches(dcfg, start_step=500)
    for _ in range(PEFT_STEPS):
        cp, state, _ = step(cp, state, next(it))
    return cp


def run(table: Table):
    cfg, dcfg, params = trained_model()
    dense = eval_ppl(params, cfg, dcfg)
    table.add("dense", ppl=round(dense, 3))
    for adapter in ["naive", "slim"]:
        for quantize_adapters in [False, True]:
            label = f"{adapter}_lora{'_q' if quantize_adapters else ''}"
            ccfg = CompressionConfig(
                quantizer="slim", pruner="wanda", adapter=adapter, rank=24,
                quantize_adapters=quantize_adapters,
            )
            cp, _ = compress_with(params, cfg, dcfg, ccfg)
            before = eval_ppl(cp, cfg, dcfg)
            cp = _peft(cp, cfg, dcfg)
            after = eval_ppl(cp, cfg, dcfg)
            table.add(
                label,
                ppl_before_ft=round(before, 3),
                ppl_after_ft=round(after, 3),
                recovered=round(before - after, 3),
            )


def main():
    t = Table("table2_finetune")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
