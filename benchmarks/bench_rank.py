"""Paper Figure 5a: adapter-rank sensitivity — eval quality vs rank ratio."""

from benchmarks.common import Table, compress_with, eval_ppl, trained_model
from repro.core.pipeline import CompressionConfig


def run(table: Table):
    cfg, dcfg, params = trained_model()
    table.add("dense", ppl=round(eval_ppl(params, cfg, dcfg), 3))
    for rank in [0, 4, 8, 16, 32, 64]:
        ccfg = CompressionConfig(
            quantizer="slim", pruner="wanda",
            adapter="none" if rank == 0 else "slim", rank=rank or None,
        )
        cp, _ = compress_with(params, cfg, dcfg, ccfg)
        table.add(
            f"rank_{rank}",
            ppl=round(eval_ppl(cp, cfg, dcfg), 3),
            rank_ratio=round(rank / cfg.d_model, 3),
        )


def main():
    t = Table("fig5a_rank")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
