"""Diff two ``BENCH_serving.json`` dumps and fail on perf regressions.

    PYTHONPATH=src python benchmarks/check_bench_regression.py \
        baseline.json new.json [--toks-margin 0.05] [--ttft-margin 0.10]

For every cell present in *both* dumps, the new run must hold

* ``tokens_per_s``  >= (1 - toks_margin) x baseline, and
* ``mean_ttft_s``   <= (1 + ttft_margin) x baseline,

i.e. throughput may dip and TTFT may grow only within the stated
noise margins. Cells that exist on one side only are reported as
added/removed but never fail the check — growing the bench matrix is
not a regression. Verdict flips (a ``true`` in the baseline that went
``false``) always fail: those are correctness gates, not timings.

Exit status: 0 clean, 1 on any regression, 2 on usage/IO errors.
"""

from __future__ import annotations

import argparse
import json
import sys


def _load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read {path}: {e}", file=sys.stderr)
        raise SystemExit(2)


def compare(
    baseline: dict,
    new: dict,
    toks_margin: float = 0.05,
    ttft_margin: float = 0.10,
) -> list:
    """Return a list of human-readable regression strings (empty = ok)."""
    regressions = []
    b_cells = baseline.get("cells", {})
    n_cells = new.get("cells", {})
    for label in sorted(set(b_cells) & set(n_cells)):
        b, n = b_cells[label], n_cells[label]
        b_toks, n_toks = b.get("tokens_per_s"), n.get("tokens_per_s")
        if b_toks and n_toks is not None:
            floor = (1.0 - toks_margin) * b_toks
            if n_toks < floor:
                regressions.append(
                    f"{label}: tokens_per_s {n_toks:.2f} < {floor:.2f} "
                    f"(baseline {b_toks:.2f}, margin {toks_margin:.0%})"
                )
        b_ttft, n_ttft = b.get("mean_ttft_s"), n.get("mean_ttft_s")
        if b_ttft and n_ttft is not None:
            ceil = (1.0 + ttft_margin) * b_ttft
            if n_ttft > ceil:
                regressions.append(
                    f"{label}: mean_ttft_s {n_ttft:.4f} > {ceil:.4f} "
                    f"(baseline {b_ttft:.4f}, margin {ttft_margin:.0%})"
                )
    b_verdicts = baseline.get("verdicts", {})
    n_verdicts = new.get("verdicts", {})
    for key in sorted(set(b_verdicts) & set(n_verdicts)):
        if b_verdicts[key] and not n_verdicts[key]:
            regressions.append(f"{key}: verdict flipped true -> false")
    return regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="fail when a BENCH_serving.json run regresses "
        "against a committed baseline"
    )
    ap.add_argument("baseline", help="committed baseline BENCH_serving.json")
    ap.add_argument("new", help="freshly generated BENCH_serving.json")
    ap.add_argument(
        "--toks-margin", type=float, default=0.05,
        help="allowed fractional tokens_per_s drop (default 0.05)",
    )
    ap.add_argument(
        "--ttft-margin", type=float, default=0.10,
        help="allowed fractional mean_ttft_s growth (default 0.10)",
    )
    args = ap.parse_args(argv)
    baseline, new = _load(args.baseline), _load(args.new)

    b_cells, n_cells = baseline.get("cells", {}), new.get("cells", {})
    shared = sorted(set(b_cells) & set(n_cells))
    added = sorted(set(n_cells) - set(b_cells))
    removed = sorted(set(b_cells) - set(n_cells))
    print(
        f"comparing {len(shared)} shared cells "
        f"({len(added)} added, {len(removed)} removed)"
    )
    for label in added:
        print(f"  + {label} (new cell, not gated)")
    for label in removed:
        print(f"  - {label} (dropped from bench)")

    regressions = compare(
        baseline, new,
        toks_margin=args.toks_margin, ttft_margin=args.ttft_margin,
    )
    for label in shared:
        b, n = b_cells[label], n_cells[label]
        if b.get("tokens_per_s") and n.get("tokens_per_s") is not None:
            delta = n["tokens_per_s"] / b["tokens_per_s"] - 1.0
            print(
                f"  {label}: tok/s {n['tokens_per_s']:.2f} "
                f"vs {b['tokens_per_s']:.2f} ({delta:+.1%})"
            )
    if regressions:
        print(f"\n{len(regressions)} regression(s):", file=sys.stderr)
        for r in regressions:
            print(f"  REGRESSION {r}", file=sys.stderr)
        return 1
    print("no regressions beyond margin")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
