"""Paper Table 20 (Eq. 13): inference FLOP-reduction ratios per arch.

TPU adaptation (DESIGN.md §4): with no sparse MXU, 2:4 does NOT halve matmul
FLOPs on TPU — we report the paper's GPU-semantics column (Eq. 13, sparsity
halves FLOPs) AND the TPU column (dense compute, adapters add FLOPs, the win
is bytes) side by side.
"""
from benchmarks.common import Table
from repro.configs import ASSIGNED, get_config


def eq13(cfg, rank_ratio=0.1, sparsity=0.5, sparse_flops_count: bool = True):
    n_active_dense = 1.0  # normalized block matmul flops
    base = (1 - sparsity) if sparse_flops_count else 1.0
    adapters = 2 * rank_ratio
    return n_active_dense / (base + adapters)


def run(table: Table):
    for arch in ASSIGNED:
        cfg = get_config(arch)
        table.add(
            f"{arch}",
            gpu_flop_reduction_sparse=round(eq13(cfg, 0.0), 3),
            gpu_flop_reduction_slim=round(eq13(cfg, 0.1), 3),
            tpu_flop_reduction_slim=round(eq13(cfg, 0.1, sparse_flops_count=False), 3),
        )


def main():
    t = Table("table20_flops")
    run(t)
    t.emit()


if __name__ == "__main__":
    main()
