"""Example 4: the multi-pod dry-run as a user-facing script — lower and
compile one architecture across the production meshes and print its roofline
terms (no TPU required; 512 placeholder host devices).

    python examples/multi_pod_dryrun.py --arch mixtral-8x22b --shape decode_32k
"""
import os
import subprocess
import sys

HERE = os.path.dirname(__file__)
SRC = os.path.join(HERE, "..", "src")

if __name__ == "__main__":
    args = sys.argv[1:] or ["--arch", "qwen3-0.6b", "--shape", "decode_32k"]
    env = dict(os.environ, PYTHONPATH=SRC)
    sys.exit(
        subprocess.call(
            [sys.executable, "-m", "repro.launch.dryrun", "--mesh", "both"] + args,
            env=env,
        )
    )
