"""Quickstart: one-shot SLiM compression of a small LM, end to end.

    PYTHONPATH=src python examples/quickstart.py

1. trains a tiny decoder-only LM on the deterministic synthetic stream,
2. compresses it with the paper's pipeline (SLiM-Quant -> 2:4 Wanda ->
   SLiM-LoRA -> 4-bit group-quantized adapters),
3. compares eval perplexity across adapter variants (the Tbl-1 ordering),
4. prints the deployed-format byte accounting.
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.core.compressed import SlimLinear
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch, synthetic_batches
from repro.models import transformer as T
from repro.models.compress import compress_model, summarize_reports
from repro.optim import adamw, apply_updates, cosine_schedule

STEPS = 120


def main():
    cfg = get_config("slim-tiny")
    dcfg = SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=128, global_batch=16, seed=0
    )
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    # -- 1. train to signal ------------------------------------------------
    init, update = adamw(cosine_schedule(5e-3, STEPS, STEPS // 10))
    state = init(params)

    @jax.jit
    def step(p, s, b):
        l, g = jax.value_and_grad(lambda pp: T.train_loss(pp, cfg, b))(p)
        u, s = update(g, s, p)
        return apply_updates(p, u), s, l

    it = synthetic_batches(dcfg)
    for i in range(STEPS):
        params, state, loss = step(params, state, next(it))
        if i % 20 == 0:
            print(f"  train step {i}: loss {float(loss):.3f}")

    eval_batch = next(synthetic_batches(dcfg, start_step=10 ** 6))
    dense_loss = float(T.train_loss(params, cfg, eval_batch, aux_weight=0.0))
    print(f"dense eval loss: {dense_loss:.4f}")

    # -- 2+3. compress with the method grid ---------------------------------
    calib = calibration_batch(dcfg, n_samples=8)
    for label, ccfg in [
        ("no adapters (Wanda 2:4 + SLiM-Quant)", CompressionConfig(adapter="none")),
        ("Naive-LoRA", CompressionConfig(adapter="naive")),
        ("SLiM-LoRA", CompressionConfig(adapter="slim")),
        ("SLiM-LoRA^Q (4-bit adapters)",
         CompressionConfig(adapter="slim", quantize_adapters=True)),
    ]:
        cp, reports = compress_model(params, cfg, calib, ccfg)
        l = float(T.train_loss(cp, cfg, eval_batch, aux_weight=0.0))
        s = summarize_reports(reports)
        print(f"  {label:40s} eval loss {l:.4f} "
              f"(err reduction {s['err_reduction']:.1%})")

    # -- 4. byte accounting --------------------------------------------------
    cp, _ = compress_model(
        params, cfg, calib,
        CompressionConfig(adapter="slim", quantize_adapters=True),
    )
    dense_bytes = sum(x.size * 2 for x in jax.tree.leaves(params))
    comp = 0
    for leaf in jax.tree.leaves(cp, is_leaf=lambda x: isinstance(x, SlimLinear)):
        comp += leaf.packed_bytes() if isinstance(leaf, SlimLinear) else leaf.size * 2
    print(f"deployed bytes: dense(bf16) {dense_bytes/2**20:.1f} MiB -> "
          f"SLiM {comp/2**20:.1f} MiB ({comp/dense_bytes:.2f}x)")


if __name__ == "__main__":
    main()
