"""Continuous-batching walkthrough: queue -> scheduler -> per-slot KV cache.

Builds a tiny model, SLiM-compresses it, then replays a staggered Poisson
arrival trace through the continuous engine: 8 requests share 3 decode
slots, freed slots are re-prefilled mid-flight (watch the slot assignments
repeat), and every output is verified against a solo static-batch run of
the same prompt — slot recycling is exact, not approximate.

    PYTHONPATH=src python examples/serve_continuous.py [--dense]
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch
from repro.models import transformer as T
from repro.models.compress import compress_model, summarize_reports
from repro.serving import ContinuousEngine, ServeEngine, synthetic_trace


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    cfg = get_config("slim-tiny")
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    if "--dense" not in argv:
        dcfg = SyntheticLMConfig(
            vocab_size=cfg.vocab_size, seq_len=64, global_batch=8, seed=0
        )
        calib = calibration_batch(dcfg, n_samples=8)
        ccfg = CompressionConfig(
            quantizer="slim", pattern="2:4", pruner="wanda", adapter="slim",
            quantize_adapters=True,
        )
        params, reports = compress_model(params, cfg, calib, ccfg)
        print("[1] compressed:", summarize_reports(reports))
    else:
        print("[1] serving dense params (--dense)")

    # 8 requests, 3 slots: arrivals force queueing, ragged budgets force
    # mid-flight slot recycling
    trace = synthetic_trace(
        8, rate=12.0, vocab_size=cfg.vocab_size,
        prompt_len=(8, 24), max_new_tokens=(6, 16), seed=1,
    )
    print(f"[2] trace: {len(trace)} requests, arrivals "
          f"{[round(r.arrival, 2) for r in trace]}")

    max_len = 24 + 16 + 8
    engine = ContinuousEngine(
        params, cfg, n_slots=3, max_len=max_len, prefill_bucket=8
    )
    res = engine.run(trace, sync_every=4)
    m = res.metrics
    print(f"[3] slots used per request: {res.slot_of} (recycled mid-flight)")
    print(f"[3] {m['total_tokens']:.0f} tokens in {m['duration_s']:.2f}s "
          f"({m['tokens_per_s']:.1f} tok/s), occupancy {m['mean_occupancy']:.2f}")
    print(f"[3] ttft mean {m['mean_ttft_s']:.3f}s p95 {m['p95_ttft_s']:.3f}s")

    # verify: every continuous output == a fresh static run of that prompt
    static = ServeEngine(params, cfg, max_len=max_len)
    for r in res.requests:
        solo = static.generate(
            {"tokens": jnp.asarray([r.prompt], jnp.int32)},
            max_new_tokens=r.max_new_tokens,
        )
        assert solo.tokens[0] == r.output, (r.rid, solo.tokens[0], r.output)
    print("[4] all outputs identical to solo static-batch runs — "
          "slot recycling is exact")

    # paged KV cache: same trace through a block-granular pool sized to
    # HALF the contiguous engine's cache (requests only occupy blocks for
    # prompt + budget, so the smaller pool still completes the trace)
    paged_trace = synthetic_trace(
        8, rate=12.0, vocab_size=cfg.vocab_size,
        prompt_len=(8, 24), max_new_tokens=(6, 16), seed=1,
    )
    bs = 8
    half_pool = (3 * max_len // 2) // bs + 2  # ~1.5 lanes of blocks + reserved
    paged = ContinuousEngine(
        params, cfg, n_slots=3, max_len=max_len, prefill_bucket=8,
        block_size=bs, n_blocks=half_pool,
    )
    pres = paged.run(paged_trace, sync_every=4)
    for r in pres.requests:
        assert r.output == res.requests[r.rid].output, r.rid
    pm = pres.metrics
    print(f"[5] paged cache ({half_pool} x {bs}-pos blocks, half the lane "
          f"memory): same tokens, {pm['tokens_per_s']:.1f} tok/s, peak "
          f"concurrency {pm['peak_concurrency']:.0f} — allocation follows "
          "actual length, not max_len")


if __name__ == "__main__":
    main()
