"""End-to-end driver: train a ~100M-param LM for a few hundred steps, apply
SLiM one-shot compression, then the paper's optional PEFT phase (frozen
compressed base, AdaFactor on the adapters, §3.4) — with checkpoints,
straggler monitoring and resumability, i.e. the full production loop.

    PYTHONPATH=src python examples/finetune_e2e.py \
        [--steps 300] [--peft-steps 100] [--seq 256] [--batch 16]

(This is a thin veneer over `repro.launch.train`; see that module for the
flag set. On this single-CPU container a 300-step run takes a while —
reduce --steps for a smoke pass.)
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main

if __name__ == "__main__":
    args = sys.argv[1:]
    defaults = [
        "--arch", "slim-100m",
        "--steps", "300",
        "--batch", "16",
        "--seq", "256",
        "--n-micro", "2",
        "--ckpt-dir", "/tmp/slim_100m_run",
        "--peft-after-compress",
        "--peft-steps", "100",
    ]
    # user args win over defaults
    train_main(defaults + args)
