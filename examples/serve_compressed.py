"""Static-batch serving of a SLiM-compressed model: one prefill + greedy
decode with per-slot EOS tracking (the paper's deployment regime). For
staggered arrivals and slot recycling see serve_continuous.py.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    serve_main(
        [
            "--arch", "slim-tiny",
            "--batch", "8",
            "--prompt-len", "64",
            "--new-tokens", "24",
            "--compress",
        ]
        + sys.argv[1:]
    )
