"""Optimizers in pure JAX (no optax): AdamW, AdaFactor, SGD-momentum.

Functional API, pytree-native, tolerant of integer / packed-uint8 leaves
(compressed models) via a trainable ``mask`` tree for PEFT (paper §3.4:
freeze W^C, train adapters only; AdaFactor is the paper's fine-tuning
optimizer — §T).

Implementation notes:
  * Frozen/non-float leaves carry a zero-size f32 sentinel in the optimizer
    state (``_EMPTY``) so every state tree has **exactly the parameter tree's
    structure** — jit-safe, checkpoint-safe, no optax-style MaskedNode.
  * Moment dtype is configurable; bf16 moments halve optimizer HBM at
    100B-param scale (used by the big configs).
  * AdaFactor stores factored second moments packed as one array
    ``[..., d_in + d_out]`` (row ‖ col) — sublinear memory, single-leaf state.
  * State shards like its parameter (specs from ``repro.models.sharding``) —
    ZeRO-style sharded optimizer state.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

def _EMPTY():
    return jnp.zeros((0,), jnp.float32)


def _frozen(leaf) -> bool:
    return leaf is None or not (
        hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)
    )


def _gvalid(g) -> bool:
    return (
        g is not None
        and hasattr(g, "dtype")
        and jnp.issubdtype(g.dtype, jnp.floating)
        and g.dtype != jax.dtypes.float0
    )


def _resolve_mask(params: Pytree, mask: Optional[Pytree]) -> Pytree:
    if mask is None:
        return jax.tree.map(lambda p: not _frozen(p), params)
    return jax.tree.map(lambda p, m: (not _frozen(p)) and bool(m), params, mask)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class OptState:
    step: jnp.ndarray
    mu: Pytree
    nu: Pytree
    residual: Pytree = None  # error-feedback accumulator (grad compression)

    def tree_flatten(self):
        return (self.step, self.mu, self.nu, self.residual), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


# ---------------------------------------------------------------------------
# schedules
# ---------------------------------------------------------------------------

def linear_schedule(base_lr: float, total_steps: int, warmup: int = 0):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        return base_lr * warm * (1.0 - frac)

    return fn


def cosine_schedule(base_lr: float, total_steps: int, warmup: int = 0, min_frac=0.1):
    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        frac = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base_lr * warm * (min_frac + (1 - min_frac) * cos)

    return fn


# ---------------------------------------------------------------------------
# gradient utilities
# ---------------------------------------------------------------------------

def global_norm(grads: Pytree) -> jnp.ndarray:
    leaves = [
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(grads)
        if _gvalid(g) and g.size
    ]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def clip_by_global_norm(grads: Pytree, max_norm: float) -> Tuple[Pytree, jnp.ndarray]:
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))

    def clip(g):
        return g * factor.astype(g.dtype) if _gvalid(g) else g

    return jax.tree.map(clip, grads), norm


def apply_updates(params: Pytree, updates: Pytree) -> Pytree:
    def add(p, u):
        if _frozen(p) or u is None or u.size == 0:
            return p
        return (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype)

    return jax.tree.map(add, params, updates)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    moment_dtype: str = "float32",
    mask: Optional[Pytree] = None,
):
    """Returns (init_fn, update_fn). mask: pytree of bool — True = trainable."""
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))
    mdt = jnp.dtype(moment_dtype)

    def init(params: Pytree) -> OptState:
        tmask = _resolve_mask(params, mask)
        def zeros(p, m):
            return jnp.zeros(p.shape, mdt) if m else _EMPTY()
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(zeros, params, tmask),
            nu=jax.tree.map(zeros, params, tmask),
        )

    def update(grads: Pytree, state: OptState, params: Pytree):
        step = state.step + 1
        t = step.astype(jnp.float32)
        lr_t = lr_fn(step)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd_u(g, m, v, p):
            if m.size == 0 or not _gvalid(g):
                return _EMPTY()
            g32 = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            return -lr_t * (
                (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
                + weight_decay * p.astype(jnp.float32)
            )

        def upd_m(g, m):
            if m.size == 0 or not _gvalid(g):
                return m
            return (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(mdt)

        def upd_v(g, v):
            if v.size == 0 or not _gvalid(g):
                return v
            g32 = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32).astype(mdt)

        updates = jax.tree.map(upd_u, grads, state.mu, state.nu, params)
        mu = jax.tree.map(upd_m, grads, state.mu)
        nu = jax.tree.map(upd_v, grads, state.nu)
        return updates, OptState(step=step, mu=mu, nu=nu, residual=state.residual)

    return init, update


# ---------------------------------------------------------------------------
# AdaFactor (Shazeer & Stern 2018) — the paper's PEFT optimizer (§T).
# Factored second moment packed as [..., d_in + d_out]; full moment for <2D.
# ---------------------------------------------------------------------------

def adafactor(
    lr: Callable | float = 1e-3,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    mask: Optional[Pytree] = None,
):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def _factored(p) -> bool:
        return p.ndim >= 2 and p.shape[-1] > 1 and p.shape[-2] > 1

    def init(params: Pytree) -> OptState:
        tmask = _resolve_mask(params, mask)

        def vstate(p, m):
            if not m:
                return _EMPTY()
            if _factored(p):
                return jnp.zeros(p.shape[:-2] + (p.shape[-2] + p.shape[-1],), jnp.float32)
            return jnp.zeros(p.shape, jnp.float32)

        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(lambda p: _EMPTY(), params),
            nu=jax.tree.map(vstate, params, tmask),
        )

    def _moments(g2, v, p):
        """Returns (vhat like p, new_v)."""
        if _factored(p):
            d0, d1 = p.shape[-2], p.shape[-1]
            rho = _moments.rho
            row = rho * v[..., :d0] + (1 - rho) * jnp.mean(g2, axis=-1)
            col = rho * v[..., d0:] + (1 - rho) * jnp.mean(g2, axis=-2)
            row_mean = jnp.mean(row, axis=-1, keepdims=True)
            vhat = (row / jnp.maximum(row_mean, eps))[..., :, None] * col[..., None, :]
            return vhat, jnp.concatenate([row, col], axis=-1)
        rho = _moments.rho
        new_v = rho * v + (1 - rho) * g2
        return new_v, new_v

    def update(grads: Pytree, state: OptState, params: Pytree):
        step = state.step + 1
        t = step.astype(jnp.float32)
        _moments.rho = 1.0 - t ** (-decay)
        lr_t = lr_fn(step)

        def upd_u(g, v, p):
            if v.size == 0 or not _gvalid(g):
                return _EMPTY()
            g32 = g.astype(jnp.float32)
            vhat, _ = _moments(g32 * g32 + eps, v, p)
            u = g32 / jnp.sqrt(vhat + eps)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * (u + weight_decay * p.astype(jnp.float32))

        def upd_v(g, v, p):
            if v.size == 0 or not _gvalid(g):
                return v
            g32 = g.astype(jnp.float32)
            _, new_v = _moments(g32 * g32 + eps, v, p)
            return new_v

        updates = jax.tree.map(upd_u, grads, state.nu, params)
        nu = jax.tree.map(upd_v, grads, state.nu, params)
        return updates, OptState(step=step, mu=state.mu, nu=nu, residual=state.residual)

    return init, update


# ---------------------------------------------------------------------------
# SGD momentum (baseline / ablations)
# ---------------------------------------------------------------------------

def sgd_momentum(
    lr: Callable | float = 0.1, momentum: float = 0.9, mask: Optional[Pytree] = None
):
    lr_fn = lr if callable(lr) else (lambda _: jnp.asarray(lr, jnp.float32))

    def init(params):
        tmask = _resolve_mask(params, mask)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(
                lambda p, m: jnp.zeros(p.shape, jnp.float32) if m else _EMPTY(),
                params,
                tmask,
            ),
            nu=jax.tree.map(lambda p: _EMPTY(), params),
        )

    def update(grads, state, params):
        step = state.step + 1
        lr_t = lr_fn(step)

        def upd_m(g, m):
            if m.size == 0 or not _gvalid(g):
                return m
            return momentum * m + g.astype(jnp.float32)

        mu = jax.tree.map(upd_m, grads, state.mu)
        updates = jax.tree.map(
            lambda m: -lr_t * m if m.size else _EMPTY(), mu
        )
        return updates, OptState(step=step, mu=mu, nu=state.nu)

    return init, update
