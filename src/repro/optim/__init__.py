from repro.optim.optimizers import (
    OptState,
    adamw,
    adafactor,
    sgd_momentum,
    apply_updates,
    cosine_schedule,
    linear_schedule,
    global_norm,
    clip_by_global_norm,
)
