import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# test hook: allow a smaller placeholder-device count (set BEFORE jax init)
if os.environ.get("DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['DRYRUN_DEVICES']}"
    )

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell with 512 placeholder host devices and dump memory / cost /
collective analyses (EXPERIMENTS §Dry-run, §Roofline).

    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch mistral-large-123b --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun.json

Cells:
  train_4k    -> train_step   (loss + grads + AdamW update, microbatched)
  prefill_32k -> prefill_step (cache fill; compressed weights = SLiM serving)
  decode_32k  -> serve_step   (1 token against a seq_len KV cache, compressed)
  long_500k   -> serve_step   (only sub-quadratic archs; full-attn archs skip
                               per DESIGN.md §6)

Two artifacts per cell:
  * the REAL compile — proves the SPMD partition is coherent; provides
    memory_analysis (argument/temp bytes per device -> fits-HBM check);
  * the extrapolated cost analysis (launch/analysis.py) — scan-aware
    per-device FLOPs / HBM bytes / collective wire bytes for §Roofline.

Everything is lowered from ShapeDtypeStructs — no arrays are allocated.
"""
import argparse
import json
import sys
import time
from typing import Any, Dict, Optional


from repro.configs import ASSIGNED, get_config
from repro.launch import hw
from repro.launch.analysis import measure_cell
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import lower_cell
from repro.models.config import SHAPES

SKIPPED_LONG = {}  # arch -> reason, reported in the summary


def run_cell(
    arch: str,
    shape: str,
    mesh_kind: str,
    compressed_serving: bool = True,
    verbose: bool = True,
    n_micro: Optional[int] = None,
    skip_analysis: bool = False,
    kv_quant: bool = False,
    probs_low_precision: bool = False,
    packed_adapters: bool = False,
    scan_groups=None,
    serving_topology: bool = False,
    gqa_expand: bool = False,
    moe_ep: bool = False,
) -> Optional[Dict[str, Any]]:
    import dataclasses

    from repro.launch.steps import serve_ccfg

    cfg = get_config(arch)
    if kv_quant or probs_low_precision or gqa_expand or moe_ep:
        cfg = dataclasses.replace(
            cfg, kv_quant=kv_quant, attn_probs_low_precision=probs_low_precision,
            gqa_expand_kv=gqa_expand, moe_expert_parallel=moe_ep,
        )
    ccfg = serve_ccfg(cfg, pack_adapters=packed_adapters)
    cell = SHAPES[shape]
    if shape == "long_500k" and not cfg.is_subquadratic:
        SKIPPED_LONG[arch] = (
            "full attention: 512k dense-KV decode skipped (DESIGN.md §6)"
        )
        if verbose:
            print(f"[skip] {arch} x {shape}: {SKIPPED_LONG[arch]}")
        return None
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))

    # 1) the real compile: SPMD coherence proof + memory analysis
    t0 = time.time()
    lowered, chips = lower_cell(
        cfg, cell, mesh, compressed_serving=compressed_serving, n_micro=n_micro,
        ccfg=ccfg, scan_groups=scan_groups, serving_topology=serving_topology,
    )
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    result: Dict[str, Any] = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_kind,
        "chips": chips,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    mem = compiled.memory_analysis()
    if mem is not None:
        for k in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "peak_memory_in_bytes",
        ):
            v = getattr(mem, k, None)
            if v is not None:
                result[k] = int(v)
        # peak accounts for donation aliasing — the authoritative per-device
        # HBM requirement
        result["per_device_bytes"] = result.get(
            "peak_memory_in_bytes",
            result.get("argument_size_in_bytes", 0)
            + result.get("temp_size_in_bytes", 0),
        )
        result["fits_hbm"] = bool(result["per_device_bytes"] <= hw.HBM_BYTES)

    # 2) scan-aware extrapolated roofline terms
    if not skip_analysis:
        t0 = time.time()
        rf = measure_cell(
            cfg, cell, mesh, compressed_serving=compressed_serving,
            n_micro=n_micro, ccfg=ccfg, serving_topology=serving_topology,
        )
        result["analysis_s"] = round(time.time() - t0, 1)
        result["roofline"] = rf.row()
        result["collective_counts"] = rf.collectives.counts
        result["collective_bytes"] = rf.collectives.bytes_by_kind

    if verbose:
        line = (
            f"[ok] {arch} x {shape} x {mesh_kind}({chips}): "
            f"compile {t_compile:.1f}s | per-dev "
            f"{result.get('per_device_bytes', 0)/2**30:.2f} GiB "
            f"fits={result.get('fits_hbm')}"
        )
        if "roofline" in result:
            r = result["roofline"]
            line += (
                f" | compute {r['t_compute_s']:.3e}s memory {r['t_memory_s']:.3e}s"
                f" collective {r['t_collective_s']:.3e}s -> {r['bottleneck']}"
                f" | useful {r['useful_ratio'] and round(r['useful_ratio'], 3)}"
            )
        print(line)
    return result


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None, choices=ASSIGNED + ["slim-tiny"])
    p.add_argument("--shape", default=None, choices=list(SHAPES))
    p.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    p.add_argument("--all", action="store_true", help="run every cell")
    p.add_argument("--dense-serving", action="store_true")
    p.add_argument("--skip-analysis", action="store_true")
    p.add_argument("--out", default=None, help="write JSON results")
    p.add_argument("--n-micro", type=int, default=None)
    # perf-iteration toggles (EXPERIMENTS §Perf)
    p.add_argument("--kv-quant", action="store_true")
    p.add_argument("--probs-bf16", action="store_true")
    p.add_argument("--packed-adapters", action="store_true")
    p.add_argument("--scan-groups", type=int, default=None)
    p.add_argument("--serve-topology", action="store_true",
                   help="replicate weights over dp (TP-only serving)")
    p.add_argument("--gqa-expand", action="store_true")
    p.add_argument("--moe-ep", action="store_true",
                   help="expert-parallel MoE weights (E over model axis)")
    args = p.parse_args(argv)

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                try:
                    r = run_cell(
                        arch, shape, mesh_kind,
                        compressed_serving=not args.dense_serving,
                        n_micro=args.n_micro,
                        skip_analysis=args.skip_analysis,
                        kv_quant=args.kv_quant,
                        probs_low_precision=args.probs_bf16,
                        packed_adapters=args.packed_adapters,
                        scan_groups=args.scan_groups,
                        serving_topology=args.serve_topology,
                        gqa_expand=args.gqa_expand,
                        moe_ep=args.moe_ep,
                    )
                    if r:
                        results.append(r)
                except Exception as e:  # a dry-run failure is a bug: surface it
                    failures.append((arch, shape, mesh_kind, repr(e)))
                    print(f"[FAIL] {arch} x {shape} x {mesh_kind}: {e!r}")

    print(
        f"\n=== dry-run summary: {len(results)} ok, {len(failures)} failed, "
        f"{len(SKIPPED_LONG)} long-context skips ==="
    )
    for a, s, m, e in failures:
        print(f"  FAIL {a} x {s} x {m}: {e[:300]}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(
                {
                    "results": results,
                    "failures": failures,
                    "skipped_long": SKIPPED_LONG,
                },
                f,
                indent=1,
            )
        print(f"wrote {args.out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
