"""Scan-aware cost measurement by linear extrapolation.

XLA's ``cost_analysis()`` on a compiled artifact is **per-device** and counts
every ``while``-loop (lax.scan) body **once**, so a 88-layer scanned model
reports ~1 layer of FLOPs. We recover exact totals structurally:

  * lower an *analysis variant* of the config with the inner scans
    flattened — ``q_chunk = seq_len`` (attention as one block) and
    ``vocab_chunk = seq_len`` (loss in one block). FLOP/byte-identical math,
    scan-free. (SSD keeps its chunking: it is vectorized over chunks, only
    the cheap inter-chunk state scan is underestimated.)
  * lower it at P=1 and P=2 periods: Δ = per-period cost (embed/head costs
    cancel); total fwd+bwd = A1 + (P-1)Δ.
  * training: per-microbatch cost measured at ``global_batch/M``; the
    optimizer is lowered separately.  total = M·fb + opt. (The gradient
    all-reduce/reduce-scatter sits inside each microbatch's bwd in the real
    scanned program too, so the M· multiplier is faithful.)

Everything stays per-device (SPMD module view): the roofline divides by
per-chip peaks directly. Wire bytes come from the same extrapolation applied
to the parsed collective ops of each artifact.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


from repro.launch.roofline import (
    CollectiveStats,
    Roofline,
    analytic_model_flops,
    parse_collectives,
)
from repro.launch.steps import default_n_micro, lower_cell, lower_opt_only
from repro.models.config import ModelConfig, ShapeCell


def _dmerge(a: Dict, b: Dict, f):
    return {k: f(a.get(k, 0), b.get(k, 0)) for k in set(a) | set(b)}


@dataclasses.dataclass
class Cost:
    flops: float
    bytes: float
    wire: float
    counts: Dict[str, int]
    wire_by_kind: Dict[str, float]

    def __add__(self, o):
        return Cost(
            self.flops + o.flops,
            self.bytes + o.bytes,
            self.wire + o.wire,
            _dmerge(self.counts, o.counts, lambda x, y: x + y),
            _dmerge(self.wire_by_kind, o.wire_by_kind, lambda x, y: x + y),
        )

    def __sub__(self, o):
        return Cost(
            self.flops - o.flops,
            self.bytes - o.bytes,
            self.wire - o.wire,
            _dmerge(self.counts, o.counts, lambda x, y: x - y),
            _dmerge(self.wire_by_kind, o.wire_by_kind, lambda x, y: x - y),
        )

    def __mul__(self, k: float):
        return Cost(
            self.flops * k,
            self.bytes * k,
            self.wire * k,
            {kk: int(v * k) for kk, v in self.counts.items()},
            {kk: v * k for kk, v in self.wire_by_kind.items()},
        )

    __rmul__ = __mul__


def _cost_of(compiled) -> Cost:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    stats = parse_collectives(compiled.as_text())
    return Cost(
        flops=float(ca.get("flops", 0.0)),
        bytes=float(ca.get("bytes accessed", 0.0)),
        wire=stats.total_bytes,
        counts=stats.counts,
        wire_by_kind=stats.bytes_by_kind,
    )


def _analysis_cfg(cfg: ModelConfig, cell: ShapeCell, n_periods: int) -> ModelConfig:
    plen = len(cfg.period)
    return dataclasses.replace(
        cfg,
        n_layers=plen * n_periods,
        q_chunk=max(cell.seq_len, 1),
        vocab_chunk=max(cell.seq_len, 1),
        unroll_layers=True,
    )


def measure_cell(
    cfg: ModelConfig,
    cell: ShapeCell,
    mesh,
    compressed_serving: bool = True,
    n_micro: Optional[int] = None,
    ccfg=None,
    serving_topology: bool = False,
) -> Roofline:
    """Extrapolated per-device roofline for the full (arch x cell x mesh)."""
    chips = mesh.devices.size
    p_total = cfg.n_periods

    if cell.kind == "train":
        m = n_micro or default_n_micro(cfg, cell, mesh)
        micro_cell = dataclasses.replace(cell, global_batch=cell.global_batch // m)
        a1, _ = lower_cell(
            _analysis_cfg(cfg, cell, 1), micro_cell, mesh, fb_only=True, n_micro=1
        )
        a2, _ = lower_cell(
            _analysis_cfg(cfg, cell, 2), micro_cell, mesh, fb_only=True, n_micro=1
        )
        o, _ = lower_opt_only(cfg, mesh)
        c1, c2, co = _cost_of(a1.compile()), _cost_of(a2.compile()), _cost_of(o.compile())
        per_period = c2 - c1
        total = m * (c1 + (p_total - 1) * per_period) + co
    else:
        d1, _ = lower_cell(
            _analysis_cfg(cfg, cell, 1), cell, mesh,
            compressed_serving=compressed_serving, ccfg=ccfg,
            serving_topology=serving_topology,
        )
        d2, _ = lower_cell(
            _analysis_cfg(cfg, cell, 2), cell, mesh,
            compressed_serving=compressed_serving, ccfg=ccfg,
            serving_topology=serving_topology,
        )
        c1, c2 = _cost_of(d1.compile()), _cost_of(d2.compile())
        total = c1 + (p_total - 1) * (c2 - c1)

    return Roofline(
        flops=total.flops,
        hbm_bytes=total.bytes,
        wire_bytes=total.wire,
        chips=chips,
        collectives=CollectiveStats(
            counts=total.counts, bytes_by_kind=total.wire_by_kind
        ),
        model_flops=analytic_model_flops(cfg, cell),
    )
