"""Format dryrun_results.json into the EXPERIMENTS.md §Dry-run / §Roofline
markdown tables.

    PYTHONPATH=src python -m repro.launch.report dryrun_results.json
"""
import json
import sys


def fmt_bytes(b):
    return f"{b/2**30:.2f}"


def fmt_s(x):
    return f"{x:.2e}"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json"
    data = json.load(open(path))
    results = data["results"]

    print("### §Dry-run (memory / compile)\n")
    print("| arch | shape | mesh | chips | compile s | peak GiB/dev | fits 16G |")
    print("|---|---|---|---|---|---|---|")
    for r in results:
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['chips']} | "
            f"{r['compile_s']} | {fmt_bytes(r.get('per_device_bytes', 0))} | "
            f"{'Y' if r.get('fits_hbm') else 'N'} |"
        )
    if data.get("skipped_long"):
        print("\nSkips (per DESIGN.md §6):")
        for k, v in data["skipped_long"].items():
            print(f"- {k} x long_500k: {v}")

    print("\n### §Roofline (per-device, single step)\n")
    print(
        "| arch | shape | mesh | t_compute | t_memory | t_collective | "
        "bottleneck | useful 6ND/HLO | AR | AG | RS | A2A | CP |"
    )
    print("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    for r in results:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        cc = r.get("collective_counts", {})
        ur = rf.get("useful_ratio")
        print(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{fmt_s(rf['t_compute_s'])} | {fmt_s(rf['t_memory_s'])} | "
            f"{fmt_s(rf['t_collective_s'])} | {rf['bottleneck']} | "
            f"{ur and round(ur, 3)} | "
            f"{cc.get('all-reduce', 0)} | {cc.get('all-gather', 0)} | "
            f"{cc.get('reduce-scatter', 0)} | {cc.get('all-to-all', 0)} | "
            f"{cc.get('collective-permute', 0)} |"
        )

    # summary stats
    fails = data.get("failures", [])
    fits = sum(1 for r in results if r.get("fits_hbm"))
    print(
        f"\n{len(results)} cells compiled; {fits} fit 16 GiB/dev; "
        f"{len(fails)} failures; {len(data.get('skipped_long', {}))} long-ctx skips."
    )


if __name__ == "__main__":
    main()
