"""Target hardware constants (TPU v5e) for roofline accounting."""

PEAK_FLOPS_BF16 = 197e12  # per chip, FLOP/s
HBM_BW = 819e9  # per chip, B/s
ICI_BW = 50e9  # per link, B/s (~both directions aggregated per link)

CHIPS_PER_POD = 256
VMEM_BYTES = 128 * 1024 * 1024  # v5e VMEM (~128 MiB)
HBM_BYTES = 16 * 1024 ** 3  # 16 GiB per chip
