"""Step builders + cell lowering shared by dryrun, analysis, and benchmarks."""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.pipeline import CompressionConfig
from repro.distributed.accum import microbatch_grads
from repro.launch.specs import (
    _with_shardings,
    abstract_params,
    abstract_slim_params,
    cache_specs_abstract,
    input_specs,
)
from repro.models import sharding as shard_rules
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeCell
from repro.optim import adamw, apply_updates, clip_by_global_norm


def serve_ccfg(cfg: ModelConfig, pack_adapters: bool = False) -> CompressionConfig:
    """Deployment format for serve cells: SLiM-Quant + 2:4 + SLiM-LoRA^Q."""
    return CompressionConfig(
        quantizer="slim", pattern="2:4", adapter="slim",
        rank=None, rank_ratio=0.1, quantize_adapters=True,
        pack_adapters=pack_adapters,
    )


def default_n_micro(cfg: ModelConfig, cell: ShapeCell, mesh) -> int:
    dp = 1
    for a in shard_rules.dp_axes(mesh):
        dp *= mesh.shape[a]
    return max(1, cell.global_batch // dp)  # microbatch of 1 seq per device


def moment_dtype_for(cfg: ModelConfig) -> str:
    return "bfloat16" if cfg.param_count() > 2e10 else "float32"


def build_train_step(cfg: ModelConfig, n_micro: int, moment_dtype: str):
    opt_init, opt_update = adamw(1e-4, moment_dtype=moment_dtype)

    def train_step(params, opt_state, batch):
        loss, grads = microbatch_grads(
            lambda p, b: T.train_loss(p, cfg, b), params, batch, n_micro
        )
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, gnorm

    return train_step, opt_init, opt_update


def abstract_opt_state(cfg: ModelConfig, mesh, params, opt_init):
    opt_state = jax.eval_shape(opt_init, params)
    pspecs = shard_rules.param_specs(params, cfg, mesh)
    ospecs = shard_rules.opt_specs(opt_state, pspecs)
    return _with_shardings(opt_state, ospecs, mesh)


def _sqrt_divisor(n: int) -> int:
    """Largest divisor of n that is <= sqrt(n) (sqrt-remat group count)."""
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            best = d
        d += 1
    return best


def lower_cell(
    cfg: ModelConfig,
    cell: ShapeCell,
    mesh,
    compressed_serving: bool = True,
    n_micro: Optional[int] = None,
    donate: bool = True,
    fb_only: bool = False,
    scan_groups: Optional[int] = None,  # None=auto (sqrt), 1=flat remat
    ccfg: Optional[CompressionConfig] = None,  # serve compression format
    serving_topology: bool = False,  # replicate weights over dp (TP-only)
):
    """Lower one (arch x shape) cell on `mesh`. Returns (lowered, chips).

    fb_only: lower just value_and_grad (no optimizer) — the analysis variant.
    """
    chips = mesh.devices.size
    with mesh:
        if cell.kind == "train":
            if scan_groups is None and not cfg.unroll_layers:
                scan_groups = _sqrt_divisor(cfg.n_periods)
            if scan_groups and scan_groups > 1:
                cfg = dataclasses.replace(cfg, scan_groups=scan_groups)
            if n_micro is None:
                n_micro = default_n_micro(cfg, cell, mesh)
            params = abstract_params(cfg, mesh)
            batch = input_specs(cfg, cell, mesh)
            if fb_only:
                def fb_step(params, batch):
                    return microbatch_grads(
                        lambda p, b: T.train_loss(p, cfg, b), params, batch, n_micro
                    )

                return jax.jit(fb_step).lower(params, batch), chips
            step, opt_init, _ = build_train_step(
                cfg, n_micro, moment_dtype_for(cfg)
            )
            opt_state = abstract_opt_state(cfg, mesh, params, opt_init)
            jitted = jax.jit(step, donate_argnums=(0, 1) if donate else ())
            return jitted.lower(params, opt_state, batch), chips

        params = (
            abstract_slim_params(
                cfg, mesh, ccfg or serve_ccfg(cfg),
                serving_topology=serving_topology,
            )
            if compressed_serving
            else abstract_params(cfg, mesh)
        )
        batch = input_specs(cfg, cell, mesh)
        if cell.kind == "prefill":

            def prefill_step(params, batch):
                return T.prefill(params, cfg, batch, max_len=cell.seq_len)

            return jax.jit(prefill_step).lower(params, batch), chips

        # decode (per-slot position vector: continuous-batching serving shape)
        cache = cache_specs_abstract(cfg, cell, mesh)
        tok = batch.get("tokens", batch.get("embeds"))
        pos = jax.ShapeDtypeStruct((tok.shape[0],), jnp.int32)

        def serve_step(params, cache, tok, pos):
            return T.decode_step(params, cfg, cache, tok, pos)

        jitted = jax.jit(serve_step, donate_argnums=(1,) if donate else ())
        return jitted.lower(params, cache, tok, pos), chips


def lower_opt_only(cfg: ModelConfig, mesh):
    """Lower just the optimizer update over the full parameter tree."""
    with mesh:
        params = abstract_params(cfg, mesh)
        _, opt_init, opt_update = build_train_step(cfg, 1, moment_dtype_for(cfg))
        opt_state = abstract_opt_state(cfg, mesh, params, opt_init)
        grads = params  # same shapes/shardings as a gradient tree

        def opt_step(grads, opt_state, params):
            g, _ = clip_by_global_norm(grads, 1.0)
            updates, opt_state = opt_update(g, opt_state, params)
            return apply_updates(params, updates), opt_state

        return jax.jit(opt_step).lower(grads, opt_state, params), mesh.devices.size
