"""Training launcher (real execution, CPU-or-TPU).

    PYTHONPATH=src python -m repro.launch.train --arch slim-tiny --steps 200
    PYTHONPATH=src python -m repro.launch.train --arch slim-100m --steps 300 \
        --batch 32 --seq 512 --ckpt-dir /tmp/run1 --peft-after-compress

Features wired in: elastic mesh (uses whatever devices exist), deterministic
resumable data stream, microbatched grad accumulation, optional int8
error-feedback gradient compression, checkpoint/restart (atomic, async,
retention), straggler/hang monitor, and the SLiM PEFT phase (compress ->
freeze base -> AdaFactor on adapters, paper §3.4).
"""
from __future__ import annotations

import argparse

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch, synthetic_batches
from repro.distributed import StepMonitor, ef_compress_grads, elastic_mesh, microbatch_grads
from repro.models import transformer as T
from repro.models.compress import compress_model, peft_mask, summarize_reports
from repro.optim import (
    adafactor,
    adamw,
    apply_updates,
    clip_by_global_norm,
    cosine_schedule,
)


def make_step(cfg, opt_update, n_micro, grad_compression):
    def step(params, opt_state, batch):
        loss, grads = microbatch_grads(
            lambda p, b: T.train_loss(p, cfg, b), params, batch, n_micro
        )
        if grad_compression:
            grads, residual = ef_compress_grads(grads, opt_state.residual)
            opt_state.residual = residual
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        updates, opt_state = opt_update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, loss, gnorm

    return jax.jit(step, donate_argnums=(0, 1))


def train_loop(
    params, cfg, args, optimizer, data_cfg, tag=""
):
    opt_init, opt_update = optimizer
    opt_state = opt_init(params)
    step_fn = make_step(cfg, opt_update, args.n_micro, args.grad_compression)

    mgr = CheckpointManager(args.ckpt_dir, keep=3) if args.ckpt_dir else None
    start = 0
    if mgr is not None:
        restored = mgr.restore_latest((params, opt_state))
        if restored is not None:
            start, (params, opt_state) = restored
            print(f"[resume] from step {start}")

    mon = StepMonitor(hang_timeout_s=args.hang_timeout).start()
    stream = synthetic_batches(data_cfg, start_step=start)
    losses = []
    for i in range(start, args.steps):
        mon.check_hang()
        mon.step_begin()
        batch = next(stream)
        params, opt_state, loss, gnorm = step_fn(params, opt_state, batch)
        if i % args.log_every == 0 or i == args.steps - 1:
            lv = float(loss)
            losses.append(lv)
            print(f"[{tag}step {i}] loss={lv:.4f} gnorm={float(gnorm):.3f} "
                  f"dt={mon.mean_dt and round(mon.mean_dt, 2)}s")
        mon.step_end()
        if mgr is not None and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, (params, opt_state), blocking=False)
    if mgr is not None:
        mgr.save(args.steps, (params, opt_state))
        mgr.wait()
    mon.stop()
    return params, losses


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="slim-tiny")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--n-micro", type=int, default=1)
    p.add_argument("--grad-compression", action="store_true")
    p.add_argument("--ckpt-dir", default=None)
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--log-every", type=int, default=10)
    p.add_argument("--hang-timeout", type=float, default=900.0)
    p.add_argument("--seed", type=int, default=0)
    # SLiM PEFT phase
    p.add_argument("--peft-after-compress", action="store_true")
    p.add_argument("--peft-steps", type=int, default=100)
    p.add_argument("--peft-lr", type=float, default=1e-3)
    p.add_argument("--rank", type=int, default=None)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = elastic_mesh(preferred_model=1)
    print(f"[mesh] {dict(mesh.shape)} devices={mesh.devices.size}")

    data_cfg = SyntheticLMConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.seq,
        global_batch=args.batch,
        seed=args.seed,
        d_model=cfg.d_model,
        vision_tokens=cfg.vision_tokens,
        input_mode=cfg.input_mode,
    )
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[model] {cfg.name}: {n_params/1e6:.1f}M params")

    optimizer = adamw(cosine_schedule(args.lr, args.steps, warmup=args.steps // 20))
    params, losses = train_loop(params, cfg, args, optimizer, data_cfg)
    print(f"[train] loss {losses[0]:.4f} -> {losses[-1]:.4f}")

    if args.peft_after_compress:
        print("[slim] one-shot compression (SLiM-Quant + 2:4 Wanda + SLiM-LoRA)")
        calib = calibration_batch(data_cfg, n_samples=8)
        ccfg = CompressionConfig(
            quantizer="slim", pattern="2:4", pruner="wanda", adapter="slim",
            rank=args.rank,
        )
        cparams, reports = compress_model(params, cfg, calib, ccfg)
        print("[slim]", summarize_reports(reports))
        eval_batch = next(synthetic_batches(data_cfg, start_step=10**6))
        l_dense = float(T.train_loss(params, cfg, eval_batch))
        l_comp = float(T.train_loss(cparams, cfg, eval_batch))
        print(f"[slim] eval loss dense={l_dense:.4f} compressed={l_comp:.4f}")

        mask = peft_mask(cparams)
        peft_opt = adafactor(args.peft_lr, mask=jax.tree.map(lambda m: bool(m), mask))
        pargs = argparse.Namespace(**vars(args))
        pargs.steps = args.peft_steps
        pargs.ckpt_dir = None
        cparams, plosses = train_loop(
            cparams, cfg, pargs, peft_opt, data_cfg, tag="peft-"
        )
        l_peft = float(T.train_loss(cparams, cfg, eval_batch))
        print(
            f"[slim] PEFT recovered: compressed {l_comp:.4f} -> {l_peft:.4f} "
            f"(dense {l_dense:.4f})"
        )


if __name__ == "__main__":
    main()
