"""Serving launcher: batched generation from a (compressed) model.

Static batch (one shot, all requests start together):

    PYTHONPATH=src python -m repro.launch.serve --arch slim-tiny \
        --batch 8 --prompt-len 64 --new-tokens 32 --compress

Continuous batching (replay a synthetic Poisson arrival trace through the
scheduler + per-slot KV cache engine):

    PYTHONPATH=src python -m repro.launch.serve --arch slim-tiny \
        --workload poisson --requests 16 --slots 4 --rate 8 --compress

Compresses the model one-shot with SLiM (optional), then runs the chosen
engine and reports prefill latency + decode tokens/s (static) or the full
serving metrics — TTFT, per-request latency, slot occupancy (workload).
On this CPU container the numbers are functional smoke only; the TPU
roofline story is in benchmarks/bench_speedup.py and EXPERIMENTS §Roofline.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch, synthetic_batches
from repro.models import transformer as T
from repro.models.compress import compress_model, summarize_reports
from repro.serving import (
    ContinuousEngine,
    EngineConfig,
    FaultPlan,
    GuardConfig,
    ObservabilityConfig,
    PagingConfig,
    ParallelConfig,
    PrefixCacheConfig,
    Router,
    ServeEngine,
    SpanTracer,
    SpecConfig,
    synthetic_trace,
)
from repro.serving.block_pool import RESERVED_BLOCKS
from repro.serving.export import (
    EngineLiveSource,
    MetricsServer,
    RouterLiveSource,
    SnapshotWriter,
    atomic_write_json,
    parse_listen,
)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="slim-tiny")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--new-tokens", type=int, default=32)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--compress", action="store_true")
    p.add_argument("--rank", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    # continuous-batching workload mode
    p.add_argument(
        "--workload", choices=["static", "poisson"], default="static",
        help="static: one batch, all requests together; poisson: replay a "
        "synthetic arrival trace through the continuous-batching engine",
    )
    p.add_argument("--requests", type=int, default=16)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--rate", type=float, default=8.0, help="arrivals per second")
    p.add_argument("--prefill-bucket", type=int, default=16)
    p.add_argument("--sync-every", type=int, default=8)
    p.add_argument(
        "--block-size", type=int, default=0,
        help="paged KV cache block size in positions (0 = contiguous "
        "max_len lane per slot)",
    )
    p.add_argument(
        "--n-blocks", type=int, default=None,
        help="paged pool size in blocks (default: equal memory to the "
        "contiguous per-slot lanes)",
    )
    p.add_argument(
        "--prefix-cache", action="store_true",
        help="share identical prompt-prefix blocks between requests "
        "(refcounted copy-on-write over the paged pool; needs --block-size)",
    )
    p.add_argument(
        "--shared-prefix", type=int, default=0,
        help="length of a common prompt prefix shared by every request in "
        "the synthetic trace (models system-prompt traffic)",
    )
    p.add_argument(
        "--preemption", action="store_true",
        help="admit optimistically (charge only the prompt's blocks), grow "
        "block tables on demand, and evict the youngest running request "
        "when the pool runs dry (token-exact resume; needs --block-size)",
    )
    p.add_argument(
        "--decode-reserve", type=int, default=2,
        help="watermark blocks held unallocated at admission for running "
        "slots to grow into (preemption mode only)",
    )
    p.add_argument(
        "--speculative", type=int, default=0, metavar="K",
        help="self-speculative decoding: draft K-1 tokens per round with "
        "the SLiM adapter path disabled, verify the window in one "
        "full-model pass, bulk-commit the accepted prefix (needs "
        "--block-size; K >= 2)",
    )
    p.add_argument(
        "--victim-policy", choices=["youngest", "cost"], default="youngest",
        help="preemption victim selection: youngest admission, or cost "
        "(blocks freed per generated token discarded)",
    )
    p.add_argument(
        "--prefix-index-cap", type=int, default=0,
        help="cap on the prefix cache's content-hash index entries "
        "(0 = unbounded; evict-oldest on overflow)",
    )
    p.add_argument(
        "--prefix-index-ttl", type=float, default=0.0,
        help="seconds a prefix-index entry may outlive its registration "
        "(0 = no TTL)",
    )
    # topology: engine = one replica; scale out with the router, scale up
    # with tensor parallelism inside each replica (docs/serving.md)
    p.add_argument(
        "--replicas", type=int, default=1,
        help="data-parallel engine replicas behind the Router (1 = a bare "
        "engine; continuous workload only)",
    )
    p.add_argument(
        "--placement", choices=["least_loaded", "prefix_affinity"],
        default="least_loaded",
        help="router placement policy: least cumulative planned work, or "
        "sticky routing by block-aligned prompt-prefix identity (keeps a "
        "shared prefix hot on one replica's prefix cache)",
    )
    p.add_argument(
        "--tp", type=int, default=1,
        help="tensor-parallel degree inside each replica: shards weights, "
        "KV pool and attention heads over a (1, tp) device mesh's model "
        "axis (needs tp visible devices)",
    )
    p.add_argument(
        "--prefix-groups", type=int, default=1,
        help="number of distinct shared prefixes in the synthetic trace "
        "(multi-tenant traffic; needs --shared-prefix)",
    )
    # observability (docs/observability.md)
    p.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="record the request lifecycle as Chrome trace-event JSON "
        "(load in Perfetto / chrome://tracing; continuous workload only)",
    )
    p.add_argument(
        "--metrics-json", default=None, metavar="PATH",
        help="dump the run's full metrics summary as JSON — every "
        "registry-generated key, not just the printed subset "
        "(continuous workload only)",
    )
    p.add_argument(
        "--listen", default=None, metavar="ADDR",
        help="serve live metrics over HTTP while the run executes: "
        "/metrics (Prometheus text exposition), /metrics.json (rolling-"
        "window snapshot), /healthz (degradation level + last-burst age). "
        "ADDR is ':9100', '127.0.0.1:9100', or a bare port; an empty host "
        "binds localhost (continuous workload only)",
    )
    p.add_argument(
        "--metrics-flush-interval", type=float, default=1.0,
        metavar="SECONDS",
        help="with --metrics-json: rewrite the live snapshot atomically "
        "(write-to-temp + rename) every SECONDS during the run, so a "
        "killed run still leaves its last consistent snapshot on disk",
    )
    p.add_argument(
        "--postmortem-dir", default=None, metavar="DIR",
        help="enable the per-request flight recorder and dump a "
        "postmortem bundle (postmortem_rid<N>.json) into DIR for every "
        "request ending FAILED/EXPIRED/ABORTED (continuous workload only)",
    )
    p.add_argument(
        "--slo-ttft", type=float, default=0.0, metavar="SECONDS",
        help="p95 time-to-first-token SLO target: the rolling-window "
        "error-budget burn feeds the degradation ladder as pressure "
        "(0 = unmonitored; needs --degrade)",
    )
    p.add_argument(
        "--slo-tpot", type=float, default=0.0, metavar="SECONDS",
        help="p95 time-per-output-token SLO target (0 = unmonitored; "
        "needs --degrade)",
    )
    p.add_argument(
        "--slo-shed-rate", type=float, default=0.0, metavar="FRACTION",
        help="target shed fraction (shed / arrivals over the rolling "
        "window); shedding at the target is burn 1.0 (0 = unmonitored; "
        "needs --degrade)",
    )
    p.add_argument(
        "--obs-window", type=float, default=60.0, metavar="SECONDS",
        help="rolling window for the live metrics (window_* keys and SLO "
        "burn computation)",
    )
    p.add_argument(
        "--profile-dir", default=None, metavar="DIR",
        help="bracket the run in jax.profiler.start_trace/stop_trace; "
        "the xprof capture lands in DIR (view with TensorBoard)",
    )
    # robustness (docs/robustness.md)
    p.add_argument(
        "--deadline", type=float, default=0.0, metavar="SECONDS",
        help="default per-request TTL: a request still queued or running "
        "this long after its arrival lands in the EXPIRED terminal state "
        "(0 = no deadlines; continuous workload only)",
    )
    p.add_argument(
        "--max-queue", type=int, default=0, metavar="N",
        help="bounded admission queue: when more than N arrived requests "
        "are waiting, the newest are shed (terminal ABORTED) instead of "
        "queueing without bound (0 = unbounded)",
    )
    p.add_argument(
        "--watchdog", type=float, default=0.0, metavar="SECONDS",
        help="burst watchdog: a decode/speculative burst whose dispatch-"
        "to-sync wall time exceeds SECONDS is counted, traced, and fed "
        "into the degradation ladder as pressure (0 = off)",
    )
    p.add_argument(
        "--degrade", action="store_true",
        help="enable the graceful-degradation ladder: under queue/deadline "
        "pressure the engine pauses prefix-cache growth, falls back from "
        "speculative to plain decode, and tightens the admission reserve "
        "— with hysteresis on recovery (docs/robustness.md)",
    )
    p.add_argument(
        "--chaos", default=None, metavar="SPEC",
        help="deterministic fault injection: semicolon-separated clauses "
        "'site[@nth][:key=val,...]' over sites "
        "admit_shortfall, extend_shortfall, kv_corrupt, nan_logits, "
        "burst_stall, queue_flood — e.g. 'nan_logits@1;burst_stall@2:"
        "arg=40'. Keys: nth, every, prob, count, arg. Seeded by --seed.",
    )
    p.add_argument(
        "--check-retrace", action="store_true",
        help="wrap every jitted hot path in the runtime retrace guard "
        "(repro.analysis.retrace): a steady-state recompile raises with "
        "the offending function and argument-shape delta; per-path "
        "compile counts print and land in --metrics-json as "
        "jit_compiles_* / jit_retraces (continuous workload only)",
    )
    args = p.parse_args(argv)

    if args.block_size > 0 and args.workload != "poisson":
        p.error("--block-size requires --workload poisson (the static "
                "ServeEngine has no paged cache)")
    if args.n_blocks is not None and args.block_size <= 0:
        p.error("--n-blocks sizes the paged pool; it needs --block-size")
    if args.prefix_cache and args.block_size <= 0:
        p.error("--prefix-cache shares pool blocks; it needs --block-size")
    if args.shared_prefix > 0 and args.workload != "poisson":
        p.error("--shared-prefix shapes the synthetic arrival trace; it "
                "needs --workload poisson")
    if args.preemption and args.block_size <= 0:
        p.error("--preemption evicts pool blocks; it needs --block-size")
    if args.speculative and args.block_size <= 0:
        p.error("--speculative verifies drafts against the paged pool; it "
                "needs --block-size")
    if args.victim_policy != "youngest" and not args.preemption:
        p.error("--victim-policy selects the preemption victim; it needs "
                "--preemption")
    if (args.prefix_index_cap or args.prefix_index_ttl) and not args.prefix_cache:
        p.error("--prefix-index-cap/--prefix-index-ttl bound the prefix "
                "cache's hash index; they need --prefix-cache")
    if (args.replicas > 1 or args.tp > 1) and args.workload != "poisson":
        p.error("--replicas/--tp shape the continuous-serving topology; "
                "they need --workload poisson")
    if args.placement != "least_loaded" and args.replicas < 2:
        p.error("--placement chooses between router replicas; it needs "
                "--replicas >= 2")
    if args.prefix_groups > 1 and not args.shared_prefix:
        p.error("--prefix-groups splits the shared prefix into tenant "
                "populations; it needs --shared-prefix")
    if args.trace_out and args.workload != "poisson":
        p.error("--trace-out records the continuous engine's lifecycle; "
                "it needs --workload poisson")
    if args.metrics_json and args.workload != "poisson":
        p.error("--metrics-json dumps the continuous engine's metrics "
                "registry; it needs --workload poisson")
    if args.check_retrace and args.workload != "poisson":
        p.error("--check-retrace guards the continuous engine's jitted hot "
                "paths; it needs --workload poisson")
    if args.listen and args.workload != "poisson":
        p.error("--listen serves the continuous engine's live metrics; it "
                "needs --workload poisson")
    if args.postmortem_dir and args.workload != "poisson":
        p.error("--postmortem-dir records the continuous engine's request "
                "lifecycles; it needs --workload poisson")
    if (
        args.slo_ttft or args.slo_tpot or args.slo_shed_rate
    ) and not args.degrade:
        p.error("--slo-ttft/--slo-tpot/--slo-shed-rate drive the "
                "degradation ladder; they need --degrade")
    if args.metrics_flush_interval <= 0:
        p.error("--metrics-flush-interval must be > 0 seconds")
    if (
        args.deadline or args.max_queue or args.watchdog or args.degrade
        or args.chaos
    ) and args.workload != "poisson":
        p.error("--deadline/--max-queue/--watchdog/--degrade/--chaos "
                "configure the continuous engine's robustness layer; they "
                "need --workload poisson")

    cfg = get_config(args.arch, reduced=args.reduced)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    data_cfg = SyntheticLMConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.prompt_len,
        global_batch=args.batch,
        seed=args.seed,
        d_model=cfg.d_model,
        vision_tokens=cfg.vision_tokens,
        input_mode=cfg.input_mode,
    )

    if args.compress:
        calib = calibration_batch(data_cfg, n_samples=8)
        ccfg = CompressionConfig(
            quantizer="slim", pattern="2:4", pruner="wanda", adapter="slim",
            rank=args.rank, quantize_adapters=True,
        )
        params, reports = compress_model(params, cfg, calib, ccfg)
        print("[slim]", summarize_reports(reports))

    if args.workload == "poisson":
        max_len = args.prompt_len + args.new_tokens + 8
        if args.block_size > 0 and max_len % args.block_size != 0:
            max_len = -(-max_len // args.block_size) * args.block_size
        bucket = args.prefill_bucket if T.supports_ragged_prefill(cfg) else 0
        trace = synthetic_trace(
            args.requests,
            rate=args.rate,
            vocab_size=cfg.vocab_size,
            prompt_len=(max(4, args.prompt_len // 2), args.prompt_len),
            max_new_tokens=(max(1, args.new_tokens // 2), args.new_tokens),
            temperature=args.temperature,
            seed=args.seed,
            shared_prefix_len=args.shared_prefix,
            shared_prefix_groups=args.prefix_groups,
        )
        tracer = SpanTracer() if args.trace_out and args.replicas == 1 else None
        guard = None
        if args.deadline or args.max_queue or args.watchdog or args.degrade:
            guard = GuardConfig(
                max_queue=args.max_queue,
                default_ttl=args.deadline,
                watchdog_s=args.watchdog,
                degradation=args.degrade,
            )
        faults = (
            FaultPlan.parse(args.chaos, seed=args.seed)
            if args.chaos
            else None
        )
        # the one front door: every engine (and every router replica) is
        # built from this config — flat kwargs are the deprecated shim
        config = EngineConfig(
            n_slots=args.slots, max_len=max_len,
            prefill_bucket=bucket, seed=args.seed,
            check_retrace=args.check_retrace,
            paging=PagingConfig(
                block_size=args.block_size,
                n_blocks=args.n_blocks,
                preemption=args.preemption,
                decode_reserve=args.decode_reserve,
                victim_policy=args.victim_policy,
            ),
            prefix_cache=PrefixCacheConfig(
                enabled=args.prefix_cache,
                max_entries=args.prefix_index_cap,
                ttl=args.prefix_index_ttl,
            ),
            speculative=SpecConfig(k=args.speculative),
            parallel=ParallelConfig(tp=args.tp),
            guard=guard,
            observability=ObservabilityConfig(
                window_s=args.obs_window,
                slo_ttft_p95_s=args.slo_ttft,
                slo_tpot_p95_s=args.slo_tpot,
                slo_shed_rate=args.slo_shed_rate,
                flight_recorder=bool(args.postmortem_dir),
                postmortem_dir=args.postmortem_dir,
            ),
        ).validate(cfg)
        router = None
        if args.replicas > 1:
            router = Router(
                params, cfg, config, n_replicas=args.replicas,
                placement=args.placement, trace=bool(args.trace_out),
                faults=faults,
            )
            engine = router.engines[0]  # n_blocks / retrace-guard prints
        else:
            engine = ContinuousEngine(
                params, cfg, config, trace=tracer, faults=faults
            )
        # the live observability plane: HTTP endpoint and/or periodic
        # crash-safe snapshots, both reading the same live source
        live_source = (
            RouterLiveSource(router)
            if router is not None
            else EngineLiveSource(engine)
        )
        server = None
        if args.listen:
            host, port = parse_listen(args.listen)
            server = MetricsServer(live_source, host, port).start()
            print(
                f"[serve/continuous] live metrics on {server.url} "
                "(/metrics /metrics.json /healthz)"
            )
        writer = None
        if args.metrics_json:
            writer = SnapshotWriter(
                args.metrics_json,
                live_source.snapshot_json,
                interval=args.metrics_flush_interval,
            ).start()
        if args.profile_dir:
            jax.profiler.start_trace(args.profile_dir)
        try:
            if router is not None:
                res = router.run(trace, sync_every=args.sync_every)
            else:
                res = engine.run(trace, sync_every=args.sync_every)
        finally:
            if args.profile_dir:
                jax.profiler.stop_trace()
                print(f"[serve/continuous] xprof capture -> {args.profile_dir}")
            if server is not None:
                server.stop()
        m = res.metrics
        cache_kind = (
            f"paged(bs={args.block_size}, blocks={engine.n_blocks}"
            + (", prefix-cache" if args.prefix_cache else "")
            + (", preemption" if args.preemption else "")
            + (f", speculative={args.speculative}" if args.speculative else "")
            + ")"
            if args.block_size > 0
            else "contiguous"
        )
        print(
            f"[serve/continuous] requests={args.requests} slots={args.slots} "
            f"cache={cache_kind} rate={args.rate}/s: "
            f"{m['total_tokens']:.0f} tokens in "
            f"{m['duration_s']:.2f}s ({m['tokens_per_s']:.1f} tok/s)"
        )
        if router is not None or args.tp > 1:
            per_rep = ", ".join(
                f"replica{i}={m.get(f'replica{i}_tokens_per_s', 0.0):.1f}"
                for i in range(args.replicas)
            )
            print(
                f"[serve/continuous] topology: replicas={args.replicas} "
                f"(placement {args.placement}) x tp={args.tp}"
                + (f" | tok/s {per_rep}" if router is not None else "")
                + (
                    f" | shed {m['router_shed']:.0f}"
                    if router is not None
                    else ""
                )
            )
        print(
            f"[serve/continuous] ttft mean {m['mean_ttft_s']:.3f}s "
            f"p95 {m['p95_ttft_s']:.3f}s | latency mean "
            f"{m['mean_latency_s']:.3f}s | occupancy {m['mean_occupancy']:.2f}"
        )
        if args.prefix_cache:
            print(
                "[serve/continuous] prefix cache: hit rate "
                f"{m['prefix_cache_hit_rate']:.2f} "
                f"({m['cached_prompt_tokens']:.0f} cached prompt tokens, "
                f"{m['prefix_hits']:.0f}/{args.requests} requests hit, "
                f"peak {m['peak_blocks_in_use']:.0f} blocks in use)"
            )
        if args.preemption:
            print(
                "[serve/continuous] preemption: "
                f"preemptions={m['preemptions']:.0f} "
                f"({m['preempted_requests']:.0f} requests evicted, "
                f"policy {args.victim_policy}, "
                f"reserve {args.decode_reserve} blocks, "
                f"peak {m['peak_blocks_in_use']:.0f}/"
                f"{engine.n_blocks - RESERVED_BLOCKS} blocks in use)"
            )
        if args.speculative:
            print(
                "[serve/continuous] speculative: "
                f"accepted_drafts={m['draft_accepted']:.0f}/"
                f"{m['draft_proposed']:.0f} proposed "
                f"(acceptance {m['draft_acceptance_rate']:.2f}, K="
                f"{args.speculative})"
            )
        if guard is not None:
            print(
                "[serve/continuous] robustness: "
                f"shed={m['shed_requests']:.0f} "
                f"expired={m['expired_requests']:.0f} "
                f"failed={m['failed_requests']:.0f} "
                f"quarantined={m['quarantined_slots']:.0f} "
                f"watchdog_trips={m['watchdog_trips']:.0f} "
                f"degraded_rounds={m['degraded_rounds']:.0f} "
                f"(peak level {m['peak_degradation_level']:.0f})"
            )
        if faults is not None:
            fired = ", ".join(
                f"{k.removeprefix('fault_')}={v:.0f}"
                for k, v in sorted(m.items())
                if k.startswith("fault_")
            )
            print(f"[serve/continuous] chaos: fired {fired}")
        if args.check_retrace:
            counts = ", ".join(
                f"{name}={n}"
                for name, n in engine.retrace_guard.compiles().items()
            )
            print(
                f"[serve/continuous] retrace guard: compiles {counts} | "
                f"retraces {m['jit_retraces']:.0f}"
            )
        if tracer is not None:
            tracer.export(args.trace_out)
            print(
                f"[serve/continuous] trace -> {args.trace_out} "
                f"({len(tracer)} events, {tracer.dropped} dropped)"
            )
        elif router is not None and args.trace_out:
            n = router.export_trace(args.trace_out)
            print(
                f"[serve/continuous] trace -> {args.trace_out} "
                f"({n} events over {args.replicas} replica lanes)"
            )
        if args.metrics_json:
            # the config rides along under its own key, so every recorded
            # run carries its provenance; metric keys stay top-level. The
            # final dump replaces the writer's periodic live snapshots —
            # atomically, like every flush before it.
            dump = dict(m)
            dump["config"] = config.to_dict()
            if writer is not None:
                writer.stop(final_payload=dump)
            else:
                atomic_write_json(args.metrics_json, dump)
            print(f"[serve/continuous] metrics -> {args.metrics_json}")
        first = res.requests[0]
        if first.output is not None:
            print("[serve/continuous] first request:", first.output[:16])
        else:
            # chaos/deadlines can leave request 0 in a non-FINISHED
            # terminal state with no trusted output
            print(
                f"[serve/continuous] first request: {first.state.value}"
                f" ({first.error})"
            )
        return

    engine = ServeEngine(
        params, cfg, max_len=args.prompt_len + args.new_tokens + 8
    )
    batch = next(synthetic_batches(data_cfg))
    batch.pop("labels", None)
    if args.profile_dir:
        jax.profiler.start_trace(args.profile_dir)
    try:
        res = engine.generate(
            batch, max_new_tokens=args.new_tokens,
            temperature=args.temperature,
        )
    finally:
        if args.profile_dir:
            jax.profiler.stop_trace()
            print(f"[serve] xprof capture -> {args.profile_dir}")
    print(
        f"[serve] batch={args.batch} prompt={args.prompt_len} "
        f"new={res.steps}: prefill {res.prefill_s:.2f}s, "
        f"decode {res.decode_s:.2f}s ({res.tokens_per_s:.1f} tok/s)"
    )
    print("[serve] first slot:", res.tokens[0][:16])


if __name__ == "__main__":
    main()
