"""Serving launcher: batched generation from a (compressed) model.

    PYTHONPATH=src python -m repro.launch.serve --arch slim-tiny \
        --batch 8 --prompt-len 64 --new-tokens 32 --compress

Compresses the model one-shot with SLiM (optional), then runs the batched
decode engine and reports prefill latency + decode tokens/s. On this CPU
container the numbers are functional smoke only; the TPU roofline story is
in benchmarks/bench_speedup.py and EXPERIMENTS §Roofline.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.pipeline import CompressionConfig
from repro.data import SyntheticLMConfig, calibration_batch, synthetic_batches
from repro.models import transformer as T
from repro.models.compress import compress_model, summarize_reports
from repro.serving import ServeEngine


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="slim-tiny")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--new-tokens", type=int, default=32)
    p.add_argument("--temperature", type=float, default=0.0)
    p.add_argument("--compress", action="store_true")
    p.add_argument("--rank", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    args = p.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    params = T.init_params(cfg, jax.random.PRNGKey(args.seed))
    data_cfg = SyntheticLMConfig(
        vocab_size=cfg.vocab_size,
        seq_len=args.prompt_len,
        global_batch=args.batch,
        seed=args.seed,
        d_model=cfg.d_model,
        vision_tokens=cfg.vision_tokens,
        input_mode=cfg.input_mode,
    )

    if args.compress:
        calib = calibration_batch(data_cfg, n_samples=8)
        ccfg = CompressionConfig(
            quantizer="slim", pattern="2:4", pruner="wanda", adapter="slim",
            rank=args.rank, quantize_adapters=True,
        )
        params, reports = compress_model(params, cfg, calib, ccfg)
        print("[slim]", summarize_reports(reports))

    engine = ServeEngine(
        params, cfg, max_len=args.prompt_len + args.new_tokens + 8
    )
    batch = next(synthetic_batches(data_cfg))
    batch.pop("labels", None)
    res = engine.generate(
        batch, max_new_tokens=args.new_tokens, temperature=args.temperature
    )
    print(
        f"[serve] batch={args.batch} prompt={args.prompt_len} "
        f"new={res.steps}: prefill {res.prefill_s:.2f}s, "
        f"decode {res.decode_s:.2f}s ({res.tokens_per_s:.1f} tok/s)"
    )
    print("[serve] first slot:", res.tokens[0][:16])


if __name__ == "__main__":
    main()
