import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={os.environ['DRYRUN_DEVICES']}"
    )

"""Perf-iteration driver (EXPERIMENTS §Perf): run one (arch x shape x mesh)
cell through a sequence of named optimization steps and print the roofline
terms + per-device memory before/after each.

    PYTHONPATH=src python -m repro.launch.perf --pair decode
    PYTHONPATH=src python -m repro.launch.perf --pair prefill --mesh single
    PYTHONPATH=src python -m repro.launch.perf --pair train

Each registered iteration is a hypothesis (see the inline notes + the
narrative log in EXPERIMENTS.md §Perf).
"""
import argparse
import json
import time
from typing import Any, Dict, Optional, Tuple

from repro.launch.dryrun import run_cell

# (step-name, hypothesis, run_cell kwargs)
Step = Tuple[str, str, Dict[str, Any]]

PAIRS: Dict[str, Dict[str, Any]] = {
    # the paper-representative pair: compressed decode serving at scale.
    # baseline is collective-bound (~83%): FSDP-sharded weights are
    # all-gathered every layer on the decode hot path.
    "decode": {
        "arch": "mistral-large-123b",
        "shape": "decode_32k",
        "steps": [
            ("baseline", "paper-faithful deployment on the training topology: "
             "FSDP+TP weights, bf16 adapters, bf16 KV", {}),
            ("serve-topology",
             "decode streams all weights each step; FSDP all-gathers dominate "
             "wire bytes -> replicate weights over dp, keep TP only [beyond]",
             {"serving_topology": True}),
            ("packed-adapters",
             "bf16 adapters ~= int4 base bytes: int4-pack them (4x fewer bytes)",
             {"serving_topology": True, "packed_adapters": True}),
            ("kv-int8",
             "KV cache dominates remaining decode memory: int8 KV halves it [beyond]",
             {"serving_topology": True, "packed_adapters": True, "kv_quant": True}),
            ("gqa-expand",
             "kv=8 heads cannot shard 16-way: score compute replicates per "
             "device; expand KV to 96 heads -> shardable [beyond]",
             {"serving_topology": True, "packed_adapters": True,
              "kv_quant": True, "gqa_expand": True}),
        ],
    },
    # the memory-bound pair: long-context prefill that overflowed HBM
    "prefill": {
        "arch": "mistral-large-123b",
        "shape": "prefill_32k",
        "steps": [
            ("baseline", "f32 softmax probs + f32 PV accumulation", {}),
            ("probs-bf16",
             "probs [B,ch,H,32k] f32 is the largest prefill transient: bf16 halves it",
             {"probs_low_precision": True}),
            ("probs-bf16+kv-int8",
             "the produced cache is the other big resident: int8 KV halves it [beyond]",
             {"probs_low_precision": True, "kv_quant": True}),
            ("gqa-expand",
             "shard the 16x-replicated score compute via KV expansion [beyond]",
             {"probs_low_precision": True, "kv_quant": True, "gqa_expand": True}),
        ],
    },
    # the collective/compute-bound pair: big-model training
    "train": {
        "arch": "mistral-large-123b",
        "shape": "train_4k",
        "steps": [
            ("flat-remat", "single-level remat baseline: n_periods saved residuals", {"scan_groups": 1}),
            ("sqrt-remat",
             "two-level remat: n_groups + n_periods/n_groups residuals (~9x fewer)",
             {"scan_groups": None}),  # auto -> sqrt divisor
            ("micro-x2",
             "fewer, larger microbatches: halves per-step collective count, "
             "2x per-microbatch activation memory",
             {"scan_groups": None, "n_micro": 8}),
        ],
    },
    # MoE decode (EP-vs-TP exploration happens via sharding rules)
    "moe": {
        "arch": "mixtral-8x22b",
        "shape": "decode_32k",
        "steps": [
            ("baseline", "TP experts, bf16 adapters/KV", {}),
            ("packed+kv8",
             "same weight-stream cuts as dense decode",
             {"packed_adapters": True, "kv_quant": True}),
        ],
    },
}


def run_pair(pair: str, mesh: str = "single", out: Optional[str] = None):  # noqa: C901
    spec = PAIRS[pair]
    rows = []
    print(f"=== §Perf pair '{pair}': {spec['arch']} x {spec['shape']} x {mesh} ===")
    for name, hypothesis, kw in spec["steps"]:
        t0 = time.time()
        r = run_cell(spec["arch"], spec["shape"], mesh, verbose=False, **kw)
        dt = time.time() - t0
        row = {
            "step": name,
            "hypothesis": hypothesis,
            "per_device_gib": round(r["per_device_bytes"] / 2 ** 30, 3),
            "fits": r["fits_hbm"],
            **{
                k: r["roofline"][k]
                for k in ("t_compute_s", "t_memory_s", "t_collective_s", "bottleneck", "useful_ratio")
            },
            "wall_s": round(dt, 1),
        }
        rows.append(row)
        print(json.dumps(row))
    # deltas
    base = rows[0]
    for r in rows[1:]:
        print(
            f"Δ {r['step']}: mem {r['per_device_gib']/max(base['per_device_gib'],1e-9):.2f}x, "
            f"t_mem {r['t_memory_s']/max(base['t_memory_s'],1e-12):.2f}x, "
            f"t_coll {r['t_collective_s']/max(base['t_collective_s'],1e-12):.2f}x"
        )
    if out:
        with open(out, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--pair", required=True, choices=list(PAIRS))
    p.add_argument("--mesh", default="single", choices=["single", "multi"])
    p.add_argument("--out", default=None)
    args = p.parse_args()
    run_pair(args.pair, args.mesh, args.out)


if __name__ == "__main__":
    main()
