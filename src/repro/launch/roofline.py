"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch x shape x mesh), in seconds (EXPERIMENTS §Roofline):

    compute    = HLO_FLOPs      / (chips * PEAK_FLOPS_BF16)
    memory     = HLO_bytes      / (chips * HBM_BW)
    collective = wire_bytes     / (chips * ICI_BW)

``cost_analysis()`` supplies FLOPs and bytes. Collective bytes are NOT in
cost_analysis: we parse the (post-SPMD) compiled HLO text and sum per-op wire
traffic with the standard algorithm models —

    all-reduce          2 x size      (ring: reduce-scatter + all-gather)
    all-gather          output size
    reduce-scatter      input-per-shard x (n-1)/n ~ output size x (n-1)
    all-to-all          size
    collective-permute  size

The per-chip second is wire_bytes / chips / ICI_BW — a deliberately simple
uniform-link model; relative movements (the thing §Perf optimizes) are
faithful even where absolute ICI seconds are approximate.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.launch import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# e.g.:  %all-reduce.1 = f32[128,1024]{1,0} all-reduce(%x), replica_groups=...
#        ROOT %t = (bf16[8]{0}, f32[4,4]{1,0}) all-gather(...)
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_by_kind: Dict[str, float]  # wire-model bytes

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    byts: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind, suffix = m.group(1), m.group(2), m.group(3)
        if suffix == "-done":
            continue  # async pair: count the -start only
        size = _shape_bytes(type_str)
        counts[kind] += 1
        if kind == "all-reduce":
            wire = 2.0 * size  # ring: reduce-scatter + all-gather passes
        else:
            wire = 1.0 * size  # output (AG) / input-shard (RS) / moved (A2A, CP)
        byts[kind] += wire
    return CollectiveStats(counts=counts, bytes_by_kind=byts)


@dataclasses.dataclass
class Roofline:
    """Per-device roofline terms.

    ``flops``/``hbm_bytes``/``wire_bytes`` are PER-DEVICE totals for one step
    (XLA's SPMD-module view — verified: cost_analysis divides by the mesh).
    ``model_flops`` is the GLOBAL analytic 6·N·D (divide by chips to compare).
    """

    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    wire_bytes: float  # per-device collective wire bytes
    chips: int
    collectives: CollectiveStats
    model_flops: Optional[float] = None  # global analytic

    @property
    def t_compute(self) -> float:
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.wire_bytes / hw.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if not self.model_flops or not self.flops:
            return None
        return self.model_flops / (self.flops * self.chips)

    def row(self) -> Dict[str, float]:
        return {
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes": self.wire_bytes,
            "useful_ratio": self.useful_flops_ratio,
        }


def roofline_from_compiled(
    compiled, chips: int, model_flops: Optional[float] = None
) -> Roofline:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    stats = parse_collectives(compiled.as_text())
    return Roofline(
        flops=flops,
        hbm_bytes=byts,
        wire_bytes=stats.total_bytes,
        chips=chips,
        collectives=stats,
        model_flops=model_flops,
    )


def analytic_model_flops(cfg, cell) -> float:
    """6*N*D for training, 2*N*D(*tokens) for inference (MoE: active params)."""
    n_active = active_param_count(cfg)
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    tokens = cell.global_batch * 1
    return 2.0 * n_active * tokens


def active_param_count(cfg) -> int:
    """Like param_count but with only top_k of n_experts active per token."""
    total = cfg.param_count()
    if not cfg.n_experts:
        return total
    # subtract inactive expert params
    d, f = cfg.d_model, cfg.moe_ff
    moe_layers = sum(1 for s in cfg.period for _ in [s] if s.moe) * cfg.n_periods
    expert_params = 3 * d * f
    inactive = moe_layers * (cfg.n_experts - cfg.top_k) * expert_params
    return total - inactive
