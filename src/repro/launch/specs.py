"""Abstract (ShapeDtypeStruct) inputs for lowering — zero allocation.

``input_specs(cfg, cell)`` returns stand-ins for every model input of a
(architecture x shape) cell; ``abstract_params`` / ``abstract_slim_params``
build the parameter trees; everything carries a NamedSharding so
``jax.jit(...).lower(**specs)`` fixes the distribution without touching
device memory. This is the pattern the multi-pod dry-run and the roofline
benchmarks share.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.core.compressed import SlimLinear
from repro.core.pipeline import CompressionConfig
from repro.models import sharding as shard_rules
from repro.models import transformer as T
from repro.models.config import ModelConfig, ShapeCell

Pytree = Any


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _with_shardings(tree: Pytree, specs: Pytree, mesh: Mesh) -> Pytree:
    def attach(leaf, spec):
        if leaf is None:
            return None
        return _sds(leaf.shape, leaf.dtype, NamedSharding(mesh, spec))

    return jax.tree.map(attach, tree, specs, is_leaf=lambda x: x is None)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def abstract_params(cfg: ModelConfig, mesh: Mesh) -> Pytree:
    shapes = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.PRNGKey(0))
    specs = shard_rules.param_specs(shapes, cfg, mesh)
    return _with_shardings(shapes, specs, mesh)


def _slimify(path_names, leaf, cfg: ModelConfig, ccfg: CompressionConfig):
    """Dense weight SDS [.., K, N] -> abstract SlimLinear (packed layout)."""
    *lead, k, n = leaf.shape
    lead = tuple(lead)
    rank = ccfg.resolve_rank(k)
    sparse = ccfg.pattern == "2:4"
    pv_shape = lead + ((k // 4, n) if sparse else (k // 2, n))
    pi_shape = lead + (k // 8, n) if sparse else None
    if ccfg.quantizer in ("group_absmax", "optq") and ccfg.group_size:
        scale_shape = lead + (k // ccfg.group_size, 1, n)
        gs = ccfg.group_size
    else:
        scale_shape = lead
        gs = 0
    adapters = ccfg.adapter != "none"
    if adapters and ccfg.pack_adapters:
        from repro.core.quantizers import fit_group_size

        gl = fit_group_size(k, ccfg.adapter_group)
        gr = fit_group_size(rank, ccfg.adapter_group)
        lora_l = _sds(lead + (k // 2, rank), jnp.uint8)
        lora_r = _sds(lead + (rank // 2, n), jnp.uint8)
        lsl = _sds(lead + (k // gl, 1, rank), jnp.float32)
        lsr = _sds(lead + (rank // gr, 1, n), jnp.float32)
    elif adapters:
        lora_l = _sds(lead + (k, rank), jnp.bfloat16)
        lora_r = _sds(lead + (rank, n), jnp.bfloat16)
        lsl = lsr = None
    else:
        lora_l = lora_r = lsl = lsr = None
    return SlimLinear(
        packed_vals=_sds(pv_shape, jnp.uint8),
        packed_idx=None if pi_shape is None else _sds(pi_shape, jnp.uint8),
        scale=_sds(scale_shape, jnp.float32),
        inv_act_scale=(
            _sds(lead + (k,), jnp.float32) if ccfg.quantizer == "slim_o" else None
        ),
        lora_l=lora_l,
        lora_r=lora_r,
        lora_scale_l=lsl,
        lora_scale_r=lsr,
        d_in=k,
        d_out=n,
        bits=ccfg.bits,
        group_size=gs,
        fmt="sparse24" if sparse else "dense_int4",
        adapter_bits=ccfg.adapter_bits
        if (ccfg.quantize_adapters or ccfg.pack_adapters)
        else 0,
        adapter_group=ccfg.adapter_group,
    )


_COMPRESS_NAMES = {
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "in_proj", "out_proj",
}


def abstract_slim_params(
    cfg: ModelConfig,
    mesh: Mesh,
    ccfg: Optional[CompressionConfig] = None,
    serving_topology: bool = False,
) -> Pytree:
    """Abstract *compressed* parameter tree (the serving deployment format).

    serving_topology: replicate weights over the dp axis (TP-only serving —
    no per-layer FSDP all-gathers on the decode hot path)."""
    ccfg = ccfg or CompressionConfig(rank=None, rank_ratio=0.1)
    shapes = jax.eval_shape(lambda k: T.init_params(cfg, k), jax.random.PRNGKey(0))

    def walk(path, leaf):
        names = shard_rules._path_names(path)
        if (
            names[-1] in _COMPRESS_NAMES
            and names[0] == "blocks"
            and leaf.ndim >= 2
            and leaf.shape[-2] % 8 == 0
            and leaf.shape[-1] % 2 == 0
        ):
            return _slimify(names, leaf, cfg, ccfg)
        return leaf

    slim = jax.tree_util.tree_map_with_path(walk, shapes)
    specs = shard_rules.param_specs(slim, cfg, mesh, serving=serving_topology)
    return _with_shardings(slim, specs, mesh)


# ---------------------------------------------------------------------------
# batches / caches
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> Dict[str, Any]:
    """Model inputs for one (arch x shape) cell, shardings attached."""
    dp = shard_rules.dp_axes(mesh)
    b = cell.global_batch
    s = cell.seq_len
    dt = jnp.dtype(cfg.dtype)
    batch: Dict[str, Any] = {}
    # batch=1 long-context cells cannot shard the batch dim
    tok_sh = NamedSharding(mesh, shard_rules._fit((dp, None), (b, s), mesh))
    emb_sh = NamedSharding(
        mesh, shard_rules._fit((dp, None, None), (b, s, cfg.d_model), mesh)
    )
    if cell.kind in ("train", "prefill"):
        if cfg.input_mode == "embeddings":
            batch["embeds"] = _sds((b, s, cfg.d_model), dt, emb_sh)
        else:
            batch["tokens"] = _sds((b, s), jnp.int32, tok_sh)
        if cell.kind == "train":
            batch["labels"] = _sds((b, s), jnp.int32, tok_sh)
        if cfg.vision_tokens:
            batch["vision_embeds"] = _sds((b, cfg.vision_tokens, cfg.d_model), dt, emb_sh)
    else:  # decode: one new token against a seq_len cache
        if cfg.input_mode == "embeddings":
            batch["embeds"] = _sds((b, 1, cfg.d_model), dt, emb_sh)
        else:
            batch["tokens"] = _sds((b, 1), jnp.int32, tok_sh)
    return batch


def cache_specs_abstract(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh) -> Pytree:
    cache_shapes = jax.eval_shape(
        lambda: T.init_cache(cfg, cell.global_batch, cell.seq_len)
    )
    specs = shard_rules.cache_specs(cache_shapes, cfg, mesh, cell.global_batch)
    return _with_shardings(cache_shapes, specs, mesh)
