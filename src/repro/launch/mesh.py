"""Device mesh construction (production, serving, tests).

Every builder here is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dryrun sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then asks for the mesh.

Default mesh shapes:
  single-pod : (16, 16)    axes (data, model)           = 256 chips
  multi-pod  : (2, 16, 16) axes (pod, data, model)      = 512 chips, 2 pods
  serving    : (1, tp)     axes (data, model)           = one TP replica

Hosts with too few devices raise ``MeshUnavailable`` (a ``RuntimeError``
subclass) so multi-device tests can skip cleanly instead of erroring.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax

# default axis names by mesh rank
_AXES_BY_RANK = {2: ("data", "model"), 3: ("pod", "data", "model")}


class MeshUnavailable(RuntimeError):
    """The host exposes fewer devices than the requested mesh shape.

    Subclasses ``RuntimeError`` so pre-existing callers that caught the
    old error keep working; tests catch this type and ``pytest.skip``.
    """


def _build(shape: Tuple[int, ...], axes: Sequence[str], hint: str):
    if len(axes) != len(shape):
        raise ValueError(f"axes {tuple(axes)} do not match shape {shape}")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise MeshUnavailable(
            f"need {n} devices for mesh {shape}, found {len(devices)} — "
            + hint
        )
    import numpy as np

    return jax.sharding.Mesh(np.array(devices).reshape(shape), tuple(axes))


def make_production_mesh(
    shape: Optional[Tuple[int, ...]] = None,
    axes: Optional[Sequence[str]] = None,
    *,
    multi_pod: bool = False,
):
    """Build the training/launch mesh.

    ``shape`` defaults to the production topology — ``(16, 16)``, or
    ``(2, 16, 16)`` with ``multi_pod=True`` — but any shape can be
    requested. ``axes`` default by rank: 2 -> (data, model),
    3 -> (pod, data, model).
    """
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    elif multi_pod:
        raise ValueError("pass either shape= or multi_pod=True, not both")
    if axes is None:
        axes = _AXES_BY_RANK.get(len(shape))
        if axes is None:
            raise ValueError(
                f"no default axis names for a rank-{len(shape)} mesh; "
                "pass axes="
            )
    return _build(
        tuple(shape), axes,
        "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
        "importing jax (launch/dryrun.py does this)",
    )


def make_serving_mesh(tp: int):
    """The one-replica serving mesh: ``(1, tp)`` over (data, model).

    The data axis is size 1 by construction — data parallelism in serving
    is the Router's job (serving/router.py spreads requests over whole
    replicas); inside a replica only the model axis is populated, so
    ``models/sharding.py`` specs shard weights/KV without ever crossing
    replica boundaries.
    """
    if tp < 1:
        raise ValueError("tp must be >= 1")
    return _build(
        (1, tp), ("data", "model"),
        f"set XLA_FLAGS=--xla_force_host_platform_device_count={tp} before "
        "importing jax, or lower EngineConfig.parallel.tp",
    )


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests; raises ``MeshUnavailable`` (skip-able)
    when the host has too few devices."""
    return _build(
        tuple(shape), axes,
        "set XLA_FLAGS=--xla_force_host_platform_device_count=8 before "
        "importing jax, or pytest.skip on MeshUnavailable",
    )
