"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state; the dryrun sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and then asks for the mesh.

Mesh shapes:
  single-pod : (16, 16)    axes (data, model)           = 256 chips
  multi-pod  : (2, 16, 16) axes (pod, data, model)      = 512 chips, 2 pods
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()[:n]
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for the production mesh, found {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 before "
            "importing jax (launch/dryrun.py does this)"
        )
    import numpy as np

    dev_array = np.array(devices).reshape(shape)
    return jax.sharding.Mesh(dev_array, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires enough host devices)."""
    import numpy as np

    n = int(np.prod(shape))
    return jax.sharding.Mesh(np.array(jax.devices()[:n]).reshape(shape), axes)
