"""Model configuration for the architecture zoo.

A model is a stack of ``n_layers`` decoder layers described by a repeating
``period``: a tuple of ``LayerSpec`` (kind in {attn, ssm, cross_attn}, plus
an MoE flag). Homogeneous archs have period length 1; Jamba's 1:7
attn:mamba interleave with MoE every 2nd layer has period length 8;
Llama-3.2-Vision's cross-attention insertion has period length 5. The
forward pass scans over ``n_layers // len(period)`` period instances with
stacked parameters, keeping the lowered HLO small at 100-layer scale.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str  # "attn" | "ssm" | "cross_attn"
    moe: bool = False


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # attention features
    qk_norm: bool = False
    sliding_window: int = 0  # 0 = full attention
    rope_theta: float = 10000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0  # 0 -> use d_ff
    capacity_factor: float = 1.25
    moe_group: int = 512  # dispatch group length (tokens)
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 64
    # layer layout
    period: Tuple[LayerSpec, ...] = (LayerSpec("attn"),)
    # cross-attention (VLM)
    vision_tokens: int = 0  # stub frontend sequence length
    # input mode: "tokens" | "embeddings" (audio/frame stub)
    input_mode: str = "tokens"
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # attention chunking for memory (flash-style scan over query blocks)
    q_chunk: int = 512
    # loss vocab chunking (never materialize [B,S,V])
    vocab_chunk: int = 2048
    # unroll the layer scan into straight-line HLO — used by the cost
    # analysis (XLA cost_analysis counts while-bodies once); real runs scan.
    unroll_layers: bool = False
    # two-level (sqrt) remat: scan over `scan_groups` groups of periods, each
    # group rematerialized as a unit -> activation memory drops from
    # O(n_periods) to O(n_groups + n_periods/n_groups) residuals. 0 = flat.
    scan_groups: int = 0
    # --- perf-iteration toggles (EXPERIMENTS §Perf; defaults = baseline) ---
    # cast softmax probabilities to the value dtype for the PV matmul
    # (flash-attention convention): halves the largest prefill live buffer.
    attn_probs_low_precision: bool = False
    # store the KV cache as int8 with per-(position, head) scales: 2x decode
    # cache memory + bandwidth (beyond-paper).
    kv_quant: bool = False
    # expert parallelism: shard the expert dim of MoE weight stacks over the
    # model axis (requires n_experts % model_size == 0, e.g. E=16 on 16-way);
    # dispatch/combine become all-to-alls instead of TP partial-sums.
    moe_expert_parallel: bool = False
    # expand KV heads to the full query-head count before attention: GQA
    # kv=8 cannot shard on a 16-way model axis (XLA replicates the score
    # compute per device); expanded heads shard cleanly. Costs repeated-K
    # bytes, wins per-device FLOPs/sharding at kv < model_parallelism.
    gqa_expand_kv: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"period={len(self.period)}"
        )

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def moe_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def has_attention(self) -> bool:
        return any(s.kind in ("attn", "cross_attn") for s in self.period)

    @property
    def is_subquadratic(self) -> bool:
        """True if long-context decode is feasible: SSM/hybrid or SWA."""
        kinds = {s.kind for s in self.period}
        if kinds == {"ssm"}:
            return True
        if "attn" in kinds and self.sliding_window == 0 and "ssm" not in kinds:
            return False
        return True  # hybrid (bounded attn share) or sliding-window

    def param_count(self) -> int:
        """Analytic parameter count (matmuls + embeddings + norms)."""
        d = self.d_model
        total = 0
        for spec in self.period:
            if spec.kind in ("attn", "cross_attn"):
                total += d * self.d_q + 2 * d * self.d_kv + self.d_q * d
                if self.qk_norm:
                    total += 2 * self.d_head
                if spec.kind == "cross_attn":
                    total += 2  # gates
            elif spec.kind == "ssm":
                proj_out = 2 * self.ssm_inner + 2 * self.ssm_groups * self.ssm_state + self.ssm_heads
                total += d * proj_out
                total += self.ssm_inner * d  # out_proj
                conv_dim = self.ssm_inner + 2 * self.ssm_groups * self.ssm_state
                total += conv_dim * self.ssm_conv
                total += 3 * self.ssm_heads  # A_log, D, dt_bias
                total += self.ssm_inner  # gated norm scale
            if spec.moe:
                total += d * self.n_experts  # router
                total += self.n_experts * 3 * d * self.moe_ff
            else:
                total += 3 * d * self.d_ff
            total += 2 * d  # the two pre-norms (approx; ssm uses one)
        total *= self.n_periods
        total += self.vocab_size * d  # embed
        if not self.tie_embeddings:
            total += d * self.vocab_size  # lm_head
        total += d  # final norm
        return total


# ---------------------------------------------------------------------------
# Input shape cells (assigned shapes; LM-family)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}
