"""Model-level SLiM compression driver.

Walks a model's parameter tree, calibrates per-linear activation statistics
by running the (eager) forward with capture hooks, and replaces each eligible
weight matrix with its compressed ``SlimLinear``. Compression is
**sequential** in the OBS convention: period k is calibrated on activations
produced by the already-compressed periods < k, so each layer compensates the
error its predecessors introduced (same protocol as SparseGPT / Wanda).

Eligible weights: the transformer-block matmuls — attention q/k/v/o, MLP
gate/up/down, MoE expert stacks (per-expert statistics), SSM in/out
projections. Routers, norms, convs, SSM scalars, embeddings and the LM head
stay dense (paper §T: only block matmuls are compressed).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressed import SlimLinear
from repro.core.pipeline import CalibStats, CompressionConfig, CompressionReport, compress_matrix
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.config import ModelConfig

Params = Dict[str, Any]

# weight names eligible for compression, per layer kind
_ELIGIBLE = {"wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "in_proj", "out_proj"}
_MOE_ELIGIBLE = {"w_gate", "w_up", "w_down"}


def _tree_slice(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _stack_slim(items: List[SlimLinear]) -> SlimLinear:
    """Stack per-period (or per-expert) SlimLinears along a new leading dim."""
    leaves = []
    from repro.core.compressed import _SLIM_FIELDS
    for f in _SLIM_FIELDS:
        vals = [getattr(it, f) for it in items]
        if any(v is None for v in vals):
            assert all(v is None for v in vals), f"inconsistent field {f}"
            leaves.append(None)
        else:
            leaves.append(jnp.stack(vals))
    proto = items[0]
    return SlimLinear(*leaves, *proto._aux())


def compress_model(
    params: Params,
    cfg: ModelConfig,
    calib_batch: Params,
    ccfg: CompressionConfig,
    verbose: bool = False,
) -> Tuple[Params, Dict[str, CompressionReport]]:
    """Returns (compressed params, per-matrix reports)."""
    x = T.embed_inputs(params, cfg, calib_batch)
    vision = calib_batch.get("vision_embeds")
    reports: Dict[str, CompressionReport] = {}
    new_periods: List[Params] = []

    for pi in range(cfg.n_periods):
        pp = _tree_slice(params["blocks"], pi)
        # (1) calibrate this period on activations from compressed prefix
        stats: Dict[str, CalibStats] = {}
        with L.capture_scope(stats, with_hessian=ccfg.needs_hessian):
            x_next, _, _ = T._apply_period(cfg, pp, x, None, 0, vision)

        # (2) compress each eligible matrix in this period
        new_pp = jax.tree_util.tree_map(lambda a: a, pp)  # shallow-ish copy
        for li, _spec in enumerate(cfg.period):
            lname = f"layer_{li}"
            lp = dict(new_pp[lname])
            for wname in list(lp.keys()):
                if wname in ("mlp", "moe"):
                    sub = dict(lp[wname])
                    for swname in list(sub.keys()):
                        if wname == "moe" and swname in _MOE_ELIGIBLE:
                            e = sub[swname].shape[0]
                            per_exp = []
                            for ei in range(e):
                                key = f"{lname}/expert_{ei}/{swname}"
                                st = stats.get(key)
                                if st is None:
                                    continue
                                sl, rep = compress_matrix(sub[swname][ei], st, ccfg)
                                reports[f"p{pi}/{key}"] = rep
                                per_exp.append(sl)
                            if len(per_exp) == e:
                                sub[swname] = _stack_slim(per_exp)
                        elif wname == "mlp" and swname in _ELIGIBLE:
                            key = f"{lname}/{swname}"
                            st = stats.get(key)
                            if st is not None:
                                sl, rep = compress_matrix(sub[swname], st, ccfg)
                                reports[f"p{pi}/{key}"] = rep
                                sub[swname] = sl
                    lp[wname] = sub
                elif wname in _ELIGIBLE:
                    key = f"{lname}/{wname}"
                    st = stats.get(key)
                    if st is not None:
                        sl, rep = compress_matrix(lp[wname], st, ccfg)
                        reports[f"p{pi}/{key}"] = rep
                        lp[wname] = sl
            new_pp[lname] = lp
        new_periods.append(new_pp)
        if verbose:
            done = sum(1 for k in reports if k.startswith(f"p{pi}/"))
            print(f"period {pi}: compressed {done} matrices")

        # (3) advance calibration activations through the *compressed* period
        x, _, _ = T._apply_period(cfg, new_pp, x, None, 0, vision)

    # stack periods back for the scan
    def stack_periods(paths: List[Params]) -> Params:
        out = {}
        for k in paths[0]:
            vals = [p[k] for p in paths]
            if isinstance(vals[0], dict):
                out[k] = stack_periods(vals)
            elif isinstance(vals[0], SlimLinear):
                out[k] = _stack_slim(vals)
            else:
                out[k] = jnp.stack(vals)
        return out

    new_params = dict(params)
    new_params["blocks"] = stack_periods(new_periods)
    return new_params, reports


# ---------------------------------------------------------------------------
# PEFT support: trainable-mask over the compressed tree (adapters only)
# ---------------------------------------------------------------------------

def peft_mask(params: Params) -> Params:
    """1.0 for trainable leaves (LoRA factors), 0.0 elsewhere."""

    def mask_path(path, leaf):
        names = [getattr(p, "name", getattr(p, "key", None)) for p in path]
        trainable = any(n in ("lora_l", "lora_r") for n in names)
        return jnp.float32(1.0) if trainable else jnp.float32(0.0)

    return jax.tree_util.tree_map_with_path(mask_path, params)


def summarize_reports(reports: Dict[str, CompressionReport]) -> Dict[str, float]:
    if not reports:
        return {}
    tot_before = sum(r.total_err_before for r in reports.values())
    tot_after = sum(r.total_err_after for r in reports.values())
    sal_before = sum(r.saliency_err_before for r in reports.values())
    sal_after = sum(r.saliency_err_after for r in reports.values())
    return {
        "n_matrices": len(reports),
        "err_before": tot_before,
        "err_after": tot_after,
        "err_reduction": 1.0 - tot_after / max(tot_before, 1e-12),
        "saliency_err_reduction": 1.0 - sal_after / max(sal_before, 1e-12),
    }
