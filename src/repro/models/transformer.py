"""Decoder-only LM assembled from the layer zoo, scanning over layer periods.

Entry points:
  init_params(cfg, key)                 -> dense parameter pytree
  train_loss(params, cfg, batch)        -> (loss, aux)   [chunked xent]
  prefill(params, cfg, batch)           -> (last_logits, cache)
  prefill_slot(params, cfg, cache, ...) -> (last_logits, cache)  [one slot]
  decode_step(params, cfg, cache, ...)  -> (logits, cache)  [per-slot pos]
  init_cache(cfg, batch, max_len)       -> cache pytree

All heavy dims flow through ``layers.linear`` so any weight leaf may be a
dense array or a ``SlimLinear``; the same code path serves dense training,
compressed inference, and adapter-only PEFT. The layer stack is a
``lax.scan`` over ``cfg.n_periods`` with per-period parameter stacks — HLO
size stays O(period), critical at 88-100 layers and for fast multi-pod
compiles. Training applies ``jax.checkpoint`` per period (full remat).
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import sharding as Sh
from repro.models.config import LayerSpec, ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _init_linear(key, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def _init_layer(key, spec: LayerSpec, cfg: ModelConfig) -> Params:
    dt = _dtype(cfg)
    d = cfg.d_model
    keys = jax.random.split(key, 16)
    p: Params = {}
    if spec.kind in ("attn", "cross_attn"):
        p["ln"] = jnp.ones((d,), dt)
        p["wq"] = _init_linear(keys[0], d, cfg.d_q, dt)
        p["wk"] = _init_linear(keys[1], d, cfg.d_kv, dt)
        p["wv"] = _init_linear(keys[2], d, cfg.d_kv, dt)
        p["wo"] = _init_linear(keys[3], cfg.d_q, d, dt)
        if cfg.qk_norm:
            p["q_norm"] = jnp.ones((cfg.d_head,), dt)
            p["k_norm"] = jnp.ones((cfg.d_head,), dt)
        if spec.kind == "cross_attn":
            p["gate_attn"] = jnp.zeros((), jnp.float32)
            p["gate_mlp"] = jnp.zeros((), jnp.float32)
            p["ln_mlp"] = jnp.ones((d,), dt)
            p["w_gate"] = _init_linear(keys[4], d, cfg.d_ff, dt)
            p["w_up"] = _init_linear(keys[5], d, cfg.d_ff, dt)
            p["w_down"] = _init_linear(keys[6], cfg.d_ff, d, dt)
            return p
    elif spec.kind == "ssm":
        d_inner = cfg.ssm_inner
        conv_dim = d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        proj_out = 2 * d_inner + 2 * cfg.ssm_groups * cfg.ssm_state + cfg.ssm_heads
        p["ln"] = jnp.ones((d,), dt)
        p["in_proj"] = _init_linear(keys[0], d, proj_out, dt)
        p["conv_w"] = (
            jax.random.normal(keys[1], (conv_dim, cfg.ssm_conv), jnp.float32) * 0.2
        ).astype(dt)
        p["a_log"] = jnp.log(
            jnp.linspace(1.0, 16.0, cfg.ssm_heads, dtype=jnp.float32)
        )
        p["d_skip"] = jnp.ones((cfg.ssm_heads,), jnp.float32)
        p["dt_bias"] = jnp.zeros((cfg.ssm_heads,), jnp.float32)
        p["gate_norm"] = jnp.ones((d_inner,), dt)
        p["out_proj"] = _init_linear(keys[2], d_inner, d, dt)
    else:
        raise ValueError(spec.kind)

    # feed-forward (dense or MoE); cross_attn returned above with its own FFN
    if spec.moe:
        f = cfg.moe_ff
        p["moe"] = {
            "ln": jnp.ones((d,), dt),
            "router": _init_linear(keys[8], d, cfg.n_experts, jnp.float32),
            "w_gate": jnp.stack(
                [_init_linear(k, d, f, dt) for k in jax.random.split(keys[9], cfg.n_experts)]
            ),
            "w_up": jnp.stack(
                [_init_linear(k, d, f, dt) for k in jax.random.split(keys[10], cfg.n_experts)]
            ),
            "w_down": jnp.stack(
                [_init_linear(k, f, d, dt) for k in jax.random.split(keys[11], cfg.n_experts)]
            ),
        }
    elif spec.kind != "cross_attn" and cfg.d_ff > 0:
        p["mlp"] = {
            "ln": jnp.ones((d,), dt),
            "w_gate": _init_linear(keys[8], d, cfg.d_ff, dt),
            "w_up": _init_linear(keys[9], d, cfg.d_ff, dt),
            "w_down": _init_linear(keys[10], cfg.d_ff, d, dt),
        }
    return p


def init_params(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    k_embed, k_head, k_blocks = jax.random.split(key, 3)
    period_keys = jax.random.split(k_blocks, cfg.n_periods)

    def init_period(k):
        lkeys = jax.random.split(k, len(cfg.period))
        return {
            f"layer_{i}": _init_layer(lkeys[i], spec, cfg)
            for i, spec in enumerate(cfg.period)
        }

    blocks = jax.vmap(init_period)(period_keys)  # stacked leading dim
    params: Params = {
        "embed": (
            jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32)
            * 0.02
        ).astype(dt),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = _init_linear(k_head, cfg.d_model, cfg.vocab_size, dt)
    return params


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _apply_period(
    cfg: ModelConfig,
    period_params: Params,
    x: jnp.ndarray,
    cache: Optional[Params],
    pos0,
    vision: Optional[jnp.ndarray],
    block_table: Optional[jnp.ndarray] = None,
    true_len=None,  # paged offset prefill: real suffix length (pads masked)
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    new_cache: Params = {}
    aux = jnp.zeros((), jnp.float32)
    for i, spec in enumerate(cfg.period):
        p = period_params[f"layer_{i}"]
        c = None if cache is None else cache.get(f"layer_{i}")
        with L.scope(f"layer_{i}"):
            if spec.kind == "attn":
                x, nc = L.attention_layer(
                    p, x, cfg, c, pos0, block_table, true_len
                )
            elif spec.kind == "ssm":
                x, nc = L.ssm_layer(p, x, cfg, c, pos0)
            elif spec.kind == "cross_attn":
                x, nc = L.cross_attention_layer(p, x, cfg, vision, c)
                if nc is not None:
                    new_cache[f"layer_{i}"] = nc
                continue  # cross layer bundles its own FFN
            else:
                raise ValueError(spec.kind)
            if nc is not None:
                new_cache[f"layer_{i}"] = nc
            if spec.moe:
                x, a = L.moe_layer(p["moe"], x, cfg)
                aux = aux + a
            elif "mlp" in p:
                x = L.mlp_layer(p["mlp"], x, cfg)
    return x, (new_cache if cache is not None else None), aux


def forward_hidden(
    params: Params,
    cfg: ModelConfig,
    x: jnp.ndarray,  # [B, S, D] embedded inputs
    cache: Optional[Params] = None,
    pos0=0,
    vision: Optional[jnp.ndarray] = None,
    remat: bool = False,
    block_table: Optional[jnp.ndarray] = None,  # [B, max_blocks] paged decode
    true_len=None,  # paged offset prefill: real suffix length
) -> Tuple[jnp.ndarray, Optional[Params], jnp.ndarray]:
    def body(carry, xs):
        h, aux = carry
        if cache is None:
            pp = xs
            h, _, a = period_fn(pp, h, None)
            return (h, aux + a), None
        pp, c = xs
        h, nc, a = period_fn(pp, h, c)
        return (h, aux + a), nc

    def period_fn(pp, h, c):
        return _apply_period(cfg, pp, h, c, pos0, vision, block_table, true_len)

    if remat:
        period_fn = jax.checkpoint(period_fn)

    if cfg.unroll_layers:
        # straight-line variant for cost analysis (scan bodies are counted
        # once by XLA cost_analysis regardless of trip count)
        h, aux = x, jnp.zeros((), jnp.float32)
        caches = []
        for i in range(cfg.n_periods):
            pp = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
            c = (
                None
                if cache is None
                else jax.tree.map(lambda a, i=i: a[i], cache)
            )
            h, nc, a = period_fn(pp, h, c)
            aux = aux + a
            if nc is not None:
                caches.append(nc)
        new_cache = (
            jax.tree.map(lambda *xs: jnp.stack(xs), *caches) if caches else None
        )
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return h, new_cache, aux

    if cfg.scan_groups > 1 and cache is None and cfg.n_periods % cfg.scan_groups == 0:
        # two-level (sqrt) remat: outer scan over groups (checkpointed as a
        # unit), inner scan over periods (checkpointed per period). Peak
        # residuals: n_groups + n_periods/n_groups period inputs instead of
        # n_periods (see EXPERIMENTS §Perf, memory-term iteration).
        g = cfg.scan_groups
        inner = cfg.n_periods // g
        blocks_r = jax.tree.map(
            lambda a: a.reshape(g, inner, *a.shape[1:]), params["blocks"]
        )

        def group_fn(carry, gp):
            def inner_body(c, pp):
                h, aux = c
                h, _, a = period_fn(pp, h, None)
                return (h, aux + a), None

            return jax.lax.scan(inner_body, carry, gp)[0]

        if remat:
            group_fn = jax.checkpoint(group_fn)

        def outer_body(carry, gp):
            return group_fn(carry, gp), None

        (h, aux), _ = jax.lax.scan(
            outer_body, (x, jnp.zeros((), jnp.float32)), blocks_r
        )
        h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
        return h, None, aux

    xs = params["blocks"] if cache is None else (params["blocks"], cache)
    (h, aux), new_cache = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return h, new_cache, aux


def embed_inputs(params: Params, cfg: ModelConfig, batch: Params) -> jnp.ndarray:
    if cfg.input_mode == "embeddings":
        return batch["embeds"].astype(_dtype(cfg))
    return jnp.take(params["embed"], batch["tokens"], axis=0).astype(_dtype(cfg))


def _head_weights(params: Params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["embed"].T  # dense only; none of the zoo ties
    return params["lm_head"]


def chunked_xent(
    h: jnp.ndarray,  # [B, S, D]
    head, labels: jnp.ndarray, chunk: int
) -> jnp.ndarray:
    """Cross-entropy without ever materializing [B, S, V]."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    hc = jnp.moveaxis(h.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def body(tot, xs):
        hb, lb = xs
        logits = L.linear(head, hb).astype(jnp.float32)  # [B, chunk, V]
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(logz - gold), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return tot / (b * s)


def train_loss(
    params: Params, cfg: ModelConfig, batch: Params, aux_weight: float = 0.01
) -> jnp.ndarray:
    x = embed_inputs(params, cfg, batch)
    vision = batch.get("vision_embeds")
    h, _, aux = forward_hidden(params, cfg, x, None, 0, vision, remat=True)
    loss = chunked_xent(h, _head_weights(params, cfg), batch["labels"], cfg.vocab_chunk)
    return loss + aux_weight * aux


# ---------------------------------------------------------------------------
# Serving: cache init, prefill, decode
# ---------------------------------------------------------------------------

def init_cache(
    cfg: ModelConfig,
    b: int,
    max_len: int,
    block_size: int = 0,
    n_blocks: int = 0,
) -> Params:
    """Decode cache pytree. ``block_size == 0`` (default) reserves one
    contiguous ``max_len`` lane per batch row. ``block_size > 0`` builds a
    *paged* cache instead: attention leaves become a shared pool of
    ``n_blocks`` blocks addressed through per-slot block tables (decode
    passes ``block_table``), while SSM/cross-attn leaves — O(1) per slot —
    stay per-row."""
    dt = _dtype(cfg)
    if block_size > 0:
        assert n_blocks > 0, "paged cache needs an explicit pool size"
        assert supports_paged_cache(cfg), (
            f"{cfg.name}: paged KV cache needs sliding_window == 0 (the "
            "ring layout aliases block offsets)"
        )
    c: Params = {}
    for i, spec in enumerate(cfg.period):
        if spec.kind == "attn":
            if block_size > 0:
                c[f"layer_{i}"] = L.init_paged_attn_cache(
                    cfg, n_blocks, block_size, dt
                )
            else:
                c[f"layer_{i}"] = L.init_attn_cache(cfg, b, max_len, dt)
        elif spec.kind == "ssm":
            c[f"layer_{i}"] = L.init_ssm_cache(cfg, b, dt)
        elif spec.kind == "cross_attn":
            c[f"layer_{i}"] = L.init_cross_cache(cfg, b, dt)
    # stack one per period for the layer scan
    return jax.tree.map(
        lambda a: jnp.tile(a[None], (cfg.n_periods,) + (1,) * a.ndim), c
    )


def prefill(
    params: Params, cfg: ModelConfig, batch: Params, max_len: int
) -> Tuple[jnp.ndarray, Params]:
    """Process the prompt, fill the cache, return logits of the last token."""
    x = embed_inputs(params, cfg, batch)
    b = x.shape[0]
    cache = init_cache(cfg, b, max_len)
    vision = batch.get("vision_embeds")
    # named_scope: an xprof/TensorBoard capture attributes this op tree to
    # the serving phase it implements (see docs/observability.md)
    with jax.named_scope("serve/prefill"):
        h, cache, _ = forward_hidden(params, cfg, x, cache, 0, vision)
    logits = L.linear(_head_weights(params, cfg), h[:, -1:, :]).astype(jnp.float32)
    return logits[:, 0], cache


def decode_step(
    params: Params,
    cfg: ModelConfig,
    cache: Params,
    token_or_embed: jnp.ndarray,  # tokens [B, 1] int32 or embeds [B, 1, D]
    pos: jnp.ndarray,  # int32 [B] per-slot positions (scalar broadcasts)
    block_table: Optional[jnp.ndarray] = None,  # [B, max_blocks] paged cache
    skip_adapters: bool = False,  # backbone-only draft forward (speculative)
) -> Tuple[jnp.ndarray, Params]:
    """One decode step. ``pos`` gives the absolute position of each row's
    token; a vector lets continuous-batching slots sit at different depths
    (ragged decode), a scalar keeps the legacy lockstep behaviour. With a
    paged cache, ``block_table`` names each row's pool blocks.

    ``skip_adapters=True`` is the self-speculative *draft* step: every
    compressed linear computes only its quantized-sparse backbone (the
    LoRA correction is skipped), so the step is a strictly cheaper forward
    of the same weights. Its K/V writes are provisional — the speculative
    engine's verify pass re-writes the same positions with full-model
    values before any of them can be committed."""
    # tensor-parallel serving: pin the cache to its mesh layout before the
    # gather/scatter ops below (no-op without an ambient serving mesh)
    cache = Sh.shard_cache(cache, cfg, token_or_embed.shape[0])
    if cfg.input_mode == "embeddings":
        x = token_or_embed.astype(_dtype(cfg))
    else:
        x = jnp.take(params["embed"], token_or_embed, axis=0).astype(_dtype(cfg))
    scope = "serve/draft_step" if skip_adapters else "serve/decode_step"
    with (
        jax.named_scope(scope),
        L.skip_adapters() if skip_adapters else contextlib.nullcontext(),
    ):
        h, cache, _ = forward_hidden(
            params, cfg, x, cache, pos, None, block_table=block_table
        )
    logits = L.linear(_head_weights(params, cfg), h[:, -1:, :]).astype(jnp.float32)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Slot-targeted prefill (continuous batching)
# ---------------------------------------------------------------------------

def supports_ragged_prefill(cfg: ModelConfig) -> bool:
    """Whether a right-padded (bucketed) prefill is *exact* for this arch.

    Attention masks pad keys out of every real query's window, but an SSM
    recurrence integrates pad steps (``dt_bias`` keeps dt > 0 on zero input)
    and MoE capacity lets pad tokens displace real ones from expert queues —
    those archs must prefill at exact prompt length. Sliding-window ring
    caches are excluded too: a padded prompt longer than the window evicts
    in-window *real* keys during the ring roll, which masking can't undo.
    """
    return cfg.sliding_window == 0 and all(
        sp.kind == "attn" and not sp.moe for sp in cfg.period
    )


def supports_paged_cache(cfg: ModelConfig) -> bool:
    """Whether the block-pool cache layout is exact for this arch. The one
    exclusion is sliding-window attention: its ring cache overwrites by
    ``pos % window``, which aliases block offsets across logical blocks.
    SSM and cross-attn layers are fine — their per-slot state is O(1) and
    stays in per-row lanes alongside the paged attention pool."""
    return cfg.sliding_window == 0


def supports_prefix_cache(cfg: ModelConfig) -> bool:
    """Whether shared-prefix block reuse is exact for this arch: pure
    attention only. A cached prefix carries *KV blocks*, not recurrent
    state — an SSM layer's state at ``cached_len`` depends on the whole
    prefix and is not reconstructible from shared blocks, and MoE capacity
    couples suffix tokens across slots. (Sliding windows are already
    excluded by the paged layout itself.)"""
    return supports_paged_cache(cfg) and all(
        sp.kind == "attn" and not sp.moe for sp in cfg.period
    )


def supports_speculative(cfg: ModelConfig) -> bool:
    """Whether self-speculative decoding is exact for this arch: pure
    attention over the paged pool. Attention state is *positional* — a
    rejected draft's K/V entries are simply overwritten or masked — but an
    SSM recurrence integrates every draft step into its state and cannot
    roll back a rejection, and MoE capacity couples draft rows across
    slots. Same gate as the prefix cache (the verify pass *is* the offset
    prefill, batched)."""
    return supports_prefix_cache(cfg)


def prefill_ragged(
    params: Params, cfg: ModelConfig, batch: Params, max_len: int, true_len
) -> Tuple[jnp.ndarray, Params]:
    """Prefill a right-padded prompt whose true length is ``true_len``
    (traced scalar <= the static padded length). Returns logits gathered at
    the last *real* token; pad cache entries get ``pos = -1`` so subsequent
    decode steps never attend to them. Exact only where
    ``supports_ragged_prefill`` holds."""
    assert supports_ragged_prefill(cfg), (
        f"{cfg.name}: ragged prefill is inexact for ssm/moe periods"
    )
    x = embed_inputs(params, cfg, batch)
    b = x.shape[0]
    true_len = jnp.asarray(true_len, jnp.int32)
    cache = init_cache(cfg, b, max_len)
    with jax.named_scope("serve/prefill_ragged"):
        h, cache, _ = forward_hidden(params, cfg, x, cache, 0, None)
    h_last = h[:, true_len - 1][:, None, :]
    logits = L.linear(_head_weights(params, cfg), h_last).astype(jnp.float32)
    masked = {}
    for lk, lv in cache.items():
        if isinstance(lv, dict) and "pos" in lv:
            lv = dict(lv)
            lv["pos"] = jnp.where(lv["pos"] >= true_len, -1, lv["pos"])
        masked[lk] = lv
    return logits[:, 0], masked


def prefill_slot(
    params: Params,
    cfg: ModelConfig,
    cache: Params,
    batch: Params,  # batch size 1
    slot,  # traced int32: destination slot in the batched cache
    max_len: int,
    true_len=None,  # set for a right-padded prompt (ragged/bucketed prefill)
    block_table: Optional[jnp.ndarray] = None,  # [B, max_blocks] paged cache
    cached_len=None,  # prefix cache: tokens already present in the slot's
    # shared blocks; ``batch`` then holds only the uncached suffix
) -> Tuple[jnp.ndarray, Params]:
    """Prefill one request and write its cache into slot ``slot`` of an
    existing batched cache (every leaf is [n_periods, B, ...]), leaving the
    other slots untouched. The unit of work behind continuous batching:
    freed slots are refilled mid-flight without touching neighbours.

    With a paged cache (``block_table`` given) the attention leaves are a
    shared block pool: the contiguous single-row prefill cache is cut into
    ``block_size`` chunks and scattered to the physical blocks named by the
    slot's table row. Unallocated tail entries of the row point at the null
    block, which absorbs the pad-chunk writes; those chunks carry only
    ``pos == -1`` entries, so the null block's invariant (never a valid
    position) is preserved — and every *allocated* block gets overwritten
    wholesale, so no stale positions from a prior owner survive admission.

    With ``cached_len`` (prefix cache hit) the slot's table already names
    shared blocks holding positions ``[0, cached_len)``; this runs the
    *offset* prefill instead: suffix tokens RoPE-rotate and write at
    absolute positions ``cached_len + i`` directly into the pool, and
    their attention spans the gathered table row — shared prefix included.
    Exact only where ``supports_prefix_cache`` holds (pure attention).
    Because the offset path writes positions one-by-one rather than
    overwriting whole blocks, the slot's fresh (non-shared) blocks have
    their ``pos`` wiped to -1 first, so no stale positions from a prior
    owner leak into the attention mask."""
    # tensor-parallel serving: pin the batched cache to its mesh layout
    # (the serving mesh's data axis is size 1, so the batch argument only
    # matters for training meshes — this path never sees one)
    cache = Sh.shard_cache(cache, cfg, 1)
    if cached_len is not None:
        assert block_table is not None, "prefix-cached prefill is paged-only"
        assert supports_prefix_cache(cfg), (
            f"{cfg.name}: prefix-cached prefill is exact only for pure-"
            "attention periods"
        )
        slot = jnp.asarray(slot, jnp.int32)
        cached_len = jnp.asarray(cached_len, jnp.int32)
        row = jax.lax.dynamic_slice_in_dim(
            block_table, slot, 1, axis=0
        )  # [1, max_blocks]
        bs_blk = cache["layer_0"]["k"].shape[2]  # pure-attn: layer_0 is attn
        # wipe stale pos in the slot's fresh blocks (table entries past the
        # cached prefix; null rows absorb their own wipe harmlessly)
        keep = (cached_len + bs_blk - 1) // bs_blk  # incl. a CoW'd last block
        wipe_rows = jnp.where(
            jnp.arange(row.shape[1]) >= keep, row[0], 0  # 0 = null block
        )
        wiped: Params = {}
        for lk, lv in cache.items():
            lv = dict(lv)
            lv["pos"] = lv["pos"].at[:, wipe_rows].set(-1)
            wiped[lk] = lv
        x = embed_inputs(params, cfg, batch)
        s = x.shape[1]
        tl = jnp.asarray(s if true_len is None else true_len, jnp.int32)
        with jax.named_scope("serve/prefill_offset"):
            h, new_cache, _ = forward_hidden(
                params, cfg, x, wiped, cached_len, None,
                block_table=row, true_len=tl,
            )
        h_last = h[:, tl - 1][:, None, :]
        logits = L.linear(_head_weights(params, cfg), h_last).astype(jnp.float32)
        return logits[:, 0], new_cache

    if true_len is None:
        logits, small = prefill(params, cfg, batch, max_len)
    else:
        logits, small = prefill_ragged(params, cfg, batch, max_len, true_len)
    slot = jnp.asarray(slot, jnp.int32)
    if block_table is None:
        cache = jax.tree.map(
            lambda big, sm: jax.lax.dynamic_update_slice_in_dim(
                big, sm.astype(big.dtype), slot, axis=1
            ),
            cache,
            small,
        )
        return logits, cache

    row = block_table[slot]  # [max_blocks] physical block ids

    def scatter_blocks(big, sm):
        # big [n_periods, n_blocks, bs, ...]; sm [n_periods, 1, c_len, ...]
        bs = big.shape[2]
        npd, _, c_len = sm.shape[:3]
        nblk = c_len // bs
        chunks = sm.astype(big.dtype).reshape(
            (npd, nblk, bs) + sm.shape[3:]
        )
        return big.at[:, row[:nblk]].set(chunks)

    def splice_row(big, sm):
        return jax.lax.dynamic_update_slice_in_dim(
            big, sm.astype(big.dtype), slot, axis=1
        )

    new_cache: Params = {}
    with jax.named_scope("serve/prefill_scatter"):
        for i, spec in enumerate(cfg.period):
            key = f"layer_{i}"
            if key not in cache:
                continue
            if spec.kind == "attn":
                new_cache[key] = {
                    leaf: scatter_blocks(cache[key][leaf], small[key][leaf])
                    for leaf in cache[key]
                }
            else:  # ssm / cross_attn state stays per-slot
                new_cache[key] = jax.tree.map(
                    splice_row, cache[key], small[key]
                )
    return logits, new_cache


# ---------------------------------------------------------------------------
# Speculative decoding: verify draft windows against the paged pool
# ---------------------------------------------------------------------------

def verify_slot(
    params: Params,
    cfg: ModelConfig,
    cache: Params,
    batch: Params,  # batch size 1: the slot's S-token draft window
    slot,  # traced int32: which slot's blocks the window writes into
    block_table: jnp.ndarray,  # [B, table_blocks] paged tables
    pos0,  # traced int32: absolute position of the window's first token
) -> Tuple[jnp.ndarray, Params]:
    """Score one slot's draft window and return *per-position* logits.

    This is ``prefill_slot(cached_len=pos0)`` generalized from "logits of
    the last real token" to "logits at every window position": the same
    offset-prefill pass — suffix K/V computed at absolute positions
    ``pos0 + i``, written straight into the slot's pool blocks, attention
    over the gathered table row — but the returned ``[1, S, V]`` logits
    give the full-model next-token distribution *after each* window token,
    which is exactly what speculative acceptance needs. The window's K/V
    writes overwrite the draft pass's provisional (backbone-only) entries,
    so every committed position ends up holding full-model K/V."""
    assert supports_speculative(cfg), (
        f"{cfg.name}: speculative verify is exact only for pure-attention "
        "periods over the paged pool"
    )
    slot = jnp.asarray(slot, jnp.int32)
    row = jax.lax.dynamic_slice_in_dim(block_table, slot, 1, axis=0)
    x = embed_inputs(params, cfg, batch)  # [1, S, D]
    with jax.named_scope("serve/verify"):
        h, cache, _ = forward_hidden(
            params, cfg, x, cache, jnp.asarray(pos0, jnp.int32), None,
            block_table=row,
        )
    logits = L.linear(_head_weights(params, cfg), h).astype(jnp.float32)
    return logits, cache


def verify_step(
    params: Params,
    cfg: ModelConfig,
    cache: Params,
    tokens: jnp.ndarray,  # [B, S] int32: every slot's draft window
    pos: jnp.ndarray,  # [B] int32: absolute position of tokens[:, 0] per slot
    block_table: jnp.ndarray,  # [B, table_blocks] paged tables
) -> Tuple[jnp.ndarray, Params]:
    """``verify_slot`` for every slot at once: one full-model pass scores
    all B draft windows, each at its own depth (per-slot ``pos`` vector
    through the paged offset-prefill branch). Returns ``[B, S, V]``
    per-position logits. Inactive rows ride along — their tables point at
    the trash block and their logits are discarded by the engine."""
    assert supports_speculative(cfg), (
        f"{cfg.name}: speculative verify is exact only for pure-attention "
        "periods over the paged pool"
    )
    x = jnp.take(params["embed"], tokens, axis=0).astype(_dtype(cfg))
    with jax.named_scope("serve/verify"):
        h, cache, _ = forward_hidden(
            params, cfg, x, cache, jnp.asarray(pos, jnp.int32), None,
            block_table=block_table,
        )
    logits = L.linear(_head_weights(params, cfg), h).astype(jnp.float32)
    return logits, cache
