"""Layer zoo: RMSNorm, RoPE, GQA attention (flash-style query chunking,
sliding-window ring cache, qk-norm), SwiGLU MLP, MoE (GShard-style grouped
one-hot dispatch with capacity), Mamba2 SSD (chunked matmul form + recurrent
decode step), and gated cross-attention (VLM).

Every matmul goes through ``linear`` which dispatches on the parameter leaf
type: a plain jnp array (dense path) or a ``SlimLinear`` (the compressed
deployed format) — so one forward definition serves dense training,
compressed serving, and PEFT.
"""
from __future__ import annotations

import contextlib
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.compressed import (
    SlimLinear,
    adapter_factors,
    dequantize_base,
    slim_linear_apply,
)
from repro.models import sharding as Sh
from repro.models.config import ModelConfig

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Calibration capture: when a capture dict is installed (eager execution
# only), every named linear records its input activations into a CalibStats
# keyed by the current scope path — this is how the SLiM pipeline gets
# per-matrix x statistics without a second forward implementation.
# ---------------------------------------------------------------------------

_CAPTURE: Optional[Dict[str, Any]] = None
_CAPTURE_HESSIAN: bool = False
_SCOPE: List[str] = []
# Adapter-skip (self-speculative drafting): while the flag is set, every
# compressed linear computes only its quantized-sparse backbone and drops
# the low-rank correction. Read at *trace* time — the speculative engine
# traces its draft step inside the scope, so the jitted draft program is
# permanently backbone-only while the verify/decode programs keep the
# full path (two distinct jit cache entries, no retracing races).
_SKIP_ADAPTERS: bool = False


@contextlib.contextmanager
def capture_scope(store: Dict[str, Any], with_hessian: bool = False):
    global _CAPTURE, _CAPTURE_HESSIAN
    prev, prev_h = _CAPTURE, _CAPTURE_HESSIAN
    _CAPTURE, _CAPTURE_HESSIAN = store, with_hessian
    try:
        yield store
    finally:
        _CAPTURE, _CAPTURE_HESSIAN = prev, prev_h


@contextlib.contextmanager
def scope(name: str):
    _SCOPE.append(name)
    try:
        yield
    finally:
        _SCOPE.pop()


@contextlib.contextmanager
def skip_adapters():
    """Trace the enclosed forward with every ``SlimLinear`` reduced to its
    backbone (no LoRA correction) — the free draft model of
    self-speculative decoding. Dense leaves are unaffected, so on an
    uncompressed model the scope is an exact no-op (drafting degenerates
    to lookahead decoding)."""
    global _SKIP_ADAPTERS
    prev = _SKIP_ADAPTERS
    _SKIP_ADAPTERS = True
    try:
        yield
    finally:
        _SKIP_ADAPTERS = prev


def _record(name: str, x: jnp.ndarray):
    if _CAPTURE is None or name is None:
        return
    from repro.core.pipeline import CalibStats

    key = "/".join(_SCOPE + [name])
    st = _CAPTURE.get(key)
    if st is None:
        st = CalibStats.init(x.shape[-1], with_hessian=_CAPTURE_HESSIAN)
    _CAPTURE[key] = st.update(x)


def linear(p, x: jnp.ndarray, name: Optional[str] = None) -> jnp.ndarray:
    """x [..., d_in] @ p -> [..., d_out]; p dense [d_in, d_out] or SlimLinear."""
    _record(name, x)
    if isinstance(p, SlimLinear):
        lead = x.shape[:-1]
        y = slim_linear_apply(
            p, x.reshape(-1, x.shape[-1]), compute_dtype=jnp.float32,
            skip_lora=_SKIP_ADAPTERS,
        )
        return y.reshape(*lead, y.shape[-1]).astype(x.dtype)
    return jnp.dot(x, p.astype(x.dtype))


def expert_matmul(p, xd: jnp.ndarray, name: Optional[str] = None) -> jnp.ndarray:
    """MoE expert matmul: xd [n, E, C, K] @ p[E, K, M] -> [n, E, C, M].

    Handles dense stacks and SlimLinear expert stacks (base + per-expert
    LoRA). Capture records per-expert input stats (dispatch zero-padding
    scales all channels uniformly, leaving saliency rankings intact).
    """
    if _CAPTURE is not None and name is not None:
        e = xd.shape[1]
        for ei in range(e):
            with scope(f"expert_{ei}"):
                _record(name, xd[:, ei].reshape(-1, xd.shape[-1]))
    if isinstance(p, SlimLinear):
        w = dequantize_base(p, jnp.float32)  # [E, K, M]
        y = jnp.einsum("neck,ekm->necm", xd, w)
        l, r = (None, None) if _SKIP_ADAPTERS else adapter_factors(p, xd.dtype)
        if l is not None:
            t = jnp.einsum("neck,ekr->necr", xd, l)
            y = y + jnp.einsum("necr,erm->necm", t, r)
        return y
    return jnp.einsum("neck,ekm->necm", xd, p.astype(xd.dtype))


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, dh], positions [..., S] (broadcastable) -> rotated x."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention core — flash-style scan over query chunks (bounded memory),
# GQA via (KV, rep) head grouping, causal + optional sliding window.
# ---------------------------------------------------------------------------

def _attend_block(
    q: jnp.ndarray,  # [B, Sq, KV, rep, dh]
    k: jnp.ndarray,  # [B, Skv, KV, dh]
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # [Sq] or [B, Sq] absolute positions of queries
    kv_pos: jnp.ndarray,  # [Skv] or [B, Skv] absolute positions of keys (-1 = invalid)
    window: int,
    probs_low_precision: bool = False,
) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum(
        "bqgrd,bsgd->bgrqs", q.astype(jnp.float32), k.astype(jnp.float32)
    ) * scale
    qp = q_pos if q_pos.ndim == 2 else q_pos[None, :]
    kp = kv_pos if kv_pos.ndim == 2 else kv_pos[None, :]
    valid = (kp[:, None, :] <= qp[:, :, None]) & (kp[:, None, :] >= 0)
    if window > 0:
        valid &= kp[:, None, :] > (qp[:, :, None] - window)
    scores = jnp.where(valid[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    if probs_low_precision:
        # flash-attention convention: PV matmul in the value dtype — halves
        # the largest live buffer of long-context prefill (§Perf memory)
        probs = probs.astype(v.dtype)
        out = jnp.einsum("bgrqs,bsgd->bqgrd", probs, v)
    else:
        out = jnp.einsum("bgrqs,bsgd->bqgrd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def mha(
    q: jnp.ndarray,  # [B, Sq, H, dh]
    k: jnp.ndarray,  # [B, Skv, KV, dh]
    v: jnp.ndarray,
    q_pos: jnp.ndarray,  # [Sq] absolute positions
    kv_pos: jnp.ndarray,  # [Skv] absolute key positions (-1 invalid)
    window: int = 0,
    q_chunk: int = 512,
    probs_low_precision: bool = False,
    expand_kv: bool = False,
) -> jnp.ndarray:
    """Memory-bounded attention: scores never exceed [B, ch, H, Skv]."""
    b, sq, h, dh = q.shape
    kv = k.shape[2]
    if expand_kv and kv < h:
        # GQA-expand: repeat K/V to the full head count so the head dim
        # shards on wide model axes (kv=8 on 16-way replicates otherwise)
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
        kv = h
    rep = h // kv
    qg = q.reshape(b, sq, kv, rep, dh)
    if sq <= q_chunk:
        out = _attend_block(qg, k, v, q_pos, kv_pos, window, probs_low_precision)
        return out.reshape(b, sq, h, dh)
    if sq % q_chunk != 0:
        # pad queries up to a chunk multiple; padded outputs are sliced away
        pad = q_chunk - sq % q_chunk
        qg = jnp.concatenate([qg, jnp.zeros((b, pad, kv, rep, dh), qg.dtype)], 1)
        q_pos = jnp.concatenate([q_pos, jnp.full((pad,), q_pos[-1], q_pos.dtype)])
        out = mha(
            qg.reshape(b, sq + pad, h, dh), k, v, q_pos, kv_pos, window,
            q_chunk, probs_low_precision,
        )
        return out[:, :sq]
    nc = sq // q_chunk
    qc = qg.reshape(b, nc, q_chunk, kv, rep, dh)
    qc = jnp.moveaxis(qc, 1, 0)  # [nc, B, ch, KV, rep, dh]
    qp = q_pos.reshape(nc, q_chunk)

    def body(_, xs):
        qblk, qpblk = xs
        out = _attend_block(qblk, k, v, qpblk, kv_pos, window, probs_low_precision)
        return None, out

    _, outs = jax.lax.scan(body, None, (qc, qp))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, dh)
    return out


# ---------------------------------------------------------------------------
# Self-attention layer (train / prefill / single-token decode w/ ring cache)
# ---------------------------------------------------------------------------

def _qk_normalize(q, k, p, cfg):
    if not cfg.qk_norm:
        return q, k
    q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
    k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k


# physical block 0 of a paged pool is the engine's null block (see
# serving/block_pool.py — not imported here to keep models free of serving):
# its pos entries only ever receive -1, so it absorbs pad writes safely
NULL_BLOCK_ID = 0


def _kv_quantize(t: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[B, S, KV, dh] -> (int8 codes, f32 scale [B, S, KV])."""
    s = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-8)
    codes = jnp.clip(jnp.round(t.astype(jnp.float32) / s[..., None]), -127, 127)
    return codes.astype(jnp.int8), s.astype(jnp.float32)


def _kv_dequantize(codes: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    return (codes.astype(jnp.float32) * scale[..., None]).astype(dtype)


def attention_layer(
    p: Params,
    x: jnp.ndarray,  # [B, S, D]
    cfg: ModelConfig,
    cache: Optional[Params] = None,
    pos0: Any = 0,  # scalar or [B] vector: absolute position of x[:, 0] per slot
    block_table: Optional[jnp.ndarray] = None,  # [B, max_blocks] paged cache
    true_len: Optional[jnp.ndarray] = None,  # real (unpadded) length of a
    # paged offset prefill; entries beyond it are never written to the pool
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, _ = x.shape
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = linear(p["wq"], h, "wq").reshape(b, s, cfg.n_heads, cfg.d_head)
    k = linear(p["wk"], h, "wk").reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = linear(p["wv"], h, "wv").reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    # tensor-parallel serving: pin the heads dim to the mesh's model axis
    # so attention stays all-local between the QKV and output projections
    # (exact no-ops without an ambient serving mesh — models/sharding.py)
    q = Sh.shard_heads(q, 2)
    k = Sh.shard_heads(k, 2)
    v = Sh.shard_heads(v, 2)
    q, k = _qk_normalize(q, k, p, cfg)
    pos0 = jnp.asarray(pos0, jnp.int32)
    per_slot = pos0.ndim == 1  # ragged decode: each batch row at its own position
    if per_slot:
        positions = pos0[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B, S]
    else:
        positions = pos0 + jnp.arange(s, dtype=jnp.int32)  # [S]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    plp = cfg.attn_probs_low_precision
    xkv = cfg.gqa_expand_kv

    def store(t):
        return _kv_quantize(t) if cfg.kv_quant else (t, None)

    new_cache = None
    if cache is None:
        # training: self-contained sequence
        kv_pos = jnp.arange(s, dtype=jnp.int32)
        out = mha(q, k, v, positions, kv_pos, cfg.sliding_window, cfg.q_chunk, plp, xkv)
    elif s > 1 and block_table is not None:
        # paged *offset* prefill (prefix-cache suffix, speculative verify):
        # the slot's table already names blocks holding positions [0, pos0);
        # this pass computes K/V only for the suffix tokens at absolute
        # positions pos0 + i, writes them straight into the slot's own
        # pool blocks, and attends over the gather of the whole table row
        # — so suffix queries see the prefix they did not write. A scalar
        # pos0 is the prefix-cache admission path (one slot, bucketed
        # suffix); a per-slot pos0 vector is the speculative *verify* step,
        # where every slot scores its own K-token draft window at its own
        # depth in one batched pass. Pad entries (i >= true_len) are routed
        # to the null block with pos = -1, preserving its never-valid
        # invariant; the engine has already wiped any fresh blocks' pos, so
        # no stale entries from a prior owner survive into the mask.
        bs_blk = cache["k"].shape[1]
        nkv, dh = cfg.n_kv_heads, cfg.d_head
        max_blocks = block_table.shape[1]
        idx = jnp.arange(s, dtype=jnp.int32)
        wvalid = (
            jnp.ones((s,), bool)
            if true_len is None
            else idx < jnp.asarray(true_len, jnp.int32)
        )
        # positions is [S] (scalar pos0) or [B, S] (per-slot verify)
        pvec = jnp.broadcast_to(
            positions if per_slot else positions[None, :], (b, s)
        )
        wv = jnp.broadcast_to(wvalid[None, :], (b, s))
        blk = jnp.clip(pvec // bs_blk, 0, max_blocks - 1)
        phys = jnp.where(
            wv, jnp.take_along_axis(block_table, blk, axis=1), NULL_BLOCK_ID
        )  # [B, S]
        off = pvec % bs_blk
        pos_w = jnp.where(wv, pvec, -1)
        kq, ks = store(k)
        vq, vs = store(v)
        ck = cache["k"].at[phys, off].set(kq)
        cv = cache["v"].at[phys, off].set(vq)
        cp = cache["pos"].at[phys, off].set(pos_w)
        new_cache = {"k": ck, "v": cv, "pos": cp}
        l_full = max_blocks * bs_blk
        gk = ck[block_table].reshape(b, l_full, nkv, dh)
        gv = cv[block_table].reshape(b, l_full, nkv, dh)
        gp = cp[block_table].reshape(b, l_full)
        if cfg.kv_quant:
            cks = cache["k_scale"].at[phys, off].set(ks)
            cvs = cache["v_scale"].at[phys, off].set(vs)
            new_cache["k_scale"] = cks
            new_cache["v_scale"] = cvs
            kd = _kv_dequantize(gk, cks[block_table].reshape(b, l_full, nkv), x.dtype)
            vd = _kv_dequantize(gv, cvs[block_table].reshape(b, l_full, nkv), x.dtype)
        else:
            kd, vd = gk, gv
        out = mha(q, kd, vd, positions, gp, cfg.sliding_window, cfg.q_chunk, plp, xkv)
    elif s > 1:
        # prefill: fill the cache (ring layout if sliding window)
        assert not per_slot, "multi-token prefill requires a scalar pos0"
        c_len = cache["k"].shape[1]
        kq, ks = store(k)
        vq, vs = store(v)
        if c_len >= s:
            def upd(buf, val, nd):
                return jax.lax.dynamic_update_slice(buf, val, (0,) * nd)
            ck = upd(cache["k"], kq, 4)
            cv = upd(cache["v"], vq, 4)
            cp = jax.lax.dynamic_update_slice(
                cache["pos"], jnp.broadcast_to(positions[None], (b, s)), (0, 0)
            )
            new_cache = {"k": ck, "v": cv, "pos": cp}
            if cfg.kv_quant:
                new_cache["k_scale"] = upd(cache["k_scale"], ks, 3)
                new_cache["v_scale"] = upd(cache["v_scale"], vs, 3)
        else:
            # sliding-window ring: keep the last c_len positions; roll so
            # slot i holds pos (s - c_len + i) — decode writes at pos % c_len
            shift = (s - c_len) % c_len
            def ring(t):
                return jnp.roll(t[:, s - c_len :], shift, axis=1)
            new_cache = {
                "k": ring(kq),
                "v": ring(vq),
                "pos": jnp.roll(
                    jnp.broadcast_to(positions[None, s - c_len :], (b, c_len)),
                    shift, axis=1,
                ),
            }
            if cfg.kv_quant:
                new_cache["k_scale"] = ring(ks)
                new_cache["v_scale"] = ring(vs)
        kv_pos = jnp.arange(s, dtype=jnp.int32)
        if cfg.kv_quant:
            # attend through the quantization lens: decode steps will only
            # ever see the dequantized cache, so prefill must too — this is
            # what makes a preemption resume (re-prefill of tokens that were
            # originally decoded) bit-identical to the uninterrupted run
            kd = _kv_dequantize(kq, ks, x.dtype)
            vd = _kv_dequantize(vq, vs, x.dtype)
        else:
            kd, vd = k, v
        out = mha(q, kd, vd, positions, kv_pos, cfg.sliding_window, cfg.q_chunk, plp, xkv)
    elif block_table is not None:
        # single-token decode against the *paged* cache: leaves are a shared
        # block pool ([n_blocks, bs, KV, dh] — no batch dim); each row writes
        # its K/V at (table[row, pos // bs], pos % bs) and attends over the
        # gather of its whole table row. Unallocated table entries point at
        # the null block, whose pos stays -1, so the mask drops them; rows
        # whose table is all trash (inactive slots) produce garbage that the
        # engine discards, and their writes land in the trash block no live
        # table references.
        bs_blk = cache["k"].shape[1]
        nkv, dh = cfg.n_kv_heads, cfg.d_head
        pv = positions[:, 0] if per_slot else jnp.broadcast_to(positions[0], (b,))
        phys = block_table[jnp.arange(b), pv // bs_blk]  # [B]
        off = pv % bs_blk
        kq, ks = store(k)
        vq, vs = store(v)
        ck = cache["k"].at[phys, off].set(kq[:, 0])
        cv = cache["v"].at[phys, off].set(vq[:, 0])
        cp = cache["pos"].at[phys, off].set(pv)
        new_cache = {"k": ck, "v": cv, "pos": cp}
        l_full = block_table.shape[1] * bs_blk
        gk = ck[block_table].reshape(b, l_full, nkv, dh)
        gv = cv[block_table].reshape(b, l_full, nkv, dh)
        gp = cp[block_table].reshape(b, l_full)
        if cfg.kv_quant:
            cks = cache["k_scale"].at[phys, off].set(ks[:, 0])
            cvs = cache["v_scale"].at[phys, off].set(vs[:, 0])
            new_cache["k_scale"] = cks
            new_cache["v_scale"] = cvs
            kd = _kv_dequantize(gk, cks[block_table].reshape(b, l_full, nkv), x.dtype)
            vd = _kv_dequantize(gv, cvs[block_table].reshape(b, l_full, nkv), x.dtype)
        else:
            kd, vd = gk, gv
        out = mha(q, kd, vd, pv[:, None], gp, cfg.sliding_window, cfg.q_chunk, plp, xkv)
    else:
        # single-token decode against the cache (ring if windowed); each batch
        # row writes at its own position, so a continuous-batching engine can
        # serve slots whose sequences are at different depths.
        c_len = cache["k"].shape[1]
        pv = positions[:, 0] if per_slot else jnp.broadcast_to(positions[0], (b,))
        slot = pv % c_len  # [B]
        bidx = jnp.arange(b)
        kq, ks = store(k)
        vq, vs = store(v)
        ck = cache["k"].at[bidx, slot].set(kq[:, 0])
        cv = cache["v"].at[bidx, slot].set(vq[:, 0])
        cp = cache["pos"].at[bidx, slot].set(pv)
        new_cache = {"k": ck, "v": cv, "pos": cp}
        if cfg.kv_quant:
            new_cache["k_scale"] = cache["k_scale"].at[bidx, slot].set(ks[:, 0])
            new_cache["v_scale"] = cache["v_scale"].at[bidx, slot].set(vs[:, 0])
            kd = _kv_dequantize(ck, new_cache["k_scale"], x.dtype)
            vd = _kv_dequantize(cv, new_cache["v_scale"], x.dtype)
        else:
            kd, vd = ck, cv
        q_pos = pv[:, None]  # [B, 1]
        out = mha(q, kd, vd, q_pos, cp, cfg.sliding_window, cfg.q_chunk, plp, xkv)
    out = out.reshape(b, s, cfg.d_q)
    return x + linear(p["wo"], out, "wo").astype(x.dtype), new_cache


def init_attn_cache(cfg: ModelConfig, b: int, max_len: int, dtype) -> Params:
    c_len = max_len
    if cfg.sliding_window:
        c_len = min(max_len, cfg.sliding_window)
    kv_dt = jnp.int8 if cfg.kv_quant else dtype
    cache = {
        "k": jnp.zeros((b, c_len, cfg.n_kv_heads, cfg.d_head), kv_dt),
        "v": jnp.zeros((b, c_len, cfg.n_kv_heads, cfg.d_head), kv_dt),
        "pos": -jnp.ones((b, c_len), jnp.int32),  # -1 = invalid slot
    }
    if cfg.kv_quant:
        cache["k_scale"] = jnp.zeros((b, c_len, cfg.n_kv_heads), jnp.float32)
        cache["v_scale"] = jnp.zeros((b, c_len, cfg.n_kv_heads), jnp.float32)
    return cache


def init_paged_attn_cache(
    cfg: ModelConfig, n_blocks: int, block_size: int, dtype
) -> Params:
    """Shared block pool replacing per-slot lanes: ``n_blocks`` blocks of
    ``block_size`` positions each, owned block-by-block via the engine's
    block tables (there is no batch axis — that's the point)."""
    kv_dt = jnp.int8 if cfg.kv_quant else dtype
    cache = {
        "k": jnp.zeros((n_blocks, block_size, cfg.n_kv_heads, cfg.d_head), kv_dt),
        "v": jnp.zeros((n_blocks, block_size, cfg.n_kv_heads, cfg.d_head), kv_dt),
        "pos": -jnp.ones((n_blocks, block_size), jnp.int32),  # -1 = invalid
    }
    if cfg.kv_quant:
        cache["k_scale"] = jnp.zeros(
            (n_blocks, block_size, cfg.n_kv_heads), jnp.float32
        )
        cache["v_scale"] = jnp.zeros(
            (n_blocks, block_size, cfg.n_kv_heads), jnp.float32
        )
    return cache


# ---------------------------------------------------------------------------
# Gated cross-attention layer (Llama-3.2-Vision style): cross-attn to the
# (stub) vision embeddings + its own gated FFN. Vision K/V are static during
# decode — cached at prefill.
# ---------------------------------------------------------------------------

def cross_attention_layer(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    vision: Optional[jnp.ndarray],  # [B, Tv, D] or None when cached
    cache: Optional[Params] = None,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, _ = x.shape
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = linear(p["wq"], h, "wq").reshape(b, s, cfg.n_heads, cfg.d_head)
    new_cache = None
    if cache is not None and vision is None:
        k, v = cache["k"], cache["v"]
        new_cache = cache
    else:
        hv = vision.astype(x.dtype)
        k = linear(p["wk"], hv, "wk").reshape(b, -1, cfg.n_kv_heads, cfg.d_head)
        v = linear(p["wv"], hv, "wv").reshape(b, -1, cfg.n_kv_heads, cfg.d_head)
        if cache is not None:
            new_cache = {"k": k, "v": v}
    tv = k.shape[1]
    q_pos = jnp.full((s,), tv, jnp.int32)  # attend over all vision tokens
    kv_pos = jnp.arange(tv, dtype=jnp.int32)
    out = mha(
        q, k, v, q_pos, kv_pos, 0, cfg.q_chunk, cfg.attn_probs_low_precision
    ).reshape(b, s, cfg.d_q)
    x = x + jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype) * linear(
        p["wo"], out, "wo"
    ).astype(x.dtype)
    hm = rmsnorm(x, p["ln_mlp"], cfg.norm_eps)
    mlp_out = swiglu(p, hm)
    x = x + jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * mlp_out
    return x, new_cache


def init_cross_cache(cfg: ModelConfig, b: int, dtype) -> Params:
    return {
        "k": jnp.zeros((b, cfg.vision_tokens, cfg.n_kv_heads, cfg.d_head), dtype),
        "v": jnp.zeros((b, cfg.vision_tokens, cfg.n_kv_heads, cfg.d_head), dtype),
    }


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------

def swiglu(p: Params, h: jnp.ndarray) -> jnp.ndarray:
    g = jax.nn.silu(linear(p["w_gate"], h, "w_gate").astype(jnp.float32))
    u = linear(p["w_up"], h, "w_up").astype(jnp.float32)
    return linear(p["w_down"], (g * u).astype(h.dtype), "w_down").astype(h.dtype)


def mlp_layer(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    return x + swiglu(p, h)


# ---------------------------------------------------------------------------
# MoE (top-k, GShard-style grouped dispatch with static capacity).
#
# Tokens are processed in groups of `cfg.moe_group`; within a group each
# expert accepts at most C = ceil(g * top_k / E * capacity_factor) tokens
# (overflow dropped — the standard capacity formulation). Dispatch/combine
# are one-hot einsums: ~1-2% FLOP overhead vs expert matmuls at our shapes,
# fully shardable (experts on the model axis -> XLA inserts all-to-alls).
# ---------------------------------------------------------------------------

def moe_layer(
    p: Params, x: jnp.ndarray, cfg: ModelConfig
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss). x [B, S, D]."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    tokens = h.reshape(b * s, d)
    n = tokens.shape[0]
    g = min(cfg.moe_group, n)
    while n % g != 0:  # largest divisor of n <= moe_group (odd batch shapes)
        g -= 1
    ng = n // g
    cap = max(1, int(math.ceil(g * k / e * cfg.capacity_factor)))
    cap = min(cap, g)

    tg = tokens.reshape(ng, g, d)
    logits = linear(p["router"], tg.astype(jnp.float32))  # [ng, g, E]
    top_vals, top_idx = jax.lax.top_k(logits, k)  # [ng, g, k]
    gates = jax.nn.softmax(top_vals, axis=-1)  # mixtral: softmax over top-k

    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)  # [ng, g, k, E]
    # position of each (token, slot) within its expert queue, counted over
    # the flattened (g, k) order
    flat = onehot.reshape(ng, g * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat  # exclusive prefix count
    pos = pos.reshape(ng, g, k, e)
    in_cap = (pos < cap).astype(jnp.float32) * onehot
    pos_clip = jnp.minimum(pos, cap - 1).astype(jnp.int32)
    slot_oh = jax.nn.one_hot(pos_clip, cap, dtype=jnp.float32)  # [ng,g,k,E,C]
    dispatch = jnp.einsum("ngke,ngkec->ngec", in_cap, slot_oh)  # {0,1}
    combine = jnp.einsum("ngk,ngke,ngkec->ngec", gates, in_cap, slot_oh)

    xd = jnp.einsum("ngec,ngd->necd", dispatch, tg.astype(jnp.float32))
    act = jax.nn.silu(expert_matmul(p["w_gate"], xd, "w_gate"))
    act = act * expert_matmul(p["w_up"], xd, "w_up")
    ye = expert_matmul(p["w_down"], act, "w_down")
    y = jnp.einsum("ngec,necd->ngd", combine, ye)
    y = y.reshape(b, s, d).astype(x.dtype)

    # Switch-style load-balance aux loss
    probs = jax.nn.softmax(logits, axis=-1)
    importance = jnp.mean(probs, axis=1)  # [ng, E]
    load = jnp.mean(onehot.sum(axis=2), axis=1)  # [ng, E]
    aux = e * jnp.mean(jnp.sum(importance * load, axis=-1))
    return x + y, aux


# ---------------------------------------------------------------------------
# Mamba2 (SSD) layer — chunked matmul form (train/prefill) + recurrent step
# (decode). State-space duality per arXiv:2405.21060, matmul-rich for the MXU.
# ---------------------------------------------------------------------------

def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """x [..., L] -> [..., L, L] with out[l, s] = sum_{s < j <= l} x[j]."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    xh: jnp.ndarray,  # [B, L, H, P]
    dt: jnp.ndarray,  # [B, L, H] (post-softplus)
    a: jnp.ndarray,  # [H] (negative)
    bmat: jnp.ndarray,  # [B, L, G, N]
    cmat: jnp.ndarray,  # [B, L, G, N]
    chunk: int,
    init_state: Optional[jnp.ndarray] = None,  # [B, H, P, N]
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y [B, L, H, P], final_state [B, H, P, N])."""
    b, l, h, pdim = xh.shape
    g, n = bmat.shape[2], bmat.shape[3]
    rep = h // g
    if l % chunk != 0:
        # zero-pad the tail: dt=0 => decay=1 and zero state contribution, so
        # padded steps are exact no-ops; outputs are sliced back.
        pad = chunk - l % chunk
        xh = jnp.concatenate([xh, jnp.zeros((b, pad, h, pdim), xh.dtype)], 1)
        dt = jnp.concatenate([dt, jnp.zeros((b, pad, h), dt.dtype)], 1)
        bmat = jnp.concatenate([bmat, jnp.zeros((b, pad, g, n), bmat.dtype)], 1)
        cmat = jnp.concatenate([cmat, jnp.zeros((b, pad, g, n), cmat.dtype)], 1)
        y, fstate = ssd_chunked(xh, dt, a, bmat, cmat, chunk, init_state)
        return y[:, :l], fstate
    nc = l // chunk
    xc = xh.reshape(b, nc, chunk, h, pdim)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    da = dtc * a.astype(jnp.float32)  # [B, nc, ch, H]
    da = jnp.moveaxis(da, -1, -2)  # [B, nc, H, ch]
    da_cs = jnp.cumsum(da, axis=-1)  # within-chunk cumulative

    # intra-chunk (diagonal blocks)
    lmat = jnp.exp(_segsum(da))  # [B, nc, H, ch, ch]
    # expand B/C groups to heads (head h belongs to group h // rep)
    bh = jnp.repeat(bc, rep, axis=3) if rep > 1 else bc  # [B,nc,ch,H,N]
    ch_ = jnp.repeat(cc, rep, axis=3) if rep > 1 else cc
    att = jnp.einsum("bzlhn,bzshn->bzhls", ch_, bh)  # [B,nc,H,ch,ch]
    att = att * lmat
    dtx = xc.astype(jnp.float32) * dtc[..., None]  # [B,nc,ch,H,P]
    y_diag = jnp.einsum("bzhls,bzshp->bzlhp", att, dtx)

    # chunk states: contribution of each chunk to the running state
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)  # [B,nc,H,ch]
    states = jnp.einsum(
        "bzlhn,bzhl,bzlhp->bzhpn", bh, decay_states * jnp.moveaxis(dtc, -1, -2), xc.astype(jnp.float32)
    )  # [B,nc,H,P,N]

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[..., -1])  # [B, nc, H]

    def scan_fn(carry, xs):
        st, dec = xs  # [B,H,P,N], [B,H]
        new = carry * dec[..., None, None] + st
        return new, carry  # emit the state *entering* this chunk

    init = (
        jnp.zeros((b, h, pdim, n), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # [B,nc,H,P,N]

    # inter-chunk output: y_off[l] = C[l] . (decay(l) * prev_state)
    state_decay = jnp.exp(da_cs)  # [B,nc,H,ch]
    y_off = jnp.einsum(
        "bzlhn,bzhpn,bzhl->bzlhp", ch_, prev_states, state_decay
    )
    y = (y_diag + y_off).reshape(b, l, h, pdim)
    return y, final_state


def ssm_layer(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Params] = None,
    pos0: Any = 0,
) -> Tuple[jnp.ndarray, Optional[Params]]:
    b, s, d = x.shape
    h_in = rmsnorm(x, p["ln"], cfg.norm_eps)
    d_inner = cfg.ssm_inner
    g, n = cfg.ssm_groups, cfg.ssm_state
    nh, hd = cfg.ssm_heads, cfg.ssm_head_dim
    conv_dim = d_inner + 2 * g * n

    zxbcdt = linear(p["in_proj"], h_in, "in_proj")  # [B,S, 2*inner + 2*g*n + nh]
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, d_inner + conv_dim], axis=-1)

    # causal depthwise conv over [x, B, C]
    kw = p["conv_w"]  # [conv_dim, K]
    kk = kw.shape[-1]
    new_conv_cache = None
    if cache is None or s > 1:
        pad = jnp.zeros((b, kk - 1, conv_dim), xbc.dtype)
        xbc_pad = jnp.concatenate([pad, xbc], axis=1)
        if cache is not None:
            new_conv_cache = xbc_pad[:, -(kk - 1) :, :]
        conv = sum(
            xbc_pad[:, i : i + s, :] * kw[:, i].astype(xbc.dtype)
            for i in range(kk)
        )
    else:
        hist = jnp.concatenate([cache["conv"], xbc], axis=1)  # [B, K, C]
        conv = jnp.einsum("bkc,ck->bc", hist.astype(jnp.float32), kw.astype(jnp.float32))[
            :, None, :
        ].astype(xbc.dtype)
        new_conv_cache = hist[:, 1:, :]
    conv = jax.nn.silu(conv.astype(jnp.float32)).astype(x.dtype)
    xs, bmat, cmat = jnp.split(conv, [d_inner, d_inner + g * n], axis=-1)
    xh = xs.reshape(b, s, nh, hd)
    bmat = bmat.reshape(b, s, g, n)
    cmat = cmat.reshape(b, s, g, n)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [H]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    new_cache = None
    if cache is None or s > 1:
        chunk = min(cfg.ssm_chunk, s)
        y, fstate = ssd_chunked(xh, dt, a, bmat, cmat, chunk)
        if cache is not None:
            new_cache = {"conv": new_conv_cache, "state": fstate.astype(jnp.float32)}
    else:
        state = cache["state"]  # [B, H, P, N]
        rep = nh // g
        bh = jnp.repeat(bmat[:, 0], rep, axis=1) if rep > 1 else bmat[:, 0]
        chh = jnp.repeat(cmat[:, 0], rep, axis=1) if rep > 1 else cmat[:, 0]
        da = jnp.exp(dt[:, 0] * a)  # [B, H]
        dbx = jnp.einsum(
            "bh,bhp,bhn->bhpn", dt[:, 0], xh[:, 0].astype(jnp.float32), bh.astype(jnp.float32)
        )
        state = state * da[..., None, None] + dbx
        y = jnp.einsum("bhpn,bhn->bhp", state, chh.astype(jnp.float32))[:, None]
        new_cache = {"conv": new_conv_cache, "state": state}
        fstate = state

    y = y + p["d_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, s, d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm(y.astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    return x + linear(p["out_proj"], y, "out_proj").astype(x.dtype), new_cache


def init_ssm_cache(cfg: ModelConfig, b: int, dtype) -> Params:
    conv_dim = cfg.ssm_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "conv": jnp.zeros((b, cfg.ssm_conv - 1, conv_dim), dtype),
        "state": jnp.zeros(
            (b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
    }
