"""Sharding rules: parameter / batch / cache / optimizer PartitionSpecs.

Strategy (DESIGN.md §5): Megatron-style tensor parallelism on the ``model``
axis + FSDP-style parameter sharding on the ``data`` axis; the ``pod`` axis
(multi-pod mesh) is pure data parallelism — parameters replicate across pods
(cross-pod DCN carries only gradient all-reduces), batch shards over
``(pod, data)``.

Every rule passes through ``_fit`` which drops a mesh axis from any dim it
does not divide — so the same rules serve all ten architectures (e.g.
mamba2's vocab 50280 is not 16-divisible: its embed falls back to
data-sharding on d_model automatically) and reduced smoke configs on one
device.

Compressed parameters (SlimLinear) shard like their dense counterparts: the
packed dims are the weight dims divided by the packing factor, so the same
(data, model) assignment applies; per-tensor scales replicate; LoRA factors
shard L on d_in(data), R on d_out(model).
"""
from __future__ import annotations

import contextlib
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

Pytree = Any

# ambient serving mesh: installed by ContinuousEngine.run() around its
# serve loop (use_serving_mesh) and consulted at trace time by the
# activation constraints below. A module global rather than a jax mesh
# context so the single-device path stays a None-check — and so the
# constraint helpers are exact no-ops (not just unsharded constraints)
# when serving without tensor parallelism.
_SERVING_MESH: Optional[Mesh] = None


@contextlib.contextmanager
def use_serving_mesh(mesh: Optional[Mesh]):
    """Install ``mesh`` as the ambient serving mesh for the duration."""
    global _SERVING_MESH
    prev = _SERVING_MESH
    _SERVING_MESH = mesh
    try:
        yield mesh
    finally:
        _SERVING_MESH = prev


def serving_mesh() -> Optional[Mesh]:
    """The ambient serving mesh (None outside use_serving_mesh)."""
    return _SERVING_MESH


def shard_heads(x: jax.Array, axis: int) -> jax.Array:
    """Constrain activation dim ``axis`` (a heads dim) to the serving
    mesh's 'model' axis. Identity without an ambient mesh or when the
    dim does not divide — the same fallback rule as ``_fit``, so tiny
    test configs pass through untouched."""
    mesh = _SERVING_MESH
    if mesh is None:
        return x
    if x.shape[axis] % _axis_size(mesh, "model") != 0:
        return x
    spec = [None] * x.ndim
    spec[axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec))
    )


def shard_cache(cache: Pytree, cfg: ModelConfig, batch: int) -> Pytree:
    """Constrain a decode/prefill cache to its serving layout (kv heads
    over 'model' per ``cache_specs``). Identity without an ambient mesh."""
    mesh = _SERVING_MESH
    if mesh is None:
        return cache
    ns = named(mesh, cache_specs(cache, cfg, mesh, batch))
    return jax.tree.map(
        lambda leaf, s: jax.lax.with_sharding_constraint(leaf, s), cache, ns
    )


def dp_axes(mesh: Mesh):
    """The data-parallel axis (or axes) of this mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        s = 1
        for n in name:
            s *= mesh.shape[n]
        return s
    return mesh.shape[name]


def _fit(spec: Tuple, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec entries that don't divide their dim (robust fallback)."""
    fitted = []
    for dim, ax in zip(shape, spec, strict=False):
        if ax is None:
            fitted.append(None)
        elif dim % _axis_size(mesh, ax) == 0:
            fitted.append(ax)
        else:
            fitted.append(None)
    return P(*fitted)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for p in path:
        n = getattr(p, "key", None)
        if n is None:
            n = getattr(p, "name", None)
        if n is None and hasattr(p, "idx"):
            n = str(p.idx)
        names.append(str(n))
    return tuple(names)


# weight-name -> (spec for [d_in, d_out]) orientation; leading stacked dims
# (periods, experts) are replicated.
_IN_OUT = {"wq", "wk", "wv", "w_gate", "w_up", "in_proj", "lm_head"}
_OUT_IN = {"wo", "w_down", "out_proj"}


def _param_rule(
    names: Tuple[str, ...], shape: Tuple[int, ...], mesh: Mesh, ep: bool = False
) -> P:
    last = names[-1]
    nd = len(shape)

    def lead(k: int):
        return (None,) * (nd - k)

    def _is_expert(wname: str) -> bool:
        # MoE expert stacks carry an extra leading E dim under the 'moe' scope
        return ep and "moe" in names and wname in ("w_gate", "w_up", "w_down")

    # SlimLinear internals
    if last == "packed_vals" or last == "packed_idx":
        wname = names[-2] if len(names) >= 2 else ""
        if _is_expert(wname) and nd >= 3:
            # expert-parallel: E over 'model'; keep FSDP on the weight dims
            if wname in _OUT_IN:
                return _fit(lead(3) + ("model", None, "data"), shape, mesh)
            return _fit(lead(3) + ("model", "data", None), shape, mesh)
        if wname in _OUT_IN:
            return _fit(lead(2) + ("model", "data"), shape, mesh)
        return _fit(lead(2) + ("data", "model"), shape, mesh)
    if last == "scale":
        if nd >= 3:  # group scales [.., K/g, 1, N]
            return _fit(lead(3) + ("data", None, "model"), shape, mesh)
        return P()
    if last == "inv_act_scale":
        return _fit(lead(1) + ("data",), shape, mesh)
    if last == "lora_l":
        return _fit(lead(2) + ("data", None), shape, mesh)
    if last == "lora_r":
        return _fit(lead(2) + (None, "model"), shape, mesh)
    if last == "lora_scale_l":  # [.., d_in/g, 1, r]
        return _fit(lead(3) + ("data", None, None), shape, mesh)
    if last == "lora_scale_r":  # [.., r/g, 1, d_out]
        return _fit(lead(3) + (None, None, "model"), shape, mesh)

    # dense weights
    if last == "embed":
        return _fit(("model", "data"), shape, mesh)
    if _is_expert(last) and nd >= 3:
        if last in _OUT_IN:
            return _fit(lead(3) + ("model", None, "data"), shape, mesh)
        return _fit(lead(3) + ("model", "data", None), shape, mesh)
    if last in _IN_OUT:
        return _fit(lead(2) + ("data", "model"), shape, mesh)
    if last in _OUT_IN:
        return _fit(lead(2) + ("model", "data"), shape, mesh)
    if last == "router":
        return _fit(lead(2) + ("data", None), shape, mesh)
    if last == "conv_w":
        return _fit(lead(2) + ("model", None), shape, mesh)
    if last in ("a_log", "d_skip", "dt_bias", "gate_norm"):
        return _fit(lead(1) + ("model",), shape, mesh)
    # norms, gates, small vectors: replicate
    return P(*([None] * nd))


def param_specs(
    params: Pytree, cfg: ModelConfig, mesh: Mesh, serving: bool = False
) -> Pytree:
    """PartitionSpec tree matching `params` (works on ShapeDtypeStructs).

    serving=True drops the FSDP ('data') axis from weights: at decode the
    whole model streams every step, so data-sharded weights cost a per-layer
    all-gather on the hot path. Serving replicates weights across the dp
    axis and keeps TP only — the classic inference topology (§Perf decode
    iteration)."""

    ep = bool(getattr(cfg, "moe_expert_parallel", False))

    def rule(path, leaf):
        if leaf is None:
            return P()
        spec = _param_rule(_path_names(path), tuple(leaf.shape), mesh, ep=ep)
        if serving:
            spec = P(*(None if ax == "data" else ax for ax in spec))
        return spec

    return jax.tree_util.tree_map_with_path(rule, params)


def batch_specs(cfg: ModelConfig, mesh: Mesh) -> Dict[str, P]:
    dp = dp_axes(mesh)
    specs = {
        "tokens": P(dp, None),
        "labels": P(dp, None),
    }
    if cfg.input_mode == "embeddings":
        specs["embeds"] = P(dp, None, None)
    if cfg.vision_tokens:
        specs["vision_embeds"] = P(dp, None, None)
    return specs


def cache_specs(cache: Pytree, cfg: ModelConfig, mesh: Mesh, batch: int) -> Pytree:
    """KV / SSM cache specs.

    batch >= dp size -> shard batch over dp; otherwise (long-context, B=1)
    shard the sequence dim of attention caches over 'data' (the
    flash-decoding layout: partial softmax stats all-reduce over 'data').
    Heads / feature dims shard over 'model' where divisible.
    """
    dp = dp_axes(mesh)
    batch_sharded = batch % _axis_size(mesh, dp) == 0

    model_size = _axis_size(mesh, "model")

    def rule(path, leaf):
        names = _path_names(path)
        last = names[-1]
        nd = leaf.ndim
        if last in ("k", "v"):  # [periods, B, S, KV, dh]
            kv, dh = leaf.shape[-2], leaf.shape[-1]
            # prefer sharding kv heads; fall back to head_dim (GQA kv=8 on a
            # 16-way model axis would otherwise replicate the whole cache)
            head_ax = (
                ("model", None) if kv % model_size == 0 else (None, "model")
            )
            if batch_sharded:
                return _fit((None, dp, None) + head_ax, leaf.shape, mesh)
            return _fit((None, None, "data") + head_ax, leaf.shape, mesh)
        if last == "pos":  # [periods, B, S]
            if batch_sharded:
                return _fit((None, dp, None), leaf.shape, mesh)
            return _fit((None, None, "data"), leaf.shape, mesh)
        if last in ("k_scale", "v_scale"):  # [periods, B, S, KV]
            kv = leaf.shape[-1]
            head_ax = "model" if kv % model_size == 0 else None
            if batch_sharded:
                return _fit((None, dp, None, head_ax), leaf.shape, mesh)
            return _fit((None, None, "data", head_ax), leaf.shape, mesh)
        if last == "conv":  # [periods, B, K-1, conv_dim]
            spec = (None, dp if batch_sharded else None, None, "model")
            return _fit(spec, leaf.shape, mesh)
        if last == "state":  # [periods, B, H, P, N]
            spec = (None, dp if batch_sharded else None, "model", None, None)
            return _fit(spec, leaf.shape, mesh)
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, cache)


def opt_specs(opt_state: Pytree, pspecs: Pytree) -> Pytree:
    """Optimizer state shards like its parameter; sentinels/scalars replicate.

    opt_state: OptState(step, mu, nu, residual) where mu/nu/residual mirror
    the param tree (possibly with zero-size sentinels or factored shapes).
    """
    from repro.optim.optimizers import OptState

    def match(spec_tree, state_tree):
        return jax.tree.map(
            lambda sp, st: sp
            if (hasattr(st, "shape") and st.ndim == len(sp))
            else P(),
            spec_tree,
            state_tree,
        )

    return OptState(
        step=P(),
        mu=match(pspecs, opt_state.mu),
        nu=match(pspecs, opt_state.nu),
        residual=None
        if opt_state.residual is None
        else match(pspecs, opt_state.residual),
    )


def named(mesh: Mesh, spec_tree: Pytree) -> Pytree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
