"""Baseline weight quantizers: AbsMax, Group AbsMax, and OPTQ-style.

Conventions (DESIGN.md §8):
  - weights ``W[d_in, d_out]``, symmetric q-bit quantization (paper Eq. 2)::

        Wq = round(clip(W / alpha, -1, 1) * (2**(q-1)))

    with integer levels clamped to ``[-(2**(q-1) - 1), 2**(q-1) - 1]`` so the
    code is sign-symmetric and int4-packable.
  - dequant: ``W_hat = Wq * alpha / 2**(q-1)``.

All functions are pure jnp and jit-safe. A ``QuantizedTensor`` carries the
integer codes plus the metadata needed to dequantize; ``dequantize`` is the
single source of truth used by the model's compressed layers and by the
Pallas kernels' reference oracles.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """Symmetric-quantized tensor.

    codes: int8 integer levels in [-(2^{q-1}-1), 2^{q-1}-1], shape = W.shape.
    scale: per-tensor scalar () or per-group array broadcastable after
           ``reshape(d_in // g, g, d_out)`` -> shape (d_in // g, 1, d_out).
    bits:  bit width q.
    group_size: 0 for per-tensor, else group length along d_in.
    """

    codes: jnp.ndarray
    scale: jnp.ndarray
    bits: int
    group_size: int

    def tree_flatten(self):
        return (self.codes, self.scale), (self.bits, self.group_size)

    @classmethod
    def tree_unflatten(cls, aux, children):
        codes, scale = children
        bits, group_size = aux
        return cls(codes=codes, scale=scale, bits=bits, group_size=group_size)

    @property
    def shape(self):
        return self.codes.shape

    def dequantize(self) -> jnp.ndarray:
        return dequantize(self)


def _qmax(bits: int) -> int:
    return 2 ** (bits - 1) - 1


def quantize_symmetric(w: jnp.ndarray, alpha: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Paper Eq. 2 with symmetric level clamp. Returns int8 codes."""
    if not 2 <= bits <= 8:
        raise ValueError(f"bits={bits}: int8 code storage supports 2..8 bits")
    half = 2 ** (bits - 1)
    scaled = jnp.clip(w / alpha, -1.0, 1.0) * half
    codes = jnp.clip(jnp.round(scaled), -_qmax(bits), _qmax(bits))
    return codes.astype(jnp.int8)


def dequantize_codes(codes: jnp.ndarray, alpha: jnp.ndarray, bits: int) -> jnp.ndarray:
    half = 2 ** (bits - 1)
    return codes.astype(jnp.float32) * (alpha / half)


def dequantize(qt: QuantizedTensor) -> jnp.ndarray:
    if qt.group_size == 0:
        return dequantize_codes(qt.codes, qt.scale, qt.bits)
    d_in, d_out = qt.codes.shape
    g = qt.group_size
    codes = qt.codes.reshape(d_in // g, g, d_out)
    w = dequantize_codes(codes, qt.scale, qt.bits)
    return w.reshape(d_in, d_out)


# ---------------------------------------------------------------------------
# AbsMax (per-tensor)
# ---------------------------------------------------------------------------

def absmax_quantize(w: jnp.ndarray, bits: int = 4) -> QuantizedTensor:
    alpha = jnp.max(jnp.abs(w))
    alpha = jnp.where(alpha <= 0, 1.0, alpha).astype(jnp.float32)
    codes = quantize_symmetric(w, alpha, bits)
    return QuantizedTensor(codes=codes, scale=alpha, bits=bits, group_size=0)


# ---------------------------------------------------------------------------
# Group AbsMax (one scale per `group_size` inputs per output column)
# ---------------------------------------------------------------------------

def fit_group_size(d_in: int, group_size: int) -> int:
    """Largest divisor of d_in that is <= group_size (>=1)."""
    g = min(group_size, d_in)
    while d_in % g != 0:
        g -= 1
    return g


def group_absmax_quantize(
    w: jnp.ndarray, bits: int = 4, group_size: int = 128
) -> QuantizedTensor:
    d_in, d_out = w.shape
    group_size = fit_group_size(d_in, group_size)
    grouped = w.reshape(d_in // group_size, group_size, d_out)
    alpha = jnp.max(jnp.abs(grouped), axis=1, keepdims=True)
    alpha = jnp.where(alpha <= 0, 1.0, alpha).astype(jnp.float32)
    codes = quantize_symmetric(grouped, alpha, bits).reshape(d_in, d_out)
    return QuantizedTensor(codes=codes, scale=alpha, bits=bits, group_size=group_size)


# ---------------------------------------------------------------------------
# OPTQ-style (GPTQ) quantizer — column-by-column with Hessian-driven update.
#
# The paper uses "Group OPTQ" as the quantizer paired with SparseGPT. We
# implement the standard OPTQ recurrence on the layer Hessian
# H = X^T X + lambda*I, processing the d_in dimension in blocks; the error of
# each quantized row is propagated into not-yet-quantized rows through the
# inverse-Cholesky factors. Pure JAX (lax.fori_loop over columns).
# ---------------------------------------------------------------------------

def optq_quantize(
    w: jnp.ndarray,
    hessian: jnp.ndarray,
    bits: int = 4,
    group_size: int = 128,
    percdamp: float = 0.01,
) -> QuantizedTensor:
    """OPTQ: quantize W[d_in, d_out] given H[d_in, d_in] = X^T X.

    Uses per-group absmax scales computed up-front (standard practice for
    "Group OPTQ"), then the OBS update: after quantizing input-row i, the
    remaining rows absorb err / Hinv[i, i] * Hinv[i, i+1:].
    """
    d_in, d_out = w.shape
    if group_size:
        group_size = fit_group_size(d_in, group_size)
    damp = percdamp * jnp.mean(jnp.diag(hessian)) + 1e-8
    h = hessian + damp * jnp.eye(d_in, dtype=hessian.dtype)
    # Hinv via Cholesky of the inverse (as in the GPTQ reference impl).
    hinv = jnp.linalg.inv(h)
    # Upper Cholesky factor of Hinv: hinv = U^T U with U upper triangular.
    u = jnp.linalg.cholesky(hinv, upper=True)

    if group_size == 0:
        alpha = jnp.max(jnp.abs(w))
        alpha = jnp.where(alpha <= 0, 1.0, alpha)
        alpha_rows = jnp.broadcast_to(alpha, (d_in, d_out))
        scale_out = alpha.astype(jnp.float32)
    else:
        grouped = w.reshape(d_in // group_size, group_size, d_out)
        ga = jnp.max(jnp.abs(grouped), axis=1, keepdims=True)
        ga = jnp.where(ga <= 0, 1.0, ga)
        alpha_rows = jnp.broadcast_to(ga, grouped.shape).reshape(d_in, d_out)
        scale_out = ga.astype(jnp.float32)

    half = 2 ** (bits - 1)
    qmax = _qmax(bits)

    def body(i, carry):
        w_work, codes = carry
        row = w_work[i]
        a = alpha_rows[i]
        c = jnp.clip(jnp.round(jnp.clip(row / a, -1.0, 1.0) * half), -qmax, qmax)
        deq = c * a / half
        err = (row - deq) / u[i, i]
        # Propagate into remaining rows (masked so rows <= i are untouched).
        mask = (jnp.arange(d_in) > i).astype(w_work.dtype)[:, None]
        w_work = w_work - mask * jnp.outer(u[i], err)
        codes = codes.at[i].set(c.astype(jnp.int8))
        return w_work, codes

    codes0 = jnp.zeros((d_in, d_out), dtype=jnp.int8)
    _, codes = jax.lax.fori_loop(0, d_in, body, (w.astype(jnp.float32), codes0))
    if group_size == 0:
        return QuantizedTensor(codes=codes, scale=scale_out, bits=bits, group_size=0)
    return QuantizedTensor(codes=codes, scale=scale_out, bits=bits, group_size=group_size)


# ---------------------------------------------------------------------------
# Error metrics
# ---------------------------------------------------------------------------

def reconstruction_error(w: jnp.ndarray, qt: QuantizedTensor) -> jnp.ndarray:
    """||W_hat - W||^2 (paper Eq. 3 objective)."""
    return jnp.sum((dequantize(qt) - w) ** 2)


def output_error(x: jnp.ndarray, w: jnp.ndarray, qt: QuantizedTensor) -> jnp.ndarray:
    """||X(W_hat - W)||^2 (paper Eq. 1, the OBS layer objective)."""
    return jnp.sum((x @ (dequantize(qt) - w)) ** 2)
