"""SLiM core: one-shot quantization + sparsity + low-rank compensation.

Public API:
  quantizers     — AbsMax / Group AbsMax / OPTQ + QuantizedTensor
  slim_quant     — SLiM-Quant histogram multigrid scale search (Alg. 1)
  pruning        — Wanda / magnitude / SparseGPT / N:M masks
  lora           — Naive-LoRA / SLiM-LoRA (Alg. 2) / adapter quantization
  pipeline       — compress_matrix + CompressionConfig (Fig. 1 pipeline)
  compressed     — SlimLinear deployed format + slim_linear_apply
  packing        — int4 nibble + 2:4 structured packing
  ste            — straight-through estimator for quantized-adapter PEFT
"""
from repro.core.quantizers import (
    QuantizedTensor,
    absmax_quantize,
    group_absmax_quantize,
    optq_quantize,
    dequantize,
)
from repro.core.slim_quant import (
    slim_quantize,
    slim_quant_alpha,
    slim_quantize_activation_aware,
    weight_abs_histogram,
    estimate_error_curve,
)
from repro.core.pruning import (
    wanda_prune,
    magnitude_prune,
    sparsegpt_prune,
    jsq_compress,
    make_mask,
    nm_mask,
    check_nm,
)
from repro.core.lora import (
    naive_lora,
    slim_lora,
    quantize_adapters,
    default_rank,
)
from repro.core.pipeline import (
    CalibStats,
    CompressionConfig,
    CompressionReport,
    compress_matrix,
)
from repro.core.compressed import SlimLinear, slim_linear_apply, build_slim_linear
from repro.core.ste import ste_quantize
