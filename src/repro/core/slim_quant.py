"""SLiM-Quant (paper §3.1, Algorithm 1).

Probabilistic reformulation of symmetric per-tensor quantization: the optimal
scale ``alpha*`` minimizes

    E_Q(alpha) = E_quant(alpha) + E_clip(alpha)
    E_quant    = int_0^alpha  f_abs(x) |deq(Q(x)) - x|^2 dx
    E_clip     = int_alpha^inf f_abs(x) (alpha - x)^2 dx

where ``f_abs`` is the PDF of |W|. Weight distributions do not match standard
PDFs (paper tested Gaussian/Laplace/Pareto/q-Gaussian/Weibull), so the
integral is evaluated **numerically on the weight-magnitude histogram** and
minimized with a **multigrid refinement**: a coarse scan over (0, max|W|]
followed by progressively finer scans around the running argmin (Alg. 1 uses
two levels; we generalize to ``levels`` with identical semantics).

Everything is vectorized over the candidate-alpha axis so one jit'd call
evaluates a whole grid against the whole histogram: cost O(n_bins * n_grid)
per level, independent of tensor size after the histogram pass.

Also here: the activation-aware variant SLiM-Quant^O (AWQ-inspired channel
scaling with the paper's joint |diag(x)·W| saliency).
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import (
    QuantizedTensor,
    _qmax,
    quantize_symmetric,
)


def histogram_bins_for(shape: Tuple[int, ...]) -> int:
    """Paper §T: n_bins = max(512, min(numel/1000, 20000))."""
    numel = 1
    for s in shape:
        numel *= int(s)
    return int(max(512, min(numel // 1000, 20000)))


def weight_abs_histogram(w: jnp.ndarray, n_bins: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Histogram of |W|: returns (probability mass p[n_bins], centers c[n_bins]).

    Sharing error computation between elements that land in the same bin is
    what makes Alg. 1 cheap (paper §T).
    """
    a = jnp.abs(w).reshape(-1).astype(jnp.float32)
    wmax = jnp.maximum(jnp.max(a), 1e-12)
    edges = jnp.linspace(0.0, wmax, n_bins + 1)
    counts, _ = jnp.histogram(a, bins=edges)
    centers = 0.5 * (edges[:-1] + edges[1:])
    p = counts.astype(jnp.float32) / jnp.maximum(jnp.sum(counts), 1)
    return p, centers


def _quant_error_at(
    alphas: jnp.ndarray,  # [G]
    p: jnp.ndarray,  # [B] probability mass per bin
    centers: jnp.ndarray,  # [B] bin centers (abs values)
    bits: int,
) -> jnp.ndarray:
    """Vectorized EstimateError over a grid of alphas. Returns [G]."""
    half = float(2 ** (bits - 1))
    qmax = float(_qmax(bits))
    a = alphas[:, None]  # [G, 1]
    x = centers[None, :]  # [1, B]
    # Reconstruction under scale a (with symmetric level clamp, matching
    # quantize_symmetric): deq = clip(round(x/a*half), -qmax, qmax) * a/half.
    levels = jnp.clip(jnp.round(x / a * half), -qmax, qmax)
    deq = levels * a / half
    err = (deq - x) ** 2
    return jnp.sum(p[None, :] * err, axis=1)


@partial(jax.jit, static_argnames=("bits", "levels", "grid"))
def slim_quant_alpha(
    p: jnp.ndarray,
    centers: jnp.ndarray,
    bits: int = 4,
    levels: int = 4,
    grid: int = 16,
) -> jnp.ndarray:
    """Multigrid search for alpha* (Alg. 1 generalized to `levels` levels).

    Level 0 scans `grid` points over (0, max]; each subsequent level scans
    `grid` points over +/- one previous step around the incumbent argmin.
    """
    wmax = centers[-1] + (centers[-1] - centers[-2]) * 0.5  # top bin edge

    lo = wmax / grid
    hi = wmax

    def level_body(carry, _):
        lo, hi = carry
        alphas = jnp.linspace(lo, hi, grid)
        errs = _quant_error_at(alphas, p, centers, bits)
        i = jnp.argmin(errs)
        best = alphas[i]
        step = (hi - lo) / (grid - 1)
        new_lo = jnp.maximum(best - step, wmax * 1e-4)
        new_hi = jnp.minimum(best + step, wmax)
        return (new_lo, new_hi), best

    (_, _), bests = jax.lax.scan(level_body, (lo, hi), None, length=levels)
    return bests[-1].astype(jnp.float32)


def slim_quantize(
    w: jnp.ndarray,
    bits: int = 4,
    n_bins: Optional[int] = None,
    levels: int = 4,
    grid: int = 16,
) -> QuantizedTensor:
    """SLiM-Quant^W: per-tensor symmetric quantization with the Alg.-1 scale."""
    if n_bins is None:
        n_bins = histogram_bins_for(w.shape)
    p, centers = weight_abs_histogram(w, n_bins)
    alpha = slim_quant_alpha(p, centers, bits=bits, levels=levels, grid=grid)
    codes = quantize_symmetric(w, alpha, bits)
    return QuantizedTensor(codes=codes, scale=alpha, bits=bits, group_size=0)


def estimate_error_curve(
    w: jnp.ndarray, alphas: jnp.ndarray, bits: int = 4, n_bins: Optional[int] = None
) -> jnp.ndarray:
    """Expose E_Q(alpha) on a user grid (for tests / Fig.-style analyses)."""
    if n_bins is None:
        n_bins = histogram_bins_for(w.shape)
    p, centers = weight_abs_histogram(w, n_bins)
    return _quant_error_at(alphas, p, centers, bits)


# ---------------------------------------------------------------------------
# Activation-aware SLiM-Quant^O (paper §3.1 "Activation-aware SLiM-Quant")
#
# Channel saliency = |diag(x_bar) . W| -> per-input-channel score
#   s_c = mean|x[:, c]| * mean|W[c, :]|    (product of normalized magnitudes)
# Top `frac` channels get weights scaled *up* by `s` and activations scaled
# *down* by 1/s: computationally equivalent, but the salient channels occupy
# more quantization levels, cutting their error. ~1% of channels leaves the
# global alpha essentially unchanged (paper's observation).
# ---------------------------------------------------------------------------

def channel_saliency(x_absmean: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """x_absmean[d_in] (calibration mean |x|), W[d_in, d_out] -> score[d_in]."""
    xm = x_absmean / jnp.maximum(jnp.mean(x_absmean), 1e-12)
    wm = jnp.mean(jnp.abs(w), axis=1)
    wm = wm / jnp.maximum(jnp.mean(wm), 1e-12)
    return xm * wm


def awq_channel_scales(
    x_absmean: jnp.ndarray,
    w: jnp.ndarray,
    frac: float = 0.01,
    s: float = 2.0,
) -> jnp.ndarray:
    """Per-input-channel weight multiplier (1 everywhere except top-frac -> s)."""
    score = channel_saliency(x_absmean, w)
    d_in = score.shape[0]
    k = max(1, int(round(frac * d_in)))
    thresh = jnp.sort(score)[-k]
    return jnp.where(score >= thresh, jnp.float32(s), jnp.float32(1.0))


def slim_quantize_activation_aware(
    w: jnp.ndarray,
    x_absmean: jnp.ndarray,
    bits: int = 4,
    frac: float = 0.01,
    s_grid: Tuple[float, ...] = (1.5, 2.0, 4.0),
    n_bins: Optional[int] = None,
) -> Tuple[QuantizedTensor, jnp.ndarray]:
    """SLiM-Quant^O. Returns (qtensor of scaled weights, act_scale[d_in]).

    The compressed layer must divide incoming activations by ``act_scale``
    (equivalently multiply by 1/act_scale); dequantize() then reproduces the
    *scaled* weights, so ``(x / act_scale) @ dequant`` approximates ``x @ W``.
    Picks s from `s_grid` by weighted reconstruction error (cheap proxy for
    the output error that AWQ grid-searches).
    """
    if n_bins is None:
        n_bins = histogram_bins_for(w.shape)

    best = None
    for s in s_grid:
        cs = awq_channel_scales(x_absmean, w, frac=frac, s=s)
        w_scaled = w * cs[:, None]
        p, centers = weight_abs_histogram(w_scaled, n_bins)
        alpha = slim_quant_alpha(p, centers, bits=bits)
        codes = quantize_symmetric(w_scaled, alpha, bits)
        qt = QuantizedTensor(codes=codes, scale=alpha, bits=bits, group_size=0)
        # Saliency-weighted error: || diag(x) (W_hat/cs - W) ||^2
        w_hat = qt.dequantize() / cs[:, None]
        err = jnp.sum((x_absmean[:, None] * (w_hat - w)) ** 2)
        if best is None or float(err) < best[0]:
            best = (float(err), qt, cs)
    _, qt, cs = best
    return qt, cs
