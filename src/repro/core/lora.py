"""One-shot low-rank error compensation: Naive-LoRA, SLiM-LoRA (Alg. 2),
L2QER-style quant-only adapters, and adapter group-quantization (§3.3).

The compressed layer computes ``y = x @ W^C + (x @ L) @ R`` with
``L[d_in, r], R[r, d_out]`` chosen so ``L R ~ W - W^C`` — exactly, in the
case of SLiM-LoRA, under the saliency norm ``||diag(x) . ||_F``:

    diag(x) L , R = SVD_r( diag(x) (W - W^C) )          (paper Eq. 11)

with ``x = mean|X| + min(mean|X|)`` (Alg. 2 line 5 — the shift keeps the
saliency function invertible when activations are ~0).

SVD backends: exact ``jnp.linalg.svd`` and a randomized subspace-iteration
SVD (Halko et al.) — the paper computes full SVDs (Tbl 21 shows its cost
dominating compression time); the randomized variant is our beyond-paper
compression-time optimization, exact up to the usual (tall, incoherent)
randomized-SVD tolerance and ~10x faster at r = 0.1 d.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import (
    QuantizedTensor,
    group_absmax_quantize,
    dequantize,
)


# ---------------------------------------------------------------------------
# SVD backends
# ---------------------------------------------------------------------------

def _svd_exact(a: jnp.ndarray, rank: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    u_r = u[:, :rank] * s[:rank][None, :]
    return u_r, vt[:rank]


def _svd_randomized(
    a: jnp.ndarray, rank: int, oversample: int = 8, iters: int = 2, seed: int = 0
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Halko-Martinsson-Tropp randomized SVD with power iteration."""
    m, n = a.shape
    k = min(rank + oversample, min(m, n))
    omega = jax.random.normal(jax.random.PRNGKey(seed), (n, k), dtype=a.dtype)
    y = a @ omega
    for _ in range(iters):
        y, _ = jnp.linalg.qr(a @ (a.T @ y))
    q, _ = jnp.linalg.qr(y)
    b = q.T @ a  # [k, n]
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    u_r = u[:, :rank] * s[:rank][None, :]
    return u_r, vt[:rank]


def lowrank_factor(
    a: jnp.ndarray, rank: int, method: str = "exact", seed: int = 0
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Best rank-`rank` factorization a ~ L @ R (Frobenius-optimal)."""
    if method == "exact":
        return _svd_exact(a, rank)
    if method == "randomized":
        return _svd_randomized(a, rank, seed=seed)
    raise ValueError(f"unknown svd method {method}")


# ---------------------------------------------------------------------------
# Adapters
# ---------------------------------------------------------------------------

def naive_lora(
    w: jnp.ndarray, w_c: jnp.ndarray, rank: int, method: str = "exact"
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Naive-LoRA: L R = SVD_r(W - W^C) — ignores element saliency."""
    err = (w - w_c).astype(jnp.float32)
    return lowrank_factor(err, rank, method)


def shift_activation_mean(x_absmean: jnp.ndarray) -> jnp.ndarray:
    """Alg. 2 line 5: x = x_tilde + min(|x_tilde|), guaranteeing x > 0."""
    x = jnp.abs(x_absmean)
    return x + jnp.min(x) + 1e-8


def slim_lora(
    w: jnp.ndarray,
    w_c: jnp.ndarray,
    x_absmean: jnp.ndarray,
    rank: int,
    method: str = "exact",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SLiM-LoRA (Alg. 2): saliency-weighted optimal adapters.

    S_C = diag(x)(W - W^C); Ltil, R = SVD_r(S_C); L = diag(1/x) Ltil.
    The result minimizes ||diag(x)(W - (W^C + L R))||_F over rank-r L R —
    the invertibility+additivity of F(W)=diag(x)W makes this exact (Eq. 9-11).
    """
    x = shift_activation_mean(x_absmean).astype(jnp.float32)
    err = (w - w_c).astype(jnp.float32)
    s_c = x[:, None] * err
    l_tilde, r = lowrank_factor(s_c, rank, method)
    l = l_tilde / x[:, None]
    return l, r


def l2qer_lora(
    w: jnp.ndarray,
    w_q: jnp.ndarray,
    x_absmean: jnp.ndarray,
    rank: int,
    method: str = "exact",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """L2QER-style baseline: adapters compensate the *quantization* error only
    (pre-sparsity) with activation scaling — the paper shows this degrades
    when combined with pruning because E_S is never seen by the adapter."""
    return slim_lora(w, w_q, x_absmean, rank, method)


# ---------------------------------------------------------------------------
# Adapter quantization (§3.3): group AbsMax, group=128; long-tailed adapter
# distributions favor group scales over SLiM-Quant here (paper's finding).
# ---------------------------------------------------------------------------

def quantize_adapters(
    l: jnp.ndarray, r: jnp.ndarray, bits: int = 4, group_size: int = 128
) -> Tuple[QuantizedTensor, QuantizedTensor]:
    def _q(a: jnp.ndarray) -> QuantizedTensor:
        d0 = a.shape[0]
        if d0 % group_size == 0:
            return group_absmax_quantize(a, bits=bits, group_size=group_size)
        # rank dim rarely divides 128; fall back to per-tensor for that factor
        from repro.core.quantizers import absmax_quantize

        return absmax_quantize(a, bits=bits)

    return _q(l), _q(r)


def dequantize_adapters(
    lq: QuantizedTensor, rq: QuantizedTensor
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    return dequantize(lq), dequantize(rq)


def default_rank(d_in: int, ratio: float = 0.1, multiple: int = 8) -> int:
    """Paper §T: rank = 10% of hidden dim; round to a lane-friendly multiple."""
    r = max(multiple, int(round(d_in * ratio)))
    return (r + multiple - 1) // multiple * multiple


def saliency_error(
    w: jnp.ndarray,
    w_c: jnp.ndarray,
    l: Optional[jnp.ndarray],
    r: Optional[jnp.ndarray],
    x_absmean: jnp.ndarray,
) -> jnp.ndarray:
    """||diag(x)(W - (W^C + LR))||_F^2 — the Eq. 8 objective (for tests)."""
    x = shift_activation_mean(x_absmean)
    approx = w_c if l is None else w_c + l @ r
    return jnp.sum((x[:, None] * (w - approx)) ** 2)
