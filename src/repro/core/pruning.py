"""One-shot pruning: Wanda, magnitude, SparseGPT, and N:M structured masks.

Paper context (§3.2): after SLiM-Quant, SLiM sparsifies the *quantized*
weights with an off-the-shelf one-shot pruner — Wanda by default. We also
implement the paper's comparison baselines (magnitude, SparseGPT with OBS
weight updates, and a JSQ-lite joint prune+quant) so the benchmark tables can
reproduce the paper's method grid.

Mask conventions: W[d_in, d_out]; mask==1 keeps a weight. N:M structure is
along the **contraction dim d_in** (groups of M consecutive input channels
per output), which is what 2:4 hardware — and our Pallas sparse24 kernel —
consumes.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.quantizers import QuantizedTensor, _qmax


# ---------------------------------------------------------------------------
# Saliency scores
# ---------------------------------------------------------------------------

def wanda_saliency(w: jnp.ndarray, x_l2: jnp.ndarray) -> jnp.ndarray:
    """Wanda: |W_ij| * ||x_i||_2  (x_l2[d_in] = per-channel L2 over calib)."""
    return jnp.abs(w) * x_l2[:, None]


def magnitude_saliency(w: jnp.ndarray) -> jnp.ndarray:
    return jnp.abs(w)


# ---------------------------------------------------------------------------
# Mask construction
# ---------------------------------------------------------------------------

def nm_mask(saliency: jnp.ndarray, n: int = 2, m: int = 4) -> jnp.ndarray:
    """Keep the top-`n` of every `m` consecutive input channels, per output."""
    d_in, d_out = saliency.shape
    if d_in % m != 0:
        raise ValueError(f"d_in={d_in} not divisible by m={m}")
    s = saliency.reshape(d_in // m, m, d_out)
    # rank within each group: keep the n largest.
    order = jnp.argsort(s, axis=1)  # ascending
    ranks = jnp.argsort(order, axis=1)
    mask = (ranks >= (m - n)).astype(saliency.dtype)
    return mask.reshape(d_in, d_out)


def unstructured_mask(saliency: jnp.ndarray, sparsity: float = 0.5) -> jnp.ndarray:
    """Per-output (column) top-k mask — Wanda's comparison group."""
    d_in, d_out = saliency.shape
    k = int(round(d_in * (1.0 - sparsity)))
    k = max(1, min(d_in, k))
    order = jnp.argsort(saliency, axis=0)
    ranks = jnp.argsort(order, axis=0)
    return (ranks >= (d_in - k)).astype(saliency.dtype)


def make_mask(
    saliency: jnp.ndarray,
    sparsity: float = 0.5,
    pattern: str = "unstructured",
) -> jnp.ndarray:
    """pattern in {"unstructured", "2:4", "1:4", "4:8", ...}."""
    if pattern == "unstructured":
        return unstructured_mask(saliency, sparsity)
    n_s, m_s = pattern.split(":")
    return nm_mask(saliency, n=int(n_s), m=int(m_s))


def wanda_prune(
    w: jnp.ndarray,
    x_l2: jnp.ndarray,
    sparsity: float = 0.5,
    pattern: str = "2:4",
) -> jnp.ndarray:
    return make_mask(wanda_saliency(w, x_l2), sparsity, pattern)


def magnitude_prune(
    w: jnp.ndarray, sparsity: float = 0.5, pattern: str = "2:4"
) -> jnp.ndarray:
    return make_mask(magnitude_saliency(w), sparsity, pattern)


# ---------------------------------------------------------------------------
# SparseGPT — Hessian-aware pruning with OBS weight updates.
#
# Processes d_in sequentially through the upper-Cholesky factor U of
# Hinv = (X^T X + damp I)^{-1}; pruning weight row i injects the OBS
# correction -(w_i / U_ii) * U_{i, i+1:} into the remaining rows. For N:M the
# mask decision is made per group of M rows using the standard saliency
# w^2 / diag(Hinv)^2 evaluated on the *updated* weights at group entry
# (SparseGPT's blocked lookahead, block = the N:M group).
# ---------------------------------------------------------------------------

def _hinv_chol(hessian: jnp.ndarray, percdamp: float = 0.01) -> jnp.ndarray:
    d = hessian.shape[0]
    damp = percdamp * jnp.mean(jnp.diag(hessian)) + 1e-8
    h = hessian + damp * jnp.eye(d, dtype=hessian.dtype)
    hinv = jnp.linalg.inv(h)
    return jnp.linalg.cholesky(hinv, upper=True)


def sparsegpt_prune(
    w: jnp.ndarray,
    hessian: jnp.ndarray,
    sparsity: float = 0.5,
    pattern: str = "2:4",
    percdamp: float = 0.01,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (w_pruned[d_in,d_out] with updates applied, mask)."""
    d_in, d_out = w.shape
    u = _hinv_chol(hessian.astype(jnp.float32), percdamp)
    diag_u = jnp.diag(u)  # U_ii = sqrt(Hinv_ii) under this factorization

    if pattern == "unstructured":
        # Global mask from initial saliency (one-shot variant), then a single
        # sequential OBS update pass for pruned rows.
        sal = (w ** 2) / (diag_u[:, None] ** 2 + 1e-12)
        mask = unstructured_mask(sal, sparsity)
        m_groups = 1
    else:
        n_s, m_s = pattern.split(":")
        n_keep, m = int(n_s), int(m_s)
        mask = None
        m_groups = m

    def unstruct_body(i, carry):
        w_work = carry
        keep = mask[i]
        row = w_work[i]
        pruned_vals = row * (1.0 - keep)
        err = pruned_vals / diag_u[i]
        below = (jnp.arange(d_in) > i).astype(w_work.dtype)[:, None]
        w_work = w_work - below * jnp.outer(u[i], err)
        w_work = w_work.at[i].set(row * keep)
        return w_work

    if pattern == "unstructured":
        w_out = jax.lax.fori_loop(0, d_in, unstruct_body, w.astype(jnp.float32))
        return w_out, mask

    # N:M path — scan over groups of m rows.
    n_groups = d_in // m_groups

    def group_body(g, carry):
        w_work, mask_acc = carry
        i0 = g * m_groups
        rows = jax.lax.dynamic_slice(w_work, (i0, 0), (m_groups, d_out))
        dvals = jax.lax.dynamic_slice(diag_u, (i0,), (m_groups,))
        sal = (rows ** 2) / (dvals[:, None] ** 2 + 1e-12)
        order = jnp.argsort(sal, axis=0)
        ranks = jnp.argsort(order, axis=0)
        keep = (ranks >= (m_groups - n_keep)).astype(w_work.dtype)

        def row_body(k, w_in):
            i = i0 + k
            row = jax.lax.dynamic_slice(w_in, (i, 0), (1, d_out))[0]
            pruned_vals = row * (1.0 - keep[k])
            err = pruned_vals / diag_u[i]
            below = (jnp.arange(d_in) > i).astype(w_in.dtype)[:, None]
            w_in = w_in - below * jnp.outer(u[i], err)
            w_in = jax.lax.dynamic_update_slice(
                w_in, (row * keep[k])[None, :], (i, 0)
            )
            return w_in

        w_work = jax.lax.fori_loop(0, m_groups, row_body, w_work)
        mask_acc = jax.lax.dynamic_update_slice(mask_acc, keep, (i0, 0))
        return w_work, mask_acc

    mask0 = jnp.zeros((d_in, d_out), dtype=jnp.float32)
    w_out, mask = jax.lax.fori_loop(
        0, n_groups, group_body, (w.astype(jnp.float32), mask0)
    )
    return w_out, mask


# ---------------------------------------------------------------------------
# JSQ-lite: joint sparsification + quantization baseline (Guo et al. 2024,
# simplified). Prunes by activation-aware saliency and quantizes the
# survivors with a clipped absmax whose clip range is chosen to minimize the
# masked reconstruction error — a single joint objective, no adapters.
# ---------------------------------------------------------------------------

def jsq_compress(
    w: jnp.ndarray,
    x_l2: jnp.ndarray,
    bits: int = 4,
    sparsity: float = 0.5,
    pattern: str = "2:4",
    n_clip_grid: int = 32,
) -> Tuple[QuantizedTensor, jnp.ndarray]:
    mask = wanda_prune(w, x_l2, sparsity, pattern)
    w_m = w * mask
    wmax = jnp.max(jnp.abs(w_m))
    half = 2 ** (bits - 1)
    qmax = _qmax(bits)
    alphas = jnp.linspace(wmax / n_clip_grid, wmax, n_clip_grid)

    def err_for(a):
        codes = jnp.clip(jnp.round(jnp.clip(w_m / a, -1, 1) * half), -qmax, qmax)
        deq = codes * a / half
        return jnp.sum(((deq - w_m) * mask) ** 2)

    errs = jax.vmap(err_for)(alphas)
    alpha = alphas[jnp.argmin(errs)]
    codes = jnp.clip(
        jnp.round(jnp.clip(w_m / alpha, -1, 1) * half), -qmax, qmax
    ).astype(jnp.int8)
    qt = QuantizedTensor(codes=codes, scale=alpha.astype(jnp.float32), bits=bits, group_size=0)
    return qt, mask


def check_nm(mask: jnp.ndarray, n: int = 2, m: int = 4) -> bool:
    """Invariant: exactly n survivors in every m-group (used by tests)."""
    d_in, d_out = mask.shape
    g = mask.reshape(d_in // m, m, d_out).sum(axis=1)
    return bool(jnp.all(g == n))
