"""The SLiM one-shot compression pipeline (paper Fig. 1).

Per weight matrix, in order:
  (1) optional activation-aware channel scaling  (SLiM-Quant^O)
  (2) quantization      -> W^Q,  E_Q            (SLiM-Quant / baselines)
  (3) pruning on W^Q    -> W^C,  E_S            (Wanda / baselines)
  (4) closed-form adapters for E_Q + E_S         (SLiM-LoRA / Naive-LoRA)
  (5) optional adapter quantization              (SLiM-LoRA^Q)
  (6) pack to the deployed layout                (core.packing)

``compress_matrix`` is the single-tensor unit; the model-level drivers in
``repro.models.compress`` walk a parameter tree, feeding each linear its
calibration statistics (sequentially, so layer k's stats reflect layers <k
already compressed — the OBS convention SparseGPT/Wanda use).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import lora as lora_lib
from repro.core import pruning as prune_lib
from repro.core import quantizers as q_lib
from repro.core import slim_quant as sq_lib
from repro.core.compressed import SlimLinear, build_slim_linear


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CalibStats:
    """Per-linear calibration statistics accumulated over the calib set."""

    x_absmean: jnp.ndarray  # [d_in]  mean |x|
    x_sqsum: jnp.ndarray  # [d_in]  sum x^2  (Wanda's ||x||_2 = sqrt of this)
    count: jnp.ndarray  # () number of rows accumulated
    hessian: Optional[jnp.ndarray] = None  # [d_in, d_in] sum X^T X (OPTQ/SparseGPT)

    def tree_flatten(self):
        return (self.x_absmean, self.x_sqsum, self.count, self.hessian), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def x_l2(self) -> jnp.ndarray:
        return jnp.sqrt(self.x_sqsum)

    @staticmethod
    def init(d_in: int, with_hessian: bool = False) -> "CalibStats":
        return CalibStats(
            x_absmean=jnp.zeros((d_in,), jnp.float32),
            x_sqsum=jnp.zeros((d_in,), jnp.float32),
            count=jnp.zeros((), jnp.float32),
            hessian=jnp.zeros((d_in, d_in), jnp.float32) if with_hessian else None,
        )

    def update(self, x: jnp.ndarray) -> "CalibStats":
        """x: [..., d_in] calibration activations for this linear."""
        x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
        n = x2.shape[0]
        new_count = self.count + n
        new_absmean = (self.x_absmean * self.count + jnp.sum(jnp.abs(x2), axis=0)) / new_count
        new_sqsum = self.x_sqsum + jnp.sum(x2 ** 2, axis=0)
        h = self.hessian
        if h is not None:
            h = h + x2.T @ x2
        return CalibStats(new_absmean, new_sqsum, new_count, h)


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """Method grid matching the paper's Table 4 notation."""

    bits: int = 4
    quantizer: str = "slim"  # slim | slim_o | absmax | group_absmax | optq | none
    group_size: int = 128  # for group quantizers
    awq_frac: float = 0.01
    sparsity: float = 0.5
    pattern: str = "2:4"  # 2:4 | unstructured | none
    pruner: str = "wanda"  # wanda | magnitude | sparsegpt | jsq | none
    adapter: str = "slim"  # slim | naive | l2qer | none
    rank_ratio: float = 0.1
    rank: Optional[int] = None  # overrides rank_ratio when set
    quantize_adapters: bool = False
    adapter_bits: int = 4
    adapter_group: int = 128
    # deployment: store adapters nibble-packed int4 (frozen; serving only)
    pack_adapters: bool = False
    svd_method: str = "exact"  # exact | randomized
    param_dtype: str = "float32"

    @property
    def needs_hessian(self) -> bool:
        return self.pruner == "sparsegpt" or self.quantizer == "optq"

    def resolve_rank(self, d_in: int) -> int:
        if self.rank is not None:
            return self.rank
        return lora_lib.default_rank(d_in, self.rank_ratio)


@dataclasses.dataclass
class CompressionReport:
    """Error decomposition for one matrix (feeds the benchmark tables)."""

    quant_err: float  # ||E_Q||_F^2
    sparse_err: float  # ||E_S||_F^2
    total_err_before: float  # ||W - W^C||_F^2
    total_err_after: float  # ||W - (W^C + LR)||_F^2
    saliency_err_before: float
    saliency_err_after: float


def _quantize(w, stats: CalibStats, cfg: CompressionConfig):
    """Returns (qt: QuantizedTensor, act_channel_scale or None)."""
    if cfg.quantizer == "slim":
        return sq_lib.slim_quantize(w, bits=cfg.bits), None
    if cfg.quantizer == "slim_o":
        qt, cs = sq_lib.slim_quantize_activation_aware(
            w, stats.x_absmean, bits=cfg.bits, frac=cfg.awq_frac
        )
        return qt, cs
    if cfg.quantizer == "absmax":
        return q_lib.absmax_quantize(w, bits=cfg.bits), None
    if cfg.quantizer == "group_absmax":
        return q_lib.group_absmax_quantize(w, bits=cfg.bits, group_size=cfg.group_size), None
    if cfg.quantizer == "optq":
        assert stats.hessian is not None, "OPTQ needs calibration Hessian"
        return (
            q_lib.optq_quantize(w, stats.hessian, bits=cfg.bits, group_size=cfg.group_size),
            None,
        )
    raise ValueError(f"unknown quantizer {cfg.quantizer}")


def _prune_mask(w_q_deq, stats: CalibStats, cfg: CompressionConfig, cs=None):
    if cfg.pattern == "none" or cfg.pruner == "none":
        return None
    x_l2 = stats.x_l2
    if cs is not None:
        x_l2 = x_l2 / cs  # deployment activations are x/cs
    if cfg.pruner == "wanda":
        return prune_lib.wanda_prune(w_q_deq, x_l2, cfg.sparsity, cfg.pattern)
    if cfg.pruner == "magnitude":
        return prune_lib.magnitude_prune(w_q_deq, cfg.sparsity, cfg.pattern)
    if cfg.pruner == "sparsegpt":
        assert stats.hessian is not None
        _, mask = prune_lib.sparsegpt_prune(
            w_q_deq, stats.hessian, cfg.sparsity, cfg.pattern
        )
        return mask
    raise ValueError(f"unknown pruner {cfg.pruner}")


def compress_matrix(
    w: jnp.ndarray, stats: CalibStats, cfg: CompressionConfig
) -> Tuple[SlimLinear, CompressionReport]:
    """Full SLiM pipeline on one W[d_in, d_out]."""
    w = w.astype(jnp.float32)
    d_in, d_out = w.shape

    # (1)+(2) quantize (optionally activation-aware)
    if cfg.quantizer == "none":
        qt = None
        cs = None
        w_q = w
    else:
        qt, cs = _quantize(w, stats, cfg)
        # dequantized *in original space* (undo channel scaling if any)
        w_q = qt.dequantize()
        if cs is not None:
            w_q = w_q / cs[:, None]
    e_q = w_q - w

    # (3) prune the quantized weights
    mask = _prune_mask(w_q, stats, cfg, cs)
    if mask is None:
        w_c = w_q
        mask_eff = jnp.ones_like(w)
    else:
        w_c = w_q * mask
        mask_eff = mask
    e_s = w_c - w_q

    # (4) adapters for the aggregate error
    rank = cfg.resolve_rank(d_in)
    if cfg.adapter == "none":
        l = r = None
    elif cfg.adapter == "naive":
        l, r = lora_lib.naive_lora(w, w_c, rank, cfg.svd_method)
    elif cfg.adapter == "slim":
        l, r = lora_lib.slim_lora(w, w_c, stats.x_absmean, rank, cfg.svd_method)
    elif cfg.adapter == "l2qer":
        # compensates E_Q only (the paper's L2QER comparison): sparsity error
        # is invisible to the adapter.
        l, r = lora_lib.slim_lora(w, w_q, stats.x_absmean, rank, cfg.svd_method)
    else:
        raise ValueError(f"unknown adapter {cfg.adapter}")

    # (5)+(6) pack
    if qt is None:
        # Sparse-only mode: quantize losslessly-ish to int8-as-int4 is wrong;
        # keep a dense int4 of absmax for layout uniformity is also wrong.
        # For quantizer=none we fall back to absmax codes at 7 bits of int8.
        qt = q_lib.absmax_quantize(w, bits=8)
        bits, gs = 8, 0
        codes = qt.codes
        scale = qt.scale
        fmt_pattern = cfg.pattern if cfg.pattern == "2:4" else "unstructured"
    else:
        bits, gs = qt.bits, qt.group_size
        codes = qt.codes
        scale = qt.scale
        fmt_pattern = cfg.pattern

    if fmt_pattern == "2:4" and mask is not None and bits <= 4:
        pattern_for_pack = "2:4"
    else:
        pattern_for_pack = "unstructured"
        if bits > 4:
            # int8 codes cannot nibble-pack; widen via two nibbles is out of
            # scope — store as two int4 halves is overkill; use dense int4 of
            # the high nibble would lose data. Instead re-quantize to 4 bits.
            qt4 = q_lib.absmax_quantize(w_c, bits=4)
            codes, scale, bits, gs = qt4.codes, qt4.scale, 4, 0

    p = build_slim_linear(
        codes=codes,
        mask=mask_eff if mask is not None else None,
        scale=scale,
        bits=bits,
        group_size=gs,
        pattern=pattern_for_pack,
        act_channel_scale=cs,
        lora_l=l,
        lora_r=r,
        adapter_bits=cfg.adapter_bits if (cfg.quantize_adapters or cfg.pack_adapters) else 0,
        adapter_group=cfg.adapter_group,
        param_dtype=getattr(jnp, cfg.param_dtype),
        pack_adapters=cfg.pack_adapters,
    )

    lr = None if l is None else l @ r
    approx_after = w_c if lr is None else w_c + lr
    report = CompressionReport(
        quant_err=float(jnp.sum(e_q ** 2)),
        sparse_err=float(jnp.sum(e_s ** 2)),
        total_err_before=float(jnp.sum((w - w_c) ** 2)),
        total_err_after=float(jnp.sum((w - approx_after) ** 2)),
        saliency_err_before=float(
            lora_lib.saliency_error(w, w_c, None, None, stats.x_absmean)
        ),
        saliency_err_after=float(
            lora_lib.saliency_error(w, w_c, l, r, stats.x_absmean)
        ),
    )
    return p, report
