"""Deployed SLiM tensor format + functional forward.

``SlimLinear`` is the parameter pytree a compressed matmul carries through
pjit: packed int4 (optionally 2:4-compressed) base weights, the SLiM-Quant
scale, optional AWQ activation scaling, and the (optionally group-quantized)
SLiM-LoRA factors. ``slim_linear_apply`` is the XLA execution path (unpack ->
dequant -> dense dot) used everywhere in the model zoo; the Pallas kernels in
``repro.kernels`` implement the same contract for the TPU hot path and are
checked against this module's semantics.

Byte accounting (per original weight position, r = 0.1 d, adapters 4-bit):
  dense bf16      16.0 bits
  dense int4       4.0 bits (+ scalar scale)
  2:4 + int4       3.0 bits (2 survivors x 4b + 2 x 2b metadata per 4)
  + adapters       ~0.8-1.7 bits amortized  -> the paper's ~0.18-0.23x totals.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.packing import (
    pack_int4,
    unpack_int4,
    pack_dense_24,
    unpack_dense_24,
)
from repro.core.quantizers import fit_group_size, quantize_symmetric
from repro.core.ste import ste_quantize


_SLIM_FIELDS = (
    "packed_vals",
    "packed_idx",
    "scale",
    "inv_act_scale",
    "lora_l",
    "lora_r",
    "lora_scale_l",
    "lora_scale_r",
)


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass
class SlimLinear:
    """Compressed linear layer parameters. y = act(x) @ W_hat + (x @ L) @ R."""

    packed_vals: jnp.ndarray  # uint8; sparse24: [d_in/4, d_out]; dense4: [d_in/2, d_out]
    packed_idx: Optional[jnp.ndarray]  # uint8 [d_in/8, d_out] iff sparse24
    scale: jnp.ndarray  # () per-tensor or [d_in//g, 1, d_out] group
    inv_act_scale: Optional[jnp.ndarray]  # [d_in] (1/s per channel) iff AWQ
    lora_l: Optional[jnp.ndarray]  # [d_in, r] float (STE-qdq'd at use) OR
    #   uint8 nibble-packed [d_in/2, r] when deployed packed (serving)
    lora_r: Optional[jnp.ndarray]  # [r, d_out] float OR uint8 [r/2, d_out]
    lora_scale_l: Optional[jnp.ndarray] = None  # group scales iff packed
    lora_scale_r: Optional[jnp.ndarray] = None
    # -- static --
    d_in: int = 0
    d_out: int = 0
    bits: int = 4
    group_size: int = 0
    fmt: str = "sparse24"  # "sparse24" | "dense_int4"
    adapter_bits: int = 0  # 0 = fp adapters; >0 = STE group-quantized at use
    adapter_group: int = 128

    def _aux(self):
        return (
            self.d_in,
            self.d_out,
            self.bits,
            self.group_size,
            self.fmt,
            self.adapter_bits,
            self.adapter_group,
        )

    def tree_flatten_with_keys(self):
        children = tuple(
            (jax.tree_util.GetAttrKey(f), getattr(self, f)) for f in _SLIM_FIELDS
        )
        return children, self._aux()

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in _SLIM_FIELDS), self._aux()

    @classmethod
    def tree_unflatten(cls, aux, children):
        pv, pi, sc, ias, l, r, lsl, lsr = children
        d_in, d_out, bits, gs, fmt, ab, ag = aux
        return cls(pv, pi, sc, ias, l, r, lsl, lsr, d_in, d_out, bits, gs, fmt, ab, ag)

    @property
    def shape(self) -> Tuple[int, int]:
        return (self.d_in, self.d_out)

    def packed_bytes(self) -> int:
        n = int(self.packed_vals.size)
        if self.packed_idx is not None:
            n += int(self.packed_idx.size)
        n += int(self.scale.size) * 4
        if self.inv_act_scale is not None:
            n += int(self.inv_act_scale.size) * 4
        for a, sc in ((self.lora_l, self.lora_scale_l), (self.lora_r, self.lora_scale_r)):
            if a is None:
                continue
            if a.dtype == jnp.uint8:  # nibble-packed deployment
                n += int(a.size) + int(sc.size) * 4
            else:
                bits = self.adapter_bits if self.adapter_bits else 16
                n += int(a.size) * bits // 8
        return n


def dequantize_base(p: SlimLinear, dtype=jnp.float32) -> jnp.ndarray:
    """Unpack + dequantize the base weights -> dense [..., d_in, d_out].

    Supports arbitrary leading dims (scan-stacked layers, MoE expert stacks):
    packed arrays are [..., packed, d_out]; per-tensor scales broadcast from
    the leading dims, group scales from [..., d_in//g, 1, d_out].
    """
    if p.fmt == "sparse24":
        codes = unpack_dense_24(p.packed_vals, p.packed_idx, p.d_in)
    elif p.fmt == "dense_int4":
        codes = unpack_int4(p.packed_vals)
    else:
        raise ValueError(f"unknown fmt {p.fmt}")
    half = 2 ** (p.bits - 1)
    if p.group_size == 0:
        scale = jnp.asarray(p.scale)
        scale = scale.reshape(*scale.shape, 1, 1) if scale.ndim else scale
        w = codes.astype(jnp.float32) * (scale / half)
    else:
        g = p.group_size
        lead = codes.shape[:-2]
        grouped = codes.reshape(*lead, p.d_in // g, g, p.d_out).astype(jnp.float32)
        w = (grouped * (p.scale / half)).reshape(*lead, p.d_in, p.d_out)
    return w.astype(dtype)


def _dequant_packed_adapter(packed, scales, bits, dtype):
    """uint8 nibble-packed [..., dim/2, other] + group scales
    [..., dim/g, 1, other] -> dense [..., dim, other]."""
    codes = unpack_int4(packed)
    *lead, dim, other = codes.shape
    half = 2 ** (bits - 1)
    g = dim // scales.shape[-3]
    grouped = codes.reshape(*lead, dim // g, g, other).astype(jnp.float32)
    return (grouped * (scales / half)).reshape(*lead, dim, other).astype(dtype)


def adapter_factors(p: SlimLinear, dtype=jnp.float32):
    """Materialize (L, R) from whatever storage the layer uses."""
    l, r = p.lora_l, p.lora_r
    if l is None:
        return None, None
    if l.dtype == jnp.uint8:  # packed int4 deployment (serving)
        bits = p.adapter_bits or 4
        l = _dequant_packed_adapter(l, p.lora_scale_l, bits, dtype)
        r = _dequant_packed_adapter(r, p.lora_scale_r, bits, dtype)
        return l, r
    if p.adapter_bits:  # PEFT: float master weights, STE-quantized at use
        l = ste_quantize(l, p.adapter_bits, p.adapter_group)
        r = ste_quantize(r, p.adapter_bits, p.adapter_group)
    return l.astype(dtype), r.astype(dtype)


def slim_linear_apply(
    p: SlimLinear, x: jnp.ndarray, compute_dtype=jnp.float32,
    skip_lora: bool = False,
) -> jnp.ndarray:
    """y = (x * inv_act_scale) @ W_hat + (x @ L) @ R.

    Adapters consume the *original* activations (AWQ scaling only compensates
    the scaled base weights); matches repro.kernels.*.ref oracles.

    ``skip_lora=True`` drops the low-rank correction and computes only the
    quantized-sparse *backbone* ``(x * inv_act_scale) @ W_hat`` — the same
    parameters driving a strictly cheaper forward pass. This is the draft
    model of self-speculative decoding (serving/speculative.py): the
    backbone is the compressed weight *before* error compensation, so its
    argmax agrees with the full layer most of the time while skipping the
    adapter dequantization and both LoRA matmuls.
    """
    w = dequantize_base(p, compute_dtype)
    xs = x if p.inv_act_scale is None else x * p.inv_act_scale.astype(x.dtype)
    y = jnp.dot(xs.astype(compute_dtype), w, preferred_element_type=compute_dtype)
    if skip_lora:
        return y
    l, r = adapter_factors(p, compute_dtype)
    if l is not None:
        y = y + jnp.dot(jnp.dot(x.astype(compute_dtype), l), r)
    return y


def build_slim_linear(
    codes: jnp.ndarray,
    mask: Optional[jnp.ndarray],
    scale: jnp.ndarray,
    bits: int,
    group_size: int,
    pattern: str,
    act_channel_scale: Optional[jnp.ndarray] = None,
    lora_l: Optional[jnp.ndarray] = None,
    lora_r: Optional[jnp.ndarray] = None,
    adapter_bits: int = 0,
    adapter_group: int = 128,
    param_dtype=jnp.float32,
    pack_adapters: bool = False,
) -> SlimLinear:
    """Assemble the deployed layout from compression-pipeline outputs.

    pack_adapters: store L/R as nibble-packed int4 with group-absmax scales
    (the frozen serving deployment — 4x smaller than bf16 adapters; not
    PEFT-trainable)."""
    d_in, d_out = codes.shape
    if pattern == "2:4":
        pv, pi = pack_dense_24(codes, mask)
        fmt = "sparse24"
    else:
        # unstructured / no sparsity: zeros stay in the dense int4 stream
        masked = codes if mask is None else (codes * mask.astype(codes.dtype))
        pv, pi = pack_int4(masked.astype(jnp.int8)), None
        fmt = "dense_int4"
    inv_as = None
    if act_channel_scale is not None:
        inv_as = (1.0 / act_channel_scale).astype(param_dtype)

    lsl = lsr = None
    if lora_l is not None and pack_adapters:
        abits = adapter_bits or 4

        def _pack(a):
            dim = a.shape[-2]
            g = fit_group_size(dim, adapter_group)
            grouped = a.reshape(dim // g, g, a.shape[-1])
            sc = jnp.max(jnp.abs(grouped), axis=1, keepdims=True)
            sc = jnp.where(sc <= 0, 1.0, sc).astype(jnp.float32)
            qcodes = quantize_symmetric(grouped, sc, abits).reshape(dim, a.shape[-1])
            return pack_int4(qcodes), sc

        lora_l, lsl = _pack(lora_l.astype(jnp.float32))
        lora_r, lsr = _pack(lora_r.astype(jnp.float32))
        adapter_bits = abits
    elif lora_l is not None:
        lora_l = lora_l.astype(param_dtype)
        lora_r = lora_r.astype(param_dtype)

    return SlimLinear(
        packed_vals=pv,
        packed_idx=pi,
        scale=jnp.asarray(scale, jnp.float32),
        inv_act_scale=inv_as,
        lora_l=lora_l,
        lora_r=lora_r,
        lora_scale_l=lsl,
        lora_scale_r=lsr,
        d_in=d_in,
        d_out=d_out,
        bits=bits,
        group_size=group_size,
        fmt=fmt,
        adapter_bits=adapter_bits,
        adapter_group=adapter_group,
    )
