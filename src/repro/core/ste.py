"""Straight-through estimators for quantization-aware PEFT (paper §3.4).

When SLiM-LoRA^Q adapters are fine-tuned, the forward pass sees the
quantize-dequantize of (L, R) while gradients flow as identity through the
rounding. The paper implements the (de)quant as Triton kernels; on TPU the
XLA fusion of these elementwise chains is already optimal, so plain jnp with
a straight-through custom_vjp is the idiomatic port (DESIGN.md §4).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _group_qdq(a: jnp.ndarray, bits: int, group_size: int) -> jnp.ndarray:
    """Group-absmax quantize->dequantize, differentiably opaque."""
    half = 2 ** (bits - 1)
    qmax = half - 1
    d0 = a.shape[0]
    if group_size and d0 % group_size == 0:
        g = a.reshape(d0 // group_size, group_size, *a.shape[1:])
        alpha = jnp.max(jnp.abs(g), axis=1, keepdims=True)
        alpha = jnp.where(alpha <= 0, 1.0, alpha)
        codes = jnp.clip(jnp.round(g / alpha * half), -qmax, qmax)
        return (codes * alpha / half).reshape(a.shape)
    alpha = jnp.max(jnp.abs(a))
    alpha = jnp.where(alpha <= 0, 1.0, alpha)
    codes = jnp.clip(jnp.round(a / alpha * half), -qmax, qmax)
    return codes * alpha / half


@partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def ste_quantize(a: jnp.ndarray, bits: int = 4, group_size: int = 128) -> jnp.ndarray:
    return _group_qdq(a, bits, group_size)


def _fwd(a, bits, group_size):
    return _group_qdq(a, bits, group_size), None


def _bwd(bits, group_size, _, g):
    return (g,)  # identity gradient: the straight-through estimator


ste_quantize.defvjp(_fwd, _bwd)
