"""Bit-packing for the deployed SLiM format (consumed by the Pallas kernels).

Two building blocks:

* int4 nibble packing — two signed 4-bit codes per uint8 along the packing
  axis. Matches the kernel's unpack: ``lo = (v & 0xF)``, sign-extended via
  ``(lo ^ 8) - 8``.

* 2:4 structured compression along d_in — each group of 4 input channels
  keeps 2 survivors. Storage:
    vals[..., d_in/2, d_out]  int8 codes of the two survivors (slot-major:
                              rows 2g, 2g+1 are group g's slot 0/1, idx0<idx1)
    idx [..., d_in/2, d_out]  uint8 in {0..3}: survivor position within group
  plus packers to 2-codes/byte (vals) and 4-indices/byte (idx) for the
  HBM-resident deployed layout: 3.0 bits per original weight position.

All functions operate on the **second-to-last axis** (the d_in axis in our
W[..., d_in, d_out] convention) so arbitrary leading dims — stacked scan
layers, MoE expert stacks — pack transparently. Pure jnp, jit-safe, exactly
inverted by the decompress functions (property-tested).
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


# ---------------------------------------------------------------------------
# int4 <-> uint8 nibbles (pack along axis -2)
# ---------------------------------------------------------------------------

def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """codes int8 in [-8, 7], shape [..., 2k, n] -> uint8 [..., k, n]."""
    if codes.shape[-2] % 2 != 0:
        raise ValueError("pack_int4 needs an even packing dim")
    u = jnp.asarray(codes, jnp.int8).astype(jnp.uint8) & 0xF
    lo = u[..., 0::2, :]
    hi = u[..., 1::2, :]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """uint8 [..., k, n] -> int8 [..., 2k, n] (sign-extended nibbles)."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = ((lo ^ 8) - 8).astype(jnp.int8)
    hi = ((hi ^ 8) - 8).astype(jnp.int8)
    stacked = jnp.stack([lo, hi], axis=-2)  # [..., k, 2, n]
    shape = (*packed.shape[:-2], packed.shape[-2] * 2, packed.shape[-1])
    return stacked.reshape(shape)


# ---------------------------------------------------------------------------
# 2-bit index packing (4 per byte, along axis -2)
# ---------------------------------------------------------------------------

def pack_idx2(idx: jnp.ndarray) -> jnp.ndarray:
    """uint8 in {0..3}, shape [..., 4k, n] -> uint8 [..., k, n]."""
    if idx.shape[-2] % 4 != 0:
        raise ValueError("pack_idx2 needs packing dim divisible by 4")
    u = idx.astype(jnp.uint8) & 0x3
    return (
        u[..., 0::4, :]
        | (u[..., 1::4, :] << 2)
        | (u[..., 2::4, :] << 4)
        | (u[..., 3::4, :] << 6)
    ).astype(jnp.uint8)


def unpack_idx2(packed: jnp.ndarray) -> jnp.ndarray:
    parts = [((packed >> (2 * s)) & 0x3).astype(jnp.uint8) for s in range(4)]
    stacked = jnp.stack(parts, axis=-2)  # [..., k, 4, n]
    shape = (*packed.shape[:-2], packed.shape[-2] * 4, packed.shape[-1])
    return stacked.reshape(shape)


# ---------------------------------------------------------------------------
# 2:4 structured compress / decompress (groups of 4 along axis -2)
# ---------------------------------------------------------------------------

def compress_24(codes: jnp.ndarray, mask: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """codes int8 [..., d_in, d_out], mask {0,1} with exactly 2 per 4-group.

    Returns (vals int8 [..., d_in/2, d_out], idx uint8 [..., d_in/2, d_out]).
    """
    *lead, d_in, d_out = codes.shape
    if d_in % 4 != 0:
        raise ValueError("d_in must be divisible by 4")
    g = codes.reshape(*lead, d_in // 4, 4, d_out)
    m = mask.reshape(*lead, d_in // 4, 4, d_out).astype(jnp.int32)
    slot = jnp.cumsum(m, axis=-2) - 1  # slot of each kept position
    pos = jnp.arange(4, dtype=jnp.int32).reshape(4, 1)
    vals_s = []
    idx_s = []
    for s in range(2):
        sel = (m == 1) & (slot == s)
        vals_s.append(
            jnp.sum(jnp.where(sel, g.astype(jnp.int32), 0), axis=-2).astype(jnp.int8)
        )
        idx_s.append(jnp.sum(jnp.where(sel, pos, 0), axis=-2).astype(jnp.uint8))
    vals = jnp.stack(vals_s, axis=-2)  # [..., G, 2, d_out]
    idx = jnp.stack(idx_s, axis=-2)
    return (
        vals.reshape(*lead, d_in // 2, d_out),
        idx.reshape(*lead, d_in // 2, d_out),
    )


def decompress_24(vals: jnp.ndarray, idx: jnp.ndarray, d_in: int) -> jnp.ndarray:
    """Inverse of compress_24 -> dense int8 [..., d_in, d_out] (zeros pruned)."""
    *lead, d_half, d_out = vals.shape
    assert d_half * 2 == d_in
    v = vals.reshape(*lead, d_in // 4, 2, d_out).astype(jnp.int32)
    i = idx.reshape(*lead, d_in // 4, 2, d_out).astype(jnp.int32)
    pos = jnp.arange(4, dtype=jnp.int32).reshape(4, 1, 1)  # [4, 1, 1]
    hit = (i[..., None, :, :] == pos).astype(jnp.int32)  # [..., G, 4, 2, O]
    dense = jnp.sum(hit * v[..., None, :, :], axis=-2)  # [..., G, 4, O]
    return dense.reshape(*lead, d_in, d_out).astype(jnp.int8)


def pack_dense_24(
    codes: jnp.ndarray, mask: jnp.ndarray
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full deployed layout: (packed_vals uint8 [..., d_in/4, d_out],
    packed_idx uint8 [..., d_in/8, d_out])."""
    vals, idx = compress_24(codes, mask)
    return pack_int4(vals), pack_idx2(idx)


def unpack_dense_24(
    packed_vals: jnp.ndarray, packed_idx: jnp.ndarray, d_in: int
) -> jnp.ndarray:
    vals = unpack_int4(packed_vals)
    idx = unpack_idx2(packed_idx)
    return decompress_24(vals, idx, d_in)
