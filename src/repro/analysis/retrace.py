"""Runtime retrace guard: steady-state compile-count invariants.

A jitted serving hot path must compile a bounded number of times —
once per distinct argument signature, with the signature set itself
bounded (one for the decode step, one per prefill bucket). Anything
beyond that is a *retrace*: the one-shot compression promise re-smuggled
in as a per-round compile at serve time. Shape-keyed retraces are
invisible to throughput asserts on small runs (the compile hides in the
first round's wall time) — this guard makes them loud.

Usage::

    guard = RetraceGuard()
    step = guard.wrap("decode", jitted_step, max_sigs=1)
    ...  # serve
    guard.compiles()   # {"decode": 1}
    guard.freeze()     # post-warmup: any further compile raises

Each wrapped call records the argument *signature* — pytree structure +
per-leaf (shape, dtype, weak_type) — and reads the function's compile
count (``fn._cache_size()``) before/after. A compile on a
previously-seen signature, a compile after :meth:`freeze`, or a
``max_sigs`` overflow raises :class:`RetraceError` naming the function
and the signature delta against the last accepted signature.

``ContinuousEngine(check_retrace=True)`` wraps prefill / prefix-prefill /
decode / speculative-round in one guard per run and surfaces the counts
as ``jit_compiles_*`` / ``jit_retraces`` metrics keys.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


class RetraceError(AssertionError):
    """A guarded function recompiled outside its steady-state budget."""


def compile_count(fn: Any) -> Optional[int]:
    """Number of traces cached for a jitted function; None when the
    object exposes no compile-count API (guard degrades to signature
    bookkeeping only)."""
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


def _leaf_signature(x: Any) -> Tuple:
    if isinstance(x, jax.Array):
        aval = getattr(x, "aval", None)
        weak = bool(getattr(aval, "weak_type", False))
        return ("jax", tuple(x.shape), str(x.dtype), weak)
    if isinstance(x, np.ndarray):
        return ("np", tuple(x.shape), str(x.dtype))
    if isinstance(x, (bool, int, float, complex)):
        # python scalars trace as weak-typed avals; the *type* is the
        # signature, the value is not (unless marked static, in which
        # case a retrace per value is exactly what we want to surface —
        # but static args don't reach here as leaves anyway)
        return ("py", type(x).__name__)
    if x is None:
        return ("none",)
    return ("obj", type(x).__name__)


def arg_signature(args: Tuple, kwargs: Optional[Dict] = None) -> Tuple:
    """Hashable signature of a call: treedef + per-leaf abstract shape."""
    leaves, treedef = jax.tree_util.tree_flatten((args, kwargs or {}))
    return (str(treedef), tuple(_leaf_signature(x) for x in leaves))


def _sig_delta(old: Tuple, new: Tuple) -> str:
    if old[0] != new[0]:
        return f"pytree structure changed: {old[0]} -> {new[0]}"
    diffs: List[str] = []
    for i, (a, b) in enumerate(zip(old[1], new[1], strict=False)):
        if a != b:
            diffs.append(f"leaf {i}: {a} -> {b}")
    if len(old[1]) != len(new[1]):
        diffs.append(f"leaf count {len(old[1])} -> {len(new[1])}")
    return "; ".join(diffs) if diffs else "signatures identical (cache evicted?)"


@dataclasses.dataclass
class _Guarded:
    fn: Any
    max_sigs: Optional[int]
    base_count: Optional[int]
    sigs: List[Tuple] = dataclasses.field(default_factory=list)
    compiles: int = 0


class RetraceGuard:
    """Tracks compile counts of wrapped jitted functions and raises
    :class:`RetraceError` on steady-state violations.

    ``strict=False`` records violations in :attr:`violations` instead of
    raising (the count still lands in :meth:`retraces`)."""

    def __init__(self, strict: bool = True):
        self.strict = strict
        self._fns: Dict[str, _Guarded] = {}
        self._frozen = False
        self.violations: List[str] = []

    # -- registration ----------------------------------------------------

    def wrap(
        self, name: str, fn: Callable, max_sigs: Optional[int] = None
    ) -> Callable:
        """Return a call-through wrapper for ``fn`` that enforces the
        steady-state invariants. ``max_sigs`` bounds the number of
        distinct argument signatures (1 for a fixed-shape decode step;
        None for bucket-bounded prefill)."""
        g = _Guarded(fn=fn, max_sigs=max_sigs, base_count=compile_count(fn))
        self._fns[name] = g

        def wrapped(*args, **kwargs):
            sig = arg_signature(args, kwargs)
            before = compile_count(g.fn)
            out = g.fn(*args, **kwargs)
            after = compile_count(g.fn)
            self._observe(name, g, sig, before, after)
            return out

        wrapped.__name__ = f"retrace_guard[{name}]"
        return wrapped

    # -- invariants ------------------------------------------------------

    def _fail(self, msg: str) -> None:
        self.violations.append(msg)
        if self.strict:
            raise RetraceError(msg)

    def _observe(
        self,
        name: str,
        g: _Guarded,
        sig: Tuple,
        before: Optional[int],
        after: Optional[int],
    ) -> None:
        compiled = after is not None and before is not None and after > before
        known = sig in g.sigs
        if compiled:
            g.compiles += after - before
            if known:
                self._fail(
                    f"`{name}` retraced on an already-traced signature "
                    f"(compile #{g.compiles} this run) — non-hashable "
                    "side input or cache eviction; signature: "
                    f"{_sig_delta(g.sigs[-1], sig)}"
                )
            elif self._frozen:
                self._fail(
                    f"`{name}` compiled post-warmup (compile "
                    f"#{g.compiles} this run) — argument delta vs last "
                    "warm signature: "
                    + (_sig_delta(g.sigs[-1], sig) if g.sigs else "first call")
                )
        if not known:
            if (
                g.max_sigs is not None
                and len(g.sigs) >= g.max_sigs
                and compiled
            ):
                self._fail(
                    f"`{name}` exceeded its signature budget "
                    f"({g.max_sigs}): shape-keyed retrace — delta vs last "
                    "accepted signature: " + _sig_delta(g.sigs[-1], sig)
                )
            g.sigs.append(sig)

    def freeze(self) -> None:
        """Enter post-warmup mode: from here on every compile (even on a
        brand-new signature) is a violation."""
        self._frozen = True

    # -- reporting -------------------------------------------------------

    def compiles(self) -> Dict[str, int]:
        """Compiles observed through the wrappers this run, per name."""
        return {name: g.compiles for name, g in self._fns.items()}

    def signatures(self, name: str) -> List[Tuple]:
        return list(self._fns[name].sigs)

    def retraces(self) -> int:
        """Steady-state violations observed (0 in a healthy run; can only
        be nonzero in ``strict=False`` mode, strict mode raises)."""
        return len(self.violations)

    # -- context manager -------------------------------------------------

    def __enter__(self) -> "RetraceGuard":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        del exc_type, exc, tb
