"""slimcheck rule set SC001–SC005 (catalog: docs/static-analysis.md).

Each rule is a function ``rule(model) -> Iterator[Finding]`` over the
per-file :class:`~repro.analysis.lint.FileModel`; the registry maps rule
ids to (summary, function). Rules anchor findings to the offending line
so suppressions (``# slimcheck: disable=SCnnn``) and the baseline can
address them.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Callable, Dict, Iterator, List, Optional, Set, Tuple

from repro.analysis.scopes import FuncInfo, Taint, attr_chain

# jit parameters that select a compiled program variant rather than feed
# it data: leaving one traced turns every distinct value into a silent
# retrace (or a tracer leaking into Python control flow). SC003 requires
# them in static_argnums/static_argnames.
CONFIG_PARAM_NAMES = {
    "interpret", "bits", "block_size", "bs", "bm", "bn", "bk", "g",
    "group_size", "K", "eos", "eos_id", "greedy", "greedy_only",
    "unroll", "n_blocks", "max_len", "vocab_size", "rank", "sync_every",
    "levels", "grid", "pattern", "arch", "n_slots", "spec_pad",
}
# NOTE: lowercase "k" is deliberately absent — in attention code `k` is
# the key tensor, not the speculative draft length.

# parameters that name cache-scale device buffers: an un-donated
# ``.at[].set`` on one doubles its HBM footprint per step (XLA must keep
# the input alive) — SC005 requires the jit site to donate them.
CACHE_PARAM_NAMES = {
    "cache", "kv_cache", "buf", "buffer", "pool", "k_pool", "v_pool",
}

# host-synchronizing callables by dotted-chain tail
_SYNC_FUNCS = {
    ("jax", "device_get"): "jax.device_get",
    ("jax", "block_until_ready"): "jax.block_until_ready",
}
# host-synchronizing methods on array values
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
# numpy materializers (traced-scope only; host lists are legitimate input)
_NP_FUNCS = {"asarray", "array"}
# builtins that force a concrete value out of a tracer
_CONCRETIZERS = {"float", "int", "bool", "complex"}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    context: str  # stripped source line — the baseline key

    def render(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _finding(model, rule: str, node: ast.AST, message: str) -> Finding:
    line = getattr(node, "lineno", 1)
    col = getattr(node, "col_offset", 0)
    return Finding(
        rule=rule,
        path=model.path,
        line=line,
        col=col,
        message=message,
        context=model.line_text(line),
    )


def _call_chain(node: ast.Call) -> Tuple[str, ...]:
    return attr_chain(node.func)


def _is_sync_call(node: ast.Call) -> Optional[str]:
    chain = _call_chain(node)
    for tail, label in _SYNC_FUNCS.items():
        if chain[-len(tail):] == tail or chain == tail[-1:]:
            return label
    if (
        isinstance(node.func, ast.Attribute)
        and node.func.attr in _SYNC_METHODS
        and not chain  # method on a non-name expression, e.g. buf[i].item()
    ):
        return f".{node.func.attr}()"
    if chain and chain[-1] in _SYNC_METHODS and len(chain) > 1:
        return f".{chain[-1]}()"
    return None


# -- SC001: Python control flow on traced values -------------------------


def _static_safe_test(test: ast.AST) -> bool:
    """`x is None` / `isinstance(...)` tests are trace-time structural
    checks, not value branches."""
    if isinstance(test, ast.Compare) and all(
        isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops
    ):
        return True
    if isinstance(test, ast.Call):
        chain = attr_chain(test.func)
        if chain and chain[-1] == "isinstance":
            return True
    return False


def sc001(model) -> Iterator[Finding]:
    for fi in model.scopes.traced_functions():
        taint = model.taint(fi)
        for node in model.walk_function(fi):
            if isinstance(node, (ast.If, ast.While)):
                test = node.test
                kind = "if" if isinstance(node, ast.If) else "while"
            elif isinstance(node, ast.Assert):
                test, kind = node.test, "assert"
            elif isinstance(node, ast.IfExp):
                test, kind = node.test, "conditional expression"
            else:
                continue
            if _static_safe_test(test):
                continue
            if not taint.is_tainted(test):
                continue
            names = sorted(taint.tainted_names(test))
            yield _finding(
                model, "SC001", node,
                f"Python `{kind}` on traced value(s) {names} inside traced "
                f"scope `{fi.qualname}` — this concretizes a tracer "
                "(ConcretizationError at best, a silent retrace per value "
                "at worst); use jnp.where / lax.cond / lax.while_loop",
            )


# -- SC002: host syncs in traced scope / the serving hot loop ------------


def _sc002_traced(model) -> Iterator[Finding]:
    for fi in model.scopes.traced_functions():
        taint = model.taint(fi)
        for node in model.walk_function(fi):
            if not isinstance(node, ast.Call):
                continue
            label = _is_sync_call(node)
            if label is not None:
                yield _finding(
                    model, "SC002", node,
                    f"host sync `{label}` inside traced scope "
                    f"`{fi.qualname}` — forces a device round-trip per "
                    "trace; return the value and sync at a declared site",
                )
                continue
            chain = _call_chain(node)
            if (
                len(chain) >= 2
                and chain[-1] in _NP_FUNCS
                and chain[-2] in ("np", "numpy")
                and node.args
                and taint.is_tainted(node.args[0])
            ):
                yield _finding(
                    model, "SC002", node,
                    f"`{'.'.join(chain)}` materializes a traced value on "
                    f"host inside traced scope `{fi.qualname}` — use "
                    "jnp.asarray or keep it on device",
                )
            elif (
                len(chain) == 1
                and chain[0] in _CONCRETIZERS
                and node.args
                and taint.is_tainted(node.args[0])
            ):
                yield _finding(
                    model, "SC002", node,
                    f"`{chain[0]}()` concretizes traced value(s) "
                    f"{sorted(taint.tainted_names(node.args[0]))} inside "
                    f"traced scope `{fi.qualname}`",
                )


def _is_device_sync_call(node: ast.Call) -> Optional[str]:
    """Loop-mode matcher: only *explicit* device syncs. `.item()` /
    `.tolist()` are excluded here — on the host side of the engine they
    are overwhelmingly numpy idiom, not device round-trips."""
    chain = _call_chain(node)
    for tail, label in _SYNC_FUNCS.items():
        if chain[-len(tail):] == tail or chain == tail[-1:]:
            return label
    if (
        isinstance(node.func, ast.Attribute)
        and node.func.attr == "block_until_ready"
    ):
        return ".block_until_ready()"
    return None


def _walk_no_defs(roots: List[ast.AST]) -> Iterator[ast.AST]:
    """ast.walk that prunes nested def/lambda subtrees — a definition
    statement inside a loop body executes nothing by itself."""
    stack = list(roots)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


def _loop_sync_calls(
    model, fi: FuncInfo, body: List[ast.stmt], seen_fns: Set[FuncInfo]
) -> Iterator[ast.Call]:
    """Sync calls in ``body``, following simple-name calls into functions
    defined locally in this module (the engine's `preempt_slot` pattern),
    but not into nested loops' own reports (dedup happens in the rule)."""
    for node in _walk_no_defs(list(body)):
        if not isinstance(node, ast.Call):
            continue
        if _is_device_sync_call(node) is not None:
            yield node
        elif isinstance(node.func, ast.Name):
            callee = model.scopes.resolve_name(node.func.id, fi)
            if (
                callee is not None
                and not callee.traced
                and callee not in seen_fns
                and not isinstance(callee.node, ast.Lambda)
            ):
                seen_fns.add(callee)
                yield from _loop_sync_calls(
                    model, callee, callee.node.body, seen_fns
                )


def _sc002_engine_loop(model) -> Iterator[Finding]:
    if "/serving/" not in model.path.replace("\\", "/"):
        return
    for fi in model.scopes.functions:
        if fi.traced or isinstance(fi.node, ast.Lambda):
            continue
        for node in ast.walk(fi.node):
            if not isinstance(node, (ast.While, ast.For)):
                continue
            seen: Set[FuncInfo] = set()
            for call in _loop_sync_calls(
                model, fi, [*node.body, *node.orelse], seen
            ):
                label = _is_device_sync_call(call)
                yield _finding(
                    model, "SC002", call,
                    f"host sync `{label}` inside the serving per-round "
                    f"loop of `{fi.qualname}` outside a declared sync "
                    "site — every occurrence stalls the dispatch "
                    "pipeline; fold into an existing sync or mark the "
                    "line `# slimcheck: sync-site`",
                )


def sc002(model) -> Iterator[Finding]:
    seen: Set[Tuple[int, int]] = set()
    for f in (*_sc002_traced(model), *_sc002_engine_loop(model)):
        key = (f.line, f.col)
        if key not in seen:
            seen.add(key)
            yield f


# -- SC003: config-like jit params that are not static -------------------


_ARRAYISH_ANNOTATIONS = {"ndarray", "Array", "ArrayLike", "DeviceArray"}


def _array_annotated(fi: FuncInfo, name: str) -> bool:
    a = fi.node.args
    for p in (*a.posonlyargs, *a.args, *a.kwonlyargs):
        if p.arg == name and p.annotation is not None:
            chain = attr_chain(p.annotation)
            return bool(chain) and chain[-1] in _ARRAYISH_ANNOTATIONS
    return False


def sc003(model) -> Iterator[Finding]:
    for fi in model.scopes.traced_functions():
        site = fi.jit_site
        if site is None or site.static_unknown:
            continue
        static = fi.static_param_names() | fi.partial_static
        loose = [
            p
            for p in fi.param_names()
            if p in CONFIG_PARAM_NAMES
            and p not in static
            and not _array_annotated(fi, p)
        ]
        if loose:
            yield _finding(
                model, "SC003", fi.node,
                f"jit of `{fi.qualname}` leaves config-like parameter(s) "
                f"{loose} traced — each distinct value retraces (or leaks "
                "a tracer into Python control flow); add to "
                "static_argnums/static_argnames",
            )


# -- SC004: Pallas entry points bypassing default_interpret --------------

_INTERPRET_RESOLVERS = {"resolve_interpret", "default_interpret"}


def sc004(model) -> Iterator[Finding]:
    for call in model.scopes.pallas_sites:
        has_interpret = any(kw.arg == "interpret" for kw in call.keywords)
        encl = model.scopes.enclosing(call)
        resolver_seen = False
        search_nodes = [encl.node] if encl is not None else [model.scopes.tree]
        for root in search_nodes:
            for node in ast.walk(root):
                if isinstance(node, ast.Call):
                    chain = attr_chain(node.func)
                    if chain and chain[-1] in _INTERPRET_RESOLVERS:
                        resolver_seen = True
                        break
            if resolver_seen:
                break
        if not has_interpret or not resolver_seen:
            where = encl.qualname if encl is not None else "<module>"
            yield _finding(
                model, "SC004", call,
                f"pallas_call in `{where}` bypasses "
                "kernels/common.default_interpret — pass "
                "`interpret=resolve_interpret(interpret)` so TPU hosts "
                "compile and CPU hosts interpret without threading flags",
            )


# -- SC005: un-donated cache mutation in jitted functions ----------------


def sc005(model) -> Iterator[Finding]:
    for fi in model.scopes.traced_functions():
        site = fi.jit_site
        if site is None:
            continue  # pallas kernels mutate Refs in place — not scored
        if site.donate_unknown:
            continue  # donation present but not statically readable
        donated = set(site.donate_names)
        pos = fi.positional_params()
        for i in site.donate_nums:
            if 0 <= i < len(pos):
                donated.add(pos[i])
        params = set(fi.param_names())
        for node in model.walk_function(fi):
            # <name>.at[...].set(...) / .add(...) on a cache-sized param
            if not (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in ("set", "add")
                and isinstance(node.func.value, ast.Subscript)
                and isinstance(node.func.value.value, ast.Attribute)
                and node.func.value.value.attr == "at"
                and isinstance(node.func.value.value.value, ast.Name)
            ):
                continue
            name = node.func.value.value.value.id
            if name in CACHE_PARAM_NAMES and name in params and name not in donated:
                yield _finding(
                    model, "SC005", node,
                    f"`.at[].{node.func.attr}` on cache-sized parameter "
                    f"`{name}` in jitted `{fi.qualname}` without donation "
                    "— XLA keeps the input alive, doubling the buffer's "
                    "HBM footprint per call; add donate_argnums/"
                    "donate_argnames for it",
                )


@dataclasses.dataclass(frozen=True)
class Rule:
    id: str
    summary: str
    func: Callable


RULES: Dict[str, Rule] = {
    r.id: r
    for r in [
        Rule("SC001", "Python control flow on traced values", sc001),
        Rule("SC002", "host sync in traced scope / serving hot loop", sc002),
        Rule("SC003", "config-like jit parameter not static", sc003),
        Rule("SC004", "Pallas entry point bypasses default_interpret", sc004),
        Rule("SC005", "un-donated cache mutation in jitted function", sc005),
    ]
}
