"""slimcheck — JAX/Pallas-aware static analysis + runtime retrace guard.

Two layers of correctness tooling for the serving hot path (see
docs/static-analysis.md):

* **Lint** (`repro.analysis.lint`, CLI ``python -m repro.analysis``): an
  AST pass that resolves every jit/pallas_call *traced scope* in a file —
  functions decorated with or passed to ``jax.jit`` / ``pl.pallas_call``,
  including locally-defined jitted closures like the continuous engine's
  ``_step`` — and checks the SC00x rule set against it (Python branches
  on traced values, host syncs in hot loops, non-static config params,
  Pallas entry points that bypass ``default_interpret``, un-donated cache
  mutation). Pure stdlib: importable and runnable without jax installed.

* **Retrace guard** (`repro.analysis.retrace`): a runtime monitor over
  ``jax.jit`` compile counts. ``ContinuousEngine(check_retrace=True)``
  wraps its hot functions in it and raises ``RetraceError`` — naming the
  function and the argument-signature delta — the moment a steady-state
  path recompiles.

The lint layer must stay importable without jax (the CI job runs it on a
bare interpreter), so the retrace module is loaded lazily on attribute
access.
"""
from __future__ import annotations

from repro.analysis.lint import (
    Baseline,
    Finding,
    LintResult,
    lint_paths,
    lint_source,
)
from repro.analysis.rules import RULES

__all__ = [
    "Baseline",
    "Finding",
    "LintResult",
    "RULES",
    "lint_paths",
    "lint_source",
    "RetraceError",
    "RetraceGuard",
    "arg_signature",
    "compile_count",
]

_RETRACE_NAMES = {"RetraceError", "RetraceGuard", "arg_signature", "compile_count"}


def __getattr__(name):  # lazy: repro.analysis.retrace imports jax
    if name in _RETRACE_NAMES:
        from repro.analysis import retrace

        return getattr(retrace, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
