"""``python -m repro.analysis`` — the slimcheck lint CLI.

    python -m repro.analysis src/                 # lint vs the default baseline
    python -m repro.analysis src/ --stats         # per-rule counts
    python -m repro.analysis --write-baseline     # accept current findings
    python -m repro.analysis --list-rules

Exit status: 0 = clean (no findings beyond the baseline), 1 = new
findings (or unparseable files). The default baseline is
``slimcheck-baseline.json`` in the working directory when it exists;
``--baseline PATH`` overrides, ``--no-baseline`` disables.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis.lint import Baseline, lint_paths
from repro.analysis.rules import RULES

DEFAULT_BASELINE = "slimcheck-baseline.json"


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="slimcheck: JAX/Pallas-aware static analysis "
        "(docs/static-analysis.md)",
    )
    p.add_argument(
        "paths", nargs="*", default=None,
        help="files/directories to lint (default: src)",
    )
    p.add_argument(
        "--rules", default=None, metavar="SC001,SC002",
        help="comma-separated rule subset (default: all)",
    )
    p.add_argument(
        "--baseline", default=None, metavar="PATH",
        help=f"baseline file of accepted findings (default: "
        f"{DEFAULT_BASELINE} if present)",
    )
    p.add_argument(
        "--no-baseline", action="store_true",
        help="ignore any baseline; report every finding",
    )
    p.add_argument(
        "--write-baseline", action="store_true",
        help="write current findings to the baseline file and exit 0",
    )
    p.add_argument(
        "--stats", action="store_true",
        help="print per-rule finding counts and suppression totals",
    )
    p.add_argument("--list-rules", action="store_true")
    args = p.parse_args(argv)

    if args.list_rules:
        for rule in RULES.values():
            print(f"{rule.id}  {rule.summary}")
        return 0

    rules = args.rules.split(",") if args.rules else None
    if rules:
        unknown = [r for r in rules if r not in RULES]
        if unknown:
            p.error(f"unknown rule(s): {unknown}; see --list-rules")

    paths = args.paths or ["src"]
    result = lint_paths(paths, rules)

    baseline_path = args.baseline or DEFAULT_BASELINE
    if args.write_baseline:
        Baseline.from_findings(result.findings).dump(baseline_path)
        print(
            f"[slimcheck] wrote {len(result.findings)} finding(s) to "
            f"{baseline_path}"
        )
        return 0

    baseline = None
    if not args.no_baseline:
        try:
            baseline = Baseline.load(baseline_path)
        except FileNotFoundError:
            if args.baseline is not None:
                print(
                    f"[slimcheck] baseline not found: {baseline_path}",
                    file=sys.stderr,
                )
                return 1

    new = (
        baseline.new_findings(result.findings)
        if baseline is not None
        else result.findings
    )
    for f in new:
        print(f.render())
    for err in result.errors:
        print(f"[slimcheck] parse error: {err}", file=sys.stderr)

    if args.stats:
        print(
            f"[slimcheck] {result.files} file(s), "
            f"{len(result.findings)} finding(s) "
            f"({len(new)} new, {result.suppressed} suppressed inline"
            + (
                f", {len(result.findings) - len(new)} baselined"
                if baseline is not None
                else ""
            )
            + ")"
        )
        for rule, n in sorted(result.by_rule().items()):
            print(f"[slimcheck]   {rule}: {n}")
        if baseline is not None:
            stale = baseline.stale_entries(result.findings)
            if stale:
                print(
                    f"[slimcheck] {len(stale)} stale baseline entr"
                    f"{'y' if len(stale) == 1 else 'ies'} (fixed findings "
                    "still baselined — consider --write-baseline):"
                )
                for rule, path, context in stale:
                    print(f"[slimcheck]   {rule} {path}: {context}")

    if new or result.errors:
        if not args.stats:
            print(
                f"[slimcheck] {len(new)} new finding(s) "
                f"across {result.files} file(s)",
                file=sys.stderr,
            )
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
