"""Traced-scope resolution and trace-time taint for the slimcheck lint.

A *traced scope* is a function whose body executes under a JAX trace:

* decorated with ``jax.jit`` (bare, factory-call, or via
  ``functools.partial(jax.jit, ...)``),
* passed to a ``jax.jit(...)`` call expression — the serving engines'
  locally-defined closures (``self._step = jax.jit(_step, ...)``) resolve
  through the enclosing scope chain,
* passed (possibly through ``functools.partial``) as the kernel body of a
  ``pl.pallas_call``,
* or *called from* any of the above **within the same module** (the
  flash-decode online-softmax helpers, the sampling core). Cross-module
  propagation is out of scope — rules that need it run where the jit
  lives.

Inside a traced scope the analysis tracks a coarse forward *taint*: the
set of names holding traced values. Non-static parameters seed it;
assignments whose right-hand side touches a tainted name propagate it.
Trace-time-static projections — ``.shape`` / ``.ndim`` / ``.dtype`` /
``.size`` attributes and ``len()`` / ``isinstance()`` calls — strip
taint, so the ubiquitous ``m, k = x.shape`` unpacking stays branchable.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple, Union

FuncNode = Union[ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda]

# attribute projections of a traced array that are static at trace time
STATIC_ATTRS = {"shape", "ndim", "dtype", "size", "weak_type", "sharding"}
# calls whose result is always trace-time static, whatever the argument
STATIC_CALLS = {"len", "isinstance", "type", "id", "repr", "str", "hash"}


def attr_chain(node: ast.AST) -> Tuple[str, ...]:
    """``jax.experimental.pallas.pallas_call`` -> ("jax", "experimental",
    "pallas", "pallas_call"); non-Name/Attribute roots yield ()."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _is_jit_func(func: ast.AST) -> bool:
    chain = attr_chain(func)
    return bool(chain) and chain[-1] == "jit"


def _is_partial_func(func: ast.AST) -> bool:
    chain = attr_chain(func)
    return bool(chain) and chain[-1] == "partial"


def _is_pallas_call_func(func: ast.AST) -> bool:
    chain = attr_chain(func)
    return bool(chain) and chain[-1] == "pallas_call"


def _literal_int_set(node: ast.AST) -> Optional[Set[int]]:
    """Evaluate a static_argnums/donate_argnums expression if it is a
    literal int / tuple / list; None = not statically known."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[int] = set()
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.add(elt.value)
            else:
                return None
        return out
    return None


def _literal_str_set(node: ast.AST) -> Optional[Set[str]]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return {node.value}
    if isinstance(node, (ast.Tuple, ast.List)):
        out: Set[str] = set()
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.add(elt.value)
            else:
                return None
        return out
    return None


@dataclasses.dataclass
class JitSite:
    """One ``jax.jit`` application (decorator or call expression)."""

    node: ast.AST  # the jit (or partial) call / decorator expression
    lineno: int
    static_names: Set[str] = dataclasses.field(default_factory=set)
    static_nums: Set[int] = dataclasses.field(default_factory=set)
    static_unknown: bool = False  # static_arg* present but not literal
    donate_nums: Set[int] = dataclasses.field(default_factory=set)
    donate_names: Set[str] = dataclasses.field(default_factory=set)
    donate_present: bool = False  # donate_arg* kwarg appears at all
    donate_unknown: bool = False  # donate_arg* present but not literal

    def absorb_kwargs(self, call: ast.Call) -> None:
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                vals = _literal_str_set(kw.value)
                if vals is None:
                    self.static_unknown = True
                else:
                    self.static_names |= vals
            elif kw.arg == "static_argnums":
                nums = _literal_int_set(kw.value)
                if nums is None:
                    self.static_unknown = True
                else:
                    self.static_nums |= nums
            elif kw.arg == "donate_argnums":
                self.donate_present = True
                nums = _literal_int_set(kw.value)
                if nums is None:
                    self.donate_unknown = True
                else:
                    self.donate_nums |= nums
            elif kw.arg == "donate_argnames":
                self.donate_present = True
                vals = _literal_str_set(kw.value)
                if vals is None:
                    self.donate_unknown = True
                else:
                    self.donate_names |= vals


@dataclasses.dataclass(eq=False)  # identity hash: one info per def node
class FuncInfo:
    node: FuncNode
    name: str
    qualname: str
    parent: Optional["FuncInfo"]
    traced: bool = False
    traced_via: Optional[str] = None  # "jit" | "pallas" | "called-from:X"
    jit_site: Optional[JitSite] = None
    # params bound by functools.partial at the jit/pallas site — trace-time
    # constants (a partial-bound python int stays a python int)
    partial_static: Set[str] = dataclasses.field(default_factory=set)
    # for call-propagated scopes: params that receive a *traced* argument
    # at some call site. None = unknown / trace root — seed every
    # non-static param.
    seeded_taint: Optional[Set[str]] = None

    @property
    def lineno(self) -> int:
        return self.node.lineno

    def param_names(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]

    def positional_params(self) -> List[str]:
        a = self.node.args
        return [p.arg for p in (*a.posonlyargs, *a.args)]

    def static_param_names(self) -> Set[str]:
        """Parameter names pinned static at this function's jit site."""
        site = self.jit_site
        if site is None:
            return set()
        names = set(site.static_names)
        pos = self.positional_params()
        for i in site.static_nums:
            if 0 <= i < len(pos):
                names.add(pos[i])
        return names


class ModuleScopes:
    """Function table, jit/pallas sites, and traced-scope closure for one
    parsed module."""

    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.functions: List[FuncInfo] = []
        self.jit_sites: List[JitSite] = []
        self.pallas_sites: List[ast.Call] = []
        self._info_of: Dict[FuncNode, FuncInfo] = {}
        # scope key (None = module, else FuncNode) -> name -> FuncInfo
        self._defs: Dict[Optional[FuncNode], Dict[str, FuncInfo]] = {None: {}}
        self._collect(tree.body, parent=None)
        self._resolve_sites()
        self._propagate_calls()

    # -- construction ---------------------------------------------------

    def _collect(self, body: Sequence[ast.stmt], parent: Optional[FuncInfo]):
        for stmt in body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._add_func(stmt, stmt.name, parent)
            elif isinstance(stmt, (ast.ClassDef,)):
                # methods live in the class namespace; treat the class as
                # transparent for parent chaining (no closure resolution
                # through it, which matches Python semantics closely
                # enough for jit-site resolution)
                self._collect(stmt.body, parent)
            else:
                for node in ast.walk(stmt):
                    if isinstance(node, ast.Lambda):
                        self._add_lambda(node, parent)

    def _add_func(self, node: FuncNode, name: str, parent: Optional[FuncInfo]):
        qual = f"{parent.qualname}.{name}" if parent else name
        info = FuncInfo(node=node, name=name, qualname=qual, parent=parent)
        self.functions.append(info)
        self._info_of[node] = info
        self._defs.setdefault(
            parent.node if parent else None, {}
        )[name] = info
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self._collect(node.body, info)

    def _add_lambda(self, node: ast.Lambda, parent: Optional[FuncInfo]):
        if node in self._info_of:
            return
        qual = f"{parent.qualname}.<lambda>" if parent else "<lambda>"
        info = FuncInfo(node=node, name="<lambda>", qualname=qual, parent=parent)
        self.functions.append(info)
        self._info_of[node] = info

    def info_of(self, node: FuncNode) -> Optional[FuncInfo]:
        return self._info_of.get(node)

    def resolve_name(
        self, name: str, scope: Optional[FuncInfo]
    ) -> Optional[FuncInfo]:
        """Resolve ``name`` to a function def visible from ``scope`` (the
        enclosing scope chain, then module level)."""
        cur = scope
        while cur is not None:
            hit = self._defs.get(cur.node, {}).get(name)
            if hit is not None:
                return hit
            cur = cur.parent
        return self._defs[None].get(name)

    def enclosing(self, node: ast.AST) -> Optional[FuncInfo]:
        """Innermost FuncInfo whose body contains ``node`` (by position)."""
        best: Optional[FuncInfo] = None
        for fi in self.functions:
            for sub in ast.walk(fi.node):
                if sub is node:
                    if best is None or _contains(best.node, fi.node):
                        best = fi
                    break
        return best

    # -- jit / pallas site resolution -----------------------------------

    def _jit_site_from_call(self, call: ast.Call) -> JitSite:
        site = JitSite(node=call, lineno=call.lineno)
        site.absorb_kwargs(call)
        return site

    def _resolve_decorators(self, fi: FuncInfo) -> None:
        node = fi.node
        if isinstance(node, ast.Lambda):
            return
        for dec in node.decorator_list:
            site: Optional[JitSite] = None
            if _is_jit_func(dec):  # bare @jax.jit
                site = JitSite(node=dec, lineno=dec.lineno)
            elif isinstance(dec, ast.Call):
                if _is_jit_func(dec.func):  # @jax.jit(...)
                    site = self._jit_site_from_call(dec)
                elif (
                    _is_partial_func(dec.func)
                    and dec.args
                    and _is_jit_func(dec.args[0])
                ):  # @functools.partial(jax.jit, ...)
                    site = self._jit_site_from_call(dec)
            if site is not None:
                self.jit_sites.append(site)
                self._mark_traced(fi, "jit", site)

    def _resolve_sites(self) -> None:
        for fi in list(self.functions):
            self._resolve_decorators(fi)
        for node in ast.walk(self.tree):
            if not isinstance(node, ast.Call):
                continue
            if _is_jit_func(node.func) and node.args:
                site = self._jit_site_from_call(node)
                self.jit_sites.append(site)
                statics: Set[str] = set()
                target = self._resolve_callable(node.args[0], node, statics)
                if target is not None:
                    target.partial_static |= statics
                    self._mark_traced(target, "jit", site)
            elif _is_pallas_call_func(node.func):
                self.pallas_sites.append(node)
                if node.args:
                    statics = set()
                    target = self._resolve_callable(node.args[0], node, statics)
                    if target is not None:
                        target.partial_static |= statics
                        self._mark_traced(target, "pallas", None)

    def _resolve_callable(
        self, expr: ast.AST, at: ast.AST, statics: Optional[Set[str]] = None
    ) -> Optional[FuncInfo]:
        """First argument of a jit/pallas_call: Name, Lambda, or
        (functools.)partial(Name|Lambda, ...). Keyword names bound by the
        partial land in ``statics`` — they are trace-time constants."""
        if isinstance(expr, ast.Lambda):
            return self._info_of.get(expr)
        if isinstance(expr, ast.Call) and _is_partial_func(expr.func):
            if statics is not None:
                statics.update(
                    kw.arg for kw in expr.keywords if kw.arg is not None
                )
            if not expr.args:
                return None
            return self._resolve_callable(expr.args[0], at, statics)
        if isinstance(expr, ast.Name):
            return self.resolve_name(expr.id, self.enclosing(at))
        return None

    def _mark_traced(
        self, fi: FuncInfo, via: str, site: Optional[JitSite]
    ) -> None:
        fi.traced = True
        if fi.traced_via is None:
            fi.traced_via = via
        if site is not None and fi.jit_site is None:
            fi.jit_site = site

    def _propagate_calls(self) -> None:
        """Functions called (by simple name) from a traced scope, defined
        in this module, are traced too — transitively. Each call site also
        records which callee params actually receive a *traced* argument
        (per the caller's taint), so a helper called with static config
        (``_quant_error_at(..., bits)`` where ``bits`` is static at the
        real jit site) is not over-tainted."""
        frontier = [fi for fi in self.functions if fi.traced]
        while frontier:
            fi = frontier.pop()
            caller_taint = Taint(fi)
            for node in ast.walk(fi.node):
                if not isinstance(node, ast.Call):
                    continue
                if not isinstance(node.func, ast.Name):
                    continue
                callee = self.resolve_name(node.func.id, fi)
                if callee is None:
                    continue
                seeds = self._call_taint_seeds(node, callee, caller_taint)
                if not callee.traced:
                    callee.traced = True
                    callee.traced_via = f"called-from:{fi.qualname}"
                    callee.seeded_taint = seeds
                    frontier.append(callee)
                elif callee.seeded_taint is not None:
                    # widen: union taint over every observed call site;
                    # None (unmappable call) widens to full taint
                    new = (
                        None
                        if seeds is None
                        else callee.seeded_taint | seeds
                    )
                    if new != callee.seeded_taint:
                        callee.seeded_taint = new
                        frontier.append(callee)

    def _call_taint_seeds(
        self, call: ast.Call, callee: FuncInfo, caller_taint: "Taint"
    ) -> Optional[Set[str]]:
        """Callee params receiving a tainted argument at this call site;
        None when the call cannot be mapped onto the signature (starred
        args, **kwargs, *args overflow) — conservatively full taint."""
        if any(isinstance(a, ast.Starred) for a in call.args):
            return None
        if any(kw.arg is None for kw in call.keywords):
            return None
        pos = callee.positional_params()
        names = set(callee.param_names())
        seeds: Set[str] = set()
        for i, arg in enumerate(call.args):
            if i >= len(pos):
                return None  # lands in *args — give up on mapping
            if caller_taint.is_tainted(arg):
                seeds.add(pos[i])
        for kw in call.keywords:
            if kw.arg not in names:
                return None
            if caller_taint.is_tainted(kw.value):
                seeds.add(kw.arg)
        return seeds

    def traced_functions(self) -> List[FuncInfo]:
        return [fi for fi in self.functions if fi.traced]


def _contains(outer: ast.AST, inner: ast.AST) -> bool:
    return any(sub is inner for sub in ast.walk(outer))


# -- taint --------------------------------------------------------------


class Taint:
    """Coarse forward taint over one traced function body.

    Two passes over the statements reach a fixpoint for the common
    backward-edge case (a loop body tainting a name read earlier)."""

    def __init__(self, fi: FuncInfo):
        self.fi = fi
        static = fi.static_param_names() | fi.partial_static
        seeds = {p for p in fi.param_names() if p not in static}
        if fi.seeded_taint is not None:
            # call-propagated scope: only params shown traced at some
            # observed call site carry taint
            seeds &= fi.seeded_taint
        self.tainted: Set[str] = seeds
        body = (
            fi.node.body
            if isinstance(fi.node.body, list)
            else [ast.Expr(fi.node.body)]  # lambda body
        )
        for _ in range(2):
            for stmt in body:
                self._visit(stmt)

    def is_tainted(self, expr: ast.AST) -> bool:
        return self._expr_tainted(expr)

    def tainted_names(self, expr: ast.AST) -> Set[str]:
        out: Set[str] = set()
        self._expr_tainted(expr, collect=out)
        return out

    # -- statement walk -------------------------------------------------

    def _visit(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            value = stmt.value
            if value is not None and self._expr_tainted(value):
                targets = (
                    stmt.targets
                    if isinstance(stmt, ast.Assign)
                    else [stmt.target]
                )
                for t in targets:
                    self._taint_target(t)
        elif isinstance(stmt, ast.For):
            if self._expr_tainted(stmt.iter):
                self._taint_target(stmt.target)
            for s in (*stmt.body, *stmt.orelse):
                self._visit(s)
            return
        elif isinstance(stmt, (ast.While, ast.If)):
            for s in (*stmt.body, *stmt.orelse):
                self._visit(s)
            return
        elif isinstance(stmt, ast.With):
            for item in stmt.items:
                if item.optional_vars is not None and self._expr_tainted(
                    item.context_expr
                ):
                    self._taint_target(item.optional_vars)
            for s in stmt.body:
                self._visit(s)
            return
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs (pl.when bodies) execute at trace time in the
            # same taint environment; walk them in place
            for s in stmt.body:
                self._visit(s)
            return
        elif isinstance(stmt, (ast.Try,)):
            for s in (
                *stmt.body,
                *(h for handler in stmt.handlers for h in handler.body),
                *stmt.orelse,
                *stmt.finalbody,
            ):
                self._visit(s)
            return

    def _taint_target(self, target: ast.AST) -> None:
        if isinstance(target, ast.Name):
            self.tainted.add(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._taint_target(elt)
        elif isinstance(target, ast.Starred):
            self._taint_target(target.value)
        # Attribute/Subscript targets: the base object is already named

    # -- expression taint ------------------------------------------------

    def _expr_tainted(
        self, expr: ast.AST, collect: Optional[Set[str]] = None
    ) -> bool:
        hit = False
        for node in self._taint_walk(expr):
            if isinstance(node, ast.Name) and node.id in self.tainted:
                hit = True
                if collect is None:
                    return True
                collect.add(node.id)
        return hit

    def _taint_walk(self, expr: ast.AST) -> Iterator[ast.AST]:
        """ast.walk that does not descend through trace-time-static
        projections (``x.shape``, ``len(x)``, ``isinstance(x, T)``)."""
        stack = [expr]
        while stack:
            node = stack.pop()
            if isinstance(node, ast.Attribute) and node.attr in STATIC_ATTRS:
                continue
            if isinstance(node, ast.Call):
                fname = attr_chain(node.func)
                if fname and fname[-1] in STATIC_CALLS:
                    continue
            yield node
            stack.extend(ast.iter_child_nodes(node))
