"""slimcheck lint runner: file models, suppressions, baseline.

Suppression syntax (checked per finding line and the comment line
directly above it):

    x = foo()  # slimcheck: disable=SC001
    # slimcheck: disable=SC002,SC005
    # slimcheck: sync-site        <- semantic alias for disable=SC002:
                                     declares an *intentional* host sync

The baseline file (``slimcheck-baseline.json``, checked in at the repo
root) records accepted findings as (rule, path, context-line) counts —
line numbers are deliberately not part of the key so unrelated edits
don't churn it. A lint run fails only on findings *not covered* by the
baseline; regenerate with ``python -m repro.analysis --write-baseline``.

This module is pure stdlib — the CI lint job runs it on a bare
interpreter, no jax required.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import os
import re
from collections import Counter
from typing import Dict, Iterator, List, Optional, Sequence, Set, Tuple

from repro.analysis.rules import RULES, Finding
from repro.analysis.scopes import FuncInfo, ModuleScopes, Taint

_SUPPRESS_RE = re.compile(
    r"#\s*slimcheck:\s*(disable|sync-site)\s*(?:=\s*([A-Z0-9,\s]+))?"
)


class FileModel:
    """Parsed module + scope/taint info handed to every rule."""

    def __init__(self, path: str, source: str):
        self.path = path.replace(os.sep, "/").replace("\\", "/")
        self.source = source
        self.lines = source.splitlines()
        self.tree = ast.parse(source, filename=path)
        self.scopes = ModuleScopes(self.tree)
        self._taints: Dict[int, Taint] = {}
        # line -> set of suppressed rule ids ("*" = all)
        self.suppressions: Dict[int, Set[str]] = {}
        for i, text in enumerate(self.lines, start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            if m.group(1) == "sync-site":
                codes = {"SC002"}
            elif m.group(2):
                codes = {c.strip() for c in m.group(2).split(",") if c.strip()}
            else:
                codes = {"*"}
            self.suppressions.setdefault(i, set()).update(codes)

    def line_text(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def taint(self, fi: FuncInfo) -> Taint:
        key = id(fi.node)
        if key not in self._taints:
            self._taints[key] = Taint(fi)
        return self._taints[key]

    def walk_function(self, fi: FuncInfo) -> Iterator[ast.AST]:
        """Every node of the function body, nested trace-time defs
        included (pl.when bodies execute under the same trace)."""
        yield from ast.walk(fi.node)

    def suppressed(self, f: Finding) -> bool:
        for line in (f.line, f.line - 1):
            codes = self.suppressions.get(line)
            if codes and ("*" in codes or f.rule in codes):
                # a suppression on the *previous* line only counts if that
                # line is comment-only (it annotates the line below)
                if line == f.line or self.line_text(line).startswith("#"):
                    return True
        return False


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]
    suppressed: int
    files: int
    errors: List[str]  # unparseable files

    def by_rule(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out


def lint_source(
    source: str, path: str = "<string>", rules: Optional[Sequence[str]] = None
) -> List[Finding]:
    model = FileModel(path, source)
    kept, _ = _run_rules(model, rules)
    return kept


def _run_rules(model: FileModel, rules: Optional[Sequence[str]]):
    active = [RULES[r] for r in rules] if rules else list(RULES.values())
    raw: List[Finding] = []
    for rule in active:
        raw.extend(rule.func(model))
    kept = [f for f in raw if not model.suppressed(f)]
    kept.sort(key=lambda f: (f.path, f.line, f.col, f.rule))
    n_suppressed = len(raw) - len(kept)
    return kept, n_suppressed


def iter_python_files(paths: Sequence[str]) -> Iterator[str]:
    for p in paths:
        if os.path.isfile(p):
            yield p
        else:
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(
                    d for d in dirs if d != "__pycache__" and not d.startswith(".")
                )
                for name in sorted(files):
                    if name.endswith(".py"):
                        yield os.path.join(root, name)


def lint_paths(
    paths: Sequence[str], rules: Optional[Sequence[str]] = None
) -> LintResult:
    findings: List[Finding] = []
    suppressed = 0
    files = 0
    errors: List[str] = []
    for path in iter_python_files(paths):
        files += 1
        try:
            with open(path, encoding="utf-8") as fh:
                source = fh.read()
            model = FileModel(path, source)
        except (SyntaxError, UnicodeDecodeError) as e:
            errors.append(f"{path}: {e}")
            continue
        kept, n_sup = _run_rules(model, rules)
        findings.extend(kept)
        suppressed += n_sup
    return LintResult(
        findings=findings, suppressed=suppressed, files=files, errors=errors
    )


# -- baseline ------------------------------------------------------------

BaselineKey = Tuple[str, str, str]  # (rule, path, context)


class Baseline:
    """Accepted findings as (rule, path, context) multiset counts."""

    VERSION = 1

    def __init__(self, counts: Optional[Counter] = None):
        self.counts: Counter = counts or Counter()

    @staticmethod
    def key(f: Finding) -> BaselineKey:
        return (f.rule, f.path, f.context)

    @classmethod
    def from_findings(cls, findings: Sequence[Finding]) -> "Baseline":
        return cls(Counter(cls.key(f) for f in findings))

    @classmethod
    def load(cls, path: str) -> "Baseline":
        with open(path, encoding="utf-8") as fh:
            data = json.load(fh)
        counts: Counter = Counter()
        for entry in data.get("findings", []):
            key = (entry["rule"], entry["path"], entry["context"])
            counts[key] = int(entry.get("count", 1))
        return cls(counts)

    def dump(self, path: str) -> None:
        entries = [
            {"rule": r, "path": p, "context": c, "count": n}
            for (r, p, c), n in sorted(self.counts.items())
        ]
        with open(path, "w", encoding="utf-8") as fh:
            json.dump(
                {"version": self.VERSION, "findings": entries}, fh, indent=2
            )
            fh.write("\n")

    def new_findings(self, findings: Sequence[Finding]) -> List[Finding]:
        """Findings beyond the baselined count for their key."""
        budget = Counter(self.counts)
        out: List[Finding] = []
        for f in findings:
            k = self.key(f)
            if budget[k] > 0:
                budget[k] -= 1
            else:
                out.append(f)
        return out

    def stale_entries(self, findings: Sequence[Finding]) -> List[BaselineKey]:
        """Baseline entries no longer produced (candidates for cleanup)."""
        seen = Counter(self.key(f) for f in findings)
        out: List[BaselineKey] = []
        for k, n in sorted(self.counts.items()):
            if seen[k] < n:
                out.append(k)
        return out
