"""Graceful-degradation guard for the serving engine.

``GuardConfig`` bundles the robustness policy the continuous engine
threads through its serve loop (docs/robustness.md):

* **deadlines** — every request gets a time-to-live (its own
  ``Request.deadline`` or ``default_ttl`` seconds past arrival). A
  queued request past its deadline is reaped to ``EXPIRED`` before it
  can waste a prefill; a *running* request past its deadline is
  host-cancelled — its slot is silenced, its blocks released, its
  partial output kept. A preempted request re-enters the queue with its
  original deadline, so preemption can never launder an expired request
  back into service.
* **bounded queue** — when more than ``max_queue`` arrived requests are
  waiting for a slot, the newest arrivals are shed (``ABORTED``) until
  the backlog fits. Preemption re-queues are exempt by construction:
  shedding picks victims newest-arrival-first and a preempted request
  keeps its original (old) arrival.
* **burst watchdog** — a decode/verify burst whose host wall time
  exceeds ``watchdog_s`` trips the watchdog: counted, traced, and fed
  into the degradation pressure signal. The engine cannot kill a wedged
  device call, but it can refuse to stay at full service around one.
* **degradation ladder** — see ``DegradationLadder``.

``DegradationLadder`` maps a scalar *pressure* signal (queue backlog per
slot + deadline urgency + recent watchdog trips) to a service level with
hysteresis: the ladder steps up when pressure crosses ``enter[level]``
and back down only when it falls below ``exit[level]``, so the engine
does not flap at a threshold. Levels are cumulative:

    0  full service
    1  prefix-cache registration of new chains pauses (lookups still hit)
    2  speculative decoding falls back to plain paged decode
    3  the admission decode-reserve doubles (admission tightens)

Every effect is reversible — when pressure clears, the ladder walks back
to level 0 and full service resumes. Level changes are deterministic in
the pressure sequence (no RNG, no wall clock), which is what makes the
chaos tests' recovery assertions exact.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence, Tuple


@dataclasses.dataclass
class GuardConfig:
    """Robustness policy knobs for ``ContinuousEngine``."""

    max_queue: int = 0  # arrived-and-waiting cap; 0 = unbounded
    default_ttl: float = 0.0  # seconds from arrival to deadline; 0 = none
    watchdog_s: float = 0.0  # burst wall-time trip threshold; 0 = off
    degradation: bool = False  # enable the ladder
    ladder_enter: Tuple[float, ...] = (1.0, 2.0, 3.0)  # pressure to step up
    ladder_exit: Tuple[float, ...] = (0.5, 1.0, 1.5)  # pressure to step down
    urgency_horizon: float = 0.25  # a running request within this many
    # seconds of its deadline counts as urgent (pressure term)

    def __post_init__(self):
        if self.max_queue < 0:
            raise ValueError("max_queue must be >= 0 (0 = unbounded)")
        if self.default_ttl < 0:
            raise ValueError("default_ttl must be >= 0 (0 = no deadline)")
        if self.watchdog_s < 0:
            raise ValueError("watchdog_s must be >= 0 (0 = off)")
        if len(self.ladder_enter) != len(self.ladder_exit):
            raise ValueError("ladder_enter and ladder_exit must pair up")
        for lo, hi in zip(self.ladder_exit, self.ladder_enter, strict=True):
            if lo >= hi:
                raise ValueError(
                    f"ladder hysteresis needs exit < enter per level "
                    f"(got exit {lo} >= enter {hi})"
                )
        if any(
            b <= a
            for a, b in zip(self.ladder_enter, self.ladder_enter[1:], strict=False)
        ):
            raise ValueError("ladder_enter thresholds must be ascending")

    @property
    def active(self) -> bool:
        """Whether any guard mechanism is on (the engine skips the whole
        guard pass otherwise)."""
        return bool(
            self.max_queue
            or self.default_ttl
            or self.watchdog_s
            or self.degradation
        )


class DegradationLadder:
    """Hysteresis state machine from pressure to service level.

    ``update(pressure)`` moves the level at most one step per call:
    up when ``pressure >= enter[level]`` (the next level's threshold),
    down when ``pressure < exit[level - 1]``. One step per round keeps
    the engine's reaction smooth under a pressure spike and makes the
    recovery trajectory testable round by round.

    **Pressure sources.** Beyond the scalar the caller passes (queue
    backlog + deadline urgency + watchdog bumps), additional sources
    register via ``add_pressure_source(fn)`` — each is a zero-argument
    callable returning a non-negative pressure contribution, summed into
    every ``update``. The SLO monitor (serving/slo.py) is the first
    consumer: a measured error-budget burn walks the ladder even when
    backlog alone wouldn't. ``last_pressure`` exposes the total the last
    ``update`` acted on (telemetry reads it instead of re-deriving).
    """

    def __init__(
        self,
        enter: Sequence[float] = (1.0, 2.0, 3.0),
        exit: Sequence[float] = (0.5, 1.0, 1.5),
    ):
        if len(enter) != len(exit):
            raise ValueError("enter and exit must pair up")
        self.enter = tuple(float(x) for x in enter)
        self.exit = tuple(float(x) for x in exit)
        self.level = 0
        self.max_level = len(self.enter)
        self.transitions = 0  # level changes (both directions)
        self.last_pressure = 0.0  # total pressure at the last update
        self._sources: list = []  # extra pressure callables, summed in

    def add_pressure_source(self, fn) -> None:
        """Register ``fn() -> float`` as an additional pressure term."""
        self._sources.append(fn)

    def update(self, pressure: float) -> int:
        for fn in self._sources:
            pressure += fn()
        self.last_pressure = pressure
        if self.level < self.max_level and pressure >= self.enter[self.level]:
            self.level += 1
            self.transitions += 1
        elif self.level > 0 and pressure < self.exit[self.level - 1]:
            self.level -= 1
            self.transitions += 1
        return self.level
