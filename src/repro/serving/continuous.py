"""Continuous-batching engine: slot-recycled decode over a shared KV cache.

The static ``ServeEngine`` starts every request together and burns decode
steps on finished slots until the whole batch drains. This engine keeps a
fixed pool of ``n_slots`` cache slots and a ``Scheduler``: when a slot
finishes (EOS or token budget) it is released and the next arrived request
is prefilled *into that slot* (``transformer.prefill_slot``) while the other
slots keep decoding — per-slot position vectors make the ragged decode
exact. Decode is the memory-bound regime where the packed SLiM weight
stream pays off, so slot occupancy is the lever on realized tokens/s.

Cache layout is selected by ``block_size``. The default (0) reserves one
contiguous ``max_len`` lane per slot — slot count x context length is a
hard HBM tradeoff. ``block_size > 0`` switches to the *paged* cache: a
shared pool of ``n_blocks`` fixed-size blocks, a per-slot block table, and
a host-side ``BlockAllocator`` the scheduler consults at admission — a
request occupies ``ceil((prompt + max_new) / block_size)`` blocks instead
of a ``max_len`` lane, so concurrency is bounded by *actual* cache use and
more slots fit the same memory (``benchmarks/bench_serving.py`` measures
it). Both layouts are token-exact under greedy decoding; the contiguous
path is the ``block_size == 0`` degenerate case.

``prefix_cache=True`` (paged, pure-attention archs only) shares identical
prompt-prefix blocks between requests: admission matches the longest
cached block-aligned prefix in the allocator's content-hash index, points
the new slot's table at those shared blocks (refcount++), and prefills
*only the uncached suffix* at an offset — RoPE positions and the slot's
pos start at ``cached_len``, and suffix attention spans the shared blocks
it did not write. A fully cached prompt copies its last block before the
last-token recompute (copy-on-write), so no slot ever writes a block with
refcount > 1.

``preemption=True`` (paged only) switches admission from worst-case
charging to **on-demand allocation**: a request is charged only its
prompt's blocks (plus a configurable ``decode_reserve`` watermark of
unallocated headroom), and the engine extends each slot's block table
just before a decode burst would cross into blocks it does not own.
When the pool genuinely runs dry the engine *preempts*: the
youngest-admitted running slot is evicted — its generated tokens are
folded into its prompt and it re-queues at its original arrival — and
its blocks return to the pool (demoted to refcount-0 cached entries
when the prefix cache is on, so the resume re-prefill is mostly a hit).
Resume is a plain prefill of the longer prompt with the remaining
budget: token-exact under greedy decoding, for pure-attention and
hybrid archs alike (the re-prefill recomputes SSM state from scratch).

``speculative=K`` (paged, pure-attention archs) turns on self-speculative
decoding: each burst round drafts K-1 tokens per slot with the SLiM
adapter path disabled (the quantized-sparse backbone is a strictly
cheaper forward of the same weights), verifies the whole K-token window
in one batched full-model offset-prefill pass, and bulk-commits the
accepted prefix — up to K tokens per slot per round, token-exact under
greedy decoding because everything committed (tokens, carry logits, and
the window's K/V overwrites) comes from the full model. See
``serving/speculative.py`` and docs/serving.md §Speculative decoding.

Device/host split: the decode step carries logits, per-slot positions, the
active mask, emitted counts, and the output token buffer entirely on
device; the host syncs two small vectors (active, emitted) once per
``sync_every``-step burst to run the scheduler, and fetches token buffers
only when a slot finishes. No per-token host round-trips. In paged mode
the block tables live host-side with the allocator; only the dirty slot
rows are updated on device when admissions/releases change them. The
host mirrors each slot's position as ``prompt_len + emitted`` (exact for
live rows), so on-demand growth needs no extra device sync.
"""
from __future__ import annotations

import dataclasses
import os
import time
import warnings
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.block_pool import (
    NULL_BLOCK,
    RESERVED_BLOCKS,
    TRASH_BLOCK,
    BlockAllocator,
    blocks_needed,
)
from repro.serving.config import EngineConfig
from repro.serving.export import atomic_write_json
from repro.serving.faults import FaultPlan
from repro.serving.guard import DegradationLadder
from repro.serving.metrics import ServingMetrics
from repro.serving.request import Request, RequestState
from repro.serving.sampling import degenerate_rows, sample_and_emit
from repro.serving.scheduler import NeverAdmittable, Scheduler
from repro.serving.tracing import (
    ENGINE_TID,
    QUEUE_TID,
    FlightRecorder,
    SpanTracer,
    slot_tid,
)

Params = Dict[str, Any]


@dataclasses.dataclass
class ContinuousResult:
    requests: List[Request]  # outputs filled in, input order
    metrics: Dict[str, float]  # ServingMetrics.summary()
    slot_of: Dict[int, int]  # rid -> slot it ran in

    @property
    def outputs(self) -> Dict[int, List[int]]:
        return {r.rid: r.output for r in self.requests}


class ContinuousEngine:
    def __init__(
        self,
        params: Params,
        cfg: ModelConfig,
        config: Optional[EngineConfig] = None,  # the one front door for
        # engine shape and policy — see serving/config.py. None + flat
        # legacy kwargs builds one through the deprecation shim below.
        *,
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
        trace: Any = None,  # SpanTracer (or True for a default one):
        # record the request lifecycle as Chrome trace events — see
        # serving/tracing.py and docs/observability.md. None = off
        # (unless config.trace asks for a default tracer), and every
        # trace site reduces to one `is not None` check.
        faults: Optional[FaultPlan] = None,  # chaos fail-point plan: the
        # engine consults it at each fault site (serving/faults.py) and
        # folds fired counts into the metrics summary as fault_* keys.
        # None = no injection, one `is not None` check per site.
        **legacy: Any,  # the pre-config flat kwargs (n_slots=, block_size=,
        # speculative=, guard=, ...) — deprecated for one release: warns
        # once per construction and maps onto an EngineConfig.
    ):
        assert cfg.input_mode == "tokens", "continuous engine serves token prompts"
        if config is None:
            config = EngineConfig.from_legacy_kwargs(legacy)
            if legacy:
                warnings.warn(
                    "flat ContinuousEngine kwargs are deprecated; build an "
                    "EngineConfig (repro.serving.config) instead — got: "
                    + ", ".join(sorted(legacy)),
                    DeprecationWarning,
                    stacklevel=2,
                )
        elif legacy:
            raise TypeError(
                "pass an EngineConfig or flat legacy kwargs, not both "
                "(got config= plus: " + ", ".join(sorted(legacy)) + ")"
            )
        # every incoherent combination dies here, before any replica state
        # exists — not deep inside the serve loop
        config.validate(cfg)
        self.config = config
        self.params = params
        self.cfg = cfg
        self.n_slots = config.n_slots
        self.max_len = config.max_len
        self.eos_id = config.eos_id
        self.prefill_bucket = config.prefill_bucket
        self.seed = config.seed
        self.block_size = config.paging.block_size
        self.prefix_cache = config.prefix_cache.enabled
        self.preemption = config.paging.preemption
        self.decode_reserve = config.paging.decode_reserve
        self.check_invariants = config.check_invariants
        self.speculative = config.speculative.k
        self.victim_policy = config.paging.victim_policy
        self.prefix_cache_max_entries = config.prefix_cache.max_entries
        self.prefix_cache_ttl = config.prefix_cache.ttl
        self.guard = config.guard
        self.faults = faults
        # -- live observability surface (serving/export.py reads these
        # from its own thread; all plain host attributes, pure reads) --
        self.metrics: Optional[ServingMetrics] = None  # this/last run's
        self.recorder: Optional[FlightRecorder] = None  # flight recorder
        self.live_level = 0  # last degradation-ladder level
        self._live_now: Optional[Callable[[], float]] = None  # engine clock
        self._last_burst_t: Optional[float] = None  # engine-clock stamp
        self._serving = False
        self._running_view: Dict[int, Request] = {}
        n_slots, max_len = config.n_slots, config.max_len
        eos_id, block_size = config.eos_id, config.paging.block_size
        speculative = config.speculative.k
        # True -> a fresh default tracer; a SpanTracer -> used as-is
        # (an *empty* tracer is falsy via __len__, so no truthiness
        # shortcuts here); anything else (None, False) -> disabled
        if trace is None and config.trace:
            trace = True
        if trace is True:
            self.tracer: Optional[SpanTracer] = SpanTracer()
        elif isinstance(trace, SpanTracer):
            self.tracer = trace
        else:
            self.tracer = None
        # -- tensor parallelism (config.parallel.tp > 1) ----------------
        # the SLiM weight tensors (int4 packed + 2:4 sparse + LoRA
        # adapters) shard over the serving mesh's "model" axis once, at
        # construction; the KV pool and decode carries follow in run().
        # Block tables and the allocator stay host-side and replica-local,
        # so the scheduler never sees the mesh.
        self.tp = config.parallel.tp
        self.mesh = None
        self._repl_ns = None  # fully-replicated NamedSharding for carries
        self._cache_ns = None  # KV pool leaf shardings, set per run()
        if self.tp > 1:
            from jax.sharding import PartitionSpec

            from repro.launch.mesh import make_serving_mesh
            from repro.models import sharding as shardlib

            self.mesh = make_serving_mesh(self.tp)
            self._repl_ns = jax.sharding.NamedSharding(
                self.mesh, PartitionSpec()
            )
            specs = shardlib.param_specs(params, cfg, self.mesh, serving=True)
            self.params = jax.device_put(
                params, shardlib.named(self.mesh, specs)
            )
        self.max_blocks = max_len // block_size if block_size > 0 else 0
        # speculative drafting writes up to K positions past a slot's
        # committed budget (the last round's verify window); block tables
        # get that much scratch tail so draft writes land in blocks the
        # slot owns, never clipped into a committed (shareable) block
        self.spec_blocks = (
            blocks_needed(speculative, block_size)
            if speculative and block_size > 0
            else 0
        )
        self.table_blocks = self.max_blocks + self.spec_blocks
        if block_size > 0:
            self.n_blocks = (
                n_slots * self.table_blocks + RESERVED_BLOCKS
                if config.paging.n_blocks is None
                else config.paging.n_blocks
            )
        else:
            self.n_blocks = 0
        if clock is None:
            self._clock, self._sleep = time.time, time.sleep
        else:
            # a custom clock must come with a sleep that advances it — a real
            # time.sleep against a frozen clock would spin the idle wait
            # forever when the queue holds only future arrivals
            self._clock = clock
            self._sleep = sleep if sleep is not None else getattr(clock, "sleep", None)
            if self._sleep is None:
                raise ValueError(
                    "custom clock needs a sleep(dt) (attribute or `sleep=` "
                    "argument) that advances it"
                )
        self._ragged = T.supports_ragged_prefill(cfg)

        ragged = self._ragged

        def _admit(
            params, cache, logits, pos, active, emitted, maxnew, temps,
            toks, true_len, slot, budget, temp, table,
        ):
            """Prefill one request into ``slot`` and splice its carry state
            (logits row, position, budget, sampling) in the same jit call —
            one dispatch per admission instead of one per state vector."""
            cache = self._pin_cache(cache)
            row, cache = T.prefill_slot(
                params, cfg, cache, {"tokens": toks}, slot, max_len,
                true_len if ragged else None, block_table=table,
            )
            logits = logits.at[slot].set(row[0])
            pos = pos.at[slot].set(true_len)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(0)
            maxnew = maxnew.at[slot].set(budget)
            temps = temps.at[slot].set(temp)
            return self._pin_carry(
                cache, logits, pos, active, emitted, maxnew, temps
            )

        self._admit_fn = _admit

        def _admit_prefix(
            params, cache, logits, pos, active, emitted, maxnew, temps,
            toks, true_suffix, cached_len, slot, budget, temp, table,
            cow_src, cow_dst,
        ):
            """Prefix-cache admission: the slot's table row already names
            shared blocks for positions [0, cached_len); copy-on-write the
            fully-cached last block if needed (``cow_src == cow_dst ==
            null`` makes it a no-op self-copy), then prefill only the
            uncached suffix at an offset. One dispatch per admission."""
            cache = self._pin_cache(cache)
            cache = jax.tree.map(
                lambda a: a.at[:, cow_dst].set(a[:, cow_src]), cache
            )
            row, cache = T.prefill_slot(
                params, cfg, cache, {"tokens": toks}, slot, max_len,
                true_suffix, block_table=table, cached_len=cached_len,
            )
            logits = logits.at[slot].set(row[0])
            pos = pos.at[slot].set(cached_len + true_suffix)
            active = active.at[slot].set(True)
            emitted = emitted.at[slot].set(0)
            maxnew = maxnew.at[slot].set(budget)
            temps = temps.at[slot].set(temp)
            return self._pin_carry(
                cache, logits, pos, active, emitted, maxnew, temps
            )

        self._admit_prefix_fn = _admit_prefix

        eos = -1 if eos_id is None else int(eos_id)  # -1 never matches a token

        def _step(
            params, cache, logits, pos, active, emitted, maxnew, buf, key,
            temps, table, poisoned,
        ):
            # quarantine carry: a row whose logits are degenerate (any
            # NaN/Inf, or all -inf — injected chaos, or real corruption
            # surfacing through attention) emits nothing, leaves the
            # active set, and is latched into `poisoned` for the per-
            # burst host sync. Only the offending row: rows never mix in
            # sampling or attention, so co-batched requests are untouched.
            cache = self._pin_cache(cache)
            bad = degenerate_rows(logits) & active
            poisoned = poisoned | bad
            live = active & ~bad
            nxt, buf, emitted, hit_eos, key = sample_and_emit(
                logits, temps, key, buf, live, emitted, eos
            )
            finished = live & (hit_eos | (emitted >= maxnew))
            still = live & ~finished
            logits, cache = T.decode_step(
                params, self.cfg, cache, nxt[:, None], pos, block_table=table
            )
            # freeze finished/inactive rows: their slot is garbage until the
            # next prefill_slot replaces it wholesale (paged: their writes
            # land in the trash block once the host retires the table row)
            pos = pos + still.astype(jnp.int32)
            return self._pin_carry(
                cache, logits, pos, still, emitted, buf, key, poisoned
            )

        self._step_fn = _step

        # the retrace guard persists across run() calls: a second serve on
        # the same engine must perform ZERO compiles (the post-warmup
        # invariant tests pin down via guard.freeze())
        self.check_retrace = config.check_retrace
        self.retrace_guard = None
        if config.check_retrace:
            from repro.analysis.retrace import RetraceGuard

            self.retrace_guard = RetraceGuard()
        self._admit = self._admit_prefix = self._step = None
        if self.mesh is None:
            # single-device: jit the hot paths now. Under TP they wait
            # for the first run(), which knows the KV pool layout and
            # pins each jit's out_shardings with it (_build_jits).
            self._build_jits()

        self._eos = eos
        # speculative rounds are built lazily per sampling mode: an
        # all-greedy trace gets the RNG-free round variant (argmax
        # drafting + longest-prefix acceptance), anything else the
        # rejection-sampling one
        self._spec_rounds: Dict[bool, Any] = {}

    def _build_jits(self) -> None:
        """Jit + (optionally) guard-wrap the hot paths.

        Under tensor parallelism every output sharding is pinned
        explicitly: the KV pool to its cache specs, carries fully
        replicated. GSPMD would otherwise hand back *canonicalized*
        sharding objects that compare unequal to the run() loop's
        device_put specs, and the second call — same shapes,
        "different" shardings — would recompile, tripping the retrace
        guard. With out_shardings the steady-state decode signature is
        unique from the first call (max_sigs=1 holds under TP)."""
        kw: Dict[str, Any] = {}
        if self.mesh is not None:
            kw = {
                "out_shardings": (self._cache_ns,) + (self._repl_ns,) * 6
            }
        # one compile per prefill shape (bounded by bucketing); carry
        # donated — and per suffix shape for the prefix variant
        admit = jax.jit(
            self._admit_fn, donate_argnums=(1, 2, 3, 4, 5, 6, 7), **kw
        )
        admit_prefix = jax.jit(
            self._admit_prefix_fn, donate_argnums=(1, 2, 3, 4, 5, 6, 7), **kw
        )
        if self.mesh is not None:
            kw = {
                "out_shardings": (self._cache_ns,) + (self._repl_ns,) * 7
            }
        step = jax.jit(self._step_fn, donate_argnums=(1,), **kw)
        if self.retrace_guard is not None:
            # prefill compiles once per bucket shape — bounded but not
            # statically known here, so no max_sigs; the decode step is
            # fixed-shape: a second signature IS the bug
            admit = self.retrace_guard.wrap("prefill", admit)
            admit_prefix = self.retrace_guard.wrap(
                "prefill_prefix", admit_prefix
            )
            step = self.retrace_guard.wrap("decode", step, max_sigs=1)
        self._admit, self._admit_prefix, self._step = (
            admit, admit_prefix, step,
        )

    def _spec_round_for(self, greedy: bool):
        fn = self._spec_rounds.get(greedy)
        if fn is None:
            # lazy import: speculative.py imports ContinuousEngine
            from repro.serving.speculative import build_spec_round

            out = None
            if self.mesh is not None:
                # pinned like _build_jits: pool + 8 replicated carries
                out = (self._cache_ns,) + (self._repl_ns,) * 8
            fn = build_spec_round(
                self.cfg, self.speculative, self._eos, greedy=greedy,
                out_shardings=out,
            )
            if self.retrace_guard is not None:
                # fixed-shape like the decode step: one signature, ever
                fn = self.retrace_guard.wrap(
                    f"spec_round_{'greedy' if greedy else 'sampled'}",
                    fn, max_sigs=1,
                )
            self._spec_rounds[greedy] = fn
        return fn

    # -- tensor-parallel sharding constraints (trace-time no-ops when ----
    # -- the engine runs without a mesh) ---------------------------------

    def _pin_cache(self, cache):
        """Constrain the KV pool to its run()-time layout (kv heads over
        the mesh's "model" axis, per models/sharding.py cache specs).
        Identity without a mesh."""
        if self._cache_ns is None:
            return cache
        return jax.tree.map(
            lambda leaf, ns: jax.lax.with_sharding_constraint(leaf, ns),
            cache, self._cache_ns,
        )

    def _pin_carry(self, cache, *carries):
        """Constrain a hot-path return value: pool to its cache specs,
        every small carry (logits, positions, masks, token buffer, RNG
        key) fully replicated. Pinning *outputs* to the same layout the
        run() loop commits *inputs* with keeps the jit signature of the
        decode step unique — the retrace guard's max_sigs=1 contract
        holds under tensor parallelism with zero steady-state compiles
        and no new sync points."""
        if self._repl_ns is None:
            return (cache, *carries)
        cache = self._pin_cache(cache)
        carries = tuple(
            jax.lax.with_sharding_constraint(x, self._repl_ns)
            for x in carries
        )
        return (cache, *carries)

    # ------------------------------------------------------------------

    def live_status(self) -> Dict[str, Any]:
        """Health view for the live exporter's ``/healthz``: a pure read
        of host attributes the serve loop maintains (no device syncs, no
        locks — callable from the exporter thread mid-run)."""
        now = self._live_now() if self._live_now is not None else None
        age = None
        if now is not None and self._last_burst_t is not None:
            age = round(max(now - self._last_burst_t, 0.0), 6)
        return {
            "status": "serving" if self._serving else "idle",
            "degradation_level": int(self.live_level),
            "last_burst_age_s": age,
            "requests_in_flight": len(self._running_view),
        }

    def run(
        self,
        requests: Sequence[Request],
        sync_every: int = 8,
        max_new_cap: Optional[int] = None,  # pin the buffer width (jit shape)
    ) -> ContinuousResult:
        if self.mesh is None:
            return self._run(requests, sync_every, max_new_cap)
        # activation constraints inside attention (models/layers.py
        # shard_heads) and the cache specs inside decode/prefill consult
        # the ambient serving mesh at trace time
        from repro.models import sharding as shardlib

        with shardlib.use_serving_mesh(self.mesh):
            return self._run(requests, sync_every, max_new_cap)

    def _run(
        self,
        requests: Sequence[Request],
        sync_every: int,
        max_new_cap: Optional[int],
    ) -> ContinuousResult:
        cfg, b = self.cfg, self.n_slots
        paged = self.block_size > 0
        allocator = (
            BlockAllocator(
                self.n_blocks, self.block_size,
                prefix_cache=self.prefix_cache,
                prefix_cache_max_entries=self.prefix_cache_max_entries,
            )
            if paged
            else None
        )
        sched = Scheduler.from_config(self.config, allocator)
        obs = self.config.observability
        metrics = ServingMetrics(
            b, window=obs.window_s, window_subs=obs.window_subs
        )
        # retained on the engine so the live exporter (and the router's
        # fleet merge) can read rolling-window state mid-run
        self.metrics = metrics
        rec = (
            FlightRecorder(obs.flight_recorder_events)
            if obs.recorder_active
            else None
        )
        self.recorder = rec
        pm_dir = obs.postmortem_dir
        if pm_dir:
            os.makedirs(pm_dir, exist_ok=True)
        compiles0 = (
            self.retrace_guard.compiles()
            if self.retrace_guard is not None
            else {}
        )
        guard = self.guard
        faults = self.faults
        tr0 = self.tracer

        def postmortem(req: Request, t: float) -> None:
            """Dump a terminal request's flight-recorder bundle (FAILED /
            EXPIRED / ABORTED terminals only) and forget its ring. The
            write is atomic (temp + rename), so a chaos crash mid-dump
            never leaves a truncated bundle."""
            if rec is None:
                return
            if pm_dir:
                ctx: Dict[str, Any] = {
                    "t": round(t, 6),
                    "degradation_level": int(self.live_level),
                    "queue_depth": sched.queue_depth(),
                }
                if faults is not None:
                    ctx["faults"] = faults.summary()
                atomic_write_json(
                    os.path.join(pm_dir, f"postmortem_rid{req.rid}.json"),
                    rec.bundle(req, ctx),
                )
            rec.discard(req.rid)

        def submit(r: Request) -> bool:
            """Submit one request; a never-admittable one (block need
            beyond the whole pool, prompt+budget beyond max_len) fails
            fast — terminal FAILED for *that request only*, instead of
            an exception killing the run or an eternal FIFO defer."""
            if (
                guard is not None
                and guard.default_ttl
                and r.deadline is None
            ):
                r.deadline = r.arrival + guard.default_ttl
            metrics.on_submit(r.rid, r.arrival)
            if rec is not None:
                rec.record(
                    r.rid, r.arrival, "submit",
                    prompt_len=len(r.prompt),
                    max_new_tokens=r.max_new_tokens,
                    deadline=r.deadline,
                )
            try:
                sched.submit(r)
            except NeverAdmittable as e:
                r.state = RequestState.FAILED
                r.error = str(e)
                metrics.on_failed(r.rid, r.arrival)
                if tr0 is not None:
                    tr0.instant(
                        "failed_submit", QUEUE_TID, r.arrival, {"rid": r.rid}
                    )
                if rec is not None:
                    rec.record(
                        r.rid, r.arrival, "failed_submit", error=str(e)
                    )
                    postmortem(r, r.arrival)
                return False
            return True

        for r in requests:
            submit(r)
        flood_extra: List[Request] = []  # queue_flood synthetic arrivals
        cap = max_new_cap or max((r.max_new_tokens for r in requests), default=1)
        over = [r.rid for r in requests if r.max_new_tokens > cap]
        if over:
            raise ValueError(
                f"requests {over} exceed max_new_cap={cap}; outputs would be "
                "silently truncated"
            )
        use_deadlines = bool(
            guard is not None and guard.default_ttl
        ) or any(r.deadline is not None for r in requests)
        ladder = (
            DegradationLadder(guard.ladder_enter, guard.ladder_exit)
            if guard is not None and guard.degradation
            else None
        )
        slo = None
        if obs.slo_active and ladder is not None:
            # lazy import: slo.py imports the metrics facade this module
            # already constructed
            from repro.serving.slo import SloMonitor

            slo = SloMonitor(obs, metrics)
            ladder.add_pressure_source(slo.pressure)
        self.live_level = 0
        base_reserve = sched.decode_reserve
        wd_pressure = 0.0  # decaying pressure bump from watchdog trips

        cache = T.init_cache(
            cfg, b, self.max_len, self.block_size, self.n_blocks
        )
        # block tables are host-owned (the allocator's view); inactive rows
        # point wholesale at the trash block so their decode writes can
        # never land in a block that has been reallocated
        table_np = (
            np.full((b, self.table_blocks), TRASH_BLOCK, np.int32)
            if paged
            else None
        )
        table_dev = jnp.asarray(table_np) if paged else None
        logits = jnp.zeros((b, cfg.vocab_size), jnp.float32)
        pos = jnp.zeros((b,), jnp.int32)
        active = jnp.zeros((b,), bool)
        emitted = jnp.zeros((b,), jnp.int32)
        maxnew = jnp.ones((b,), jnp.int32)
        buf = jnp.zeros((b, cap), jnp.int32)
        temps = jnp.zeros((b,), jnp.float32)
        key = jax.random.PRNGKey(self.seed)
        # cumulative (accepted, proposed) draft counts, device-resident so
        # speculative rounds never force an extra host sync
        spec_counters = jnp.zeros((2,), jnp.int32)
        # quarantine latch: set inside the decode/verify step when a row's
        # logits go degenerate, fetched with the regular burst sync, and
        # cleared host-side when the slot is quarantined or recycled
        poisoned = jnp.zeros((b,), bool)

        if self.mesh is not None:
            # commit the device state once, before the first trace: the
            # pool sharded per models/sharding.py cache specs, everything
            # else replicated. Committed shardings key the jit cache, and
            # every hot-path jit pins its out_shardings to the same
            # layout (_build_jits), so warm runs never see a second
            # decode signature.
            from repro.models import sharding as shardlib

            self._cache_ns = shardlib.named(
                self.mesh, shardlib.cache_specs(cache, cfg, self.mesh, b)
            )
            if self._admit is None:
                self._build_jits()  # first run: layout is now known
            cache = jax.device_put(cache, self._cache_ns)
            (
                logits, pos, active, emitted, maxnew, buf, temps, key,
                spec_counters, poisoned,
            ) = (
                jax.device_put(x, self._repl_ns)
                for x in (
                    logits, pos, active, emitted, maxnew, buf, temps, key,
                    spec_counters, poisoned,
                )
            )
            if table_dev is not None:
                table_dev = jax.device_put(table_dev, self._repl_ns)
        # built after the mesh block: under TP the speculative round's
        # out_shardings need the cache layout committed above
        spec_fn = (
            self._spec_round_for(all(r.temperature == 0 for r in requests))
            if self.speculative
            else None
        )

        running: Dict[int, Request] = {}  # slot -> request
        emitted_host: Dict[int, int] = {}  # slot -> emitted as of last sync
        # a running slot's position is always len(serving prompt) + emitted;
        # generated only mutates at preempt, after the slot leaves `running`
        def slot_pos0(slot: int) -> int:
            r = running[slot]
            return r.prompt_len + len(r.generated)
        peak_running = 0
        t0 = self._clock()

        def now() -> float:
            return self._clock() - t0

        # live-exporter hooks: the engine clock, the running view, and
        # fault visibility. All host-side state the exporter thread reads
        # without touching the device or the serve loop.
        self._live_now = now
        self._last_burst_t = None
        self._running_view = running
        self._serving = True
        if faults is not None:
            faults.on_fire = lambda site: metrics.on_fault(site, now())

        tr = self.tracer
        span_start: Dict[int, float] = {}  # slot -> running-span start
        if tr is not None:
            tr.name_slots(b)
            if allocator is not None:
                # point evictions (clock-hand reclaim, index drops) fire
                # deep inside the allocator; surface them as instants
                allocator.on_event = lambda name, args: tr.instant(
                    name, ENGINE_TID, now(), args
                )
        # host wall-time attribution: every stretch of the loop is charged
        # to the phase that ends it (schedule / prefill / decode / verify),
        # on the host's monotonic clock — idle waits are charged nowhere
        ph_last = time.perf_counter()

        def phase(name: str) -> None:
            nonlocal ph_last
            t = time.perf_counter()
            metrics.on_phase(name, t - ph_last)
            ph_last = t

        def phase_skip() -> None:
            nonlocal ph_last
            ph_last = time.perf_counter()

        def push_rows(slots) -> None:
            """Mirror dirty host-side block-table rows to the device in
            one dispatch; the rest of the table stands untouched."""
            nonlocal table_dev
            dirty = np.asarray(sorted(set(slots)))
            table_dev = table_dev.at[dirty].set(jnp.asarray(table_np[dirty]))

        def wipe_pos(cache, blocks):
            """Invalidate recycled blocks before any decode gather can
            reach them: a prior owner's pos entries must never enter an
            attention mask (the K/V payload is masked garbage)."""
            wipe = jnp.asarray(sorted(set(blocks)), jnp.int32)
            return {
                lk: (
                    {**lv, "pos": lv["pos"].at[:, wipe].set(-1)}
                    if "pos" in lv
                    else lv
                )
                for lk, lv in cache.items()
            }

        def corrupt_block(cache, blk: int):
            """Chaos helper (``kv_corrupt``): overwrite one physical
            block's payload with NaN in every float-dtype leaf. The
            "pos" leaf is left intact so attention keeps gathering the
            corrupted payload — the failure must surface through the
            real read path, not vanish behind a mask. Quantized (int8)
            k/v leaves cannot hold NaN; there the per-block scales are
            float and carry the poison instead."""
            return {
                lk: {
                    name: (
                        leaf.at[:, blk].set(jnp.nan)
                        if name != "pos"
                        and jnp.issubdtype(leaf.dtype, jnp.floating)
                        else leaf
                    )
                    for name, leaf in lv.items()
                }
                for lk, lv in cache.items()
            }

        def preempt_slot(victim: int) -> None:
            """Evict ``victim``: stitch its emitted-so-far tokens into its
            resume prompt (the scheduler re-queues it), return its blocks
            to the pool, and silence its device row. The row's pending
            writes land in the trash block once the table update below
            reaches the device — before the next burst."""
            nonlocal active
            req = running.pop(victim)
            em = emitted_host.pop(victim)
            toks = (
                # preemption is rare by construction (pool pressure); the
                # victim's emitted tokens must survive the eviction
                [int(t) for t in jax.device_get(buf[victim])[:em]]  # slimcheck: sync-site
                if em > 0
                else []
            )
            sched.preempt(victim, toks)
            table_np[victim] = TRASH_BLOCK
            active = active.at[victim].set(False)
            t_ev = now()
            metrics.on_preempt(req.rid, t_ev)
            if rec is not None:
                rec.record(req.rid, t_ev, "preempt", emitted=em)
            if tr is not None:
                tr.instant(
                    "preempt", slot_tid(victim), t_ev,
                    {"rid": req.rid, "emitted": em},
                )
                tr.complete(
                    "request", slot_tid(victim),
                    span_start.pop(victim, t_ev), t_ev,
                    {"rid": req.rid, "preempted": True},
                )

        def cancel_slot(
            slot: int,
            state: RequestState,
            err: str,
            keep_tokens: bool,
        ) -> Request:
            """Host-side cancellation: terminate the request running in
            ``slot`` without waiting for its decode to finish. The device
            row is silenced (active off, table row to trash) and the
            blocks released; ``keep_tokens=False`` (quarantine) discards
            the output entirely — a poisoned slot's tokens are untrusted
            — and keeps its blocks out of the prefix cache."""
            nonlocal active, poisoned
            req = running.pop(slot)
            em = emitted_host.pop(slot)
            if keep_tokens and em > 0:
                # cancellations are rare (deadline/quarantine events);
                # the partial output must survive the slot teardown
                toks = [int(t) for t in jax.device_get(buf[slot])[:em]]  # slimcheck: sync-site
            else:
                toks = []
            req.output = req.generated + toks if keep_tokens else None
            req.error = err
            if (
                not keep_tokens
                and allocator is not None
                and allocator.prefix_cache
            ):
                # a quarantined slot's blocks may hold corrupted KV; they
                # must never be matchable from the hash index again
                allocator.purge_slot_index(slot)
            sched.release(slot, tokens=None, state=state)
            if paged:
                table_np[slot] = TRASH_BLOCK
            active = active.at[slot].set(False)
            poisoned = poisoned.at[slot].set(False)
            t_ev = now()
            if tr is not None:
                name = (
                    "quarantine"
                    if state is RequestState.FAILED
                    else "expire"
                )
                tr.instant(
                    name, slot_tid(slot), t_ev,
                    {"rid": req.rid, "emitted": em},
                )
                tr.complete(
                    "request", slot_tid(slot),
                    span_start.pop(slot, t_ev), t_ev,
                    {"rid": req.rid, "state": state.value},
                )
            return req

        flood_rid = -1  # synthetic queue_flood rids count down from -1

        while sched.pending() or running:
            t_round = now()
            if allocator is not None and allocator.prefix_cache:
                # keep the allocator's clock current (stamps registrations)
                # and sweep TTL-expired index entries before matching
                allocator.tick(t_round)
                if self.prefix_cache_ttl > 0:
                    allocator.expire_index(t_round - self.prefix_cache_ttl)

            # -- robustness guard pass (serving/guard.py) ---------------
            if use_deadlines:
                # reap-before-admit: an expired queued request (a
                # preemption victim past its deadline included) never
                # wastes a prefill and never re-admits
                for req in sched.reap_expired(t_round):
                    req.error = (
                        f"deadline {req.deadline:.3f}s passed at "
                        f"t={t_round:.3f}s (queued)"
                    )
                    req.output = list(req.generated) if req.generated else None
                    metrics.on_expired(req.rid, t_round)
                    if tr is not None:
                        tr.instant(
                            "expire", QUEUE_TID, t_round, {"rid": req.rid}
                        )
                    if rec is not None:
                        rec.record(req.rid, t_round, "expire", where="queued")
                        postmortem(req, t_round)
                # host-side cancellation of running slots past deadline
                expired_slots = sched.expired_running(t_round)
                for slot in expired_slots:
                    req = cancel_slot(
                        slot,
                        RequestState.EXPIRED,
                        f"deadline passed at t={t_round:.3f}s (running)",
                        keep_tokens=True,
                    )
                    metrics.on_expired(req.rid, t_round)
                    if rec is not None:
                        rec.record(
                            req.rid, t_round, "expire", where="running"
                        )
                        postmortem(req, t_round)
                if paged and expired_slots:
                    push_rows(expired_slots)
            # -- chaos fail points (serving/faults.py) ------------------
            if faults is not None:
                n_flood = faults.should_fire("queue_flood", 2 * b)
                for _ in range(n_flood):
                    fr = Request(
                        rid=flood_rid,
                        prompt=[(-flood_rid + j) % cfg.vocab_size
                                for j in range(4)],
                        arrival=t_round,
                        max_new_tokens=min(4, cap),
                    )
                    flood_rid -= 1
                    if submit(fr):
                        flood_extra.append(fr)
                if n_flood and tr is not None:
                    tr.instant(
                        "fault_queue_flood", QUEUE_TID, t_round,
                        {"n": n_flood},
                    )

            if ladder is not None:
                # pressure: arrived-and-waiting backlog per slot, plus
                # running requests close to their deadline, plus a
                # decaying bump per recent watchdog trip
                urgent = sum(
                    1
                    for r2 in running.values()
                    if r2.deadline is not None
                    and r2.deadline - t_round < guard.urgency_horizon
                )
                pressure = (
                    sched.queue.ready_count(t_round) / b
                    + urgent / b
                    + wd_pressure
                )
                wd_pressure *= 0.5
                if slo is not None:
                    # refresh the rolling-window burn before the ladder
                    # reads it (the monitor is a registered source, so
                    # update() below sums it into the total)
                    slo.update(t_round)
                prev_level = ladder.level
                level = ladder.update(pressure)
                self.live_level = level
                metrics.on_degraded(level, t_round)
                if tr is not None:
                    # last_pressure includes registered sources (SLO burn)
                    tr.counter(
                        "degradation", t_round,
                        level=level, pressure=round(ladder.last_pressure, 3),
                    )
                if rec is not None and level != prev_level:
                    # a level change is part of every in-flight request's
                    # story — stamp it into each ring
                    for r2 in running.values():
                        rec.record(
                            r2.rid, t_round, "degrade",
                            level=level, prev=prev_level,
                        )
                if allocator is not None and allocator.prefix_cache:
                    # level >= 1: stop growing the prefix index under
                    # pressure (existing chains keep serving hits)
                    allocator.register_new_chains = level < 1
                # level >= 3: tighten admission so running slots keep
                # more growth headroom (fewer preemption storms)
                sched.decode_reserve = (
                    base_reserve * 2 if level >= 3 else base_reserve
                )

            if faults is not None and faults.should_fire("admit_shortfall"):
                # simulate the allocator coming up empty at admission:
                # nothing admits this round; queued requests defer (and
                # age toward their deadlines) exactly as under real
                # pool exhaustion
                admits = []
                if tr is not None:
                    tr.instant("fault_admit_shortfall", ENGINE_TID, t_round)
            else:
                admits = sched.admit(now())
            if guard is not None and guard.max_queue:
                # shed AFTER admission: the bound caps the backlog that
                # free slots could not absorb this round — a request
                # arriving while a slot is idle is never dropped
                for req in sched.shed_overflow(t_round, guard.max_queue):
                    req.error = "shed: queue full"
                    metrics.on_shed(req.rid, t_round)
                    if tr is not None:
                        tr.instant(
                            "shed", QUEUE_TID, t_round, {"rid": req.rid}
                        )
                    if rec is not None:
                        rec.record(req.rid, t_round, "shed")
                        postmortem(req, t_round)
            if not admits and not running:
                nxt_arrival = sched.next_arrival()
                if nxt_arrival is None:
                    # the guard pass drained everything this round
                    # (expiry/shedding emptied both the queue and the
                    # running set): the run is over
                    break
                t_idle = now()
                self._sleep(max(nxt_arrival - now(), 0.0) + 1e-4)
                if tr is not None:
                    tr.complete("idle", ENGINE_TID, t_idle, now())
                phase_skip()  # idle wait is not host scheduling work
                continue

            if paged and admits:
                # bind the freshly allocated blocks before any prefill or
                # decode sees the table (unallocated tail -> null block);
                # only the dirty slot rows are pushed, in one dispatch
                wipe_admit: List[int] = []
                for slot, _ in admits:
                    blocks = allocator.blocks_of(slot)
                    table_np[slot] = NULL_BLOCK
                    table_np[slot, : len(blocks)] = blocks
                    # cold prefill overwrites the first max_blocks table
                    # entries wholesale, but a speculative request whose
                    # prompt+budget charge spills into the scratch tail
                    # (worst-case charging) binds recycled blocks there
                    # as-is — wipe their stale pos before any gather
                    if len(blocks) > self.max_blocks:
                        wipe_admit.extend(blocks[self.max_blocks :])
                push_rows(slot for slot, _ in admits)
                if wipe_admit:
                    cache = wipe_pos(cache, wipe_admit)

            if admits:
                phase("schedule")
            for slot, req in admits:
                t_admit = now()
                metrics.on_admit(req.rid, t_admit)
                if rec is not None:
                    rec.record(
                        req.rid, t_admit, "admit",
                        slot=slot, resume=req.n_preemptions > 0,
                    )
                if tr is not None:
                    # queued span: submission (arrival) -> this admission
                    tr.complete(
                        "queued", QUEUE_TID, req.arrival, t_admit,
                        {"rid": req.rid, "resume": req.n_preemptions > 0},
                    )
                    span_start[slot] = t_admit
                # a resume (after preemption) prefills the original prompt
                # plus everything generated so far, with the leftover budget
                sp = req.serving_prompt
                plen = len(sp)
                budget = req.remaining_new_tokens
                info = allocator.admit_info(slot) if self.prefix_cache else None
                if info is not None and info.hit:
                    # shared-prefix admission: prefill only the uncached
                    # suffix; the CoW block copy rides the same dispatch
                    suffix = sp[info.cached_len :]
                    blen = sched.bucket_len(len(suffix))
                    toks = jnp.asarray(
                        suffix + [0] * (blen - len(suffix)), jnp.int32
                    )[None, :]
                    (
                        cache, logits, pos, active, emitted, maxnew, temps,
                    ) = self._admit_prefix(
                        self.params, cache, logits, pos, active, emitted,
                        maxnew, temps, toks, jnp.int32(len(suffix)),
                        jnp.int32(info.cached_len), jnp.int32(slot),
                        jnp.int32(budget),
                        jnp.float32(req.temperature), table_dev,
                        jnp.int32(info.cow_src), jnp.int32(info.cow_dst),
                    )
                else:
                    blen = sched.bucket_len(plen)
                    toks = jnp.asarray(
                        sp + [0] * (blen - plen), jnp.int32
                    )[None, :]
                    (
                        cache, logits, pos, active, emitted, maxnew, temps,
                    ) = self._admit(
                        self.params, cache, logits, pos, active, emitted,
                        maxnew, temps, toks, jnp.int32(plen), jnp.int32(slot),
                        jnp.int32(budget),
                        jnp.float32(req.temperature), table_dev,
                    )
                with jax.profiler.TraceAnnotation("serve/prefill"):
                    # TTFT is defined at this fence: first token cannot be
                    # timestamped without waiting for the prefill dispatch
                    jax.block_until_ready(logits)  # slimcheck: sync-site
                t_first = now()
                metrics.on_first_token(req.rid, t_first)
                if rec is not None:
                    rec.record(req.rid, t_first, "first_token")
                if tr is not None:
                    cached = info.cached_len if info is not None else 0
                    tr.complete(
                        "prefill", slot_tid(slot), t_admit, t_first,
                        {
                            "rid": req.rid,
                            "prompt_len": plen,
                            "cached_len": cached,
                            "prefix_hit": cached > 0,
                            "resume": req.n_preemptions > 0,
                        },
                    )
                phase("prefill")
                if self.prefix_cache:
                    metrics.on_prefix_lookup(
                        req.rid, info.cached_len if info else 0, plen,
                        resume=req.n_preemptions > 0,
                    )
                running[slot] = req
                emitted_host[slot] = 0
            if paged and self.preemption and running:
                # on-demand growth: before the burst, every running slot
                # must own the blocks its next sync_every writes can touch
                # (a write through a null/stale table entry would corrupt
                # shared state). Oldest slots claim headroom first; when
                # the pool runs dry the youngest running slot is evicted
                # and re-queued — repeat until the extension fits.
                grow_dirty: List[int] = []
                fresh_blocks: List[int] = []
                # a speculative burst advances up to K per round and its
                # verify windows write up to K positions past the budget
                adv = sync_every * (self.speculative or 1)
                for slot in sorted(running, key=sched.slot_seq.__getitem__):
                    if slot not in running:
                        continue  # preempted earlier in this same pass
                    req = running[slot]
                    pos_now = slot_pos0(slot) + emitted_host[slot]
                    cap_pos = (
                        slot_pos0(slot) + req.remaining_new_tokens
                        + self.speculative
                    )
                    target = min(pos_now + adv, cap_pos)
                    while True:
                        owned = len(allocator.blocks_of(slot))
                        need = blocks_needed(target, self.block_size) - owned
                        if need <= 0:
                            break
                        if faults is not None and faults.should_fire(
                            "extend_shortfall"
                        ):
                            # simulate the pool coming up empty mid-run;
                            # the normal preemption path must absorb it
                            # without corrupting any surviving slot
                            got = None
                            if tr is not None:
                                tr.instant(
                                    "fault_extend_shortfall",
                                    slot_tid(slot), now(), {"rid": req.rid},
                                )
                        else:
                            got = allocator.extend(slot, need)
                        if got is not None:
                            table_np[slot, owned : owned + need] = got
                            grow_dirty.append(slot)
                            fresh_blocks.extend(got)
                            if tr is not None:
                                tr.instant(
                                    "grow", slot_tid(slot), now(),
                                    {"rid": req.rid, "blocks": need},
                                )
                            if rec is not None:
                                rec.record(
                                    req.rid, now(), "grow", blocks=need
                                )
                            break
                        victim = sched.pick_victim(
                            {
                                s2: len(running[s2].generated)
                                + emitted_host[s2]
                                for s2 in running
                            }
                        )
                        assert victim is not None  # running is non-empty
                        preempt_slot(victim)
                        grow_dirty.append(victim)
                        if victim == slot:
                            break  # slot was the youngest: evicted itself
                if grow_dirty:
                    push_rows(grow_dirty)
                if fresh_blocks:
                    # recycled blocks can carry a prior owner's pos entries;
                    # wipe before any decode gather can reach them through
                    # the updated table
                    cache = wipe_pos(cache, fresh_blocks)
                if not running:
                    continue  # everything was evicted; re-admit first

            peak_running = max(peak_running, len(running))
            t_round = now()
            metrics.on_queue_depth(sched.queue_depth(), t_round)
            if tr is not None:
                tr.counter("queue_depth", t_round, depth=sched.queue_depth())
            if allocator is not None:
                in_use = allocator.in_use()
                metrics.on_blocks_in_use(in_use, t_round)
                if tr is not None:
                    tr.counter("blocks_in_use", t_round, blocks=in_use)
                if self.check_invariants:
                    allocator.check()

            phase("schedule")

            # -- chaos: pre-burst device-state injections ---------------
            if faults is not None and running:
                victim = min(running, key=sched.slot_seq.__getitem__)
                if faults.should_fire("nan_logits"):
                    # poison the oldest running slot's carry logits; the
                    # in-step quarantine latch must catch it before a
                    # single token emits from the bad distribution
                    logits = logits.at[victim].set(jnp.nan)
                    if tr is not None:
                        tr.instant(
                            "fault_nan_logits", slot_tid(victim), now(),
                            {"rid": running[victim].rid},
                        )
                    if rec is not None:
                        rec.record(
                            running[victim].rid, now(), "fault",
                            site="nan_logits",
                        )
                if paged and faults.should_fire("kv_corrupt"):
                    # corrupt an exclusively-owned (refcount-1) block so
                    # the blast radius is provably one slot: CoW already
                    # guarantees shared blocks are never written, so a
                    # single-owner block is what real corruption hits
                    hit = next(
                        (
                            (s2, b2)
                            for s2 in sorted(
                                running, key=sched.slot_seq.__getitem__
                            )
                            for b2 in allocator.blocks_of(s2)
                            if allocator.refcount(b2) == 1
                        ),
                        None,
                    )
                    if hit is not None:
                        cache = corrupt_block(cache, hit[1])
                        if tr is not None:
                            tr.instant(
                                "fault_kv_corrupt", slot_tid(hit[0]), now(),
                                {"rid": running[hit[0]].rid, "block": hit[1]},
                            )
                        if rec is not None:
                            rec.record(
                                running[hit[0]].rid, now(), "fault",
                                site="kv_corrupt", block=hit[1],
                            )

            # degradation level >= 2 swaps the speculative round for the
            # plain paged decode step: strictly cheaper per dispatch, and
            # already a registered hot path (compiles once under the
            # retrace guard's max_sigs=1 — a mode switch, not a retrace)
            use_spec = bool(self.speculative) and not (
                ladder is not None and ladder.level >= 2
            )
            t_burst = now()
            if use_spec:
                # each round is one dispatch: K-1 backbone draft steps,
                # a batched full-model verify of every slot's window, and
                # the rejection-sampled bulk commit
                metrics.on_decode_steps(sync_every * self.speculative)
                with jax.profiler.TraceAnnotation("serve/speculative_burst"):
                    for _ in range(sync_every):
                        (
                            cache, logits, pos, active, emitted, buf, key,
                            spec_counters, poisoned,
                        ) = spec_fn(
                            self.params, cache, logits, pos, active, emitted,
                            maxnew, buf, key, temps, table_dev, spec_counters,
                            poisoned,
                        )
            else:
                metrics.on_decode_steps(sync_every)
                with jax.profiler.TraceAnnotation("serve/decode_burst"):
                    for _ in range(sync_every):
                        (
                            cache, logits, pos, active, emitted, buf, key,
                            poisoned,
                        ) = self._step(
                            self.params, cache, logits, pos, active,
                            emitted, maxnew, buf, key, temps, table_dev,
                            poisoned,
                        )
            if faults is not None:
                stall_ms = faults.should_fire("burst_stall", 50)
                if stall_ms:
                    # artificial stall between dispatch and sync: latency
                    # accounting and the watchdog must see it; token
                    # outputs must not change
                    self._sleep(stall_ms / 1000.0)
                    if tr is not None:
                        tr.instant(
                            "fault_burst_stall", ENGINE_TID, now(),
                            {"ms": stall_ms},
                        )
            # THE per-burst sync: one fetch feeds the growth planner, the
            # completion scan, and the quarantine pass (the burst's
            # dispatches are async, so the blocking wait lands here and is
            # charged to the burst's phase — "verify" when speculative,
            # since the fused draft+verify+commit dispatch is dominated by
            # the full-model pass)
            with jax.profiler.TraceAnnotation("serve/burst_sync"):
                (
                    host_active, host_emitted, host_poisoned,
                ) = jax.device_get(  # slimcheck: sync-site
                    (active, emitted, poisoned)
                )
            phase("verify" if use_spec else "decode")
            self._last_burst_t = now()  # /healthz liveness stamp
            if tr is not None:
                tr.complete(
                    "speculative_burst" if use_spec else "decode_burst",
                    ENGINE_TID, t_burst, now(),
                    {"rounds": sync_every, "running": len(running)},
                )
            if guard is not None and guard.watchdog_s:
                dt_burst = now() - t_burst
                if dt_burst > guard.watchdog_s:
                    # a stalled burst (device hiccup, injected stall)
                    # trips the watchdog: counted, traced, and fed into
                    # the degradation ladder as decaying pressure
                    t_trip = now()
                    metrics.on_watchdog(t_trip)
                    wd_pressure += 1.0
                    if tr is not None:
                        tr.instant(
                            "watchdog_trip", ENGINE_TID, t_trip,
                            {"burst_s": round(dt_burst, 4)},
                        )
            fresh_tokens = 0
            for s in running:
                # host mirror of each slot's position (plen + emitted) —
                # what the on-demand growth pass plans the next burst from
                em = int(host_emitted[s])
                # per-burst token delta (emitted resets to 0 at admission,
                # so em only grows within a slot's tenancy): feeds the
                # rolling tokens/s window from the sync we already paid for
                fresh_tokens += em - emitted_host[s]
                emitted_host[s] = em
            if fresh_tokens > 0:
                metrics.on_tokens(fresh_tokens, now())

            # quarantine pass MUST precede the completion scan: a
            # poisoned row went inactive in-step without emitting, so the
            # done_slots scan below would misread it as a normal finish
            bad_slots = [s for s in list(running) if host_poisoned[s]]
            for slot in bad_slots:
                req = cancel_slot(
                    slot,
                    RequestState.FAILED,
                    "non-finite logits: slot quarantined",
                    keep_tokens=False,
                )
                t_q = now()
                metrics.on_quarantine(req.rid, t_q)
                metrics.on_failed(req.rid, t_q)
                if rec is not None:
                    rec.record(req.rid, t_q, "quarantine")
                    postmortem(req, t_q)
            if paged and bad_slots:
                push_rows(bad_slots)

            done_slots = [s for s in running if not host_active[s]]
            if done_slots:
                # token buffers leave the device only when a slot finishes
                host_buf = jax.device_get(buf)  # slimcheck: sync-site
                t_done = now()
                for slot in done_slots:
                    req = running.pop(slot)
                    emitted_host.pop(slot)
                    n = int(host_emitted[slot])
                    # stitch tokens generated before any preemption onto
                    # this final running span's output
                    req.output = req.generated + [
                        int(t) for t in host_buf[slot, :n]
                    ]
                    metrics.on_finish(req.rid, t_done, len(req.output))
                    if rec is not None:
                        # clean finish: the ring has served its purpose
                        rec.discard(req.rid)
                    if tr is not None:
                        tr.complete(
                            "request", slot_tid(slot),
                            span_start.pop(slot, t_done), t_done,
                            {"rid": req.rid, "tokens": len(req.output)},
                        )
                    # paged: blocks return to the pool; with the prefix
                    # cache the full blocks of prompt + output demote to
                    # cached entries so a multi-turn follow-up re-prefills
                    # only its new suffix
                    sched.release(
                        slot,
                        tokens=(
                            req.prompt + req.output
                            if self.prefix_cache
                            else None
                        ),
                    )
                    if paged:
                        # retire the row before the next decode burst: the
                        # freed blocks may be reallocated this very loop
                        table_np[slot] = TRASH_BLOCK
                if paged:
                    push_rows(done_slots)

        if self.speculative:
            accepted, proposed = (
                int(v) for v in jax.device_get(spec_counters)
            )
            metrics.on_speculative(accepted, proposed)
        if allocator is not None and allocator.prefix_cache:
            metrics.on_index_evictions(allocator.index_evictions)
        summary = metrics.summary()
        summary["peak_concurrency"] = float(peak_running)
        if self.retrace_guard is not None:
            # this run's compiles per hot path (0 across the board once
            # the engine is warm) and total guard violations observed
            for name, n in self.retrace_guard.compiles().items():
                summary[f"jit_compiles_{name}"] = float(
                    n - compiles0.get(name, 0)
                )
            summary["jit_retraces"] = float(self.retrace_guard.retraces())
        if faults is not None:
            # per-site fired counts under "fault_<site>" keys — the chaos
            # smoke jobs assert these are nonzero for the planned sites
            summary.update(faults.summary())
        if ladder is not None and allocator is not None:
            # leave the allocator as we found it for the next run
            allocator.register_new_chains = True
        if guard is not None:
            sched.decode_reserve = base_reserve
        self._serving = False
        return ContinuousResult(
            requests=list(requests) + flood_extra,
            metrics=summary,
            slot_of=dict(sched.assignments),
        )
