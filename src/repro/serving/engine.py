"""Batched serving engine for (compressed) models.

Static-batch decoding: a fixed slot count, per-slot positions and EOS
tracking, greedy or temperature sampling, one jit'd generation step shared
across the run (cache donated — no per-token reallocation). Works with dense
or SLiM-compressed parameter trees (the forward dispatches per leaf).

The decode loop keeps everything on device: emitted tokens accumulate in a
preallocated [B, max_new] buffer and the EOS/done mask is folded into the
jitted step, so the host transfers results once at the end (plus one scalar
all-done probe every ``sync_every`` steps when an EOS id is set) instead of
a per-token device round-trip.

This is the serving counterpart of the paper's deployment section: weights
live in the packed SLiM format; decode is the memory-bound regime where the
packed weight stream pays off (bench_speedup.py quantifies it). For
staggered arrivals and slot recycling see ``serving.continuous``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.sampling import sample_and_emit

Params = Dict[str, Any]


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]  # per-slot generated tokens (post-prompt)
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        n = sum(len(t) for t in self.tokens)
        return n / max(self.decode_s, 1e-9)


class ServeEngine:
    def __init__(
        self,
        params: Params,
        cfg: ModelConfig,
        max_len: int = 512,
        eos_id: Optional[int] = None,
        donate_cache: bool = True,
    ):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.eos_id = eos_id
        eos = -1 if eos_id is None else int(eos_id)  # -1 never matches

        def _gen_step(params, cache, logits, pos, key, buf, emitted, done, temp):
            nxt, buf, emitted, hit_eos, key = sample_and_emit(
                logits, temp, key, buf, ~done, emitted, eos
            )
            done = done | hit_eos
            logits, cache = T.decode_step(params, cfg, cache, nxt[:, None], pos)
            return cache, logits, pos + 1, key, buf, emitted, done

        self._gen_step = jax.jit(
            _gen_step, donate_argnums=(1,) if donate_cache else ()
        )
        self._prefill = jax.jit(
            lambda params, batch: T.prefill(params, cfg, batch, max_len=max_len)
        )

    def generate(
        self,
        batch: Params,  # {"tokens": [B, S]} or embeddings variant
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        seed: int = 0,
        sync_every: int = 16,  # all-done probe cadence when eos_id is set
    ) -> GenerationResult:
        tok_key = "tokens" if "tokens" in batch else "embeds"
        b, s = batch[tok_key].shape[:2]
        assert s + max_new_tokens <= self.max_len

        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        prefill_s = time.time() - t0

        key = jax.random.PRNGKey(seed)
        pos = jnp.full((b,), s, jnp.int32)  # per-slot positions (lockstep here)
        buf = jnp.zeros((b, max_new_tokens), jnp.int32)
        emitted = jnp.zeros((b,), jnp.int32)
        done = jnp.zeros((b,), bool)
        temp = jnp.float32(temperature)

        t0 = time.time()
        steps = 0
        for i in range(max_new_tokens):
            cache, logits, pos, key, buf, emitted, done = self._gen_step(
                self.params, cache, logits, pos, key, buf, emitted, done, temp
            )
            steps = i + 1
            if (
                self.eos_id is not None
                and steps % sync_every == 0
                # the all-done early-exit probe, rate-limited by sync_every
                and bool(jax.device_get(jnp.all(done)))  # slimcheck: sync-site
            ):
                break
        host_buf, host_emitted = jax.device_get((buf, emitted))
        decode_s = time.time() - t0
        out = [
            [int(t) for t in host_buf[j, : host_emitted[j]]] for j in range(b)
        ]
        return GenerationResult(
            tokens=out, steps=steps, prefill_s=prefill_s, decode_s=decode_s
        )
