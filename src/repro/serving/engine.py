"""Batched serving engine for (compressed) models.

Static-batch continuous decoding: a fixed slot count, per-slot positions and
EOS tracking, greedy or temperature sampling, one jit'd decode_step shared
across the run (cache donated — no per-token reallocation). Works with dense
or SLiM-compressed parameter trees (the forward dispatches per leaf).

This is the serving counterpart of the paper's deployment section: weights
live in the packed SLiM format; decode is the memory-bound regime where the
3-bit weight stream pays off (bench_speedup.py quantifies it).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig

Params = Dict[str, Any]


@dataclasses.dataclass
class GenerationResult:
    tokens: List[List[int]]  # per-slot generated tokens (post-prompt)
    steps: int
    prefill_s: float
    decode_s: float

    @property
    def tokens_per_s(self) -> float:
        n = sum(len(t) for t in self.tokens)
        return n / max(self.decode_s, 1e-9)


class ServeEngine:
    def __init__(
        self,
        params: Params,
        cfg: ModelConfig,
        max_len: int = 512,
        eos_id: Optional[int] = None,
        donate_cache: bool = True,
    ):
        self.params = params
        self.cfg = cfg
        self.max_len = max_len
        self.eos_id = eos_id

        def _decode(params, cache, tok, pos):
            return T.decode_step(params, cfg, cache, tok, pos)

        self._decode = jax.jit(
            _decode, donate_argnums=(1,) if donate_cache else ()
        )
        self._prefill = jax.jit(
            lambda params, batch: T.prefill(params, cfg, batch, max_len=max_len)
        )

    def generate(
        self,
        batch: Params,  # {"tokens": [B, S]} or embeddings variant
        max_new_tokens: int = 32,
        temperature: float = 0.0,
        seed: int = 0,
    ) -> GenerationResult:
        tok_key = "tokens" if "tokens" in batch else "embeds"
        b, s = batch[tok_key].shape[:2]
        assert s + max_new_tokens <= self.max_len

        t0 = time.time()
        logits, cache = self._prefill(self.params, batch)
        logits = jax.block_until_ready(logits)
        prefill_s = time.time() - t0

        key = jax.random.PRNGKey(seed)
        done = jnp.zeros((b,), bool)
        out: List[List[int]] = [[] for _ in range(b)]

        t0 = time.time()
        steps = 0
        for i in range(max_new_tokens):
            if temperature > 0:
                key, sk = jax.random.split(key)
                nxt = jax.random.categorical(sk, logits / temperature, axis=-1)
            else:
                nxt = jnp.argmax(logits, axis=-1)
            nxt = nxt.astype(jnp.int32)
            host = jax.device_get(nxt)
            for j in range(b):
                if not bool(done[j]):
                    out[j].append(int(host[j]))
            if self.eos_id is not None:
                done = done | (nxt == self.eos_id)
                if bool(jnp.all(done)):
                    steps = i + 1
                    break
            logits, cache = self._decode(
                self.params, cache, nxt[:, None], jnp.int32(s + i)
            )
            steps = i + 1
        jax.block_until_ready(logits)
        decode_s = time.time() - t0
        return GenerationResult(
            tokens=out, steps=steps, prefill_s=prefill_s, decode_s=decode_s
        )
