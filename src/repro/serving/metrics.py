"""Serving metrics: per-request TTFT/latency and fleet-level throughput,
slot occupancy, block-pool occupancy, and preemption counters.

All times are seconds relative to the run start (the engine's clock).
TTFT is measured at prefill completion — with greedy sampling the first
token is fully determined by the prefill logits, and this definition is
engine-agnostic so static and continuous engines compare directly. A
preempted request's TTFT is its *first* admission (the resume prefill
does not reset it), and its token count is the final stitched output.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Set


@dataclasses.dataclass
class RequestTrace:
    arrival: float
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    n_tokens: int = 0

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def latency(self) -> Optional[float]:
        if self.finished is None:
            return None
        return self.finished - self.arrival


def _quantile(xs: List[float], q: float) -> float:
    if not xs:
        return float("nan")
    ys = sorted(xs)
    idx = min(len(ys) - 1, max(0, math.ceil(q * len(ys)) - 1))
    return ys[idx]


class ServingMetrics:
    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.requests: Dict[int, RequestTrace] = {}
        self.occupancy_samples: List[float] = []  # active slots per sample
        self.decode_steps: int = 0  # for token-exact occupancy
        self.end_time: float = 0.0
        # prefix-cache counters (stay zero when the cache is off)
        self.cached_prompt_tokens: int = 0
        self.total_prompt_tokens: int = 0
        self.prefix_hits: int = 0
        self.prefix_lookups: int = 0
        self.resume_prefix_hits: int = 0  # preemption resumes that re-hit
        self.resume_cached_tokens: int = 0
        # block-pool occupancy (stay zero for the contiguous layout)
        self.peak_blocks_in_use: int = 0
        self.blocks_in_use_samples: List[int] = []
        # preemption counters (stay zero under worst-case charging)
        self.preemptions: int = 0
        self.preempted_rids: Set[int] = set()
        # speculative-decoding counters (stay zero with speculation off)
        self.draft_accepted: int = 0
        self.draft_proposed: int = 0
        # prefix-index cap counter (stays zero while the index is unbounded)
        self.prefix_index_evictions: int = 0

    # -- event hooks -------------------------------------------------------

    def on_submit(self, rid: int, arrival: float) -> None:
        self.requests[rid] = RequestTrace(arrival=arrival)

    def on_admit(self, rid: int, t: float) -> None:
        self.requests[rid].admitted = t

    def on_first_token(self, rid: int, t: float) -> None:
        tr = self.requests[rid]
        if tr.first_token is None:  # a resume prefill keeps the first TTFT
            tr.first_token = t

    def on_finish(self, rid: int, t: float, n_tokens: int) -> None:
        tr = self.requests[rid]
        tr.finished = t
        tr.n_tokens = n_tokens
        self.end_time = max(self.end_time, t)

    def on_occupancy(self, active_slots: float) -> None:
        self.occupancy_samples.append(active_slots)

    def on_preempt(self, rid: int, t: float) -> None:
        """Record an eviction: the request running in a slot lost its
        blocks and went back to the queue at time ``t``."""
        self.preemptions += 1
        self.preempted_rids.add(rid)

    def on_prefix_lookup(
        self, rid: int, cached_tokens: int, prompt_tokens: int, resume: bool = False
    ) -> None:
        """Record a prefix-cache lookup at admission: ``cached_tokens`` of
        the ``prompt_tokens``-token prompt rode shared blocks (0 = miss).
        ``resume=True`` marks a preemption-resume admission — those count
        in separate ``resume_*`` counters so the hit rate keeps measuring
        cross-request sharing, not a request re-matching its own evicted
        blocks."""
        if resume:
            self.resume_cached_tokens += cached_tokens
            if cached_tokens > 0:
                self.resume_prefix_hits += 1
            return
        self.prefix_lookups += 1
        self.cached_prompt_tokens += cached_tokens
        self.total_prompt_tokens += prompt_tokens
        if cached_tokens > 0:
            self.prefix_hits += 1

    def on_speculative(self, accepted: int, proposed: int) -> None:
        """Record cumulative draft-token counts: of ``proposed`` tokens
        the draft (backbone-only) model put forward, ``accepted`` survived
        full-model verification. The acceptance rate is the quality of
        the free draft model — 1.0 for a dense model (drafting degenerates
        to exact lookahead)."""
        self.draft_accepted += int(accepted)
        self.draft_proposed += int(proposed)

    def on_index_evictions(self, n: int) -> None:
        """Record the allocator's cumulative prefix-index cap evictions."""
        self.prefix_index_evictions = int(n)

    def on_blocks_in_use(self, n: int) -> None:
        self.peak_blocks_in_use = max(self.peak_blocks_in_use, int(n))
        self.blocks_in_use_samples.append(int(n))

    def on_decode_steps(self, n: int) -> None:
        """Count decode steps run across all slots. When recorded, occupancy
        is computed token-exactly as emitted_tokens / (steps * slots) — every
        step emits one token per truly-live slot, except a request's final
        EOS-consuming step, which occupies the slot but emits nothing (the
        stop token is excluded from outputs), so occupancy reads slightly
        conservative under EOS-terminated traffic.

        The speculative engine records K step-opportunities per round, so
        there ``mean_occupancy`` is the realized fraction of *peak
        speculative throughput* — slot idleness and draft rejections fold
        into one number (acceptance is reported separately) — and is not
        directly comparable with a non-speculative run's occupancy."""
        self.decode_steps += n

    # -- summary -----------------------------------------------------------

    def total_tokens(self) -> int:
        return sum(tr.n_tokens for tr in self.requests.values())

    def summary(self) -> Dict[str, float]:
        ttfts = [tr.ttft for tr in self.requests.values() if tr.ttft is not None]
        lats = [tr.latency for tr in self.requests.values() if tr.latency is not None]
        dur = max(self.end_time, 1e-9)
        if self.decode_steps > 0:
            occ = self.total_tokens() / (self.decode_steps * self.n_slots)
        elif self.occupancy_samples:
            occ = sum(self.occupancy_samples) / (
                len(self.occupancy_samples) * self.n_slots
            )
        else:
            occ = 0.0
        blocks = self.blocks_in_use_samples
        return {
            "n_requests": float(len(self.requests)),
            "completed": float(len(lats)),
            "total_tokens": float(self.total_tokens()),
            "duration_s": dur,
            "tokens_per_s": self.total_tokens() / dur,
            "mean_ttft_s": sum(ttfts) / len(ttfts) if ttfts else float("nan"),
            "p50_ttft_s": _quantile(ttfts, 0.50),
            "p95_ttft_s": _quantile(ttfts, 0.95),
            "mean_latency_s": sum(lats) / len(lats) if lats else float("nan"),
            "p95_latency_s": _quantile(lats, 0.95),
            "mean_occupancy": occ,
            # prefix-cache: token-weighted hit rate (cached / prompt tokens)
            "prefix_cache_hit_rate": (
                self.cached_prompt_tokens / self.total_prompt_tokens
                if self.total_prompt_tokens
                else 0.0
            ),
            "cached_prompt_tokens": float(self.cached_prompt_tokens),
            "prefix_hits": float(self.prefix_hits),
            "peak_blocks_in_use": float(self.peak_blocks_in_use),
            "mean_blocks_in_use": sum(blocks) / len(blocks) if blocks else 0.0,
            "preemptions": float(self.preemptions),
            "preempted_requests": float(len(self.preempted_rids)),
            "resume_prefix_hits": float(self.resume_prefix_hits),
            "resume_cached_tokens": float(self.resume_cached_tokens),
            # speculative decoding: draft-token acceptance
            "draft_accepted": float(self.draft_accepted),
            "draft_proposed": float(self.draft_proposed),
            "draft_acceptance_rate": (
                self.draft_accepted / self.draft_proposed
                if self.draft_proposed
                else 0.0
            ),
            "prefix_index_evictions": float(self.prefix_index_evictions),
        }
