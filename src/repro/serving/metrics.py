"""Serving metrics: a registry of typed instruments behind the engine's
per-request TTFT/latency and fleet-level throughput accounting.

The registry holds three instrument kinds:

* ``Counter`` — a monotonically growing value (preemptions, draft tokens,
  decode steps, per-phase wall time).
* ``Gauge`` — a sampled time series ``(t, value)`` with last/peak/mean
  (blocks in use, queue depth, slot occupancy).
* ``Histogram`` — fixed-boundary buckets with streaming p50/p95/p99
  estimation (TTFT, per-request latency, inter-token latency). With
  ``track_exact=True`` (the serving default — a run's request count is
  small) raw samples are kept alongside the buckets and quantiles are
  exact order statistics; ``track_exact=False`` is the bounded-memory
  streaming mode whose quantiles interpolate within the bucket holding
  the target rank.

``ServingMetrics`` is the engine-facing facade: event hooks
(``on_submit``/``on_admit``/.../``on_finish``) route into registry
instruments, and ``summary()`` is generated from the registry — its keys
are stable across PRs (``BENCH_serving.json`` tracks them).

All times are seconds relative to the run start (the engine's clock).
TTFT is measured at prefill completion — with greedy sampling the first
token is fully determined by the prefill logits, and this definition is
engine-agnostic so static and continuous engines compare directly. A
preempted request's TTFT is its *first* admission (the resume prefill
does not reset it), and its token count is the final stitched output.
TPOT (inter-token latency) is ``(finished - first_token) / (n_tokens -
1)`` per request — the steady-state decode interval; single-token
requests have no interval and are excluded. Every timestamped event
advances ``end_time``, so a run where nothing finishes (interrupted or
budget-exhausted traces) still reports a sane duration.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

# log-spaced second-scale boundaries: TTFT/latency land mid-range on the
# CPU container, sub-ms to minutes stays resolvable
DEFAULT_TIME_BUCKETS = (
    0.0005,
    0.001,
    0.0025,
    0.005,
    0.01,
    0.025,
    0.05,
    0.1,
    0.25,
    0.5,
    1.0,
    2.5,
    5.0,
    10.0,
    30.0,
    60.0,
)

# the engine's host-attributed phases; summary always carries all four
PHASES = ("schedule", "prefill", "decode", "verify")


@dataclasses.dataclass
class RequestTrace:
    arrival: float
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    n_tokens: int = 0

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def latency(self) -> Optional[float]:
        if self.finished is None:
            return None
        return self.finished - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Mean inter-token latency (time-per-output-token) over the
        decode phase; ``None`` until finished or with < 2 tokens (no
        interval to measure)."""
        if self.finished is None or self.first_token is None:
            return None
        if self.n_tokens < 2:
            return None
        return (self.finished - self.first_token) / (self.n_tokens - 1)


def _quantile(xs: Sequence[float], q: float) -> float:
    if not xs:
        return float("nan")
    ys = sorted(xs)
    idx = min(len(ys) - 1, max(0, math.ceil(q * len(ys)) - 1))
    return ys[idx]


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------


class Counter:
    """Monotonically growing value. ``set`` exists for counters mirrored
    from another subsystem's cumulative count (e.g. the allocator's index
    evictions) and still never moves backwards."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        if v < self.value:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value = v

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """A sampled time series: ``set(value, t)`` appends one sample."""

    __slots__ = ("name", "samples", "last", "peak")

    def __init__(self, name: str):
        self.name = name
        self.samples: List[Tuple[Optional[float], float]] = []
        self.last = 0.0
        self.peak = 0.0

    def set(self, v: float, t: Optional[float] = None) -> None:
        self.samples.append((t, v))
        self.last = v
        self.peak = max(self.peak, v)

    def values(self) -> List[float]:
        return [v for _, v in self.samples]

    def mean(self) -> float:
        if not self.samples:
            return 0.0
        return sum(v for _, v in self.samples) / len(self.samples)

    def snapshot(self) -> Dict[str, float]:
        return {
            "last": self.last,
            "peak": self.peak,
            "mean": self.mean(),
            "n_samples": float(len(self.samples)),
        }


class Histogram:
    """Fixed-boundary histogram with streaming quantile estimation.

    ``boundaries`` are ascending upper edges; bucket ``i`` covers
    ``(boundaries[i-1], boundaries[i]]`` with an implicit overflow bucket
    above the last edge. ``quantile`` returns an exact order statistic
    when raw samples are tracked, otherwise a linear interpolation inside
    the bucket holding the target rank (error bounded by that bucket's
    width — the property tests pin this)."""

    __slots__ = (
        "name",
        "boundaries",
        "counts",
        "n",
        "total",
        "_min",
        "_max",
        "_samples",
    )

    def __init__(
        self,
        name: str,
        boundaries: Sequence[float] = DEFAULT_TIME_BUCKETS,
        track_exact: bool = True,
    ):
        bounds = tuple(float(b) for b in boundaries)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:], strict=False)):
            raise ValueError("boundaries must be non-empty and ascending")
        self.name = name
        self.boundaries = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.n = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._samples: Optional[List[float]] = [] if track_exact else None

    def observe(self, x: float) -> None:
        if math.isnan(x):
            return
        self.n += 1
        self.total += x
        self._min = min(self._min, x)
        self._max = max(self._max, x)
        lo, hi = 0, len(self.boundaries)
        while lo < hi:  # first bucket whose upper edge holds x
            mid = (lo + hi) // 2
            if x <= self.boundaries[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        if self._samples is not None:
            self._samples.append(x)

    def mean(self) -> float:
        return self.total / self.n if self.n else float("nan")

    def quantile(self, q: float) -> float:
        if self._samples is not None:
            return _quantile(self._samples, q)
        return self.quantile_est(q)

    def quantile_est(self, q: float) -> float:
        """Bucket-interpolated quantile (the streaming estimate)."""
        if self.n == 0:
            return float("nan")
        rank = min(self.n - 1, max(0, math.ceil(q * self.n) - 1))
        seen = 0
        for i, c in enumerate(self.counts):
            if rank < seen + c:
                lo = self.boundaries[i - 1] if i > 0 else self._min
                hi = self.boundaries[i] if i < len(self.boundaries) else self._max
                lo = max(lo, self._min)
                hi = min(hi, self._max)
                if c == 1 or hi <= lo:
                    return min(max(lo, self._min), self._max)
                frac = (rank - seen + 0.5) / c
                return lo + frac * (hi - lo)
            seen += c
        return self._max  # unreachable: ranks are < n

    def snapshot(self) -> Dict[str, float]:
        return {
            "n": float(self.n),
            "mean": self.mean(),
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }


class MetricsRegistry:
    """Name-keyed instrument store; getters are get-or-create so call
    sites never pre-declare, and a name is pinned to its first kind."""

    def __init__(self):
        self._instruments: Dict[str, object] = {}

    def _get(self, name: str, kind, *args, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = kind(name, *args, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, kind):
            raise TypeError(
                f"instrument {name!r} is {type(inst).__name__}, "
                f"not {kind.__name__}"
            )
        return inst

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(
        self,
        name: str,
        boundaries: Sequence[float] = DEFAULT_TIME_BUCKETS,
        track_exact: bool = True,
    ) -> Histogram:
        return self._get(name, Histogram, boundaries, track_exact)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Per-instrument summaries, keyed ``kind/name``."""
        out = {}
        for name, inst in sorted(self._instruments.items()):
            kind = type(inst).__name__.lower()
            out[f"{kind}/{name}"] = inst.snapshot()
        return out


# ---------------------------------------------------------------------------
# Engine-facing facade
# ---------------------------------------------------------------------------


class ServingMetrics:
    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self.requests: Dict[int, RequestTrace] = {}
        self.end_time: float = 0.0
        self.preempted_rids: Set[int] = set()
        r = self.registry = MetricsRegistry()
        # counters (each stays zero when its feature is off)
        self._decode_steps = r.counter("decode_steps")
        self._cached_prompt_tokens = r.counter("cached_prompt_tokens")
        self._total_prompt_tokens = r.counter("total_prompt_tokens")
        self._prefix_hits = r.counter("prefix_hits")
        self._prefix_lookups = r.counter("prefix_lookups")
        self._resume_prefix_hits = r.counter("resume_prefix_hits")
        self._resume_cached_tokens = r.counter("resume_cached_tokens")
        self._preemptions = r.counter("preemptions")
        self._draft_accepted = r.counter("draft_accepted")
        self._draft_proposed = r.counter("draft_proposed")
        self._prefix_index_evictions = r.counter("prefix_index_evictions")
        self._phase = {p: r.counter(f"phase_{p}_s") for p in PHASES}
        # robustness accounting (serving/guard.py, docs/robustness.md):
        # every terminal outcome that is not FINISHED has its own counter,
        # so shed + expired + failed + completed partitions the requests
        # that left the system
        self._shed = r.counter("shed_requests")
        self._expired = r.counter("expired_requests")
        self._failed = r.counter("failed_requests")
        self._quarantined = r.counter("quarantined_slots")
        self._degraded_rounds = r.counter("degraded_rounds")
        self._watchdog_trips = r.counter("watchdog_trips")
        # gauges (time series; peak/mean land in summary)
        self._occupancy = r.gauge("slot_occupancy")
        self._blocks_in_use = r.gauge("blocks_in_use")
        self._queue_depth = r.gauge("queue_depth")
        self._degradation_level = r.gauge("degradation_level")
        # histograms (exact quantiles per run, streaming buckets for free)
        self._ttft = r.histogram("ttft_s")
        self._latency = r.histogram("latency_s")
        self._tpot = r.histogram("tpot_s")

    # -- back-compat views -------------------------------------------------

    @property
    def decode_steps(self) -> int:
        return int(self._decode_steps.value)

    @property
    def preemptions(self) -> int:
        return int(self._preemptions.value)

    @property
    def occupancy_samples(self) -> List[float]:
        return self._occupancy.values()

    @property
    def blocks_in_use_samples(self) -> List[int]:
        return [int(v) for v in self._blocks_in_use.values()]

    @property
    def peak_blocks_in_use(self) -> int:
        return int(self._blocks_in_use.peak)

    # -- event hooks -------------------------------------------------------

    def _touch(self, t: float) -> None:
        """Advance the run's end time. Every timestamped event calls this,
        so a run where no request ever finishes still reports its true
        span instead of a ~0 duration and a garbage tokens/s."""
        self.end_time = max(self.end_time, t)

    def on_submit(self, rid: int, arrival: float) -> None:
        self.requests[rid] = RequestTrace(arrival=arrival)
        self._touch(arrival)

    def on_admit(self, rid: int, t: float) -> None:
        self.requests[rid].admitted = t
        self._touch(t)

    def on_first_token(self, rid: int, t: float) -> None:
        tr = self.requests[rid]
        if tr.first_token is None:  # a resume prefill keeps the first TTFT
            tr.first_token = t
            self._ttft.observe(tr.ttft)
        self._touch(t)

    def on_finish(self, rid: int, t: float, n_tokens: int) -> None:
        tr = self.requests[rid]
        tr.finished = t
        tr.n_tokens = n_tokens
        self._latency.observe(tr.latency)
        if tr.tpot is not None:
            self._tpot.observe(tr.tpot)
        self._touch(t)

    def on_occupancy(self, active_slots: float) -> None:
        self._occupancy.set(active_slots)

    def on_preempt(self, rid: int, t: float) -> None:
        """Record an eviction: the request running in a slot lost its
        blocks and went back to the queue at time ``t``."""
        self._preemptions.inc()
        self.preempted_rids.add(rid)
        self._touch(t)

    def on_prefix_lookup(
        self, rid: int, cached_tokens: int, prompt_tokens: int, resume: bool = False
    ) -> None:
        """Record a prefix-cache lookup at admission: ``cached_tokens`` of
        the ``prompt_tokens``-token prompt rode shared blocks (0 = miss).
        ``resume=True`` marks a preemption-resume admission — those count
        in separate ``resume_*`` counters so the hit rate keeps measuring
        cross-request sharing, not a request re-matching its own evicted
        blocks."""
        if resume:
            self._resume_cached_tokens.inc(cached_tokens)
            if cached_tokens > 0:
                self._resume_prefix_hits.inc()
            return
        self._prefix_lookups.inc()
        self._cached_prompt_tokens.inc(cached_tokens)
        self._total_prompt_tokens.inc(prompt_tokens)
        if cached_tokens > 0:
            self._prefix_hits.inc()

    def on_speculative(self, accepted: int, proposed: int) -> None:
        """Record cumulative draft-token counts: of ``proposed`` tokens
        the draft (backbone-only) model put forward, ``accepted`` survived
        full-model verification. The acceptance rate is the quality of
        the free draft model — 1.0 for a dense model (drafting degenerates
        to exact lookahead)."""
        self._draft_accepted.inc(int(accepted))
        self._draft_proposed.inc(int(proposed))

    def on_index_evictions(self, n: int) -> None:
        """Record the allocator's cumulative prefix-index cap evictions."""
        self._prefix_index_evictions.set(int(n))

    # -- robustness hooks (serving/guard.py) -------------------------------

    def on_shed(self, rid: int, t: float) -> None:
        """A queued request was dropped by bounded-queue load shedding
        (terminal state ABORTED; it never ran)."""
        self._shed.inc()
        self._touch(t)

    def on_expired(self, rid: int, t: float) -> None:
        """A request outlived its deadline — reaped from the queue or
        host-cancelled mid-decode (terminal state EXPIRED)."""
        self._expired.inc()
        self._touch(t)

    def on_failed(self, rid: int, t: float) -> None:
        """The engine gave up on a request (terminal state FAILED):
        never-admittable at submit, or its slot was quarantined."""
        self._failed.inc()
        self._touch(t)

    def on_quarantine(self, rid: int, t: float) -> None:
        """A running slot produced non-finite logits and was quarantined;
        counts the slot event on top of the request's ``on_failed``."""
        self._quarantined.inc()
        self._touch(t)

    def on_degraded(self, level: int, t: Optional[float] = None) -> None:
        """Sample the degradation ladder's level this round; rounds at a
        level above 0 also count into ``degraded_rounds``."""
        self._degradation_level.set(float(level), t)
        if level > 0:
            self._degraded_rounds.inc()
        if t is not None:
            self._touch(t)

    def on_watchdog(self, t: float) -> None:
        """A decode/verify burst exceeded the watchdog's wall-time
        threshold."""
        self._watchdog_trips.inc()
        self._touch(t)

    def on_blocks_in_use(self, n: int, t: Optional[float] = None) -> None:
        self._blocks_in_use.set(int(n), t)
        if t is not None:
            self._touch(t)

    def on_queue_depth(self, n: int, t: Optional[float] = None) -> None:
        """Sample the arrival queue's depth (requests waiting for a slot
        or for blocks) — the backlog signal SLO scheduling keys off."""
        self._queue_depth.set(int(n), t)
        if t is not None:
            self._touch(t)

    def on_phase(self, phase: str, seconds: float) -> None:
        """Attribute ``seconds`` of host wall time to an engine phase
        (one of ``PHASES``); the per-phase totals land in summary as
        ``phase_<name>_s``."""
        self._phase[phase].inc(seconds)

    def on_decode_steps(self, n: int) -> None:
        """Count decode steps run across all slots. When recorded, occupancy
        is computed token-exactly as emitted_tokens / (steps * slots) — every
        step emits one token per truly-live slot, except a request's final
        EOS-consuming step, which occupies the slot but emits nothing (the
        stop token is excluded from outputs), so occupancy reads slightly
        conservative under EOS-terminated traffic.

        The speculative engine records K step-opportunities per round, so
        there ``mean_occupancy`` is the realized fraction of *peak
        speculative throughput* — slot idleness and draft rejections fold
        into one number (acceptance is reported separately) — and is not
        directly comparable with a non-speculative run's occupancy."""
        self._decode_steps.inc(n)

    # -- summary -----------------------------------------------------------

    def total_tokens(self) -> int:
        return sum(tr.n_tokens for tr in self.requests.values())

    def summary(self) -> Dict[str, float]:
        dur = max(self.end_time, 1e-9)
        steps = self._decode_steps.value
        if steps > 0:
            occ = self.total_tokens() / (steps * self.n_slots)
        elif self._occupancy.samples:
            occ = self._occupancy.mean() / self.n_slots
        else:
            occ = 0.0
        out = {
            "n_requests": float(len(self.requests)),
            "completed": float(self._latency.n),
            "total_tokens": float(self.total_tokens()),
            "duration_s": dur,
            "tokens_per_s": self.total_tokens() / dur,
            "mean_ttft_s": self._ttft.mean(),
            "p50_ttft_s": self._ttft.quantile(0.50),
            "p95_ttft_s": self._ttft.quantile(0.95),
            "p99_ttft_s": self._ttft.quantile(0.99),
            "mean_latency_s": self._latency.mean(),
            "p50_latency_s": self._latency.quantile(0.50),
            "p95_latency_s": self._latency.quantile(0.95),
            "p99_latency_s": self._latency.quantile(0.99),
            # inter-token latency (time per output token, decode phase)
            "mean_tpot_s": self._tpot.mean(),
            "tpot_p50_s": self._tpot.quantile(0.50),
            "tpot_p95_s": self._tpot.quantile(0.95),
            "tpot_p99_s": self._tpot.quantile(0.99),
            "mean_occupancy": occ,
            # prefix-cache: token-weighted hit rate (cached / prompt tokens)
            "prefix_cache_hit_rate": (
                self._cached_prompt_tokens.value / self._total_prompt_tokens.value
                if self._total_prompt_tokens.value
                else 0.0
            ),
            "cached_prompt_tokens": self._cached_prompt_tokens.value,
            "total_prompt_tokens": self._total_prompt_tokens.value,
            "prefix_hits": self._prefix_hits.value,
            "peak_blocks_in_use": self._blocks_in_use.peak,
            "mean_blocks_in_use": self._blocks_in_use.mean(),
            "preemptions": self._preemptions.value,
            "preempted_requests": float(len(self.preempted_rids)),
            "resume_prefix_hits": self._resume_prefix_hits.value,
            "resume_cached_tokens": self._resume_cached_tokens.value,
            # speculative decoding: draft-token acceptance
            "draft_accepted": self._draft_accepted.value,
            "draft_proposed": self._draft_proposed.value,
            "draft_acceptance_rate": (
                self._draft_accepted.value / self._draft_proposed.value
                if self._draft_proposed.value
                else 0.0
            ),
            "prefix_index_evictions": self._prefix_index_evictions.value,
            # arrival-queue backlog time series
            "mean_queue_depth": self._queue_depth.mean(),
            "peak_queue_depth": self._queue_depth.peak,
            # robustness: non-FINISHED terminal outcomes + guard activity
            "shed_requests": self._shed.value,
            "expired_requests": self._expired.value,
            "failed_requests": self._failed.value,
            "quarantined_slots": self._quarantined.value,
            "degraded_rounds": self._degraded_rounds.value,
            "watchdog_trips": self._watchdog_trips.value,
            "peak_degradation_level": self._degradation_level.peak,
        }
        # host wall-time attribution (schedule / prefill / decode / verify)
        for p in PHASES:
            out[f"phase_{p}_s"] = self._phase[p].value
        return out


# ---------------------------------------------------------------------------
# Fleet aggregation (serving/router.py)
# ---------------------------------------------------------------------------

# summary keys that take the max across replicas: wall-clock span, peaks,
# and quantiles (the fleet's p95 is conservatively bounded by the worst
# replica's — exact fleet quantiles would need the raw samples)
_MERGE_MAX = {
    "duration_s",
    "p50_ttft_s",
    "p95_ttft_s",
    "p99_ttft_s",
    "p50_latency_s",
    "p95_latency_s",
    "p99_latency_s",
    "tpot_p50_s",
    "tpot_p95_s",
    "tpot_p99_s",
}

# weighted means: key -> the summary key whose value weights it
_MERGE_WEIGHTED = {
    "mean_ttft_s": "completed",
    "mean_latency_s": "completed",
    "mean_tpot_s": "completed",
    "mean_occupancy": "total_tokens",
    "mean_blocks_in_use": "duration_s",
    "mean_queue_depth": "duration_s",
}


def merge_replica_summaries(
    summaries: Sequence[Dict[str, float]],
) -> Dict[str, float]:
    """Fold per-replica ``ServingMetrics.summary()`` dicts into one
    fleet-level summary (the aggregate half of ``RouterResult.metrics``).

    Each replica runs on its own clock, so ``tokens_per_s`` *sums* — the
    fleet's aggregate throughput is what N side-by-side replicas deliver
    — while ``duration_s`` and the peaks/quantiles take the max. Count
    keys (requests, tokens, preemptions, phase seconds, fault counters,
    anything not otherwise classified) sum; per-replica means recombine
    weighted by their natural denominator (completed requests for
    latency-family means, tokens for occupancy, duration for the backlog
    gauges). The two hit-rate keys are recomputed from the summed
    numerators/denominators so the fleet rate is token-weighted, not an
    average of averages."""
    keys: List[str] = []
    for s in summaries:
        for k in s:
            if k not in keys:
                keys.append(k)
    out: Dict[str, float] = {}
    for k in keys:
        vals = [(s[k], s) for s in summaries if k in s]
        if k in _MERGE_MAX or k.startswith("peak_"):
            out[k] = max(v for v, _ in vals)
        elif k in _MERGE_WEIGHTED:
            wkey = _MERGE_WEIGHTED[k]
            pairs = [(v, s.get(wkey, 0.0)) for v, s in vals if not math.isnan(v)]
            wsum = sum(w for _, w in pairs)
            if not pairs:
                out[k] = float("nan")
            elif wsum <= 0:
                out[k] = sum(v for v, _ in pairs) / len(pairs)
            else:
                out[k] = sum(v * w for v, w in pairs) / wsum
        else:
            out[k] = sum(v for v, _ in vals)
    # rates: recompute from the summed counters (token-weighted)
    if "total_prompt_tokens" in out:
        out["prefix_cache_hit_rate"] = (
            out.get("cached_prompt_tokens", 0.0) / out["total_prompt_tokens"]
            if out["total_prompt_tokens"]
            else 0.0
        )
    if "draft_proposed" in out:
        out["draft_acceptance_rate"] = (
            out.get("draft_accepted", 0.0) / out["draft_proposed"]
            if out["draft_proposed"]
            else 0.0
        )
    return out
