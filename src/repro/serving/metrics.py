"""Serving metrics: a registry of typed instruments behind the engine's
per-request TTFT/latency and fleet-level throughput accounting.

The registry holds three instrument kinds:

* ``Counter`` — a monotonically growing value (preemptions, draft tokens,
  decode steps, per-phase wall time).
* ``Gauge`` — a sampled time series ``(t, value)`` with last/peak/mean
  (blocks in use, queue depth, slot occupancy).
* ``Histogram`` — fixed-boundary buckets with streaming p50/p95/p99
  estimation (TTFT, per-request latency, inter-token latency). With
  ``track_exact=True`` (the serving default — a run's request count is
  small) raw samples are kept alongside the buckets and quantiles are
  exact order statistics; ``track_exact=False`` is the bounded-memory
  streaming mode whose quantiles interpolate within the bucket holding
  the target rank.

``ServingMetrics`` is the engine-facing facade: event hooks
(``on_submit``/``on_admit``/.../``on_finish``) route into registry
instruments, and ``summary()`` is generated from the registry — its keys
are stable across PRs (``BENCH_serving.json`` tracks them).

All times are seconds relative to the run start (the engine's clock).
TTFT is measured at prefill completion — with greedy sampling the first
token is fully determined by the prefill logits, and this definition is
engine-agnostic so static and continuous engines compare directly. A
preempted request's TTFT is its *first* admission (the resume prefill
does not reset it), and its token count is the final stitched output.
TPOT (inter-token latency) is ``(finished - first_token) / (n_tokens -
1)`` per request — the steady-state decode interval; single-token
requests have no interval and are excluded. Every timestamped event
advances ``end_time``, so a run where nothing finishes (interrupted or
budget-exhausted traces) still reports a sane duration.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

# log-spaced second-scale boundaries: TTFT/latency land mid-range on the
# CPU container, sub-ms to minutes stays resolvable
DEFAULT_TIME_BUCKETS = (
    0.0005,
    0.001,
    0.0025,
    0.005,
    0.01,
    0.025,
    0.05,
    0.1,
    0.25,
    0.5,
    1.0,
    2.5,
    5.0,
    10.0,
    30.0,
    60.0,
)

# the engine's host-attributed phases; summary always carries all four
PHASES = ("schedule", "prefill", "decode", "verify")


@dataclasses.dataclass
class RequestTrace:
    arrival: float
    admitted: Optional[float] = None
    first_token: Optional[float] = None
    finished: Optional[float] = None
    n_tokens: int = 0

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token is None:
            return None
        return self.first_token - self.arrival

    @property
    def latency(self) -> Optional[float]:
        if self.finished is None:
            return None
        return self.finished - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        """Mean inter-token latency (time-per-output-token) over the
        decode phase; ``None`` until finished or with < 2 tokens (no
        interval to measure)."""
        if self.finished is None or self.first_token is None:
            return None
        if self.n_tokens < 2:
            return None
        return (self.finished - self.first_token) / (self.n_tokens - 1)


def _quantile(xs: Sequence[float], q: float) -> float:
    if not xs:
        return float("nan")
    ys = sorted(xs)
    idx = min(len(ys) - 1, max(0, math.ceil(q * len(ys)) - 1))
    return ys[idx]


def _bucket_quantile(
    counts: Sequence[int],
    boundaries: Sequence[float],
    n: int,
    mn: float,
    mx: float,
    q: float,
) -> float:
    """Quantile of a bucketed distribution: linear interpolation inside
    the bucket holding the target rank, clipped to the observed [mn, mx]
    range — error bounded by that bucket's width. Shared by ``Histogram``
    (streaming mode), ``WindowedHistogram``, and the fleet merge."""
    if n == 0:
        return float("nan")
    rank = min(n - 1, max(0, math.ceil(q * n) - 1))
    seen = 0
    for i, c in enumerate(counts):
        if rank < seen + c:
            lo = boundaries[i - 1] if i > 0 else mn
            hi = boundaries[i] if i < len(boundaries) else mx
            lo = max(lo, mn)
            hi = min(hi, mx)
            if c == 1 or hi <= lo:
                return min(max(lo, mn), mx)
            frac = (rank - seen + 0.5) / c
            return lo + frac * (hi - lo)
        seen += c
    return mx  # unreachable: ranks are < n


# ---------------------------------------------------------------------------
# Instruments
# ---------------------------------------------------------------------------


class Counter:
    """Monotonically growing value. ``set`` exists for counters mirrored
    from another subsystem's cumulative count (e.g. the allocator's index
    evictions) and still never moves backwards."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def set(self, v: float) -> None:
        if v < self.value:
            raise ValueError(f"counter {self.name} cannot decrease")
        self.value = v

    def snapshot(self) -> Dict[str, float]:
        return {"value": self.value}


class Gauge:
    """A sampled time series: ``set(value, t)`` appends one sample."""

    __slots__ = ("name", "samples", "last", "peak")

    def __init__(self, name: str):
        self.name = name
        self.samples: List[Tuple[Optional[float], float]] = []
        self.last = 0.0
        self.peak = 0.0

    def set(self, v: float, t: Optional[float] = None) -> None:
        self.samples.append((t, v))
        self.last = v
        self.peak = max(self.peak, v)

    def values(self) -> List[float]:
        return [v for _, v in self.samples]

    def mean(self) -> float:
        if not self.samples:
            return 0.0
        return sum(v for _, v in self.samples) / len(self.samples)

    def snapshot(self) -> Dict[str, float]:
        return {
            "last": self.last,
            "peak": self.peak,
            "mean": self.mean(),
            "n_samples": float(len(self.samples)),
        }


class Histogram:
    """Fixed-boundary histogram with streaming quantile estimation.

    ``boundaries`` are ascending upper edges; bucket ``i`` covers
    ``(boundaries[i-1], boundaries[i]]`` with an implicit overflow bucket
    above the last edge. ``quantile`` returns an exact order statistic
    when raw samples are tracked, otherwise a linear interpolation inside
    the bucket holding the target rank (error bounded by that bucket's
    width — the property tests pin this)."""

    __slots__ = (
        "name",
        "boundaries",
        "counts",
        "n",
        "total",
        "_min",
        "_max",
        "_samples",
    )

    def __init__(
        self,
        name: str,
        boundaries: Sequence[float] = DEFAULT_TIME_BUCKETS,
        track_exact: bool = True,
    ):
        bounds = tuple(float(b) for b in boundaries)
        if not bounds or any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:], strict=False)):
            raise ValueError("boundaries must be non-empty and ascending")
        self.name = name
        self.boundaries = bounds
        self.counts = [0] * (len(bounds) + 1)
        self.n = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._samples: Optional[List[float]] = [] if track_exact else None

    def observe(self, x: float) -> None:
        if math.isnan(x):
            return
        self.n += 1
        self.total += x
        self._min = min(self._min, x)
        self._max = max(self._max, x)
        lo, hi = 0, len(self.boundaries)
        while lo < hi:  # first bucket whose upper edge holds x
            mid = (lo + hi) // 2
            if x <= self.boundaries[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1
        if self._samples is not None:
            self._samples.append(x)

    def mean(self) -> float:
        return self.total / self.n if self.n else float("nan")

    def quantile(self, q: float) -> float:
        if self._samples is not None:
            return _quantile(self._samples, q)
        return self.quantile_est(q)

    def quantile_est(self, q: float) -> float:
        """Bucket-interpolated quantile (the streaming estimate)."""
        return _bucket_quantile(
            self.counts, self.boundaries, self.n, self._min, self._max, q
        )

    def snapshot(self) -> Dict[str, float]:
        return {
            "n": float(self.n),
            "mean": self.mean(),
            "p50": self.quantile(0.50),
            "p95": self.quantile(0.95),
            "p99": self.quantile(0.99),
        }

    def state(self) -> Dict[str, object]:
        """The histogram's full distribution as plain JSON types — what
        the fleet merge (``merge_histogram_states``) and the live
        exporter consume. ``min``/``max`` are ``None`` when empty (the
        infinities don't survive JSON)."""
        return {
            "boundaries": list(self.boundaries),
            "counts": list(self.counts),
            "n": self.n,
            "total": self.total,
            "min": self._min if self.n else None,
            "max": self._max if self.n else None,
            "samples": (
                list(self._samples) if self._samples is not None else None
            ),
        }


class WindowedHistogram:
    """Rolling-window histogram: a ring of ``n_sub`` sub-window buckets
    on the engine clock, so quantiles cover the *last* ``window`` seconds
    instead of the run's lifetime.

    ``observe(x, t)`` lands the sample in the sub-window holding ``t``
    (each ``window / n_sub`` seconds wide); a ring slot is reset lazily
    when its epoch comes back around, so there is no timer thread and
    reads never mutate state. A snapshot at time ``now`` merges the
    sub-windows whose epochs fall inside ``[now - window, now]`` —
    samples expire with sub-window granularity (a sample drops out
    between ``window`` and ``window + window/n_sub`` seconds after it
    was observed). No raw samples are kept: quantiles interpolate inside
    the merged buckets, with error bounded by one bucket width (the
    property tests pin this against exact order statistics)."""

    __slots__ = (
        "name",
        "boundaries",
        "window",
        "n_sub",
        "sub",
        "_epoch",
        "_counts",
        "_n",
        "_total",
        "_min",
        "_max",
        "_t_last",
    )

    def __init__(
        self,
        name: str,
        window: float = 60.0,
        n_sub: int = 12,
        boundaries: Sequence[float] = DEFAULT_TIME_BUCKETS,
    ):
        bounds = tuple(float(b) for b in boundaries)
        if not bounds or any(
            b2 <= b1 for b1, b2 in zip(bounds, bounds[1:], strict=False)
        ):
            raise ValueError("boundaries must be non-empty and ascending")
        if window <= 0:
            raise ValueError("window must be > 0 seconds")
        if n_sub < 1:
            raise ValueError("n_sub must be >= 1")
        self.name = name
        self.boundaries = bounds
        self.window = float(window)
        self.n_sub = int(n_sub)
        self.sub = self.window / self.n_sub
        self._epoch = [-1] * self.n_sub
        self._counts = [[0] * (len(bounds) + 1) for _ in range(self.n_sub)]
        self._n = [0] * self.n_sub
        self._total = [0.0] * self.n_sub
        self._min = [math.inf] * self.n_sub
        self._max = [-math.inf] * self.n_sub
        self._t_last = 0.0

    def observe(self, x: float, t: float) -> None:
        if math.isnan(x):
            return
        t = max(t, 0.0)
        self._t_last = max(self._t_last, t)
        epoch = int(t / self.sub)
        i = epoch % self.n_sub
        if self._epoch[i] > epoch:
            return  # older than the whole ring: nothing to record it in
        if self._epoch[i] != epoch:
            self._epoch[i] = epoch
            self._counts[i] = [0] * (len(self.boundaries) + 1)
            self._n[i] = 0
            self._total[i] = 0.0
            self._min[i] = math.inf
            self._max[i] = -math.inf
        lo, hi = 0, len(self.boundaries)
        while lo < hi:  # first bucket whose upper edge holds x
            mid = (lo + hi) // 2
            if x <= self.boundaries[mid]:
                hi = mid
            else:
                lo = mid + 1
        self._counts[i][lo] += 1
        self._n[i] += 1
        self._total[i] += x
        self._min[i] = min(self._min[i], x)
        self._max[i] = max(self._max[i], x)

    def merged(
        self, now: Optional[float] = None
    ) -> Tuple[List[int], int, float, float, float]:
        """The live window's merged distribution at ``now`` (default:
        the last observed timestamp): ``(counts, n, total, min, max)``.
        Pure read — snapshots never perturb the ring."""
        eff = self._t_last if now is None else max(now, 0.0)
        cur = int(eff / self.sub)
        lo = cur - self.n_sub + 1
        counts = [0] * (len(self.boundaries) + 1)
        n, total = 0, 0.0
        mn, mx = math.inf, -math.inf
        for i in range(self.n_sub):
            e = self._epoch[i]
            if e < 0 or e < lo or e > cur:
                continue
            for j, c in enumerate(self._counts[i]):
                counts[j] += c
            n += self._n[i]
            total += self._total[i]
            mn = min(mn, self._min[i])
            mx = max(mx, self._max[i])
        return counts, n, total, mn, mx

    def count(self, now: Optional[float] = None) -> int:
        return self.merged(now)[1]

    def mean(self, now: Optional[float] = None) -> float:
        _, n, total, _, _ = self.merged(now)
        return total / n if n else float("nan")

    def quantile(self, q: float, now: Optional[float] = None) -> float:
        counts, n, _, mn, mx = self.merged(now)
        return _bucket_quantile(counts, self.boundaries, n, mn, mx, q)

    def fraction_above(self, x: float, now: Optional[float] = None) -> float:
        """Fraction of windowed samples above ``x``, interpolating inside
        the bucket straddling it — the SLO monitor's error-budget signal
        (e.g. fraction of TTFTs above the p95 target)."""
        counts, n, _, mn, mx = self.merged(now)
        if n == 0:
            return 0.0
        above = 0.0
        for i, c in enumerate(counts):
            if not c:
                continue
            lo = self.boundaries[i - 1] if i > 0 else mn
            hi = self.boundaries[i] if i < len(self.boundaries) else mx
            lo = max(lo, mn)
            hi = min(hi, mx)
            if hi <= lo:  # degenerate bucket: a point mass at lo
                above += c if x < lo else 0
            elif x < lo:
                above += c
            elif x >= hi:
                pass
            else:
                above += c * (hi - x) / (hi - lo)
        return above / n

    def snapshot(self, now: Optional[float] = None) -> Dict[str, float]:
        counts, n, total, mn, mx = self.merged(now)
        return {
            "n": float(n),
            "mean": total / n if n else float("nan"),
            "p50": _bucket_quantile(counts, self.boundaries, n, mn, mx, 0.50),
            "p95": _bucket_quantile(counts, self.boundaries, n, mn, mx, 0.95),
            "p99": _bucket_quantile(counts, self.boundaries, n, mn, mx, 0.99),
        }

    def state(self, now: Optional[float] = None) -> Dict[str, object]:
        """Merged-window distribution as plain JSON types (exporter /
        fleet-merge format; same shape as ``Histogram.state``)."""
        counts, n, total, mn, mx = self.merged(now)
        return {
            "boundaries": list(self.boundaries),
            "counts": counts,
            "n": n,
            "total": total,
            "min": mn if n else None,
            "max": mx if n else None,
            "samples": None,
        }


class WindowedRate:
    """Rolling-window event rate: per-sub-window sums on the same lazy
    ring as ``WindowedHistogram``. ``add(n, t)`` accumulates; ``rate``
    divides the windowed total by the elapsed window span (clamped to
    ``[window/n_sub, window]`` so an early-run rate is not diluted by
    time that has not passed yet)."""

    __slots__ = ("name", "window", "n_sub", "sub", "_epoch", "_sums", "_t_last")

    def __init__(self, name: str, window: float = 60.0, n_sub: int = 12):
        if window <= 0:
            raise ValueError("window must be > 0 seconds")
        if n_sub < 1:
            raise ValueError("n_sub must be >= 1")
        self.name = name
        self.window = float(window)
        self.n_sub = int(n_sub)
        self.sub = self.window / self.n_sub
        self._epoch = [-1] * self.n_sub
        self._sums = [0.0] * self.n_sub
        self._t_last = 0.0

    def add(self, n: float, t: float) -> None:
        t = max(t, 0.0)
        self._t_last = max(self._t_last, t)
        epoch = int(t / self.sub)
        i = epoch % self.n_sub
        if self._epoch[i] > epoch:
            return  # older than the whole ring
        if self._epoch[i] != epoch:
            self._epoch[i] = epoch
            self._sums[i] = 0.0
        self._sums[i] += n

    def total(self, now: Optional[float] = None) -> float:
        eff = self._t_last if now is None else max(now, 0.0)
        cur = int(eff / self.sub)
        lo = cur - self.n_sub + 1
        return sum(
            s
            for e, s in zip(self._epoch, self._sums, strict=True)
            if 0 <= e and lo <= e <= cur
        )

    def rate(self, now: Optional[float] = None) -> float:
        eff = self._t_last if now is None else max(now, 0.0)
        span = min(max(eff, self.sub), self.window)
        return self.total(now) / span

    def snapshot(self, now: Optional[float] = None) -> Dict[str, float]:
        return {"total": self.total(now), "per_s": self.rate(now)}


def _labeled_key(name: str, labels: Optional[Dict[str, str]]) -> str:
    """Full registry key for a (name, labels) pair — Prometheus-style
    ``name{k="v",...}`` with sorted label names, so the same label set
    always maps to the same instrument."""
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in sorted(labels.items()))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Name-keyed instrument store; getters are get-or-create so call
    sites never pre-declare, and a name is pinned to its first kind.
    ``labels`` (counters) key distinct instruments under one base name —
    the exporter renders them as one labelled Prometheus family."""

    def __init__(self):
        self._instruments: Dict[str, object] = {}
        # full key -> (base name, labels) for labelled instruments; the
        # exporter reads this to reassemble label sets per family
        self._labels: Dict[str, Tuple[str, Dict[str, str]]] = {}

    def _get(self, name: str, kind, *args, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = kind(name, *args, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, kind):
            raise TypeError(
                f"instrument {name!r} is {type(inst).__name__}, "
                f"not {kind.__name__}"
            )
        return inst

    def counter(
        self, name: str, labels: Optional[Dict[str, str]] = None
    ) -> Counter:
        key = _labeled_key(name, labels)
        if labels:
            self._labels[key] = (name, dict(labels))
        return self._get(key, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(
        self,
        name: str,
        boundaries: Sequence[float] = DEFAULT_TIME_BUCKETS,
        track_exact: bool = True,
    ) -> Histogram:
        return self._get(name, Histogram, boundaries, track_exact)

    def windowed_histogram(
        self,
        name: str,
        window: float = 60.0,
        n_sub: int = 12,
        boundaries: Sequence[float] = DEFAULT_TIME_BUCKETS,
    ) -> WindowedHistogram:
        return self._get(name, WindowedHistogram, window, n_sub, boundaries)

    def windowed_rate(
        self, name: str, window: float = 60.0, n_sub: int = 12
    ) -> WindowedRate:
        return self._get(name, WindowedRate, window, n_sub)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def instruments(self):
        """Iterate ``(key, base_name, labels, instrument)`` rows sorted
        by key — the exporter's view of the registry."""
        for key in sorted(self._instruments):
            base, labels = self._labels.get(key, (key, {}))
            yield key, base, labels, self._instruments[key]

    def snapshot(
        self, now: Optional[float] = None
    ) -> Dict[str, Dict[str, float]]:
        """Per-instrument summaries, keyed ``kind/name``. ``now`` (engine
        clock) selects the windowed instruments' evaluation time; reads
        never mutate any instrument, so this is safe mid-run."""
        out = {}
        for name, inst in sorted(self._instruments.items()):
            kind = type(inst).__name__.lower()
            if isinstance(inst, (WindowedHistogram, WindowedRate)):
                out[f"{kind}/{name}"] = inst.snapshot(now)
            else:
                out[f"{kind}/{name}"] = inst.snapshot()
        return out


# ---------------------------------------------------------------------------
# Engine-facing facade
# ---------------------------------------------------------------------------


class ServingMetrics:
    def __init__(
        self,
        n_slots: int,
        window: float = 60.0,
        window_subs: int = 12,
    ):
        self.n_slots = n_slots
        self.requests: Dict[int, RequestTrace] = {}
        self.end_time: float = 0.0
        self.preempted_rids: Set[int] = set()
        r = self.registry = MetricsRegistry()
        # counters (each stays zero when its feature is off)
        self._decode_steps = r.counter("decode_steps")
        self._cached_prompt_tokens = r.counter("cached_prompt_tokens")
        self._total_prompt_tokens = r.counter("total_prompt_tokens")
        self._prefix_hits = r.counter("prefix_hits")
        self._prefix_lookups = r.counter("prefix_lookups")
        self._resume_prefix_hits = r.counter("resume_prefix_hits")
        self._resume_cached_tokens = r.counter("resume_cached_tokens")
        self._preemptions = r.counter("preemptions")
        self._draft_accepted = r.counter("draft_accepted")
        self._draft_proposed = r.counter("draft_proposed")
        self._prefix_index_evictions = r.counter("prefix_index_evictions")
        self._phase = {p: r.counter(f"phase_{p}_s") for p in PHASES}
        # robustness accounting (serving/guard.py, docs/robustness.md):
        # every terminal outcome that is not FINISHED has its own counter,
        # so shed + expired + failed + completed partitions the requests
        # that left the system
        self._shed = r.counter("shed_requests")
        self._expired = r.counter("expired_requests")
        self._failed = r.counter("failed_requests")
        self._quarantined = r.counter("quarantined_slots")
        self._degraded_rounds = r.counter("degraded_rounds")
        self._watchdog_trips = r.counter("watchdog_trips")
        # gauges (time series; peak/mean land in summary)
        self._occupancy = r.gauge("slot_occupancy")
        self._blocks_in_use = r.gauge("blocks_in_use")
        self._queue_depth = r.gauge("queue_depth")
        self._degradation_level = r.gauge("degradation_level")
        # histograms (exact quantiles per run, streaming buckets for free)
        self._ttft = r.histogram("ttft_s")
        self._latency = r.histogram("latency_s")
        self._tpot = r.histogram("tpot_s")
        # rolling-window instruments (the live plane): last-N-seconds
        # views of the same events, readable mid-run without perturbing
        # anything — docs/observability.md §Live plane
        self.window = float(window)
        self._w_ttft = r.windowed_histogram("window_ttft_s", window, window_subs)
        self._w_tpot = r.windowed_histogram("window_tpot_s", window, window_subs)
        self._w_tokens = r.windowed_rate("window_tokens", window, window_subs)
        self._w_arrivals = r.windowed_rate("window_arrivals", window, window_subs)
        self._w_shed = r.windowed_rate("window_shed", window, window_subs)
        self._w_expired = r.windowed_rate("window_expired", window, window_subs)
        # token emission total (monotone companion of the windowed rate)
        self._tokens_emitted = r.counter("tokens_emitted")
        # chaos: per-site fired counters, labelled for /metrics
        self._fault_fired: Dict[str, Counter] = {}

    # -- back-compat views -------------------------------------------------

    @property
    def decode_steps(self) -> int:
        return int(self._decode_steps.value)

    @property
    def preemptions(self) -> int:
        return int(self._preemptions.value)

    @property
    def occupancy_samples(self) -> List[float]:
        return self._occupancy.values()

    @property
    def blocks_in_use_samples(self) -> List[int]:
        return [int(v) for v in self._blocks_in_use.values()]

    @property
    def peak_blocks_in_use(self) -> int:
        return int(self._blocks_in_use.peak)

    # -- event hooks -------------------------------------------------------

    def _touch(self, t: float) -> None:
        """Advance the run's end time. Every timestamped event calls this,
        so a run where no request ever finishes still reports its true
        span instead of a ~0 duration and a garbage tokens/s."""
        self.end_time = max(self.end_time, t)

    def on_submit(self, rid: int, arrival: float) -> None:
        self.requests[rid] = RequestTrace(arrival=arrival)
        self._w_arrivals.add(1, arrival)
        self._touch(arrival)

    def on_admit(self, rid: int, t: float) -> None:
        self.requests[rid].admitted = t
        self._touch(t)

    def on_first_token(self, rid: int, t: float) -> None:
        tr = self.requests[rid]
        if tr.first_token is None:  # a resume prefill keeps the first TTFT
            tr.first_token = t
            self._ttft.observe(tr.ttft)
            self._w_ttft.observe(tr.ttft, t)
        self._touch(t)

    def on_finish(self, rid: int, t: float, n_tokens: int) -> None:
        tr = self.requests[rid]
        tr.finished = t
        tr.n_tokens = n_tokens
        self._latency.observe(tr.latency)
        if tr.tpot is not None:
            self._tpot.observe(tr.tpot)
            self._w_tpot.observe(tr.tpot, t)
        self._touch(t)

    def on_tokens(self, n: int, t: float) -> None:
        """Record ``n`` freshly emitted tokens at engine time ``t`` —
        the rolling tokens/s signal. Fed from the per-burst host mirror,
        so it costs no extra device sync."""
        if n > 0:
            self._tokens_emitted.inc(n)
            self._w_tokens.add(n, t)
            self._touch(t)

    def on_occupancy(self, active_slots: float) -> None:
        self._occupancy.set(active_slots)

    def on_preempt(self, rid: int, t: float) -> None:
        """Record an eviction: the request running in a slot lost its
        blocks and went back to the queue at time ``t``."""
        self._preemptions.inc()
        self.preempted_rids.add(rid)
        self._touch(t)

    def on_prefix_lookup(
        self, rid: int, cached_tokens: int, prompt_tokens: int, resume: bool = False
    ) -> None:
        """Record a prefix-cache lookup at admission: ``cached_tokens`` of
        the ``prompt_tokens``-token prompt rode shared blocks (0 = miss).
        ``resume=True`` marks a preemption-resume admission — those count
        in separate ``resume_*`` counters so the hit rate keeps measuring
        cross-request sharing, not a request re-matching its own evicted
        blocks."""
        if resume:
            self._resume_cached_tokens.inc(cached_tokens)
            if cached_tokens > 0:
                self._resume_prefix_hits.inc()
            return
        self._prefix_lookups.inc()
        self._cached_prompt_tokens.inc(cached_tokens)
        self._total_prompt_tokens.inc(prompt_tokens)
        if cached_tokens > 0:
            self._prefix_hits.inc()

    def on_speculative(self, accepted: int, proposed: int) -> None:
        """Record cumulative draft-token counts: of ``proposed`` tokens
        the draft (backbone-only) model put forward, ``accepted`` survived
        full-model verification. The acceptance rate is the quality of
        the free draft model — 1.0 for a dense model (drafting degenerates
        to exact lookahead)."""
        self._draft_accepted.inc(int(accepted))
        self._draft_proposed.inc(int(proposed))

    def on_index_evictions(self, n: int) -> None:
        """Record the allocator's cumulative prefix-index cap evictions."""
        self._prefix_index_evictions.set(int(n))

    # -- robustness hooks (serving/guard.py) -------------------------------

    def on_shed(self, rid: int, t: float) -> None:
        """A queued request was dropped by bounded-queue load shedding
        (terminal state ABORTED; it never ran)."""
        self._shed.inc()
        self._w_shed.add(1, t)
        self._touch(t)

    def on_expired(self, rid: int, t: float) -> None:
        """A request outlived its deadline — reaped from the queue or
        host-cancelled mid-decode (terminal state EXPIRED)."""
        self._expired.inc()
        self._w_expired.add(1, t)
        self._touch(t)

    def on_fault(self, site: str, t: Optional[float] = None) -> None:
        """A chaos fail point fired: count it per-site under the
        labelled ``fault_fired{site=...}`` counter family, so live chaos
        runs are inspectable from ``/metrics``."""
        c = self._fault_fired.get(site)
        if c is None:
            c = self.registry.counter("fault_fired", labels={"site": site})
            self._fault_fired[site] = c
        c.inc()
        if t is not None:
            self._touch(t)

    def on_failed(self, rid: int, t: float) -> None:
        """The engine gave up on a request (terminal state FAILED):
        never-admittable at submit, or its slot was quarantined."""
        self._failed.inc()
        self._touch(t)

    def on_quarantine(self, rid: int, t: float) -> None:
        """A running slot produced non-finite logits and was quarantined;
        counts the slot event on top of the request's ``on_failed``."""
        self._quarantined.inc()
        self._touch(t)

    def on_degraded(self, level: int, t: Optional[float] = None) -> None:
        """Sample the degradation ladder's level this round; rounds at a
        level above 0 also count into ``degraded_rounds``."""
        self._degradation_level.set(float(level), t)
        if level > 0:
            self._degraded_rounds.inc()
        if t is not None:
            self._touch(t)

    def on_watchdog(self, t: float) -> None:
        """A decode/verify burst exceeded the watchdog's wall-time
        threshold."""
        self._watchdog_trips.inc()
        self._touch(t)

    def on_blocks_in_use(self, n: int, t: Optional[float] = None) -> None:
        self._blocks_in_use.set(int(n), t)
        if t is not None:
            self._touch(t)

    def on_queue_depth(self, n: int, t: Optional[float] = None) -> None:
        """Sample the arrival queue's depth (requests waiting for a slot
        or for blocks) — the backlog signal SLO scheduling keys off."""
        self._queue_depth.set(int(n), t)
        if t is not None:
            self._touch(t)

    def on_phase(self, phase: str, seconds: float) -> None:
        """Attribute ``seconds`` of host wall time to an engine phase
        (one of ``PHASES``); the per-phase totals land in summary as
        ``phase_<name>_s``."""
        self._phase[phase].inc(seconds)

    def on_decode_steps(self, n: int) -> None:
        """Count decode steps run across all slots. When recorded, occupancy
        is computed token-exactly as emitted_tokens / (steps * slots) — every
        step emits one token per truly-live slot, except a request's final
        EOS-consuming step, which occupies the slot but emits nothing (the
        stop token is excluded from outputs), so occupancy reads slightly
        conservative under EOS-terminated traffic.

        The speculative engine records K step-opportunities per round, so
        there ``mean_occupancy`` is the realized fraction of *peak
        speculative throughput* — slot idleness and draft rejections fold
        into one number (acceptance is reported separately) — and is not
        directly comparable with a non-speculative run's occupancy."""
        self._decode_steps.inc(n)

    # -- summary -----------------------------------------------------------

    def total_tokens(self) -> int:
        # list() first: the exporter thread reads this mid-run while the
        # serve loop inserts new requests, and dict iteration during an
        # insert raises
        return sum(tr.n_tokens for tr in list(self.requests.values()))

    def live_snapshot(self, now: Optional[float] = None) -> Dict[str, float]:
        """The rolling-window view at engine time ``now`` (default: the
        last event's timestamp) plus enough lifetime context to read a
        ``/metrics.json`` scrape standalone. Pure read — callable from
        the exporter thread mid-run without perturbing the registry."""
        t = self.end_time if now is None else now
        arrivals = self._w_arrivals.total(t)
        shed = self._w_shed.total(t)
        reqs = list(self.requests.values())
        return {
            "now_s": t,
            "window_s": self.window,
            "window_ttft_n": float(self._w_ttft.count(t)),
            "window_mean_ttft_s": self._w_ttft.mean(t),
            "window_p50_ttft_s": self._w_ttft.quantile(0.50, t),
            "window_p95_ttft_s": self._w_ttft.quantile(0.95, t),
            "window_tpot_n": float(self._w_tpot.count(t)),
            "window_p50_tpot_s": self._w_tpot.quantile(0.50, t),
            "window_p95_tpot_s": self._w_tpot.quantile(0.95, t),
            "window_tokens_per_s": self._w_tokens.rate(t),
            "window_arrivals_per_s": self._w_arrivals.rate(t),
            "window_shed_per_s": self._w_shed.rate(t),
            "window_expired_per_s": self._w_expired.rate(t),
            "window_shed_rate": shed / arrivals if arrivals else 0.0,
            # lifetime context
            "n_requests": float(len(reqs)),
            "completed": float(self._latency.n),
            "tokens_emitted": self._tokens_emitted.value,
            "queue_depth": self._queue_depth.last,
            "degradation_level": self._degradation_level.last,
            "shed_requests": self._shed.value,
            "expired_requests": self._expired.value,
            "failed_requests": self._failed.value,
        }

    def histogram_states(self) -> Dict[str, Dict[str, object]]:
        """The latency-family histograms' full distributions, keyed by
        name — what the router merges bucket-wise for fleet quantiles."""
        return {
            "ttft_s": self._ttft.state(),
            "latency_s": self._latency.state(),
            "tpot_s": self._tpot.state(),
        }

    def summary(self) -> Dict[str, float]:
        dur = max(self.end_time, 1e-9)
        steps = self._decode_steps.value
        if steps > 0:
            occ = self.total_tokens() / (steps * self.n_slots)
        elif self._occupancy.samples:
            occ = self._occupancy.mean() / self.n_slots
        else:
            occ = 0.0
        out = {
            "n_requests": float(len(self.requests)),
            "completed": float(self._latency.n),
            "total_tokens": float(self.total_tokens()),
            "duration_s": dur,
            "tokens_per_s": self.total_tokens() / dur,
            "mean_ttft_s": self._ttft.mean(),
            "p50_ttft_s": self._ttft.quantile(0.50),
            "p95_ttft_s": self._ttft.quantile(0.95),
            "p99_ttft_s": self._ttft.quantile(0.99),
            "mean_latency_s": self._latency.mean(),
            "p50_latency_s": self._latency.quantile(0.50),
            "p95_latency_s": self._latency.quantile(0.95),
            "p99_latency_s": self._latency.quantile(0.99),
            # inter-token latency (time per output token, decode phase)
            "mean_tpot_s": self._tpot.mean(),
            "tpot_p50_s": self._tpot.quantile(0.50),
            "tpot_p95_s": self._tpot.quantile(0.95),
            "tpot_p99_s": self._tpot.quantile(0.99),
            "mean_occupancy": occ,
            # prefix-cache: token-weighted hit rate (cached / prompt tokens)
            "prefix_cache_hit_rate": (
                self._cached_prompt_tokens.value / self._total_prompt_tokens.value
                if self._total_prompt_tokens.value
                else 0.0
            ),
            "cached_prompt_tokens": self._cached_prompt_tokens.value,
            "total_prompt_tokens": self._total_prompt_tokens.value,
            "prefix_hits": self._prefix_hits.value,
            "peak_blocks_in_use": self._blocks_in_use.peak,
            "mean_blocks_in_use": self._blocks_in_use.mean(),
            "preemptions": self._preemptions.value,
            "preempted_requests": float(len(self.preempted_rids)),
            "resume_prefix_hits": self._resume_prefix_hits.value,
            "resume_cached_tokens": self._resume_cached_tokens.value,
            # speculative decoding: draft-token acceptance
            "draft_accepted": self._draft_accepted.value,
            "draft_proposed": self._draft_proposed.value,
            "draft_acceptance_rate": (
                self._draft_accepted.value / self._draft_proposed.value
                if self._draft_proposed.value
                else 0.0
            ),
            "prefix_index_evictions": self._prefix_index_evictions.value,
            # arrival-queue backlog time series
            "mean_queue_depth": self._queue_depth.mean(),
            "peak_queue_depth": self._queue_depth.peak,
            # robustness: non-FINISHED terminal outcomes + guard activity
            "shed_requests": self._shed.value,
            "expired_requests": self._expired.value,
            "failed_requests": self._failed.value,
            "quarantined_slots": self._quarantined.value,
            "degraded_rounds": self._degraded_rounds.value,
            "watchdog_trips": self._watchdog_trips.value,
            "peak_degradation_level": self._degradation_level.peak,
        }
        # host wall-time attribution (schedule / prefill / decode / verify)
        for p in PHASES:
            out[f"phase_{p}_s"] = self._phase[p].value
        return out


# ---------------------------------------------------------------------------
# Fleet aggregation (serving/router.py)
# ---------------------------------------------------------------------------

# summary keys that take the max across replicas: the wall-clock span
# (replicas run side by side) and every ``peak_*`` key
_MERGE_MAX = {
    "duration_s",
}

# latency-quantile keys -> (histogram name, quantile). With per-replica
# histogram states the fleet value is recomputed from the *merged*
# distribution (max-of-p95s is not the fleet p95); the old max lands
# under ``<key>_peak`` (worst replica) either way.
_QUANTILE_KEYS = {
    "p50_ttft_s": ("ttft_s", 0.50),
    "p95_ttft_s": ("ttft_s", 0.95),
    "p99_ttft_s": ("ttft_s", 0.99),
    "p50_latency_s": ("latency_s", 0.50),
    "p95_latency_s": ("latency_s", 0.95),
    "p99_latency_s": ("latency_s", 0.99),
    "tpot_p50_s": ("tpot_s", 0.50),
    "tpot_p95_s": ("tpot_s", 0.95),
    "tpot_p99_s": ("tpot_s", 0.99),
}

# latency means -> histogram whose merged total/n recomputes them exactly
_MEAN_HIST_KEYS = {
    "mean_ttft_s": "ttft_s",
    "mean_latency_s": "latency_s",
    "mean_tpot_s": "tpot_s",
}

# weighted means: key -> the summary key whose value weights it
_MERGE_WEIGHTED = {
    "mean_ttft_s": "completed",
    "mean_latency_s": "completed",
    "mean_tpot_s": "completed",
    "mean_occupancy": "total_tokens",
    "mean_blocks_in_use": "duration_s",
    "mean_queue_depth": "duration_s",
}


def merge_histogram_states(
    states: Sequence[Optional[Dict[str, object]]],
) -> Optional[Dict[str, object]]:
    """Merge per-replica ``Histogram.state()`` dicts bucket-wise into one
    fleet distribution. All replicas share the same fixed edges (they are
    built from one config), so counts sum element-wise; raw samples
    concatenate when every contributing state kept them (then fleet
    quantiles are exact order statistics). Empty/missing states drop
    out; returns ``None`` when nothing contributed."""
    live = [s for s in states if s and s.get("n")]
    if not live:
        return None
    bounds = live[0]["boundaries"]
    for s in live[1:]:
        if s["boundaries"] != bounds:
            raise ValueError(
                "cannot merge histograms with different bucket boundaries"
            )
    counts = [
        sum(s["counts"][i] for s in live) for i in range(len(bounds) + 1)
    ]
    samples = None
    if all(s.get("samples") is not None for s in live):
        samples = [x for s in live for x in s["samples"]]
    return {
        "boundaries": list(bounds),
        "counts": counts,
        "n": sum(s["n"] for s in live),
        "total": sum(s["total"] for s in live),
        "min": min(s["min"] for s in live),
        "max": max(s["max"] for s in live),
        "samples": samples,
    }


def quantile_of_state(state: Optional[Dict[str, object]], q: float) -> float:
    """Quantile of a ``Histogram.state()`` dict: exact when raw samples
    survived the merge, bucket-interpolated otherwise."""
    if state is None or not state["n"]:
        return float("nan")
    if state.get("samples"):
        return _quantile(state["samples"], q)
    return _bucket_quantile(
        state["counts"],
        tuple(state["boundaries"]),
        state["n"],
        state["min"],
        state["max"],
        q,
    )


def merge_replica_summaries(
    summaries: Sequence[Dict[str, float]],
    histograms: Optional[
        Sequence[Optional[Dict[str, Dict[str, object]]]]
    ] = None,
) -> Dict[str, float]:
    """Fold per-replica ``ServingMetrics.summary()`` dicts into one
    fleet-level summary (the aggregate half of ``RouterResult.metrics``).

    Each replica runs on its own clock, so ``tokens_per_s`` *sums* — the
    fleet's aggregate throughput is what N side-by-side replicas deliver
    — while ``duration_s`` and the peaks take the max. Count keys
    (requests, tokens, preemptions, phase seconds, fault counters,
    anything not otherwise classified) sum; per-replica means recombine
    weighted by their natural denominator (completed requests for
    latency-family means, tokens for occupancy, duration for the backlog
    gauges). The two hit-rate keys are recomputed from the summed
    numerators/denominators so the fleet rate is token-weighted, not an
    average of averages.

    **Fleet quantiles.** ``histograms`` (one ``histogram_states()`` dict
    per summary, aligned; ``Router.run`` passes it) merges the underlying
    distributions bucket-wise and recomputes the latency quantiles from
    the *merged* distribution — the max of per-replica p95s is not the
    fleet p95 (a replica serving 5% of traffic badly dominates it).
    Every quantile key additionally lands under ``<key>_peak`` carrying
    the old worst-replica max; without ``histograms`` the primary key
    falls back to that max (conservative, as before)."""
    keys: List[str] = []
    for s in summaries:
        for k in s:
            if k not in keys:
                keys.append(k)
    merged_hists: Dict[str, Optional[Dict[str, object]]] = {}
    if histograms is not None:
        per_rep = [h or {} for h in histograms]
        for name in {nm for h in per_rep for nm in h}:
            merged_hists[name] = merge_histogram_states(
                [h.get(name) for h in per_rep]
            )
    out: Dict[str, float] = {}
    for k in keys:
        vals = [(s[k], s) for s in summaries if k in s]
        if k in _QUANTILE_KEYS:
            finite = [v for v, _ in vals if not math.isnan(v)]
            peak = max(finite) if finite else float("nan")
            out[f"{k}_peak"] = peak
            hname, q = _QUANTILE_KEYS[k]
            if merged_hists.get(hname) is not None:
                out[k] = quantile_of_state(merged_hists[hname], q)
            else:
                out[k] = peak
        elif k in _MERGE_MAX or k.startswith("peak_"):
            out[k] = max(v for v, _ in vals)
        elif k in _MERGE_WEIGHTED:
            hstate = merged_hists.get(_MEAN_HIST_KEYS.get(k, ""))
            if hstate is not None:
                # exact fleet mean from the merged distribution
                out[k] = hstate["total"] / hstate["n"]
                continue
            wkey = _MERGE_WEIGHTED[k]
            pairs = [(v, s.get(wkey, 0.0)) for v, s in vals if not math.isnan(v)]
            wsum = sum(w for _, w in pairs)
            if not pairs:
                out[k] = float("nan")
            elif wsum <= 0:
                out[k] = sum(v for v, _ in pairs) / len(pairs)
            else:
                out[k] = sum(v * w for v, w in pairs) / wsum
        else:
            out[k] = sum(v for v, _ in vals)
    # rates: recompute from the summed counters (token-weighted)
    if "total_prompt_tokens" in out:
        out["prefix_cache_hit_rate"] = (
            out.get("cached_prompt_tokens", 0.0) / out["total_prompt_tokens"]
            if out["total_prompt_tokens"]
            else 0.0
        )
    if "draft_proposed" in out:
        out["draft_acceptance_rate"] = (
            out.get("draft_accepted", 0.0) / out["draft_proposed"]
            if out["draft_proposed"]
            else 0.0
        )
    return out
