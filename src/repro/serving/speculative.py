"""Self-speculative decoding: the SLiM backbone as a free draft model.

SLiM decomposes every weight into a quantized 2:4-sparse *backbone* plus a
low-rank *adapter* that compensates the compression error. That structure
is a draft model for free: the backbone without the adapter is a strictly
cheaper forward pass of the *same* weights — no second checkpoint, no
separate draft KV cache, no extra block-pool pressure. Per round the
engine

1. **drafts** K-1 tokens with the adapter path disabled
   (``decode_step(skip_adapters=True)`` — ``SlimLinear`` layers compute
   only the backbone matmul). Draft K/V writes land in the slot's own
   pool blocks at the drafted positions; they are provisional, not
   trusted;
2. **verifies** the whole K-token window (the carry-committed token plus
   the K-1 proposals) in one full-model pass: ``transformer.verify_step``
   is the PR-3 offset-prefill generalized to per-slot position vectors
   and per-position logits, so every slot scores its own window at its
   own depth in a single dispatch. The verify pass re-writes the window's
   K/V with full-model values — whatever gets committed was computed by
   the full model, which is what makes greedy speculative decoding
   token-exact;
3. **accepts** by standard speculative rejection sampling
   (``sampling.speculative_accept``; greedy rows reduce to the longest
   matching prefix) and **commits in bulk**
   (``sampling.emit_speculative``): up to K tokens per row land in the
   on-device output buffers, positions advance by the committed count,
   and the carry logits become the full-model distribution after the last
   accepted token — so the next round's first token is always exact.

Rejected draft positions need no explicit rollback: their pool entries
hold positions strictly greater than every committed position, so causal
masking hides them until the next round's writes overwrite them, and they
can never fall inside a *full* committed block — the only thing the
prefix cache ever registers.

On a dense (uncompressed) model ``skip_adapters`` is a no-op, the draft
*is* the target, and the scheme degenerates to exact lookahead decoding —
every proposal is accepted, which makes dense runs a useful calibration
ceiling for the acceptance-rate metric.

The engine entry point is ``ContinuousEngine(speculative=K)``;
``SpeculativeEngine`` is a thin alias that makes the mode explicit. It
composes with the prefix cache (committed blocks hold full-model K/V) and
preemption (a victim's accepted tokens fold into the resume prompt like
any others; the scheduler charges the decode-reserve watermark in units
of K-token draft windows).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.serving.continuous import ContinuousEngine
from repro.serving.sampling import (
    degenerate_rows,
    draw_tokens,
    emit_speculative,
    speculative_accept,
)

# Unrolling the per-period layer scan inside the round is what lets XLA
# CSE one weight-decompression across all K forwards (see
# ``build_spec_round``); past this many periods the unrolled HLO gets big
# enough that compile time wins over the dequant sharing, so deep stacks
# keep the scan.
UNROLL_PERIOD_LIMIT = 16


def build_spec_round(
    cfg: ModelConfig, k: int, eos: int, unroll: Optional[bool] = None,
    greedy: bool = False, out_shardings=None,
):
    """Build the jitted speculative round: K-1 backbone draft steps, one
    batched full-model verify, rejection-sampled bulk commit — a single
    dispatch per round.

    The round is traced with the layer scan *unrolled* (for stacks up to
    ``UNROLL_PERIOD_LIMIT`` periods; override with ``unroll``). The round
    program contains K forward passes over the same compressed weights,
    and the weight decompression (int4 unpack + 2:4 expand + dequant) is
    loop-invariant across them — but ``lax.scan`` walls each forward's
    layers into separate loops XLA cannot share across. Unrolled, common
    subexpression elimination collapses the K identical dequants into
    one, which roughly halves the round's cost for compressed models on
    backends where dequant dominates (the measured K=4 round drops ~2x
    on CPU). The non-speculative step gains nothing from unrolling — one
    forward per program has nothing to share — so this is a win the
    round *structure* unlocks.

    The returned function maps
    ``(params, cache, logits, pos, active, emitted, maxnew, buf, key,
    temps, table, counters, poisoned)`` to
    ``(cache, logits, pos, active, emitted, buf, key, counters,
    poisoned)`` with the same carry conventions as the non-speculative
    ``_step``; ``counters`` is a length-2 int32 vector accumulating
    (accepted, proposed) draft counts for the acceptance-rate metric.

    ``poisoned`` [B] bool is the quarantine carry (docs/robustness.md):
    a row whose carry logits are degenerate (NaN/Inf — see
    ``sampling.degenerate_rows``) or whose verify pass produces a
    degenerate distribution at *any* window position commits nothing
    this round, leaves the active set, and is latched into ``poisoned``
    for the engine's per-burst host sync to quarantine. Only the
    offending row is affected — acceptance, commits, and draft counters
    for co-batched rows are untouched (rows never mix in attention or
    sampling, so a poisoned row cannot corrupt its neighbours' state).

    ``greedy=True`` builds the all-greedy variant the engine selects when
    every request in a trace is temperature-0: argmax drafting and
    longest-prefix acceptance with no RNG at all — the categorical/gumbel
    draws are a measurable slice of an otherwise matmul-only round.

    ``out_shardings`` (tensor-parallel serving only) pins the round's
    output layouts to the exact shardings the engine device_puts its
    carries with — without the pin GSPMD returns canonicalized sharding
    objects that are spec-unequal to the inputs' and the second round
    recompiles (tripping the retrace guard's max_sigs=1 budget).
    """
    assert k >= 2, "a speculative round needs at least one draft proposal"
    if unroll is None:
        unroll = cfg.n_periods <= UNROLL_PERIOD_LIMIT
    if unroll and not cfg.unroll_layers:
        cfg = dataclasses.replace(cfg, unroll_layers=True)

    def round_fn(
        params, cache, logits, pos, active, emitted, maxnew, buf, key,
        temps, table, counters, poisoned,
    ):
        # quarantine check on the way in: a degenerate carry (NaN logits
        # injected, or poisoned KV from the previous round's writes)
        # means nothing this row drafts or verifies can be trusted
        bad = degenerate_rows(logits) & active
        # window token 0: drawn from the carry logits — full-model, so it
        # is the token the non-speculative engine would emit next
        if greedy:
            cur = draw_tokens(logits, temps, key, greedy_only=True)
        else:
            key, sk = jax.random.split(key)
            cur = draw_tokens(logits, temps, sk)
        fed = [cur]
        dlogits = []
        # K-1 chained draft steps: backbone-only forward, provisional K/V
        # writes at pos + i - 1 through the slot's own table row. The
        # named_scope brackets let an xprof capture split the round's
        # device time into draft / verify / commit (decode_step adds its
        # own serve/draft_step scope per forward).
        with jax.named_scope("spec/draft"):
            for i in range(1, k):
                d, cache = T.decode_step(
                    params, cfg, cache, cur[:, None], pos + (i - 1),
                    block_table=table, skip_adapters=True,
                )
                if greedy:
                    cur = draw_tokens(d, temps, key, greedy_only=True)
                else:
                    key, sk = jax.random.split(key)
                    cur = draw_tokens(d, temps, sk)
                fed.append(cur)
                dlogits.append(d)
            fed = jnp.stack(fed, axis=1)  # [B, K]
            dstack = jnp.stack(dlogits, axis=1)  # [B, K-1, V]
        # one full-model pass scores the whole window for every slot and
        # overwrites the drafts' provisional K/V with full-model values
        tgt, cache = T.verify_step(params, cfg, cache, fed, pos, table)
        # a degenerate verify distribution at any window position (NaN
        # from corrupted KV the verify attention gathered) poisons the
        # row: nothing from this window may commit
        bad = bad | (
            ~jnp.all(jnp.isfinite(jnp.max(tgt, axis=-1)), axis=-1) & active
        )
        ok = active & ~bad
        with jax.named_scope("spec/commit"):
            n_acc, carry, key = speculative_accept(
                fed, dstack, tgt, temps, key, greedy=greedy
            )
            buf, emitted, committed, still = emit_speculative(
                fed, n_acc, buf, ok, emitted, maxnew, eos
            )
        # pos advances by the committed count for every row — finished
        # rows freeze at their committed length, so any later (ignored)
        # writes they make stay strictly beyond their committed chain
        # (a poisoned row commits nothing and freezes where it was)
        pos = pos + committed
        logits = jnp.where(ok[:, None], carry, logits)
        counters = counters.at[0].add(jnp.sum(jnp.where(ok, n_acc - 1, 0)))
        counters = counters.at[1].add(jnp.sum(ok.astype(jnp.int32)) * (k - 1))
        poisoned = poisoned | bad
        return cache, logits, pos, still, emitted, buf, key, counters, poisoned

    kw = {} if out_shardings is None else {"out_shardings": out_shardings}
    return jax.jit(round_fn, donate_argnums=(1,), **kw)


class SpeculativeEngine(ContinuousEngine):
    """``ContinuousEngine`` with self-speculative decoding always on —
    ``speculative`` defaults to 4 and must be >= 2. Purely a naming
    convenience: ``ContinuousEngine(speculative=K)`` is the same engine."""

    def __init__(self, params, cfg, speculative: int = 4, **kw):
        if speculative < 2:
            raise ValueError("SpeculativeEngine needs speculative >= 2")
        super().__init__(params, cfg, speculative=speculative, **kw)
