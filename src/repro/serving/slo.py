"""SLO monitoring: rolling-window error-budget burn feeding the ladder.

An SLO here is "at most 5% of requests may miss the target" — e.g. a p95
TTFT target of 200 ms means the slowest 5% are the error budget. Over the
engine's rolling window (``ObservabilityConfig.window_s``) the monitor
measures the fraction of samples actually missing each target and divides
by the 5% budget: that ratio is the **burn rate**, the standard SRE
signal. Burn 1.0 means the service is exactly on target (spending budget
as fast as it accrues); burn 2.0 means a sustained breach that will
exhaust the budget in half the window; burn 0 means no misses.

Three targets are monitored, each optional (0 = unmonitored):

* ``slo_ttft_p95_s``  — p95 time-to-first-token,
* ``slo_tpot_p95_s``  — p95 time-per-output-token,
* ``slo_shed_rate``   — shed requests per arrival (budget = the target
  itself: shedding *at* the configured rate is burn 1.0).

``pressure()`` sums the burns (capped at ``slo_pressure_cap``) and is
registered as an additional pressure source on the engine's
``DegradationLadder`` — so a *measured* SLO breach walks the ladder even
when queue backlog alone wouldn't, and the ladder's enter/exit hysteresis
applies unchanged because burn is continuous in the underlying miss
fraction. The monitor only reads the rolling-window instruments the
metrics facade already maintains: no new clocks, no device syncs, and a
disabled monitor (no targets) is never constructed.

Burn gauges land in the registry (``slo_burn_ttft`` / ``slo_burn_tpot`` /
``slo_burn_shed`` / ``slo_pressure``) so the live exporter serves them,
and rounds with any breach count into ``slo_breach_rounds``.
"""

from __future__ import annotations

from typing import Dict, Optional

from repro.serving.config import ObservabilityConfig
from repro.serving.metrics import ServingMetrics

# an SLO target of the p95 flavour leaves 5% of requests as error budget
P95_BUDGET = 0.05


class SloMonitor:
    """Rolling-window burn-rate tracker over a ``ServingMetrics``."""

    def __init__(self, obs: ObservabilityConfig, metrics: ServingMetrics):
        if not obs.slo_active:
            raise ValueError(
                "SloMonitor needs at least one SLO target "
                "(slo_ttft_p95_s / slo_tpot_p95_s / slo_shed_rate)"
            )
        self.obs = obs
        self.metrics = metrics
        r = metrics.registry
        self._g_ttft = r.gauge("slo_burn_ttft")
        self._g_tpot = r.gauge("slo_burn_tpot")
        self._g_shed = r.gauge("slo_burn_shed")
        self._g_pressure = r.gauge("slo_pressure")
        self._breach_rounds = r.counter("slo_breach_rounds")
        self._pressure = 0.0

    # -- burn computation --------------------------------------------------

    def burns(self, now: Optional[float] = None) -> Dict[str, float]:
        """Per-target burn rates over the rolling window ending at
        ``now`` (engine clock). Pure read."""
        m, obs = self.metrics, self.obs
        out = {}
        if obs.slo_ttft_p95_s:
            miss = m._w_ttft.fraction_above(obs.slo_ttft_p95_s, now)
            out["ttft"] = miss / P95_BUDGET
        if obs.slo_tpot_p95_s:
            miss = m._w_tpot.fraction_above(obs.slo_tpot_p95_s, now)
            out["tpot"] = miss / P95_BUDGET
        if obs.slo_shed_rate:
            arrivals = m._w_arrivals.total(now)
            shed = m._w_shed.total(now)
            rate = shed / arrivals if arrivals else 0.0
            out["shed"] = rate / obs.slo_shed_rate
        return out

    def update(self, now: float) -> float:
        """Recompute burns at engine time ``now``, record the gauges,
        and cache the ladder pressure for ``pressure()``. The engine
        calls this once per serve-loop round, before the ladder update."""
        burns = self.burns(now)
        total = min(sum(burns.values()), self.obs.slo_pressure_cap)
        self._pressure = total
        if "ttft" in burns:
            self._g_ttft.set(burns["ttft"], now)
        if "tpot" in burns:
            self._g_tpot.set(burns["tpot"], now)
        if "shed" in burns:
            self._g_shed.set(burns["shed"], now)
        self._g_pressure.set(total, now)
        if any(b >= 1.0 for b in burns.values()):
            self._breach_rounds.inc()
        return total

    def pressure(self) -> float:
        """The last ``update``'s capped burn total — registered on the
        ``DegradationLadder`` as an additional pressure source."""
        return self._pressure
