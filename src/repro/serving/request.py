"""Requests, the arrival queue, and synthetic workload traces.

A ``Request`` is one user generation: a token prompt, an arrival time
(seconds, relative to trace start), a generation budget, and per-request
sampling parameters. ``RequestQueue`` is the arrival-ordered admission
queue the scheduler pops from. ``synthetic_trace`` builds deterministic
Poisson-arrival workloads for benchmarks and the ``--workload`` serve mode.
"""
from __future__ import annotations

import bisect
import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    arrival: float = 0.0  # seconds since trace start
    max_new_tokens: int = 32
    temperature: float = 0.0  # per-request sampling (0 = greedy)

    # filled in by the engine
    output: Optional[List[int]] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


class RequestQueue:
    """Arrival-ordered FIFO: requests become poppable once ``now`` has
    passed their arrival time (the trace replays real clock arrivals)."""

    def __init__(self, requests: Sequence[Request] = ()):
        self._q: List[Request] = sorted(requests, key=lambda r: r.arrival)

    def push(self, req: Request) -> None:
        bisect.insort(self._q, req, key=lambda r: r.arrival)

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._q and self._q[0].arrival <= now:
            return self._q.pop(0)
        return None

    def next_arrival(self) -> Optional[float]:
        return self._q[0].arrival if self._q else None

    def __len__(self) -> int:
        return len(self._q)


def synthetic_trace(
    n_requests: int,
    rate: float,  # mean arrivals per second (Poisson)
    vocab_size: int,
    prompt_len: Tuple[int, int] = (16, 16),  # inclusive range
    max_new_tokens: Tuple[int, int] = (16, 32),
    temperature: float = 0.0,
    seed: int = 0,
) -> List[Request]:
    """Deterministic Poisson-arrival trace. The first request arrives at
    t=0 so runs start immediately; subsequent gaps are exponential."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), n_requests)
    gaps[0] = 0.0
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        mnew = int(rng.integers(max_new_tokens[0], max_new_tokens[1] + 1))
        prompt = rng.integers(0, vocab_size, plen).tolist()
        reqs.append(
            Request(
                rid=i,
                prompt=[int(t) for t in prompt],
                arrival=float(arrivals[i]),
                max_new_tokens=mnew,
                temperature=temperature,
            )
        )
    return reqs
