"""Requests, the arrival queue, and synthetic workload traces.

A ``Request`` is one user generation: a token prompt, an arrival time
(seconds, relative to trace start), a generation budget, and per-request
sampling parameters. ``RequestQueue`` is the arrival-ordered admission
queue the scheduler pops from. ``synthetic_trace`` builds deterministic
Poisson-arrival workloads for benchmarks and the ``--workload`` serve mode.

Preemption (the on-demand paged engine) adds a small state machine:

    QUEUED -> RUNNING -> FINISHED
                 |  ^
                 v  |  (evicted under memory pressure, re-queued with its
             PREEMPTED  generated-so-far tokens appended to the prompt)

A preempted request keeps everything it already generated in
``generated``; the scheduler re-queues it and the engine re-prefills
``serving_prompt`` (= prompt + generated) with the *remaining* budget, so
the resumed decode continues token-exactly where the evicted one stopped
(greedy decoding is deterministic in the prefix).

The robustness layer (serving/guard.py, docs/robustness.md) adds three
more *terminal* states reachable from anywhere pre-terminal:

* ``EXPIRED`` — the request outlived its deadline (queued past its TTL,
  or host-cancelled mid-decode). Partial output is kept; ``error`` says
  when it expired.
* ``ABORTED`` — shed by bounded-queue admission before ever running.
* ``FAILED`` — the engine gave up on the request itself: it could never
  be admitted (block need exceeds the whole pool), or its slot was
  quarantined after producing non-finite logits.

Terminal states never transition again (``RequestState.is_terminal``);
``Request.error`` carries the human-readable reason for every
non-FINISHED terminal state.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np


class RequestState(str, enum.Enum):
    """Lifecycle of a request inside the serving engine."""

    QUEUED = "queued"  # waiting in the arrival queue for a slot + blocks
    RUNNING = "running"  # admitted to a slot, prefilling or decoding
    PREEMPTED = "preempted"  # evicted under memory pressure (transient:
    # the scheduler immediately re-queues, moving it back to QUEUED)
    FINISHED = "finished"  # EOS or budget exhausted; ``output`` is final
    EXPIRED = "expired"  # deadline passed (queued or host-cancelled)
    ABORTED = "aborted"  # shed by bounded-queue admission, never ran
    FAILED = "failed"  # never-admittable, or quarantined (NaN/Inf logits)

    @property
    def is_terminal(self) -> bool:
        """Terminal states never transition again; the engine's drain
        loop only waits on non-terminal requests."""
        return self in (
            RequestState.FINISHED,
            RequestState.EXPIRED,
            RequestState.ABORTED,
            RequestState.FAILED,
        )


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    arrival: float = 0.0  # seconds since trace start
    max_new_tokens: int = 32
    temperature: float = 0.0  # per-request sampling (0 = greedy)
    deadline: Optional[float] = None  # absolute engine-clock time past
    # which the request expires (None = no deadline; the engine fills in
    # arrival + GuardConfig.default_ttl when a default TTL is set)

    # filled in by the engine
    output: Optional[List[int]] = None
    state: RequestState = RequestState.QUEUED
    generated: List[int] = dataclasses.field(default_factory=list)
    n_preemptions: int = 0
    error: Optional[str] = None  # reason for a non-FINISHED terminal state

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def serving_prompt(self) -> List[int]:
        """What the engine prefills: the original prompt plus every token
        generated in earlier (preempted) running spans — resume is a
        plain prefill of this longer prompt."""
        return self.prompt + self.generated

    @property
    def remaining_new_tokens(self) -> int:
        """Generation budget left after earlier preempted spans."""
        return self.max_new_tokens - len(self.generated)


class RequestQueue:
    """Arrival-ordered FIFO: requests become poppable once ``now`` has
    passed their arrival time (the trace replays real clock arrivals).

    Backed by a heap keyed on ``(arrival, seq)`` where ``seq`` is the
    submission order — push/pop are O(log n) and equal-arrival requests
    pop in deterministic FIFO order. A re-queued (preempted) request
    keeps its original arrival time, so it sorts ahead of every
    later-arriving request rather than to the back of the line."""

    def __init__(self, requests: Sequence[Request] = ()):
        self._seq = 0
        self._front_seq = -1
        self._q: List[Tuple[float, int, Request]] = []
        for r in requests:
            self.push(r)

    def push(self, req: Request, front: bool = False) -> None:
        """Enqueue a request. ``front=True`` (preemption requeue) makes it
        sort ahead of every already-queued request with the same arrival
        time — the evicted request goes back to the head of the line, not
        the tail, so eviction can never starve it behind peers that
        arrived together."""
        if front:
            seq = self._front_seq
            self._front_seq -= 1
        else:
            seq = self._seq
            self._seq += 1
        heapq.heappush(self._q, (req.arrival, seq, req))

    def peek_ready(self, now: float) -> Optional[Request]:
        """The request ``pop_ready`` would return, without removing it —
        lets the scheduler check block availability before committing."""
        if self._q and self._q[0][0] <= now:
            return self._q[0][2]
        return None

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._q and self._q[0][0] <= now:
            return heapq.heappop(self._q)[2]
        return None

    def next_arrival(self) -> Optional[float]:
        return self._q[0][0] if self._q else None

    def ready_count(self, now: float) -> int:
        """Requests whose arrival has passed — the *live* backlog (the
        bounded-queue cap applies to these, not to future arrivals a
        replayed trace holds)."""
        return sum(arr <= now for arr, _, _ in self._q)

    def drain_expired(self, now: float) -> List[Request]:
        """Remove and return every queued request whose deadline has
        passed. O(n) rebuild — called once per scheduling round, and the
        heap is small (the backlog)."""
        expired = [
            req
            for _, _, req in self._q
            if req.deadline is not None and now > req.deadline
        ]
        if expired:
            gone = {id(r) for r in expired}
            self._q = [e for e in self._q if id(e[2]) not in gone]
            heapq.heapify(self._q)
        return expired

    def shed_newest(self, now: float, max_ready: int) -> List[Request]:
        """Remove and return newest-arrival ready requests until at most
        ``max_ready`` remain ready — bounded-queue load shedding. Newest
        first means preemption re-queues (which keep their original, old
        arrival) are never shed before fresh arrivals."""
        ready = sorted(
            (e for e in self._q if e[0] <= now),
            key=lambda e: (e[0], e[1]),
            reverse=True,
        )
        if len(ready) <= max_ready:
            return []
        drop = ready[: len(ready) - max_ready]
        gone = {id(e[2]) for e in drop}
        self._q = [e for e in self._q if id(e[2]) not in gone]
        heapq.heapify(self._q)
        return [e[2] for e in drop]

    def __len__(self) -> int:
        return len(self._q)


def synthetic_trace(
    n_requests: int,
    rate: float,  # mean arrivals per second (Poisson)
    vocab_size: int,
    prompt_len: Tuple[int, int] = (16, 16),  # inclusive range
    max_new_tokens: Tuple[int, int] = (16, 32),
    temperature: float = 0.0,
    seed: int = 0,
    shared_prefix_len: int = 0,
    shared_prefix_groups: int = 1,
) -> List[Request]:
    """Deterministic Poisson-arrival trace. The first request arrives at
    t=0 so runs start immediately; subsequent gaps are exponential.

    ``shared_prefix_len > 0`` models system-prompt / few-shot traffic:
    every request's prompt starts with the same ``shared_prefix_len``
    tokens (truncated for prompts shorter than the prefix), followed by a
    per-request random tail — the workload the prefix cache serves.

    ``shared_prefix_groups > 1`` splits that traffic into several tenant
    populations, each with its own shared prefix; request ``i`` belongs to
    group ``i % groups`` (round-robin, so groups interleave in arrival
    order — the workload where the router's prefix-affinity placement
    beats least-loaded by keeping each tenant's prefix hot on one
    replica). ``groups=1`` reproduces the pre-group trace bit-exactly:
    the extra prefix draws only happen for ``groups > 1``."""
    if shared_prefix_groups < 1:
        raise ValueError("shared_prefix_groups must be >= 1")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), n_requests)
    gaps[0] = 0.0
    arrivals = np.cumsum(gaps)
    prefixes = [rng.integers(0, vocab_size, shared_prefix_len).tolist()]
    for _ in range(shared_prefix_groups - 1):
        prefixes.append(rng.integers(0, vocab_size, shared_prefix_len).tolist())
    reqs = []
    for i in range(n_requests):
        shared = prefixes[i % shared_prefix_groups]
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        mnew = int(rng.integers(max_new_tokens[0], max_new_tokens[1] + 1))
        head = shared[: min(plen, shared_prefix_len)]
        tail = rng.integers(0, vocab_size, plen - len(head)).tolist()
        prompt = head + tail
        reqs.append(
            Request(
                rid=i,
                prompt=[int(t) for t in prompt],
                arrival=float(arrivals[i]),
                max_new_tokens=mnew,
                temperature=temperature,
            )
        )
    return reqs
