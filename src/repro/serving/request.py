"""Requests, the arrival queue, and synthetic workload traces.

A ``Request`` is one user generation: a token prompt, an arrival time
(seconds, relative to trace start), a generation budget, and per-request
sampling parameters. ``RequestQueue`` is the arrival-ordered admission
queue the scheduler pops from. ``synthetic_trace`` builds deterministic
Poisson-arrival workloads for benchmarks and the ``--workload`` serve mode.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    arrival: float = 0.0  # seconds since trace start
    max_new_tokens: int = 32
    temperature: float = 0.0  # per-request sampling (0 = greedy)

    # filled in by the engine
    output: Optional[List[int]] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)


class RequestQueue:
    """Arrival-ordered FIFO: requests become poppable once ``now`` has
    passed their arrival time (the trace replays real clock arrivals).

    Backed by a heap keyed on ``(arrival, seq)`` where ``seq`` is the
    submission order — push/pop are O(log n) and equal-arrival requests
    pop in deterministic FIFO order."""

    def __init__(self, requests: Sequence[Request] = ()):
        self._seq = 0
        self._q: List[Tuple[float, int, Request]] = []
        for r in requests:
            self.push(r)

    def push(self, req: Request) -> None:
        heapq.heappush(self._q, (req.arrival, self._seq, req))
        self._seq += 1

    def peek_ready(self, now: float) -> Optional[Request]:
        """The request ``pop_ready`` would return, without removing it —
        lets the scheduler check block availability before committing."""
        if self._q and self._q[0][0] <= now:
            return self._q[0][2]
        return None

    def pop_ready(self, now: float) -> Optional[Request]:
        if self._q and self._q[0][0] <= now:
            return heapq.heappop(self._q)[2]
        return None

    def next_arrival(self) -> Optional[float]:
        return self._q[0][0] if self._q else None

    def __len__(self) -> int:
        return len(self._q)


def synthetic_trace(
    n_requests: int,
    rate: float,  # mean arrivals per second (Poisson)
    vocab_size: int,
    prompt_len: Tuple[int, int] = (16, 16),  # inclusive range
    max_new_tokens: Tuple[int, int] = (16, 32),
    temperature: float = 0.0,
    seed: int = 0,
    shared_prefix_len: int = 0,
) -> List[Request]:
    """Deterministic Poisson-arrival trace. The first request arrives at
    t=0 so runs start immediately; subsequent gaps are exponential.

    ``shared_prefix_len > 0`` models system-prompt / few-shot traffic:
    every request's prompt starts with the same ``shared_prefix_len``
    tokens (truncated for prompts shorter than the prefix), followed by a
    per-request random tail — the workload the prefix cache serves."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / max(rate, 1e-9), n_requests)
    gaps[0] = 0.0
    arrivals = np.cumsum(gaps)
    shared = rng.integers(0, vocab_size, shared_prefix_len).tolist()
    reqs = []
    for i in range(n_requests):
        plen = int(rng.integers(prompt_len[0], prompt_len[1] + 1))
        mnew = int(rng.integers(max_new_tokens[0], max_new_tokens[1] + 1))
        head = shared[: min(plen, shared_prefix_len)]
        tail = rng.integers(0, vocab_size, plen - len(head)).tolist()
        prompt = head + tail
        reqs.append(
            Request(
                rid=i,
                prompt=[int(t) for t in prompt],
                arrival=float(arrivals[i]),
                max_new_tokens=mnew,
                temperature=temperature,
            )
        )
    return reqs
