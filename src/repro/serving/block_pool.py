"""Block-granular KV cache accounting for the paged continuous engine.

The paged cache is a shared pool of ``n_blocks`` fixed-size blocks (each
``block_size`` cache positions x layer x KV-head); a request occupies
``ceil(positions / block_size)`` of them instead of a whole ``max_len``
lane. ``BlockAllocator`` is the host-side free list the scheduler consults
at admission (admit iff the request's worst-case block need is free) and
returns blocks to at release. Allocation is exact bookkeeping, no device
traffic — the device sees only the per-slot block *tables* the engine
builds from these ids.

Two physical blocks are reserved and never allocated:

* block 0 — the **null** block: every unallocated block-table entry points
  here. Its ``pos`` entries are only ever written with ``-1`` (prefill pad
  tails), so gathers through unallocated table entries are always masked.
* block 1 — the **trash** block: released/never-filled slots have their
  whole table row pointed here, so the decode step's unconditional K/V
  write for inactive rows lands in a block no live table references,
  instead of corrupting blocks that may have been reallocated.

Prefix caching (``prefix_cache=True``) layers three mechanisms on top of
the free list:

* **refcounts** — a physical block may appear in several slots' tables at
  once; ``release`` decrements instead of freeing, and a block only leaves
  circulation when its count hits zero.
* **content-hash index** — every *full* prompt block is registered under
  the chained hash of its token prefix (``h_j = hash((h_{j-1}, tokens of
  block j))``), so an admission can find the longest block-aligned cached
  prefix of its prompt and point its table at those blocks (refcount++).
  A refcount-0 hashed block is *evictable*, not free: it keeps its content
  and can be revived by a later match.
* **copy-on-write** — no slot ever writes a block whose refcount exceeds
  one. The single write-into-shared case is a fully cached prompt (the
  engine must recompute the last prompt token for its logits): the last
  matched block is copied to a fresh block owned by the slot before the
  write. Eviction is clock-hand: when an admission would otherwise defer,
  the hand sweeps the pool and drops refcount-0 cached blocks.

On-demand allocation (the preemption-enabled engine) adds two per-slot
paths on top of admission-time allocation:

* ``extend(slot, n)`` — grow a running slot's table by ``n`` fresh blocks
  as its decode actually crosses block boundaries, instead of charging
  the worst case up front. Returns ``None`` (no state mutated) when even
  eviction cannot supply the blocks — the engine then preempts a victim.
* ``preempt(slot, tokens)`` — release a victim's blocks back to the pool.
  With ``prefix_cache=True`` the victim's *full* blocks (prompt and
  generated tokens both — their KV is deterministic in the token chain)
  are first registered in the hash index, so they demote to refcount-0
  *cached* entries rather than plain free blocks and the victim's
  re-prefill at resume is mostly a prefix-cache hit.

Invariants (``check`` in tests):
  - a block's refcount equals the number of slot tables holding it;
  - null/trash are never handed out;
  - free, evictable (hashed, refcount 0) and referenced blocks partition
    the ``n_blocks - RESERVED_BLOCKS`` allocatable blocks;
  - the hash index is a bijection onto the hashed blocks.
"""
from __future__ import annotations

import dataclasses
from collections import Counter, deque
from typing import Callable, Deque, Dict, List, Optional, Sequence

NULL_BLOCK = 0  # read target of unallocated table entries; pos stays -1
TRASH_BLOCK = 1  # write target of inactive slots; never read by live rows
RESERVED_BLOCKS = 2


def blocks_needed(n_positions: int, block_size: int) -> int:
    return -(-n_positions // block_size)


def chain_hashes(tokens: Sequence[int], block_size: int) -> List[int]:
    """Chained content hashes of the *full* blocks of ``tokens``: entry j
    identifies the whole prefix ``tokens[: (j+1) * block_size]``, so equal
    hashes mean equal prefixes, not just equal blocks."""
    hashes: List[int] = []
    h = 0
    for j in range(len(tokens) // block_size):
        h = hash((h, tuple(tokens[j * block_size : (j + 1) * block_size])))
        hashes.append(h)
    return hashes


def prefix_route_key(tokens: Sequence[int], block_size: int) -> Optional[int]:
    """The routing identity of a prompt's shared prefix: the chain hash
    of its *first* full block (``None`` when the prompt has no full block
    or paging is off). Two prompts share a key iff their first
    ``block_size`` tokens are equal — exactly the granularity at which
    the prefix cache can share their blocks — so the router's
    prefix-affinity placement (serving/router.py) keys stickiness on it."""
    if block_size <= 0 or len(tokens) < block_size:
        return None
    return chain_hashes(tokens[:block_size], block_size)[0]


@dataclasses.dataclass
class PrefixAdmit:
    """What the engine needs to prefill an admission with a cached prefix.

    ``cached_len`` counts prompt tokens already present in the slot's
    blocks (0 = cold); the engine prefills only ``prompt[cached_len:]``.
    ``cow_src/cow_dst`` name the device block copy for the fully-cached
    case (both ``NULL_BLOCK`` when no copy is needed)."""

    cached_len: int = 0
    cached_blocks: int = 0  # table entries holding valid prefix data
    cow_src: int = NULL_BLOCK
    cow_dst: int = NULL_BLOCK

    @property
    def hit(self) -> bool:
        return self.cached_len > 0


class BlockAllocator:
    def __init__(
        self,
        n_blocks: int,
        block_size: int,
        prefix_cache: bool = False,
        prefix_cache_max_entries: int = 0,  # 0 = unbounded hash index
    ):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if n_blocks <= RESERVED_BLOCKS:
            raise ValueError(
                f"pool of {n_blocks} blocks leaves nothing to allocate "
                f"({RESERVED_BLOCKS} reserved)"
            )
        if prefix_cache_max_entries < 0:
            raise ValueError("prefix_cache_max_entries must be >= 0")
        self.n_blocks = n_blocks
        self.block_size = block_size
        self.prefix_cache = prefix_cache
        self.prefix_cache_max_entries = prefix_cache_max_entries
        self.index_evictions = 0  # entries dropped by cap/TTL (metrics)
        # degradation-ladder valve (serving/guard.py level 1): False
        # pauses registration of *new* chains in the hash index — lookups
        # against already-cached chains still hit, so shared-prefix
        # traffic keeps its wins while churn stops growing the index
        self.register_new_chains = True
        # optional telemetry hook: called as on_event(name, args_dict) at
        # point occurrences deep inside the allocator (clock-hand block
        # reclaim, index subtree drops); the engine wires it to its span
        # tracer. None (the default) costs one comparison per event.
        self.on_event: Optional[Callable[[str, Dict[str, int]], None]] = None
        self._now = 0.0  # engine clock, fed via tick(); stamps registrations
        self._stamp: Dict[int, float] = {}  # chain hash -> registration time
        self._free: Deque[int] = deque(range(RESERVED_BLOCKS, n_blocks))
        self._owned: Dict[int, List[int]] = {}  # slot -> table blocks (in order)
        self._ref: Dict[int, int] = {}  # block -> refcount (allocated only)
        # prefix-cache state: hashed blocks keep their content while
        # refcount 0 (evictable) until the clock hand reclaims them.
        # Both index dicts are registration-ordered (Python dict order), so
        # the cap's evict-oldest sweep is the front of ``_block_of``.
        self._hash_of: Dict[int, int] = {}  # block -> chain hash
        self._block_of: Dict[int, int] = {}  # chain hash -> block
        # chain-tree links: entry h's parent is the hash of the one-block-
        # shorter prefix (0 = chain root). Cap/TTL drops cascade to the
        # whole subtree — a suffix entry whose ancestor is gone can never
        # match again, so keeping it would waste cap space and blocks.
        self._parent: Dict[int, int] = {}  # chain hash -> parent hash
        self._kids: Dict[int, set] = {}  # chain hash -> child hashes
        self._hand: int = RESERVED_BLOCKS  # clock-hand eviction cursor
        self._n_evict: int = 0  # hashed blocks with refcount 0 (O(1) count)
        self._info: Dict[int, PrefixAdmit] = {}  # slot -> last admit info

    @property
    def capacity(self) -> int:
        """Total allocatable blocks (pool minus reserved)."""
        return self.n_blocks - RESERVED_BLOCKS

    def n_evictable(self) -> int:
        return self._n_evict

    def available(self) -> int:
        """Blocks an admission could obtain: free plus evictable cached."""
        return len(self._free) + self.n_evictable()

    def in_use(self) -> int:
        """Blocks pinned by live slots (excludes evictable cached blocks)."""
        return self.capacity - self.available()

    def can_allocate(self, n: int) -> bool:
        return n <= self.available()

    # -- free-list internals ------------------------------------------------

    def _evict_one(self) -> None:
        """Clock-hand sweep: reclaim the next refcount-0 cached block."""
        for _ in range(self.capacity):
            blk = self._hand
            self._hand += 1
            if self._hand >= self.n_blocks:
                self._hand = RESERVED_BLOCKS
            if blk in self._hash_of and self._ref.get(blk, 0) == 0:
                # pool-pressure reclaim: drop just this entry. Descendant
                # entries it strands stay evictable and are reclaimed as
                # the hand (or a cap/TTL cascade) reaches them.
                self._unlink(self._hash_of[blk])
                if self.on_event is not None:
                    self.on_event("cache_evict", {"block": blk})
                return
        raise RuntimeError("eviction requested but no refcount-0 cached block")

    def _take_free(self, n: int) -> List[int]:
        while len(self._free) < n:
            self._evict_one()
        return [self._free.popleft() for _ in range(n)]

    # -- hash-index bookkeeping ---------------------------------------------

    def _register(self, h: int, blk: int, parent: int = 0) -> None:
        """Index ``blk`` under chain hash ``h`` (``parent`` = hash of the
        one-block-shorter prefix, 0 for a chain root), enforcing the
        optional entry cap. Matching always walks from the chain root, so
        the index must never hold an entry whose prefix is gone: an entry
        whose parent is no longer indexed is skipped outright, and when
        the index would exceed ``prefix_cache_max_entries`` the
        *oldest-registered chain* loses its deepest leaf — dropping from
        the tail keeps every surviving entry matchable. Dropped blocks
        stay owned if referenced, or move straight to the free list if
        they were evictable."""
        if parent and parent not in self._block_of:
            # the one-block-shorter prefix has been dropped (cap/TTL/
            # clock-hand); this entry could never match — dead weight
            return
        self._block_of[h] = blk
        self._hash_of[blk] = h
        self._stamp[h] = self._now
        self._parent[h] = parent
        if parent:
            self._kids.setdefault(parent, set()).add(h)
        cap = self.prefix_cache_max_entries
        while cap and len(self._block_of) > cap:
            old = next(iter(self._block_of))  # oldest chain's rootmost entry
            while self._kids.get(old):
                old = next(iter(self._kids[old]))  # walk to a leaf
            self._unlink(old)
            self.index_evictions += 1

    def _unlink(self, h: int) -> None:
        """Remove one index entry and its tree links (no cascade)."""
        blk = self._block_of.pop(h)
        del self._hash_of[blk]
        self._stamp.pop(h, None)
        parent = self._parent.pop(h, 0)
        kids = self._kids.get(parent)
        if kids is not None:
            kids.discard(h)
            if not kids:
                del self._kids[parent]
        if self._ref.get(blk, 0) == 0:
            self._n_evict -= 1
            self._free.append(blk)

    def _drop_entry(self, h: int) -> None:
        """Cap/TTL drop: unregister ``h`` and every descendant entry
        (none of which could match once ``h`` is gone). Iterative — a
        conversation-length chain is one long parent->child line, far
        deeper than Python's recursion limit."""
        stack, subtree = [h], []
        while stack:
            cur = stack.pop()
            subtree.append(cur)
            stack.extend(self._kids.get(cur, ()))
        for cur in subtree:
            self._kids.pop(cur, None)  # descendants all drop; no discards
            self._unlink(cur)
            self.index_evictions += 1
        if self.on_event is not None:
            self.on_event("index_drop", {"entries": len(subtree)})

    def tick(self, now: float) -> None:
        """Advance the allocator's clock; later registrations are stamped
        with it (the TTL sweep's time base)."""
        self._now = now

    def expire_index(self, cutoff: float) -> int:
        """TTL sweep: drop every index entry registered before ``cutoff``.
        Registration order is time order (the clock only moves forward),
        so this pops from the front and costs O(dropped). Returns the
        number of entries dropped."""
        n = 0
        while self._block_of:
            old_h = next(iter(self._block_of))
            if self._stamp.get(old_h, 0.0) >= cutoff:
                break
            before = self.index_evictions
            self._drop_entry(old_h)  # cascades to the stranded subtree
            n += self.index_evictions - before
        return n

    # -- plain allocation (no prefix sharing) -------------------------------

    def allocate(self, slot: int, n: int) -> List[int]:
        """Hand ``n`` fresh blocks to ``slot``. The scheduler releases a
        slot before reusing it, so a double-allocate is a bug, not a
        policy. Evicts refcount-0 cached blocks if the free list is short."""
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already owns blocks")
        if not self.can_allocate(n):
            raise RuntimeError(
                f"allocating {n} blocks with only {self.available()} available"
            )
        blocks = self._take_free(n)
        for b in blocks:
            self._ref[b] = 1
        self._owned[slot] = blocks
        return list(blocks)

    # -- on-demand growth / preemption --------------------------------------

    def extend(self, slot: int, n: int) -> Optional[List[int]]:
        """Grow ``slot``'s table by ``n`` fresh blocks (the on-demand
        decode path: the engine calls this when a slot's next burst will
        cross into blocks it does not own yet). Evicts refcount-0 cached
        blocks as needed; returns ``None`` — with no state mutated — when
        even eviction cannot supply ``n`` blocks, in which case the
        caller preempts a victim and retries.

        The returned blocks are *appended* to the slot's table in order;
        their contents are stale (a prior owner's data may survive), so
        the engine must wipe their ``pos`` entries to -1 before any
        decode step can gather them."""
        if slot not in self._owned:
            raise RuntimeError(f"slot {slot} owns no blocks to extend")
        if n <= 0:
            return []
        if not self.can_allocate(n):
            return None
        blocks = self._take_free(n)
        for b in blocks:
            self._ref[b] = 1
        self._owned[slot].extend(blocks)
        return list(blocks)

    def release_cached(self, slot: int, tokens: Optional[Sequence[int]]) -> None:
        """Release a slot's blocks, first demoting its full blocks to
        cached entries.

        ``tokens`` is the committed chain whose KV the slot's blocks hold
        — prompt plus every generated token (a preemption victim's
        generated-so-far, or a finished request's whole output). Every
        *full* block of that chain not already in the hash index is
        registered first, so the release turns it into a refcount-0
        *cached* entry instead of a free block: a preemption victim's
        resume re-prefill matches its own chain, and a multi-turn
        follow-up whose prompt extends a finished request's
        prompt + output rides the earlier turn's blocks. With the prefix
        cache off (or ``tokens=None``) this is a plain ``release``."""
        if self.prefix_cache and tokens is not None and self.register_new_chains:
            table = self._owned.get(slot, [])
            hashes = chain_hashes(tokens, self.block_size)
            for j, h in enumerate(hashes):
                if j >= len(table):
                    break
                blk = table[j]
                if h in self._block_of or blk in self._hash_of:
                    continue  # chain (or block) already indexed
                self._register(h, blk, parent=hashes[j - 1] if j else 0)
        self.release(slot)

    def preempt(self, slot: int, tokens: Optional[Sequence[int]] = None) -> None:
        """Release a preemption victim's blocks back to the pool —
        ``release_cached`` under its historical name (the victim's resume
        re-prefill then pays only for the partial last block)."""
        self.release_cached(slot, tokens)

    # -- prefix-cached admission --------------------------------------------

    def _match_chain(self, hashes: Sequence[int]) -> List[int]:
        """Longest run of indexed blocks along a hash chain."""
        matched: List[int] = []
        for h in hashes:
            blk = self._block_of.get(h)
            if blk is None:
                break
            matched.append(blk)
        return matched

    def match_prefix(self, tokens: Sequence[int]) -> List[int]:
        """Longest chain of cached blocks covering a block-aligned prefix
        of ``tokens`` (pure lookup: nothing is pinned)."""
        return self._match_chain(chain_hashes(tokens, self.block_size))

    def admit_request(
        self,
        slot: int,
        tokens: Sequence[int],
        n_pos: int,
        n_pos_cold: Optional[int] = None,
        reserve: int = 0,
    ) -> Optional[PrefixAdmit]:
        """Atomically admit a request: match its longest cached prefix, pin
        the matched blocks (refcount++), allocate the uncached remainder
        (evicting refcount-0 cached blocks as needed), and register the
        fresh full prompt blocks in the hash index. Returns ``None`` —
        with no state mutated — when even after eviction the remainder
        would not fit (the scheduler defers FIFO).

        ``n_pos`` is the request's total position need (prompt + budget
        under worst-case charging; just the prompt under on-demand
        admission); ``n_pos_cold`` optionally inflates it for the cold
        path (bucketed prefill writes whole blocks). ``reserve`` is the
        on-demand decode watermark: the admission defers unless it fits
        with ``reserve`` blocks of headroom left for running slots to
        grow into. A fully cached prompt keeps all its matched blocks but
        copies the last one to a fresh block (``cow_src/cow_dst``) so the
        last-token recompute never writes a block with refcount > 1."""
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already owns blocks")
        bs = self.block_size
        hashes = chain_hashes(tokens, bs)
        matched = self._match_chain(hashes)
        n_tok = len(tokens)
        cow = bool(matched) and len(matched) * bs == n_tok
        total = blocks_needed(
            max(n_pos, n_pos_cold or 0) if not matched else n_pos, bs
        )
        n_fresh = total - len(matched) + (1 if cow else 0)
        # matched evictable blocks are being revived — they are not
        # reclaimable capacity for this same admission
        matched_evictable = sum(
            1 for b in set(matched) if self._ref.get(b, 0) == 0
        )
        headroom = len(self._free) + self.n_evictable() - matched_evictable
        if n_fresh + reserve > headroom:
            return None
        for b in matched:
            if self._ref.get(b, 0) == 0:
                self._n_evict -= 1  # revived from the evictable pool
            self._ref[b] = self._ref.get(b, 0) + 1  # pin before any eviction
        fresh = self._take_free(n_fresh)
        for b in fresh:
            self._ref[b] = 1
        if cow:
            # table order: matched[:-1] + [copy of matched[-1]] + rest
            src = matched[-1]
            dst = fresh[0]
            self._ref[src] -= 1
            if self._ref[src] == 0:  # revived-then-copied evictable block
                del self._ref[src]
                self._n_evict += 1
            table = matched[:-1] + [dst] + fresh[1:]
            info = PrefixAdmit(
                cached_len=n_tok - 1,
                cached_blocks=len(matched),
                cow_src=src,
                cow_dst=dst,
            )
        else:
            table = matched + fresh
            info = PrefixAdmit(
                cached_len=len(matched) * bs, cached_blocks=len(matched)
            )
        # register this prompt's fresh full blocks so later admissions can
        # share them (their content is written by the prefill the engine
        # dispatches before any subsequent admission's reads); paused at
        # degradation level >= 1 — matching above still served the hit
        if self.register_new_chains:
            for j in range(len(matched), len(hashes)):
                h = hashes[j]
                if h not in self._block_of:
                    self._register(
                        h, table[j], parent=hashes[j - 1] if j else 0
                    )
        self._owned[slot] = table
        self._info[slot] = info
        return info

    def admit_info(self, slot: int) -> PrefixAdmit:
        return self._info.get(slot, PrefixAdmit())

    # -- shared state -------------------------------------------------------

    def blocks_of(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def refcount(self, blk: int) -> int:
        """How many slot tables hold ``blk`` (0 for free/evictable)."""
        return self._ref.get(blk, 0)

    def purge_slot_index(self, slot: int) -> int:
        """Drop every hash-index entry held by ``slot``'s blocks (each
        with its stranded descendants). Quarantine path: a slot whose KV
        produced non-finite logits may hold corrupted block payloads, and
        a corrupted block that stays matchable would poison every later
        admission that rides it. Returns the number of entries dropped.
        Call *before* ``release`` — afterwards the slot owns nothing."""
        dropped = 0
        for blk in self._owned.get(slot, ()):
            h = self._hash_of.get(blk)
            if h is not None:
                before = self.index_evictions
                self._drop_entry(h)
                dropped += self.index_evictions - before
        return dropped

    def release(self, slot: int) -> None:
        self._info.pop(slot, None)
        for blk in self._owned.pop(slot, ()):
            self._ref[blk] -= 1
            if self._ref[blk] == 0:
                del self._ref[blk]
                if blk in self._hash_of:  # hashed blocks become evictable
                    self._n_evict += 1
                else:
                    self._free.append(blk)

    def check(self) -> None:
        """Assert the ownership/refcount/index invariants (test hook)."""
        counts = Counter(b for bs_ in self._owned.values() for b in bs_)
        for slot, bs_ in self._owned.items():
            assert len(set(bs_)) == len(bs_), f"slot {slot} table repeats a block"
        assert dict(counts) == self._ref, "refcounts disagree with slot tables"
        referenced = set(self._ref)
        free = set(self._free)
        assert len(free) == len(self._free), "free list repeats a block"
        assert not referenced & free, "referenced block on free list"
        hashed = set(self._hash_of)
        assert not hashed & free, "hashed block on free list"
        evictable = hashed - referenced
        assert self._n_evict == len(evictable), "evictable counter drifted"
        assert len(free) + len(evictable) + len(referenced) == self.capacity
        for reserved in (NULL_BLOCK, TRASH_BLOCK):
            assert reserved not in referenced
            assert reserved not in free
            assert reserved not in hashed
        assert len(self._block_of) == len(self._hash_of)
        for blk, h in self._hash_of.items():
            assert self._block_of[h] == blk, "hash index is not a bijection"
        assert set(self._stamp) == set(self._block_of), (
            "registration stamps disagree with the hash index"
        )
        assert set(self._parent) == set(self._block_of), (
            "chain-tree links disagree with the hash index"
        )
        if self.prefix_cache_max_entries:
            assert len(self._block_of) <= self.prefix_cache_max_entries, (
                "hash index exceeded its entry cap"
            )
