"""Block-granular KV cache accounting for the paged continuous engine.

The paged cache is a shared pool of ``n_blocks`` fixed-size blocks (each
``block_size`` cache positions x layer x KV-head); a request occupies
``ceil(positions / block_size)`` of them instead of a whole ``max_len``
lane. ``BlockAllocator`` is the host-side free list the scheduler consults
at admission (admit iff the request's worst-case block need is free) and
returns blocks to at release. Allocation is exact bookkeeping, no device
traffic — the device sees only the per-slot block *tables* the engine
builds from these ids.

Two physical blocks are reserved and never allocated:

* block 0 — the **null** block: every unallocated block-table entry points
  here. Its ``pos`` entries are only ever written with ``-1`` (prefill pad
  tails), so gathers through unallocated table entries are always masked.
* block 1 — the **trash** block: released/never-filled slots have their
  whole table row pointed here, so the decode step's unconditional K/V
  write for inactive rows lands in a block no live table references,
  instead of corrupting blocks that may have been reallocated.

Invariants (``check`` in tests):
  - a physical block is owned by at most one slot at a time;
  - null/trash are never handed out;
  - ``len(free) + sum(owned) == n_blocks - RESERVED_BLOCKS`` always.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, Dict, List

NULL_BLOCK = 0  # read target of unallocated table entries; pos stays -1
TRASH_BLOCK = 1  # write target of inactive slots; never read by live rows
RESERVED_BLOCKS = 2


def blocks_needed(n_positions: int, block_size: int) -> int:
    return -(-n_positions // block_size)


class BlockAllocator:
    def __init__(self, n_blocks: int, block_size: int):
        if block_size < 1:
            raise ValueError(f"block_size must be >= 1, got {block_size}")
        if n_blocks <= RESERVED_BLOCKS:
            raise ValueError(
                f"pool of {n_blocks} blocks leaves nothing to allocate "
                f"({RESERVED_BLOCKS} reserved)"
            )
        self.n_blocks = n_blocks
        self.block_size = block_size
        self._free: Deque[int] = deque(range(RESERVED_BLOCKS, n_blocks))
        self._owned: Dict[int, List[int]] = {}  # slot -> blocks

    @property
    def capacity(self) -> int:
        """Total allocatable blocks (pool minus reserved)."""
        return self.n_blocks - RESERVED_BLOCKS

    def available(self) -> int:
        return len(self._free)

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def allocate(self, slot: int, n: int) -> List[int]:
        """Hand ``n`` blocks to ``slot``. The scheduler releases a slot
        before reusing it, so a double-allocate is a bug, not a policy."""
        if slot in self._owned:
            raise RuntimeError(f"slot {slot} already owns blocks")
        if not self.can_allocate(n):
            raise RuntimeError(
                f"allocating {n} blocks with only {len(self._free)} free"
            )
        blocks = [self._free.popleft() for _ in range(n)]
        self._owned[slot] = blocks
        return list(blocks)

    def blocks_of(self, slot: int) -> List[int]:
        return list(self._owned.get(slot, ()))

    def release(self, slot: int) -> None:
        for blk in self._owned.pop(slot, ()):
            self._free.append(blk)

    def check(self) -> None:
        """Assert the ownership invariants (test hook)."""
        owned = [b for bs in self._owned.values() for b in bs]
        assert len(set(owned)) == len(owned), "block owned by two slots"
        assert not set(owned) & set(self._free), "owned block on free list"
        assert NULL_BLOCK not in owned and TRASH_BLOCK not in owned
        assert len(owned) + len(self._free) == self.capacity
