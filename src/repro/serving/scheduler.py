"""Slot scheduler for continuous batching.

Maps queued requests onto a fixed pool of decode slots: a slot freed by a
finished request (EOS or budget) is refilled mid-flight by the next
arrived request, so decode batches stay full under load instead of
draining to the slowest member (the static-batch failure mode).

Admission control is by construction: a request is only admitted when
``prompt_len + max_new_tokens`` fits the engine's cache (checked at
``submit``) and a slot is free. Optional prefill-length bucketing pads
the prompt up to the next multiple of ``prefill_bucket``, bounding the
number of distinct prefill shapes — and therefore jit recompiles — to
``max_len / prefill_bucket`` (exactness of padded prefill is the model's
``supports_ragged_prefill`` contract).

With a paged KV cache the scheduler additionally consults a
``BlockAllocator``. Under the default **worst-case charging**, a request
is admitted when a slot is free *and* its worst-case block need —
``ceil(max(prompt + max_new, padded_prefill) / block_size)`` — is
available, and its blocks return to the pool at ``release``. Deferral is
FIFO (the head of the queue blocks younger requests) so admission order
stays deterministic under memory pressure.

``on_demand=True`` (the preemption-enabled engine) switches to
**watermark admission**: a request is charged only
``blocks_needed(prompt)`` at admission, plus ``decode_reserve`` blocks of
headroom that stay unallocated (the watermark running slots grow into
block-by-block as decode crosses boundaries). The reserve is waived while
no slot is occupied, so a lone request whose total need equals the pool
is still admissible. When the pool genuinely runs dry mid-decode the
engine preempts: ``pick_victim`` names the youngest-admitted running
slot (preempting the youngest wastes the least completed work and can
never starve the oldest), and ``preempt`` folds the victim's generated
tokens into its prompt and re-queues it at its original arrival time, so
resume is a plain re-prefill of the longer prompt — token-exact under
greedy decoding.

With prefix caching on the allocator, admission routes through
``BlockAllocator.admit_request``: the request is charged only the
uncached remainder of its block need (its longest cached block-aligned
prompt prefix rides shared, refcounted blocks), and the allocator may
evict refcount-0 cached blocks rather than defer.

``spec_pad=K`` (the speculative engine) widens every charge by K
positions of draft scratch — the last verify window writes up to K
positions past the budget — and charges the decode-reserve watermark in
units of K-token windows. ``victim_policy="cost"`` replaces
youngest-first victim selection with blocks-freed per
generated-token-discarded scoring (the oldest admission stays exempt, so
the no-starvation guarantee survives).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.serving.block_pool import BlockAllocator, blocks_needed
from repro.serving.request import Request, RequestQueue, RequestState


class NeverAdmittable(ValueError):
    """The request could not be served by this engine under *any* pool
    state — its worst-case block need exceeds the whole allocatable pool
    (or it fails a static validity check). Raised at ``submit`` so the
    FIFO admission loop can never defer on it forever; the engine
    catches it and fails just that request instead of the whole run."""


class Scheduler:
    def __init__(
        self,
        n_slots: int,
        max_len: int,
        prefill_bucket: int = 0,
        allocator: Optional[BlockAllocator] = None,
        on_demand: bool = False,
        decode_reserve: int = 0,
        spec_pad: int = 0,  # speculative draft-window length K: charging
        # covers K positions of draft scratch past the budget, and the
        # decode-reserve watermark is charged in units of K-token windows
        victim_policy: str = "youngest",  # "youngest" | "cost"
    ):
        if on_demand and allocator is None:
            raise ValueError("on-demand admission needs a BlockAllocator")
        if decode_reserve < 0:
            raise ValueError("decode_reserve must be >= 0")
        if spec_pad < 0:
            raise ValueError("spec_pad must be >= 0")
        if victim_policy not in ("youngest", "cost"):
            raise ValueError(
                f"unknown victim_policy {victim_policy!r} "
                "(expected 'youngest' or 'cost')"
            )
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket
        self.allocator = allocator
        self.on_demand = on_demand
        self.decode_reserve = decode_reserve
        self.spec_pad = spec_pad
        self.victim_policy = victim_policy
        self.queue = RequestQueue()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.assignments: Dict[int, int] = {}  # rid -> slot (last wins)
        self.slot_seq: Dict[int, int] = {}  # slot -> admission sequence
        self._admit_counter = 0

    @classmethod
    def from_config(cls, config, allocator: Optional[BlockAllocator] = None):
        """Build a scheduler from an ``EngineConfig`` (serving/config.py)
        — the derivation the engine uses, factored out so the Router and
        the tests construct byte-identical scheduling policy from the
        same config object. The decode-reserve watermark only applies
        under preemption (on-demand admission); worst-case charging
        ignores it by construction."""
        preempt = config.paging.preemption
        return cls(
            config.n_slots,
            config.max_len,
            config.prefill_bucket,
            allocator,
            on_demand=preempt,
            decode_reserve=config.paging.decode_reserve if preempt else 0,
            spec_pad=config.speculative.k,
            victim_policy=config.paging.victim_policy,
        )

    # -- admission --------------------------------------------------------

    def submit(self, req: Request) -> None:
        # a fresh submit resets any state a previous run left behind (so
        # traces can be replayed through several engines) — before the
        # capacity check below, which reads serving_prompt
        req.state = RequestState.QUEUED
        req.generated = []
        req.n_preemptions = 0
        req.output = None
        req.error = None
        need = req.prompt_len + req.max_new_tokens
        if need > self.max_len:
            raise NeverAdmittable(
                f"request {req.rid}: prompt+budget {need} exceeds max_len "
                f"{self.max_len}"
            )
        if req.prompt_len == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1 "
                "(the decode step always emits the first sampled token)"
            )
        if self.allocator is not None:
            nb = self.block_need(req)
            if nb > self.allocator.capacity:
                # fail fast: deferral could never help — FIFO admission
                # would wedge the whole queue behind this request forever
                raise NeverAdmittable(
                    f"request {req.rid}: needs {nb} cache blocks but the "
                    f"pool only holds {self.allocator.capacity} — it could "
                    "never be admitted"
                )
        self.queue.push(req)

    def block_need(self, req: Request) -> int:
        """Worst-case block count for a request: covers the generation
        budget, the (possibly longer) bucketed prefill write, and — in
        speculative mode — the up-to-K positions of draft scratch the
        last verify window can write past the budget."""
        assert self.allocator is not None
        plen = len(req.serving_prompt)
        need_pos = max(plen + req.remaining_new_tokens, self.bucket_len(plen))
        return blocks_needed(need_pos + self.spec_pad, self.allocator.block_size)

    def prefill_need(self, req: Request) -> int:
        """On-demand block count at admission: just the prompt. Bucketed
        prefill pad chunks land in the null block, and decode growth is
        ``BlockAllocator.extend`` territory."""
        assert self.allocator is not None
        return blocks_needed(len(req.serving_prompt), self.allocator.block_size)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self, now: float) -> List[Tuple[int, Request]]:
        """Pop arrived requests into free slots; returns (slot, request)
        pairs to prefill. Called between decode bursts. With an
        allocator, a request is only popped once its blocks are
        guaranteed — if the queue head doesn't fit, admission defers
        (FIFO) until a release returns enough blocks."""
        admitted = []
        for slot in self.free_slots():
            req = self.queue.peek_ready(now)
            if req is None:
                break
            # the decode-reserve watermark only applies while other slots
            # are running (they are what grows into the headroom); an
            # idle pool admits anything that fits outright. Speculative
            # decode grows in K-token draft windows, so the reserve is
            # charged in units of K: each reserve unit covers the blocks
            # one window's growth can claim.
            reserve = self.decode_reserve if self.running() > 0 else 0
            if reserve and self.spec_pad and self.allocator is not None:
                reserve *= blocks_needed(self.spec_pad, self.allocator.block_size)
            if self.allocator is not None and self.allocator.prefix_cache:
                sp = req.serving_prompt
                if self.on_demand:
                    info = self.allocator.admit_request(
                        slot, sp, len(sp), reserve=reserve
                    )
                else:
                    total = len(sp) + req.remaining_new_tokens + self.spec_pad
                    info = self.allocator.admit_request(
                        slot,
                        sp,
                        total,
                        n_pos_cold=max(total, self.bucket_len(len(sp)) + self.spec_pad),
                    )
                if info is None:
                    break
            elif self.allocator is not None:
                if self.on_demand:
                    nb = self.prefill_need(req)
                    if not self.allocator.can_allocate(nb + reserve):
                        break
                else:
                    nb = self.block_need(req)
                    if not self.allocator.can_allocate(nb):
                        break
                self.allocator.allocate(slot, nb)
            self.queue.pop_ready(now)
            req.state = RequestState.RUNNING
            self.slots[slot] = req
            self.assignments[req.rid] = slot
            self.slot_seq[slot] = self._admit_counter
            self._admit_counter += 1
            admitted.append((slot, req))
        return admitted

    def release(
        self,
        slot: int,
        tokens: Optional[Sequence[int]] = None,
        state: RequestState = RequestState.FINISHED,
    ) -> None:
        """Free a finished slot. With the prefix cache and ``tokens`` (the
        request's committed chain: prompt + output), the slot's full
        blocks demote to cached index entries instead of free blocks, so
        a multi-turn follow-up whose prompt extends this conversation
        re-prefills only its new suffix.

        ``state`` is the terminal state the released request lands in:
        ``FINISHED`` by default, ``EXPIRED`` for a deadline cancellation,
        ``FAILED`` for a quarantined slot (those callers pass
        ``tokens=None`` — a quarantined slot's KV must never demote into
        the prefix cache)."""
        req = self.slots[slot]
        if req is not None:
            req.state = state
        self.slots[slot] = None
        self.slot_seq.pop(slot, None)
        if self.allocator is not None:
            if tokens is not None and self.allocator.prefix_cache:
                self.allocator.release_cached(slot, tokens)
            else:
                self.allocator.release(slot)

    # -- robustness: expiry + load shedding --------------------------------

    def reap_expired(self, now: float) -> List[Request]:
        """Drain queued requests whose deadline has passed (state ->
        ``EXPIRED``). Runs before admission each round so an expired
        request never wastes a prefill."""
        expired = self.queue.drain_expired(now)
        for req in expired:
            req.state = RequestState.EXPIRED
        return expired

    def expired_running(self, now: float) -> List[int]:
        """Slots whose running request is past its deadline — the
        engine's host-side cancellation candidates."""
        return [
            slot
            for slot, req in enumerate(self.slots)
            if req is not None
            and req.deadline is not None
            and now > req.deadline
        ]

    def shed_overflow(self, now: float, max_ready: int) -> List[Request]:
        """Bounded-queue load shedding: drop newest-arrival ready
        requests (state -> ``ABORTED``) until at most ``max_ready``
        remain waiting. Future arrivals in a replayed trace don't count
        against the bound, and preemption re-queues (old arrivals) are
        shed last."""
        shed = self.queue.shed_newest(now, max_ready)
        for req in shed:
            req.state = RequestState.ABORTED
        return shed

    # -- preemption -------------------------------------------------------

    def pick_victim(
        self, generated: Optional[Dict[int, int]] = None
    ) -> Optional[int]:
        """Choose the running slot to evict.

        ``"youngest"`` (default): the slot admitted most recently —
        discards the least completed work and guarantees the oldest
        request always makes progress (no starvation).

        ``"cost"``: the slot with the best blocks-freed per
        generated-token-discarded ratio (``generated`` maps slot to its
        generated-so-far count; a missing entry reads as 0) — evictions
        prefer slots that return a lot of memory at little re-prefill
        cost. The oldest-admitted slot is exempt while anything else is
        running, which preserves the no-starvation guarantee; ties break
        youngest-first."""
        if not self.slot_seq:
            return None
        youngest = max(self.slot_seq, key=self.slot_seq.__getitem__)
        if self.victim_policy == "youngest" or len(self.slot_seq) == 1:
            return youngest
        gen = generated or {}
        oldest = min(self.slot_seq, key=self.slot_seq.__getitem__)

        def score(slot: int) -> float:
            freed = (
                len(self.allocator.blocks_of(slot))
                if self.allocator is not None
                else 1
            )
            return freed / (1.0 + gen.get(slot, 0))

        candidates = [s for s in self.slot_seq if s != oldest]
        # best score, ties broken youngest-first (least work lost)
        return max(candidates, key=lambda s: (score(s), self.slot_seq[s]))

    def preempt(self, slot: int, new_tokens: Sequence[int]) -> Request:
        """Evict the request running in ``slot``: fold ``new_tokens``
        (everything it generated this span) into its resume prompt,
        release its blocks (demoting full blocks to cached entries when
        the allocator prefix-caches), and re-queue it at its original
        arrival time. Token-exact resume is the caller's contract: the
        engine re-prefills ``serving_prompt`` with the remaining
        budget."""
        req = self.slots[slot]
        assert req is not None, f"slot {slot} is not running"
        req.generated.extend(int(t) for t in new_tokens)
        req.n_preemptions += 1
        req.state = RequestState.PREEMPTED
        self.slots[slot] = None
        self.slot_seq.pop(slot, None)
        if self.allocator is not None:
            # serving_prompt now covers exactly the positions whose KV
            # the slot's blocks hold: prompt + everything generated
            self.allocator.preempt(slot, tokens=req.serving_prompt)
        self.requeue(req)
        return req

    def requeue(self, req: Request) -> None:
        """Put a preempted request back at the *head* of the arrival
        queue: it keeps its original arrival time (ahead of every later
        arrival) and jumps same-arrival peers, so eviction can never
        starve it and it becomes admissible immediately."""
        req.state = RequestState.QUEUED
        self.queue.push(req, front=True)

    # -- state ------------------------------------------------------------

    def pending(self) -> bool:
        return len(self.queue) > 0

    def queue_depth(self) -> int:
        """Requests waiting for a slot (or for blocks) — the backlog the
        telemetry layer samples every scheduling round."""
        return len(self.queue)

    def running(self) -> int:
        return sum(r is not None for r in self.slots)

    def next_arrival(self) -> Optional[float]:
        return self.queue.next_arrival()

    # -- prefill shape bucketing ------------------------------------------

    def bucket_len(self, prompt_len: int) -> int:
        """Padded prefill length for a prompt: next multiple of
        ``prefill_bucket`` (0 = exact length, one compile per distinct
        prompt length)."""
        if self.prefill_bucket <= 0:
            return prompt_len
        b = self.prefill_bucket
        return min(-(-prompt_len // b) * b, self.max_len)
