"""Slot scheduler for continuous batching.

Maps queued requests onto a fixed pool of decode slots: a slot freed by a
finished request (EOS or budget) is refilled mid-flight by the next arrived
request, so decode batches stay full under load instead of draining to the
slowest member (the static-batch failure mode).

Admission control is by construction: a request is only admitted when
``prompt_len + max_new_tokens`` fits the engine's cache (checked at
``submit``) and a slot is free. Optional prefill-length bucketing pads the
prompt up to the next multiple of ``prefill_bucket``, bounding the number of
distinct prefill shapes — and therefore jit recompiles — to
``max_len / prefill_bucket`` (exactness of padded prefill is the model's
``supports_ragged_prefill`` contract).

With a paged KV cache the scheduler additionally consults a
``BlockAllocator``: a request is admitted when a slot is free *and* its
worst-case block need — ``ceil(max(prompt + max_new, padded_prefill) /
block_size)`` — is available, and its blocks return to the pool at
``release``. Deferral is FIFO (the head of the queue blocks younger
requests) so admission order stays deterministic under memory pressure.

With prefix caching on the allocator, admission routes through
``BlockAllocator.admit_request``: the request is charged only
``blocks_needed(total) - cached_blocks`` fresh blocks (its longest cached
block-aligned prompt prefix rides shared, refcounted blocks), and the
allocator may evict refcount-0 cached blocks rather than defer.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.serving.block_pool import BlockAllocator, blocks_needed
from repro.serving.request import Request, RequestQueue


class Scheduler:
    def __init__(
        self,
        n_slots: int,
        max_len: int,
        prefill_bucket: int = 0,
        allocator: Optional[BlockAllocator] = None,
    ):
        self.n_slots = n_slots
        self.max_len = max_len
        self.prefill_bucket = prefill_bucket
        self.allocator = allocator
        self.queue = RequestQueue()
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.assignments: Dict[int, int] = {}  # rid -> slot (history, last wins)

    # -- admission --------------------------------------------------------

    def submit(self, req: Request) -> None:
        need = req.prompt_len + req.max_new_tokens
        if need > self.max_len:
            raise ValueError(
                f"request {req.rid}: prompt+budget {need} exceeds max_len "
                f"{self.max_len}"
            )
        if req.prompt_len == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if req.max_new_tokens < 1:
            raise ValueError(
                f"request {req.rid}: max_new_tokens must be >= 1 "
                "(the decode step always emits the first sampled token)"
            )
        if self.allocator is not None:
            nb = self.block_need(req)
            if nb > self.allocator.capacity:
                raise ValueError(
                    f"request {req.rid}: needs {nb} cache blocks but the "
                    f"pool only holds {self.allocator.capacity} — it could "
                    "never be admitted"
                )
        self.queue.push(req)

    def block_need(self, req: Request) -> int:
        """Worst-case block count for a request: covers the generation
        budget and the (possibly longer) bucketed prefill write."""
        assert self.allocator is not None
        need_pos = max(
            req.prompt_len + req.max_new_tokens, self.bucket_len(req.prompt_len)
        )
        return blocks_needed(need_pos, self.allocator.block_size)

    def free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slots) if r is None]

    def admit(self, now: float) -> List[Tuple[int, Request]]:
        """Pop arrived requests into free slots; returns (slot, request)
        pairs to prefill. Called between decode bursts. With an allocator,
        a request is only popped once its blocks are guaranteed — if the
        queue head doesn't fit, admission defers (FIFO) until a release
        returns enough blocks."""
        admitted = []
        for slot in self.free_slots():
            req = self.queue.peek_ready(now)
            if req is None:
                break
            if self.allocator is not None and self.allocator.prefix_cache:
                # one atomic call: match cached prefix, pin it, allocate
                # (evicting if needed) only the uncached remainder
                info = self.allocator.admit_request(
                    slot,
                    req.prompt,
                    req.prompt_len + req.max_new_tokens,
                    n_pos_cold=max(
                        req.prompt_len + req.max_new_tokens,
                        self.bucket_len(req.prompt_len),
                    ),
                )
                if info is None:
                    break
            elif self.allocator is not None:
                nb = self.block_need(req)
                if not self.allocator.can_allocate(nb):
                    break
                self.allocator.allocate(slot, nb)
            self.queue.pop_ready(now)
            self.slots[slot] = req
            self.assignments[req.rid] = slot
            admitted.append((slot, req))
        return admitted

    def release(self, slot: int) -> None:
        self.slots[slot] = None
        if self.allocator is not None:
            self.allocator.release(slot)

    # -- state ------------------------------------------------------------

    def pending(self) -> bool:
        return len(self.queue) > 0

    def running(self) -> int:
        return sum(r is not None for r in self.slots)

    def next_arrival(self) -> Optional[float]:
        return self.queue.next_arrival()

    # -- prefill shape bucketing ------------------------------------------

    def bucket_len(self, prompt_len: int) -> int:
        """Padded prefill length for a prompt: next multiple of
        ``prefill_bucket`` (0 = exact length, one compile per distinct
        prompt length)."""
        if self.prefill_bucket <= 0:
            return prompt_len
        b = self.prefill_bucket
        return min(-(-prompt_len // b) * b, self.max_len)
