"""Typed engine configuration: the single front door to ``ContinuousEngine``.

PRs 1-8 grew ``ContinuousEngine.__init__`` to ~20 flat keyword arguments
whose legality constraints (prefix cache needs paging, speculative decoding
needs pure-attention periods, ...) were scattered between the constructor
and the serve loop. ``EngineConfig`` collapses them into one dataclass of
grouped sub-configs:

* ``PagingConfig``      — paged KV pool: block size, pool size, preemption
  policy (on-demand growth, eviction, victim selection).
* ``PrefixCacheConfig`` — shared prompt-prefix blocks: on/off plus the
  content-hash index bounds (entry cap, TTL).
* ``SpecConfig``        — self-speculative decoding window K.
* ``ParallelConfig``    — tensor-parallel degree: ``tp > 1`` shards the
  weights, KV pool and attention heads over a ``(1, tp)`` device mesh's
  ``model`` axis (models/sharding.py specs; block tables stay host-side
  and replica-local).
* ``GuardConfig``       — the existing robustness policy (serving/guard.py),
  embedded unchanged.

``validate()`` rejects every incoherent combination **at construction**
(the checks that used to live in ``ContinuousEngine.__init__``), so a
``Router`` building N replicas fails before the first replica exists, not
deep inside replica 3's serve loop. Checks that need the model
architecture (paged-cache support, pure-attention requirements) run when
``model_cfg`` is passed — the engine passes it; config-only callers get
the structural checks.

``to_dict``/``from_dict`` (and the JSON string variants) round-trip the
config losslessly — ``launch/serve.py --metrics-json`` embeds the config
in the metrics dump so every recorded run carries its own provenance.

The old flat kwargs stay accepted for one release through
``EngineConfig.from_legacy_kwargs`` (the engine shim warns once per
construction and maps them onto a config).
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Optional

from repro.serving.guard import GuardConfig

# old flat ContinuousEngine kwarg -> (sub-config, field) it maps onto;
# None means the kwarg is a top-level EngineConfig field of the same name
LEGACY_KWARGS: Dict[str, Optional[tuple]] = {
    "n_slots": None,
    "max_len": None,
    "eos_id": None,
    "prefill_bucket": None,
    "seed": None,
    "check_invariants": None,
    "check_retrace": None,
    "block_size": ("paging", "block_size"),
    "n_blocks": ("paging", "n_blocks"),
    "preemption": ("paging", "preemption"),
    "decode_reserve": ("paging", "decode_reserve"),
    "victim_policy": ("paging", "victim_policy"),
    "prefix_cache": ("prefix_cache", "enabled"),
    "prefix_cache_max_entries": ("prefix_cache", "max_entries"),
    "prefix_cache_ttl": ("prefix_cache", "ttl"),
    "speculative": ("speculative", "k"),
    "guard": None,
}


@dataclasses.dataclass
class PagingConfig:
    """Paged KV cache pool (serving/block_pool.py)."""

    block_size: int = 0  # positions per block; 0 = contiguous max_len lanes
    n_blocks: Optional[int] = None  # pool size (None = equal memory to
    # n_slots contiguous lanes, plus the reserved blocks)
    preemption: bool = False  # on-demand growth + eviction under pressure
    decode_reserve: int = 2  # watermark blocks held back at admission
    victim_policy: str = "youngest"  # "youngest" | "cost"

    @property
    def paged(self) -> bool:
        return self.block_size > 0


@dataclasses.dataclass
class PrefixCacheConfig:
    """Shared prompt-prefix blocks over the paged pool."""

    enabled: bool = False
    max_entries: int = 0  # content-hash index cap; 0 = unbounded
    ttl: float = 0.0  # seconds an index entry may outlive registration


@dataclasses.dataclass
class SpecConfig:
    """Self-speculative decoding (serving/speculative.py)."""

    k: int = 0  # window length; K >= 2 drafts K-1 tokens per round, 0 = off


@dataclasses.dataclass
class ParallelConfig:
    """Tensor parallelism inside one replica (models/sharding.py)."""

    tp: int = 1  # model-axis mesh size; 1 = single device


@dataclasses.dataclass
class ObservabilityConfig:
    """The live observability plane (docs/observability.md §Live plane):
    rolling-window instruments, SLO monitoring feeding the degradation
    ladder, and the per-request flight recorder. All defaults keep the
    plane passive: windowed instruments always record (they are cheap
    ring updates on existing hook paths), but no SLO targets means no
    monitor and no ladder pressure, and the flight recorder is off."""

    window_s: float = 60.0  # rolling-window span (engine clock seconds)
    window_subs: int = 12  # ring granularity: sub-windows per window
    slo_ttft_p95_s: float = 0.0  # p95 TTFT target; 0 = unmonitored
    slo_tpot_p95_s: float = 0.0  # p95 TPOT target; 0 = unmonitored
    slo_shed_rate: float = 0.0  # shed/arrival rate target; 0 = unmonitored
    slo_pressure_cap: float = 4.0  # max ladder pressure the monitor adds
    flight_recorder: bool = False  # record per-request lifecycle rings
    flight_recorder_events: int = 64  # ring capacity per request
    postmortem_dir: Optional[str] = None  # dump bundles for FAILED/
    # EXPIRED/ABORTED terminals here (flight recorder implied on)

    def __post_init__(self):
        if self.window_s <= 0:
            raise ValueError("window_s must be > 0 seconds")
        if self.window_subs < 1:
            raise ValueError("window_subs must be >= 1")
        for name in ("slo_ttft_p95_s", "slo_tpot_p95_s", "slo_shed_rate"):
            if getattr(self, name) < 0:
                raise ValueError(f"{name} must be >= 0 (0 = unmonitored)")
        if self.slo_pressure_cap <= 0:
            raise ValueError("slo_pressure_cap must be > 0")
        if self.flight_recorder_events < 1:
            raise ValueError("flight_recorder_events must be >= 1")

    @property
    def slo_active(self) -> bool:
        """Whether any SLO target is set (the engine builds an
        ``SloMonitor`` and wires it into the ladder only then)."""
        return bool(
            self.slo_ttft_p95_s or self.slo_tpot_p95_s or self.slo_shed_rate
        )

    @property
    def recorder_active(self) -> bool:
        return bool(self.flight_recorder or self.postmortem_dir)


@dataclasses.dataclass
class EngineConfig:
    """Everything that shapes one ``ContinuousEngine`` replica.

    Runtime collaborators (clock/sleep, a live ``SpanTracer``, a chaos
    ``FaultPlan``) are deliberately NOT here: they are process-local
    objects, not serializable configuration — the engine takes them as
    keyword arguments next to the config.
    """

    n_slots: int = 8
    max_len: int = 512
    eos_id: Optional[int] = None
    prefill_bucket: int = 0
    seed: int = 0
    check_invariants: bool = False
    check_retrace: bool = False
    trace: bool = False  # True = the engine builds a default SpanTracer
    paging: PagingConfig = dataclasses.field(default_factory=PagingConfig)
    prefix_cache: PrefixCacheConfig = dataclasses.field(
        default_factory=PrefixCacheConfig
    )
    speculative: SpecConfig = dataclasses.field(default_factory=SpecConfig)
    parallel: ParallelConfig = dataclasses.field(default_factory=ParallelConfig)
    guard: Optional[GuardConfig] = None
    observability: ObservabilityConfig = dataclasses.field(
        default_factory=ObservabilityConfig
    )

    # -- validation --------------------------------------------------------

    def validate(self, model_cfg: Any = None) -> "EngineConfig":
        """Reject incoherent combinations with ``ValueError``.

        Structural checks always run; architecture-dependent checks
        (paged-cache exactness, pure-attention requirements for prefix
        caching / speculative decoding / bucketed prefill, the MoE
        exclusion) additionally run when ``model_cfg`` is given.
        Returns ``self`` so construction sites can chain:
        ``EngineConfig(...).validate(cfg)``.
        """
        # local import: transformer capability gates live model-side
        from repro.models import transformer as T

        pg, pc, sp = self.paging, self.prefix_cache, self.speculative
        if self.n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        if self.max_len < 1:
            raise ValueError("max_len must be >= 1")
        if self.prefill_bucket < 0:
            raise ValueError("prefill_bucket must be >= 0")
        if pc.enabled:
            if pg.block_size <= 0:
                raise ValueError(
                    "prefix_cache shares pool blocks; it needs block_size > 0"
                )
            if model_cfg is not None and not T.supports_prefix_cache(model_cfg):
                raise ValueError(
                    f"{model_cfg.name}: prefix caching is exact only for pure-"
                    "attention periods (shared blocks carry KV, not "
                    "SSM/MoE state)"
                )
        if pg.preemption and pg.block_size <= 0:
            raise ValueError(
                "preemption evicts pool blocks; it needs block_size > 0"
            )
        if pg.decode_reserve < 0:
            raise ValueError("decode_reserve must be >= 0")
        if sp.k:
            if sp.k < 2:
                raise ValueError(
                    "speculative=K drafts K-1 tokens per round; it needs "
                    "K >= 2"
                )
            if pg.block_size <= 0:
                raise ValueError(
                    "speculative decoding verifies draft windows against "
                    "the paged pool; it needs block_size > 0"
                )
            if model_cfg is not None and not T.supports_speculative(model_cfg):
                raise ValueError(
                    f"{model_cfg.name}: self-speculative decoding is exact only "
                    "for pure-attention periods (an SSM recurrence cannot "
                    "roll back a rejected draft, and MoE capacity couples "
                    "draft rows across slots)"
                )
        if pc.max_entries < 0:
            raise ValueError("prefix_cache_max_entries must be >= 0")
        if pc.ttl < 0:
            raise ValueError("prefix_cache_ttl must be >= 0")
        if (pc.max_entries or pc.ttl) and not pc.enabled:
            raise ValueError(
                "prefix_cache_max_entries/prefix_cache_ttl bound the "
                "prefix cache's hash index; they need prefix_cache=True"
            )
        if pg.victim_policy not in ("youngest", "cost"):
            raise ValueError(
                f"unknown victim_policy {pg.victim_policy!r} "
                "(expected 'youngest' or 'cost')"
            )
        if pg.victim_policy != "youngest" and not pg.preemption:
            raise ValueError(
                "victim_policy selects the preemption victim; it needs "
                "preemption=True"
            )
        if pg.block_size > 0:
            if model_cfg is not None and not T.supports_paged_cache(model_cfg):
                raise ValueError(
                    f"{model_cfg.name}: paged KV cache is inexact for sliding-"
                    "window ring caches; use block_size=0"
                )
            if self.max_len % pg.block_size != 0:
                raise ValueError(
                    f"max_len {self.max_len} must be a multiple of block_size "
                    f"{pg.block_size} (prefill splices whole blocks)"
                )
        if model_cfg is not None and any(s.moe for s in model_cfg.period):
            # MoE expert capacity couples batch rows at decode — see the
            # exactness discussion in serving/continuous.py; ROADMAP item
            raise ValueError(
                f"{model_cfg.name}: continuous batching over MoE periods is "
                "not exact (expert capacity couples slots); use ServeEngine"
            )
        if (
            self.prefill_bucket > 0
            and model_cfg is not None
            and not T.supports_ragged_prefill(model_cfg)
        ):
            raise ValueError(
                f"{model_cfg.name}: prefill bucketing needs ragged prefill "
                "(pure-attention periods); use prefill_bucket=0"
            )
        if self.parallel.tp < 1:
            raise ValueError("parallel.tp must be >= 1")
        obs = self.observability
        if obs.slo_active and not (self.guard is not None and self.guard.degradation):
            # SLO targets without the ladder would measure burn and act on
            # nothing; catch the misconfiguration at construction
            raise ValueError(
                "observability SLO targets drive the degradation ladder; "
                "they need guard=GuardConfig(degradation=True)"
            )
        return self

    # -- legacy kwarg shim -------------------------------------------------

    @classmethod
    def from_legacy_kwargs(cls, kwargs: Dict[str, Any]) -> "EngineConfig":
        """Map the pre-config flat ``ContinuousEngine`` kwargs onto a
        config. Unknown names raise ``TypeError`` (same contract as the
        old constructor signature)."""
        unknown = sorted(set(kwargs) - set(LEGACY_KWARGS))
        if unknown:
            raise TypeError(
                f"unknown ContinuousEngine argument(s): {', '.join(unknown)}"
            )
        cfg = cls()
        for name, value in kwargs.items():
            dest = LEGACY_KWARGS[name]
            if dest is None:
                setattr(cfg, name, value)
            else:
                sub, field = dest
                setattr(getattr(cfg, sub), field, value)
        return cfg

    # -- (de)serialization -------------------------------------------------

    def to_dict(self) -> Dict[str, Any]:
        """A plain-JSON-types dict (tuples become lists)."""
        return json.loads(self.to_json())

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EngineConfig":
        d = dict(d)
        guard = d.pop("guard", None)
        if guard is not None:
            # JSON turned the ladder tuples into lists; restore them so the
            # round-tripped config compares equal to the original
            for key in ("ladder_enter", "ladder_exit"):
                if key in guard:
                    guard[key] = tuple(guard[key])
            guard = GuardConfig(**guard)
        return cls(
            paging=PagingConfig(**d.pop("paging", {})),
            prefix_cache=PrefixCacheConfig(**d.pop("prefix_cache", {})),
            speculative=SpecConfig(**d.pop("speculative", {})),
            parallel=ParallelConfig(**d.pop("parallel", {})),
            guard=guard,
            observability=ObservabilityConfig(**d.pop("observability", {})),
            **d,
        )

    @classmethod
    def from_json(cls, s: str) -> "EngineConfig":
        return cls.from_dict(json.loads(s))
