"""Live metrics export: Prometheus text exposition, JSON snapshots, a
pure-stdlib HTTP endpoint, and crash-safe on-disk snapshots.

The exporter is a *read-side* plane over the metrics registry
(serving/metrics.py): every render walks the registry's instruments and
formats their current state — counters and gauges as single series,
histograms (lifetime and rolling-window) as cumulative ``_bucket`` /
``_sum`` / ``_count`` series, windowed rates as ``_per_s`` gauges — in
the Prometheus text exposition format 0.0.4. Reads never mutate any
instrument, so scraping a live engine mid-run is safe by construction;
the engine's serve loop is never blocked by a scrape (the HTTP server
runs on its own daemon threads and only ever *reads* host-side Python
state — no device syncs, no jit interaction).

Three surfaces, all served by ``MetricsServer`` (``launch/serve.py
--listen :9100``):

* ``/metrics``       — Prometheus text exposition (all instruments,
  ``repro_``-prefixed; fleet runs label series per replica and add
  bucket-merged ``replica="fleet"`` histogram series),
* ``/metrics.json``  — the rolling-window ``live_snapshot`` plus health,
* ``/healthz``       — degradation level, last-burst age, and a coarse
  ``status`` (serving / idle).

``SnapshotWriter`` flushes the same JSON snapshot to disk on an interval
via write-to-temp + atomic rename (``atomic_write_json``), so a killed
or chaos-stricken run still leaves the last consistent snapshot behind.
"""

from __future__ import annotations

import json
import math
import os
import tempfile
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.serving.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    WindowedHistogram,
    WindowedRate,
)

METRIC_PREFIX = "repro_"
PROMETHEUS_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

# one exporter row: (family name, kind, labels, payload)
Row = Tuple[str, str, Dict[str, str], Dict[str, Any]]


def atomic_write_json(path: str, obj: Any) -> None:
    """Write ``obj`` as JSON via temp file + atomic rename: a reader (or
    a crash) never sees a partial file, only the previous or the new
    snapshot."""
    d = os.path.dirname(os.path.abspath(path))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".snapshot-", suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(obj, f, indent=2, sort_keys=True)
            f.write("\n")
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


# ---------------------------------------------------------------------------
# Prometheus text exposition (format 0.0.4)
# ---------------------------------------------------------------------------


def _sanitize(name: str) -> str:
    """Metric-name charset: [a-zA-Z_:][a-zA-Z0-9_:]*."""
    out = "".join(c if c.isalnum() or c in "_:" else "_" for c in name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _escape_label(v: str) -> str:
    """Label-value escaping per the exposition format: backslash, double
    quote, and newline."""
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


def _labels_text(labels: Dict[str, str]) -> str:
    if not labels:
        return ""
    inner = ",".join(
        f'{_sanitize(k)}="{_escape_label(str(v))}"'
        for k, v in sorted(labels.items())
    )
    return "{" + inner + "}"


def _fmt(v: float) -> str:
    if isinstance(v, float) and math.isnan(v):
        return "NaN"
    if isinstance(v, float) and math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if float(v) == int(v) and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


def registry_rows(
    registry: MetricsRegistry,
    now: Optional[float] = None,
    labels: Optional[Dict[str, str]] = None,
) -> List[Row]:
    """Flatten a registry into exporter rows. ``labels`` (e.g.
    ``{"replica": "0"}``) are added to every row — how the fleet
    exposition distinguishes replicas under one family name."""
    extra = labels or {}
    rows: List[Row] = []
    for _key, base, lbl, inst in registry.instruments():
        all_lbl = {**lbl, **extra}
        if isinstance(inst, Counter):
            rows.append((base, "counter", all_lbl, {"value": inst.value}))
        elif isinstance(inst, Gauge):
            rows.append((base, "gauge", all_lbl, {"value": inst.last}))
        elif isinstance(inst, Histogram):
            rows.append((base, "histogram", all_lbl, inst.state()))
        elif isinstance(inst, WindowedHistogram):
            rows.append((base, "histogram", all_lbl, inst.state(now)))
        elif isinstance(inst, WindowedRate):
            rows.append(
                (f"{base}_per_s", "gauge", all_lbl, {"value": inst.rate(now)})
            )
    return rows


def histogram_state_rows(
    states: Dict[str, Optional[Dict[str, Any]]],
    labels: Optional[Dict[str, str]] = None,
) -> List[Row]:
    """Rows for pre-merged histogram states (the router's bucket-merged
    fleet distributions)."""
    rows: List[Row] = []
    for name, state in sorted(states.items()):
        if state is not None:
            rows.append((name, "histogram", dict(labels or {}), state))
    return rows


def render_prometheus(rows: Sequence[Row], prefix: str = METRIC_PREFIX) -> str:
    """Render exporter rows as Prometheus text exposition. Families
    (same name) share one ``# TYPE`` line; histogram payloads expand to
    cumulative ``_bucket{le=...}`` series plus ``_sum``/``_count``. The
    ``_count`` and ``+Inf`` bucket are both computed from the same
    bucket sum, so the cumulative invariant holds even if the payload
    was snapshotted mid-update."""
    families: Dict[str, Tuple[str, List[Tuple[Dict[str, str], Dict]]]] = {}
    order: List[str] = []
    for name, kind, labels, payload in rows:
        fam = prefix + _sanitize(name)
        if fam not in families:
            families[fam] = (kind, [])
            order.append(fam)
        elif families[fam][0] != kind:
            raise ValueError(
                f"metric family {fam} rendered as both "
                f"{families[fam][0]} and {kind}"
            )
        families[fam][1].append((labels, payload))
    lines: List[str] = []
    for fam in order:
        kind, series = families[fam]
        lines.append(f"# TYPE {fam} {kind}")
        for labels, payload in series:
            if kind == "histogram":
                counts = payload["counts"]
                bounds = payload["boundaries"]
                n = sum(counts)
                cum = 0
                for edge, c in zip(bounds, counts, strict=False):
                    cum += c
                    le = {**labels, "le": _fmt(float(edge))}
                    lines.append(f"{fam}_bucket{_labels_text(le)} {cum}")
                le = {**labels, "le": "+Inf"}
                lines.append(f"{fam}_bucket{_labels_text(le)} {n}")
                lines.append(
                    f"{fam}_sum{_labels_text(labels)} "
                    f"{_fmt(float(payload['total']))}"
                )
                lines.append(f"{fam}_count{_labels_text(labels)} {n}")
            else:
                lines.append(
                    f"{fam}{_labels_text(labels)} "
                    f"{_fmt(float(payload['value']))}"
                )
    return "\n".join(lines) + ("\n" if lines else "")


# ---------------------------------------------------------------------------
# Live sources (what the HTTP server and snapshot writer read)
# ---------------------------------------------------------------------------


class EngineLiveSource:
    """Read-side adapter over one ``ContinuousEngine``. All three views
    are pure reads of host-side state; before the first run (no metrics
    yet) they degrade to empty/idle payloads."""

    def __init__(self, engine: Any):
        self.engine = engine

    def _now(self) -> Optional[float]:
        now_fn = getattr(self.engine, "_live_now", None)
        return now_fn() if now_fn is not None else None

    def prometheus(self) -> str:
        m = self.engine.metrics
        if m is None:
            return render_prometheus([])
        return render_prometheus(registry_rows(m.registry, self._now()))

    def snapshot_json(self) -> Dict[str, Any]:
        m = self.engine.metrics
        out: Dict[str, Any] = {"health": self.engine.live_status()}
        if m is not None:
            out["live"] = m.live_snapshot(self._now())
        return out

    def health(self) -> Dict[str, Any]:
        return self.engine.live_status()


class RouterLiveSource:
    """Read-side adapter over a ``Router`` fleet: per-replica series
    labelled ``replica="i"`` plus bucket-merged ``replica="fleet"``
    histogram series, so fleet quantiles come from one merged
    distribution — never a per-replica max."""

    def __init__(self, router: Any):
        self.router = router

    def _live(self) -> List[Tuple[int, Any]]:
        return [
            (i, eng.metrics)
            for i, eng in enumerate(self.router.engines)
            if eng.metrics is not None
        ]

    def prometheus(self) -> str:
        rows: List[Row] = []
        for i, m in self._live():
            now_fn = getattr(self.router.engines[i], "_live_now", None)
            now = now_fn() if now_fn is not None else None
            rows.extend(
                registry_rows(m.registry, now, labels={"replica": str(i)})
            )
        rows.extend(
            histogram_state_rows(
                self.router.merged_histogram_states(),
                labels={"replica": "fleet"},
            )
        )
        return render_prometheus(rows)

    def snapshot_json(self) -> Dict[str, Any]:
        return {
            "health": self.health(),
            "replicas": {
                str(i): m.live_snapshot() for i, m in self._live()
            },
            "fleet": self.router.live_snapshot(),
        }

    def health(self) -> Dict[str, Any]:
        statuses = [eng.live_status() for eng in self.router.engines]
        level = max(
            (s.get("degradation_level", 0) for s in statuses), default=0
        )
        ages = [
            s["last_burst_age_s"]
            for s in statuses
            if s.get("last_burst_age_s") is not None
        ]
        return {
            "status": (
                "serving"
                if any(s.get("status") == "serving" for s in statuses)
                else "idle"
            ),
            "degradation_level": level,
            "last_burst_age_s": min(ages) if ages else None,
            "n_replicas": len(statuses),
        }


# ---------------------------------------------------------------------------
# HTTP endpoint (pure stdlib, daemon threads)
# ---------------------------------------------------------------------------


def parse_listen(addr: str) -> Tuple[str, int]:
    """``":9100"`` / ``"0.0.0.0:9100"`` / ``"9100"`` -> (host, port).
    Empty host binds localhost (scraping a dev run should not open a
    public port by accident)."""
    host, sep, port = addr.rpartition(":")
    if not sep:
        host, port = "", addr
    try:
        port_n = int(port)
    except ValueError:
        raise ValueError(f"invalid --listen address {addr!r}") from None
    return (host or "127.0.0.1", port_n)


class MetricsServer:
    """Threaded stdlib HTTP server over a live source (engine or
    router). ``port=0`` binds an ephemeral port (tests); ``.port`` holds
    the bound one. The server threads are daemons and every handler is a
    pure read, so a wedged scrape can never wedge the serve loop."""

    def __init__(self, source: Any, host: str = "127.0.0.1", port: int = 0):
        self.source = source
        src = source

        class _Handler(BaseHTTPRequestHandler):
            def _send(self, code: int, body: bytes, ctype: str) -> None:
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):  # noqa: N802 (stdlib handler contract)
                path = self.path.split("?", 1)[0]
                try:
                    if path == "/metrics":
                        self._send(
                            200,
                            src.prometheus().encode(),
                            PROMETHEUS_CONTENT_TYPE,
                        )
                    elif path == "/metrics.json":
                        body = json.dumps(
                            src.snapshot_json(), sort_keys=True
                        ).encode()
                        self._send(200, body, "application/json")
                    elif path == "/healthz":
                        body = json.dumps(src.health(), sort_keys=True).encode()
                        self._send(200, body, "application/json")
                    else:
                        self._send(404, b"not found\n", "text/plain")
                except BrokenPipeError:
                    pass  # scraper went away mid-response

            def log_message(self, *args):  # silence per-request stderr spam
                pass

        self._server = ThreadingHTTPServer((host, port), _Handler)
        self._server.daemon_threads = True
        self.host, self.port = self._server.server_address[:2]
        self._thread = threading.Thread(
            target=self._server.serve_forever,
            name="metrics-server",
            daemon=True,
        )

    def start(self) -> "MetricsServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()
        self._thread.join(timeout=5.0)

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"


# ---------------------------------------------------------------------------
# Crash-safe periodic snapshots
# ---------------------------------------------------------------------------


class SnapshotWriter:
    """Flush ``payload_fn()`` to ``path`` atomically every ``interval``
    seconds on a daemon thread, plus a final flush at ``stop()``. A run
    killed between flushes leaves the last consistent snapshot on disk
    (the crash-safety contract of ``--metrics-json``)."""

    def __init__(
        self,
        path: str,
        payload_fn: Callable[[], Any],
        interval: float = 1.0,
    ):
        if interval <= 0:
            raise ValueError("interval must be > 0 seconds")
        self.path = path
        self.payload_fn = payload_fn
        self.interval = interval
        self.flushes = 0
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._loop, name="snapshot-writer", daemon=True
        )

    def _flush(self) -> None:
        try:
            atomic_write_json(self.path, self.payload_fn())
            self.flushes += 1
        except Exception:
            # a transient render race or full disk must not kill the
            # writer loop — the next interval retries
            pass

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            self._flush()

    def start(self) -> "SnapshotWriter":
        self._thread.start()
        return self

    def stop(self, final_payload: Optional[Any] = None) -> None:
        self._stop.set()
        self._thread.join(timeout=5.0)
        if final_payload is not None:
            atomic_write_json(self.path, final_payload)
        else:
            self._flush()


__all__ = [
    "METRIC_PREFIX",
    "PROMETHEUS_CONTENT_TYPE",
    "atomic_write_json",
    "registry_rows",
    "histogram_state_rows",
    "render_prometheus",
    "parse_listen",
    "EngineLiveSource",
    "RouterLiveSource",
    "MetricsServer",
    "SnapshotWriter",
    "merge_histogram_states",
    "quantile_of_state",
]
