"""Data-parallel router: spread requests over N engine replicas.

The serving topology after the engine-as-replica refactor is two layers:

* **inside** a replica, ``EngineConfig.parallel.tp`` shards the weights,
  KV pool and attention heads over a ``(1, tp)`` device mesh's ``model``
  axis (tensor parallelism — models/sharding.py);
* **above** the replicas, this ``Router`` is the data-parallel layer: it
  owns N independent ``ContinuousEngine`` replicas built from the *same*
  ``EngineConfig`` and places each incoming request on exactly one of
  them. Replicas share nothing at runtime — no KV, no block tables, no
  prefix-cache index — so the router's only coupling is the placement
  decision itself.

Placement is **deterministic and upfront**: requests are planned in
arrival order (ties broken by rid) before any replica runs, so the same
trace always produces the same per-replica assignment — the property the
router determinism tests pin. Two policies ship, and ``placement`` also
accepts any callable with the same signature for experiments:

* ``"least_loaded"`` (default): each request lands on the replica with
  the smallest cumulative planned cost, where a request's cost is its
  worst-case token work ``prompt_len + max_new_tokens``; ties go to the
  lowest replica index.
* ``"prefix_affinity"``: requests are routed by their prompt's
  block-aligned prefix identity (``block_pool.prefix_route_key`` — the
  chain hash of the first full ``block_size`` tokens), sticky to the
  replica that saw the prefix first. Requests sharing a system prompt or
  few-shot header therefore land on the same replica and hit its prefix
  cache, instead of spraying cold prefills across the fleet; prompts with
  no full block (or with paging off) fall back to least-loaded.

**Bounded queues.** Each replica has an admission queue of capacity
``queue_capacity`` (0 = unbounded). The router models a replica's
backlog at planning time: a placed request is estimated to occupy its
replica until ``arrival + est_tpot * cost`` (``est_tpot`` seconds per
token; the default 0 makes occupancy instantaneous, i.e. the bound only
fires under a positive service-time estimate). A request whose preferred
replica is full spills to the next candidate; when *every* replica is
full it is shed — terminal state ``ABORTED``, never submitted, counted
in ``router_shed`` — the same contract as the engine's own bounded-queue
load shedding (docs/robustness.md), one layer up.

**Observability.** Each replica gets its own metrics and — when
``trace=True`` — its own ``SpanTracer`` lane (``pid=i``, process name
``replica{i}``), so a fleet's traces merge into one Perfetto timeline
(``tracing.merge_traces``). ``RouterResult.metrics`` carries the
aggregate summary (``metrics.merge_replica_summaries`` — throughput is
the *sum* of per-replica tokens/s) plus every per-replica summary under
a ``replica{i}_`` key prefix (docs/observability.md).

Replicas run **sequentially** on the host: the container is
single-process and the engines' serve loops are host-driven, so true
concurrency would interleave nothing but Python. Each ``engine.run``
starts its own clock, which keeps per-replica tokens/s a per-engine
rate; the aggregate models the fleet where replicas genuinely run side
by side. Token-exactness across placements holds for greedy requests
(temperature 0): a greedy request's output depends only on its own
prompt, never on co-batched neighbours — the engine's exactness
invariant — so routing cannot change what any request generates.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.models.config import ModelConfig
from repro.serving.block_pool import prefix_route_key
from repro.serving.config import EngineConfig
from repro.serving.continuous import ContinuousEngine, ContinuousResult
from repro.serving.metrics import (
    merge_histogram_states,
    merge_replica_summaries,
    quantile_of_state,
)
from repro.serving.request import Request, RequestState
from repro.serving.tracing import SpanTracer, merge_traces

# placement plan: request index -> replica, plus the shed list
Plan = Tuple[Dict[int, int], List[Request]]
PlacementFn = Callable[..., Plan]


def _depth(done_at: List[float], t: float) -> int:
    """Requests estimated still in flight on a replica at time ``t``."""
    return sum(d > t for d in done_at)


def plan_least_loaded(
    requests: Sequence[Request],
    n_replicas: int,
    block_size: int,
    queue_capacity: int,
    est_tpot: float,
) -> Plan:
    """Greedy least-loaded placement (see module docstring)."""
    return _plan(
        requests, n_replicas, block_size, queue_capacity, est_tpot,
        affinity=False,
    )


def plan_prefix_affinity(
    requests: Sequence[Request],
    n_replicas: int,
    block_size: int,
    queue_capacity: int,
    est_tpot: float,
) -> Plan:
    """Sticky prefix-affinity placement (see module docstring)."""
    return _plan(
        requests, n_replicas, block_size, queue_capacity, est_tpot,
        affinity=True,
    )


def _plan(
    requests: Sequence[Request],
    n_replicas: int,
    block_size: int,
    queue_capacity: int,
    est_tpot: float,
    affinity: bool,
) -> Plan:
    order = sorted(requests, key=lambda r: (r.arrival, r.rid))
    load = [0.0] * n_replicas
    done_at: List[List[float]] = [[] for _ in range(n_replicas)]
    sticky: Dict[int, int] = {}  # prefix route key -> replica
    assignment: Dict[int, int] = {}
    shed: List[Request] = []
    for r in order:
        cost = float(r.prompt_len + r.max_new_tokens)
        key = (
            prefix_route_key(r.prompt, block_size)
            if affinity and block_size > 0
            else None
        )
        ranked = sorted(range(n_replicas), key=lambda i: (load[i], i))
        if key is not None and key in sticky:
            home = sticky[key]
            ranked = [home] + [i for i in ranked if i != home]
        chosen = None
        for i in ranked:
            if (
                queue_capacity <= 0
                or _depth(done_at[i], r.arrival) < queue_capacity
            ):
                chosen = i
                break
        if chosen is None:
            shed.append(r)
            continue
        assignment[r.rid] = chosen
        load[chosen] += cost
        done_at[chosen].append(r.arrival + est_tpot * cost)
        if key is not None and key not in sticky:
            sticky[key] = chosen
    return assignment, shed


PLACEMENTS: Dict[str, PlacementFn] = {
    "least_loaded": plan_least_loaded,
    "prefix_affinity": plan_prefix_affinity,
}


@dataclasses.dataclass
class RouterResult:
    """One routed run: merged requests (input order), aggregate metrics,
    the placement that produced them, and each replica's own result."""

    requests: List[Request]
    metrics: Dict[str, float]  # aggregate + ``replica{i}_``-prefixed keys
    assignment: Dict[int, int]  # rid -> replica index (shed rids absent)
    replica_results: List[Optional[ContinuousResult]]  # None = idle replica

    @property
    def outputs(self) -> Dict[int, Optional[List[int]]]:
        return {r.rid: r.output for r in self.requests}


class Router:
    """N-replica data-parallel front door over ``ContinuousEngine``.

    All replicas are built from one ``EngineConfig`` (validated against
    the model *before* the first replica exists) and share the parameter
    pytree — replicating engine state N times costs N KV pools, not N
    copies of the weights.
    """

    def __init__(
        self,
        params: Any,
        cfg: ModelConfig,
        config: EngineConfig,
        n_replicas: int = 2,
        placement: Any = "least_loaded",  # name in PLACEMENTS or a callable
        queue_capacity: int = 0,  # per-replica bound (0 = unbounded)
        est_tpot: float = 0.0,  # seconds/token service estimate for the bound
        trace: bool = False,  # one SpanTracer lane (pid) per replica
        clock: Optional[Callable[[], float]] = None,
        sleep: Optional[Callable[[float], None]] = None,
        faults: Any = None,  # FaultPlan, applied to every replica
        engine_cls: type = ContinuousEngine,
    ):
        if n_replicas < 1:
            raise ValueError("n_replicas must be >= 1")
        if queue_capacity < 0:
            raise ValueError("queue_capacity must be >= 0")
        if est_tpot < 0:
            raise ValueError("est_tpot must be >= 0")
        if callable(placement):
            self._plan_fn = placement
            self.placement = getattr(placement, "__name__", "custom")
        else:
            if placement not in PLACEMENTS:
                raise ValueError(
                    f"unknown placement {placement!r} "
                    f"(expected one of {sorted(PLACEMENTS)} or a callable)"
                )
            self._plan_fn = PLACEMENTS[placement]
            self.placement = placement
        config.validate(cfg)
        self.config = config
        self.cfg = cfg
        self.n_replicas = n_replicas
        self.queue_capacity = queue_capacity
        self.est_tpot = est_tpot
        self.tracers: List[Optional[SpanTracer]] = []
        self.engines: List[ContinuousEngine] = []
        for i in range(n_replicas):
            tracer = (
                SpanTracer(pid=i, process_name=f"replica{i}")
                if trace
                else None
            )
            self.tracers.append(tracer)
            self.engines.append(
                engine_cls(
                    params, cfg, config,
                    clock=clock, sleep=sleep, trace=tracer, faults=faults,
                )
            )

    # -- placement ---------------------------------------------------------

    def plan(self, requests: Sequence[Request]) -> Plan:
        """The deterministic placement for ``requests`` (no side effects):
        ``(rid -> replica, shed requests)``."""
        return self._plan_fn(
            requests,
            self.n_replicas,
            self.config.paging.block_size,
            self.queue_capacity,
            self.est_tpot,
        )

    # -- serving -----------------------------------------------------------

    def run(
        self,
        requests: Sequence[Request],
        sync_every: int = 8,
        max_new_cap: Optional[int] = None,
    ) -> RouterResult:
        """Route ``requests`` over the replicas and drain every
        completion into one result. Replicas execute sequentially (host-
        driven loops — see module docstring); the aggregate summary sums
        their independent throughputs."""
        assignment, shed = self.plan(requests)
        for r in shed:
            r.state = RequestState.ABORTED
            r.output = None
            r.error = (
                f"router: all {self.n_replicas} replica queues at "
                f"capacity {self.queue_capacity}"
            )
        # one shared buffer width so every replica's decode shapes (and
        # therefore outputs under preemption-free greedy decoding) match
        # the single-engine run's
        cap = max_new_cap or max(
            (r.max_new_tokens for r in requests), default=1
        )
        results: List[Optional[ContinuousResult]] = []
        for i, eng in enumerate(self.engines):
            subset = [r for r in requests if assignment.get(r.rid) == i]
            results.append(
                eng.run(subset, sync_every, cap) if subset else None
            )
        summaries = [
            res.metrics if res is not None else {} for res in results
        ]
        # pair each non-empty summary with its engine's retained histogram
        # states: fleet quantiles then come from the *merged* distribution
        # (bucket sums), not the per-replica max — see
        # metrics.merge_replica_summaries
        hists = [
            (
                eng.metrics.histogram_states()
                if res is not None and eng.metrics is not None
                else None
            )
            for eng, res in zip(self.engines, results, strict=True)
        ]
        metrics = merge_replica_summaries(
            [s for s in summaries if s],
            histograms=[h for s, h in zip(summaries, hists) if s],
        )
        metrics["router_n_replicas"] = float(self.n_replicas)
        metrics["router_shed"] = float(len(shed))
        for i, s in enumerate(summaries):
            for k, v in s.items():
                metrics[f"replica{i}_{k}"] = v
        return RouterResult(
            requests=list(requests),
            metrics=metrics,
            assignment=assignment,
            replica_results=results,
        )

    # -- observability -----------------------------------------------------

    def merged_histogram_states(self) -> Dict[str, Optional[Dict[str, Any]]]:
        """Fleet latency distributions: each replica's lifetime histogram
        states merged bucket-wise (the ``replica="fleet"`` series on the
        live ``/metrics`` exposition). Replicas that have not run yet
        contribute nothing."""
        per_replica = [
            eng.metrics.histogram_states()
            for eng in self.engines
            if eng.metrics is not None
        ]
        names = sorted({n for h in per_replica for n in h})
        return {
            n: merge_histogram_states([h.get(n) for h in per_replica])
            for n in names
        }

    def live_snapshot(self) -> Dict[str, Any]:
        """Fleet-level live view: merged-distribution quantiles plus
        summed lifetime counters — what the router's ``/metrics.json``
        serves under ``"fleet"``. Pure read, callable mid-run."""
        merged = self.merged_histogram_states()
        out: Dict[str, Any] = {
            "n_replicas": float(self.n_replicas),
            "p50_ttft_s": quantile_of_state(merged.get("ttft_s"), 0.50),
            "p95_ttft_s": quantile_of_state(merged.get("ttft_s"), 0.95),
            "p99_ttft_s": quantile_of_state(merged.get("ttft_s"), 0.99),
            "p95_tpot_s": quantile_of_state(merged.get("tpot_s"), 0.95),
            "p95_latency_s": quantile_of_state(
                merged.get("latency_s"), 0.95
            ),
        }
        snaps = [
            eng.metrics.live_snapshot()
            for eng in self.engines
            if eng.metrics is not None
        ]
        for key in (
            "n_requests", "completed", "tokens_emitted",
            "shed_requests", "expired_requests", "failed_requests",
        ):
            out[key] = float(sum(s.get(key) or 0 for s in snaps))
        return out

    def trace_dict(self) -> Dict[str, Any]:
        """The fleet's merged Chrome trace (one pid per replica)."""
        live = [t for t in self.tracers if t is not None]
        if not live:
            raise ValueError("Router was built with trace=False")
        return merge_traces(live)

    def export_trace(self, path: str) -> int:
        """Write the merged fleet trace as Chrome trace-event JSON."""
        import json

        d = self.trace_dict()
        with open(path, "w") as f:
            json.dump(d, f)
            f.write("\n")
        return sum(len(t) for t in self.tracers if t is not None)
