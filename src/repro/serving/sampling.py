"""Shared on-device sample/emit core for the serving engines.

Both the static (``engine.ServeEngine``) and continuous
(``continuous.ContinuousEngine``) decode steps need the same primitive:
draw the next token per row (greedy or temperature), append it to each
live row's output buffer, and flag EOS hits — all inside jit, with no
host traffic. Kept in one place so the two engines can't drift.

The speculative engine (``serving/speculative.py``) adds two more
primitives over the same conventions: ``speculative_accept`` — standard
speculative rejection sampling of a drafted token window against the
full model's per-position logits (greedy rows reduce to
longest-matching-prefix, which is provably token-exact) — and
``emit_speculative``, the multi-token bulk commit that replays the
one-token emit semantics (EOS is a signal, budgets count real tokens)
over an accepted window.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def degenerate_rows(logits):
    """Rows of ``logits`` [B, V] with no well-defined sampling outcome:
    any NaN, any +inf, or all -inf (an empty distribution). Returns [B]
    bool. ``max`` over the row catches all three at once — NaN and +inf
    propagate into it, and an all--inf row's max is -inf — while a row
    that is merely *partially* masked with -inf keeps a finite max and
    passes.

    This is the quarantine signal: the serving engine checks it every
    decode/verify step and fails the offending slot (docs/robustness.md)
    rather than letting a poisoned distribution emit tokens. ``draw_
    tokens`` additionally pins the drawn token for such rows to 0, so
    even a caller that ignores the signal never sees an out-of-support
    garbage draw."""
    return ~jnp.isfinite(jnp.max(logits, axis=-1))


def _sanitize(logits, bad):
    """Replace ``bad`` rows with a one-hot distribution on token 0 —
    the defined outcome for a degenerate row under both the greedy and
    the categorical path (-1e9 never survives gumbel noise)."""
    v = logits.shape[-1]
    pinned = jnp.where(jnp.arange(v) == 0, 0.0, -1e9)
    return jnp.where(bad[:, None], pinned, logits)


def draw_tokens(logits, temps, key, greedy_only: bool = False):
    """Draw one token per row from ``logits`` [B, V]: argmax where the
    row's temperature is 0, temperature-scaled categorical otherwise.
    Returns [B] int32. ``greedy_only`` is a static fast path that skips
    the categorical draw (and therefore all RNG work) entirely.

    Degenerate rows (``degenerate_rows``) deterministically draw token
    0 on both paths — a *defined* outcome, never a silent garbage token.
    The draw alone does not signal the problem; engines that must
    quarantine check ``degenerate_rows`` themselves."""
    b = logits.shape[0]
    logits = _sanitize(logits, degenerate_rows(logits))
    greedy = jnp.argmax(logits, axis=-1)
    if greedy_only:
        return greedy.astype(jnp.int32)
    t = jnp.broadcast_to(jnp.asarray(temps, jnp.float32), (b,))
    # greedy rows (t == 0) discard `sampled`; divide by 1 instead of ~0 so
    # the dead branch doesn't feed +-inf logits into categorical
    safe_t = jnp.where(t > 0, t, 1.0)
    sampled = jax.random.categorical(key, logits / safe_t[:, None])
    return jnp.where(t > 0, sampled, greedy).astype(jnp.int32)


def sample_and_emit(logits, temps, key, buf, live, emitted, eos):
    """One sampling + emission step for all rows.

    logits  [B, V] f32      carried logits to sample from
    temps   scalar or [B]   per-row temperature (0 = greedy)
    buf     [B, cap] i32    output token buffer
    live    [B] bool        rows still emitting (others' writes are dropped)
    emitted [B] i32         tokens emitted so far per row
    eos     int             EOS token id (-1 = never matches)

    Returns (nxt [B] i32, buf, emitted, hit_eos [B] bool, key).

    The EOS token is a stop *signal*, not output: it is neither written to
    ``buf`` nor counted in ``emitted``, so callers never see the stop token
    and token budgets/throughput count real tokens only.
    """
    b = logits.shape[0]
    key, sk = jax.random.split(key)
    nxt = draw_tokens(logits, temps, sk)
    hit_eos = nxt == eos
    emit = live & ~hit_eos
    # non-emitting rows target index buf.shape[1]; mode="drop" discards
    idx = jnp.where(emit, emitted, buf.shape[1])
    buf = buf.at[jnp.arange(b), idx].set(nxt, mode="drop")
    emitted = emitted + emit.astype(jnp.int32)
    return nxt, buf, emitted, hit_eos, key


def speculative_accept(fed, draft_logits, target_logits, temps, key,
                       greedy: bool = False):
    """Accept a drafted window by speculative rejection sampling.

    fed           [B, K] i32    tokens fed to the verify pass. ``fed[:, 0]``
                                was drawn from the full model's carry
                                logits (always correct); ``fed[:, i]`` for
                                i >= 1 was proposed by the draft model from
                                ``draft_logits[:, i-1]``.
    draft_logits  [B, K-1, V]   the draft distribution behind each proposal
    target_logits [B, K, V]     full-model logits after each fed token
                                (``target_logits[:, i]`` is the
                                distribution of window position i+1)
    temps         [B] f32       per-row temperature (0 = greedy)

    Returns ``(n_acc [B] i32 in [1, K], carry_logits [B, V], key)``.

    ``n_acc`` counts accepted fed tokens: ``fed[:, 0]`` always, then each
    proposal while every earlier one was accepted and

    * greedy rows: ``fed[:, i] == argmax(target_logits[:, i-1])`` —
      longest matching prefix, token-exact against one-by-one decoding;
    * temperature rows: ``u < p(tok) / q(tok)`` with ``p``/``q`` the
      temperature-scaled target/draft distributions (the classic
      acceptance test).

    ``carry_logits`` is what the next round's first token must be drawn
    from: the target logits after the last accepted token, except for a
    temperature row that rejected mid-window, which carries the *residual*
    ``max(p - q, 0)`` at the rejection position (re-expressed as
    temperature-scaled logits) — the correction that makes each committed
    token's marginal distribution exactly the full model's.
    """
    b, k = fed.shape
    t = jnp.broadcast_to(jnp.asarray(temps, jnp.float32), (b,))
    if k == 1:  # no proposals: the window is just the carry token
        return jnp.ones((b,), jnp.int32), target_logits[:, 0], key
    if greedy:
        # static all-greedy fast path (the engine selects it when a whole
        # trace is temperature-0): pure argmax comparison, no softmaxes,
        # no residuals, and — crucially for the hot round — no RNG
        ok = fed[:, 1:] == jnp.argmax(target_logits[:, : k - 1], axis=-1)
        acc = jnp.cumprod(ok.astype(jnp.int32), axis=1)
        n_acc = (1 + jnp.sum(acc, axis=1)).astype(jnp.int32)
        carry = jnp.take_along_axis(
            target_logits, (n_acc - 1)[:, None, None], axis=1
        )[:, 0]
        return n_acc, carry, key
    safe_t = jnp.where(t > 0, t, 1.0)[:, None, None]
    p = jax.nn.softmax(target_logits[:, : k - 1] / safe_t, axis=-1)
    q = jax.nn.softmax(draft_logits / safe_t, axis=-1)
    props = fed[:, 1:]  # [B, K-1] draft proposals
    p_tok = jnp.take_along_axis(p, props[..., None], axis=-1)[..., 0]
    q_tok = jnp.take_along_axis(q, props[..., None], axis=-1)[..., 0]
    key, sk = jax.random.split(key)
    u = jax.random.uniform(sk, (b, k - 1))
    ok_temp = u * q_tok < p_tok  # accept with probability min(1, p/q)
    ok_greedy = props == jnp.argmax(target_logits[:, : k - 1], axis=-1)
    ok = jnp.where(t[:, None] > 0, ok_temp, ok_greedy)
    acc = jnp.cumprod(ok.astype(jnp.int32), axis=1)  # prefix acceptance
    n_acc = (1 + jnp.sum(acc, axis=1)).astype(jnp.int32)
    carry = jnp.take_along_axis(
        target_logits, (n_acc - 1)[:, None, None], axis=1
    )[:, 0]  # [B, V] target logits after the last accepted token
    # temperature rows that rejected a proposal carry the residual of the
    # rejection position instead; scaling the log-residual by t makes the
    # next round's logits/t softmax reproduce max(p - q, 0) exactly
    residual = jnp.maximum(p - q, 0.0)
    rej = jnp.minimum(n_acc - 1, k - 2)  # clamp for fully accepted rows
    res_at = jnp.take_along_axis(residual, rej[:, None, None], axis=1)[:, 0]
    res_logits = t[:, None] * jnp.log(res_at + 1e-20)
    rejected = (t > 0) & (n_acc < k)
    carry = jnp.where(rejected[:, None], res_logits, carry)
    return n_acc, carry, key


def emit_speculative(fed, n_acc, buf, active, emitted, maxnew, eos):
    """Bulk-commit an accepted window into the output buffers.

    Emits ``fed[:, i]`` for each row while ``i < n_acc`` and the row is
    still live, replaying the one-token emit semantics position by
    position (K unrolled in-trace steps): EOS is a stop signal — never
    written to ``buf``, never counted — and the token that brings
    ``emitted`` to ``maxnew`` is emitted and then ends the row, exactly
    like the non-speculative step's post-emit budget check.

    Returns ``(buf, emitted, committed [B] i32, still [B] bool)`` where
    ``committed`` counts tokens emitted from this window (the caller's
    position advance) and ``still`` flags rows that survive the round.
    """
    b, k = fed.shape
    cap = buf.shape[1]
    rows = jnp.arange(b)
    alive = active
    committed = jnp.zeros((b,), jnp.int32)
    for i in range(k):
        tok = fed[:, i]
        ok = alive & (i < n_acc)
        hit = ok & (tok == eos)
        emit = ok & ~hit
        idx = jnp.where(emit, emitted, cap)
        buf = buf.at[rows, idx].set(tok, mode="drop")
        emitted = emitted + emit.astype(jnp.int32)
        committed = committed + emit.astype(jnp.int32)
        alive = alive & ~hit & ~(emit & (emitted >= maxnew))
    return buf, emitted, committed, alive
